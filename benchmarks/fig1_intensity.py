"""Paper Fig. 1 — computation-intensity motivation study.

Left panel: distribution of per-shard computation intensity (flops / main-
memory accesses) for a (64K, 64K, 64K) GEMM distributed across 1..64K
devices under all RC/CR strategies at each degree.
Right panel: the spread across strategies at a fixed degree (64K devices).

Reproduction targets: intensity falls with parallelism degree; wide spread
across strategies at fixed degree (the motivation for cross-stack search).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import transform
from repro.core.graph import Node
from repro.core.lmgraph import gemm_graph
from repro.core.parallelism import Strategy, enumerate_strategies
from repro.core.roofline import operational_intensity

M = N = K = 65536


def intensity_for(strategy: Strategy) -> float:
    g = gemm_graph(M, N, K)
    sh = transform.shard_graph(g, strategy)
    return operational_intensity(sh.nodes["gemm"])


def run(degrees=(1, 16, 256, 4096, 65536)) -> Dict[int, Dict[str, float]]:
    out = {}
    for deg in degrees:
        vals = []
        for st in enumerate_strategies(deg, max_lp=1):
            vals.append(intensity_for(st))
        v = np.asarray(vals)
        out[deg] = {"min": float(v.min()), "p25": float(np.percentile(v, 25)),
                    "median": float(np.median(v)),
                    "p75": float(np.percentile(v, 75)),
                    "max": float(v.max()), "n_strategies": len(vals)}
    return out


def main(verbose: bool = True) -> Dict:
    table = run()
    degrees = sorted(table)
    if verbose:
        print("fig1: computation intensity of 64K^3 GEMM vs parallelism")
        print(f"{'devices':>8} {'min':>9} {'median':>9} {'max':>9} "
              f"{'#strat':>7}")
        for d in degrees:
            r = table[d]
            print(f"{d:8d} {r['min']:9.1f} {r['median']:9.1f} "
                  f"{r['max']:9.1f} {r['n_strategies']:7d}")
    # paper claims: median intensity decreases with degree; spread > 2x
    medians = [table[d]["median"] for d in degrees]
    assert medians[0] > medians[-1], "intensity must fall with parallelism"
    spread = table[degrees[-1]]["max"] / max(table[degrees[-1]]["min"], 1e-9)
    return {"medians": medians, "spread_at_max_degree": spread,
            "table": table}


if __name__ == "__main__":
    main()
