"""Paper Fig. 10 — co-optimizing technology, parallelism and hardware.

Three incremental configurations per logic node (paper §9.2):
  1. naive data parallelism on template budgets;
  2. + parallelism-strategy search (paper claim: ~2x);
  3. + hardware-architecture (budget) search via the SOE
     (paper claim: meaningful on mature nodes, 20-30% on advanced).
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ShapeCell, get_config
from repro.configs.paper_lm import GLOBAL_BATCH, N_NODES, SEQ_LEN
from repro.core import age, lmgraph, roofline, simulate, soe, techlib
from repro.core.parallelism import Strategy
from repro.core.roofline import PPEConfig

PPE = PPEConfig(n_tilings=12)


def run_node(logic: str, n_devices: int = N_NODES,
             soe_steps: int = 12, soe_starts: int = 2) -> Dict[str, float]:
    tech = techlib.make_tech_config(logic, "HBM2E", "IB-NDR-X8")
    cfg = get_config("paper-lm")
    cell = ShapeCell("paper", SEQ_LEN, GLOBAL_BATCH, "train")
    g = lmgraph.build_graph(cfg, cell)
    budgets = age.Budgets.default()
    roofline.clear_cache()
    arch = age.generate(tech, budgets)
    naive = float(simulate.predict(
        arch, g, Strategy("RC", dp=n_devices), cfg=PPE).total_s)
    strat = soe.co_optimize(tech, g, n_devices, search_arch=False, ppe=PPE,
                            template=budgets)
    coopt = soe.co_optimize(
        tech, g, n_devices, search_arch=True, ppe=PPE, template=budgets,
        cfg=soe.SOEConfig(steps=soe_steps, starts=soe_starts),
        strategies=[strat.strategy], max_strategies=8)
    return {"naive_dp": naive, "parallelism_opt": strat.time_s,
            "parallelism+arch_opt": min(coopt.time_s, strat.time_s),
            "best_strategy": strat.strategy.name}


def main(verbose: bool = True, nodes=("N12", "N7", "N3")) -> Dict:
    out = {}
    for lg in nodes:
        out[lg] = run_node(lg)
        if verbose:
            r = out[lg]
            print(f"fig10 {lg}: naive {r['naive_dp']:.3f}s -> strategy "
                  f"{r['parallelism_opt']:.3f}s "
                  f"({r['naive_dp']/r['parallelism_opt']:.2f}x, "
                  f"{r['best_strategy']}) -> +arch "
                  f"{r['parallelism+arch_opt']:.3f}s")
    speedups = [out[lg]["naive_dp"] / out[lg]["parallelism_opt"]
                for lg in nodes]
    return {"per_node": out, "strategy_speedups": speedups}


if __name__ == "__main__":
    main()
