"""Calibration gain (ISSUE-4 tentpole acceptance): measured GEMM sweep ->
fitted profile -> strictly lower mean relative error than the
uncalibrated techlib entry.

Methodology = paper Figs. 6-7 upgraded from one post-hoc scalar to the
full `repro.calibrate` loop: measure jit'd GEMMs on THIS container's CPU,
fit the efficiency/overhead vector by multi-start GD through the traced
roofline, and validate measured-vs-predicted.  Asserts:

  * calibrated MRE < uncalibrated MRE (strict, the acceptance criterion);
  * log-time correlation of the calibrated model >= 0.9 (paper reports
    0.98-0.996 on P4/DGX-1);
  * a calibrated in-memory `pathfinder.sweep` runs end-to-end consuming
    the profile and produces different (anchored) timings.
"""

from __future__ import annotations

from typing import Dict

from repro.calibrate import fitting, microbench, profiles, report
from repro.core import age
from repro.core.roofline import PPEConfig


def main(verbose: bool = True, reps: int = 3) -> Dict:
    spec = microbench.default_spec("quick", reps=reps)
    stats = microbench.MicrobenchRunner(spec).run()
    template = age.cpu_host_microarch()
    ppe = PPEConfig(n_tilings=8)
    res = fitting.fit(stats.records, template, ppe=ppe,
                      cfg=fitting.FitConfig(steps=60, starts=4))
    base = report.validation_report(stats.records, template, ppe=ppe)
    cal = report.validation_report(stats.records, template,
                                   params=res.params, ppe=ppe)
    mre_base = base["overall"]["mre"]
    mre_cal = cal["overall"]["mre"]
    assert mre_cal < mre_base, (
        f"calibrated MRE {mre_cal:.3f} not strictly below uncalibrated "
        f"{mre_base:.3f}")
    corr = cal["overall"]["corr_log"]
    assert corr >= 0.9, f"calibrated corr(log t) {corr:.3f} < 0.9"

    # the profile must flow through the sweep engine end-to-end
    from repro.core import pathfinder
    profile = profiles.CalibrationProfile(tech="cpu_host",
                                          params=res.params)
    plain = pathfinder.sweep(["qwen1.5-0.5b"], ["train_4k"], [(2, 2)],
                             ppe=PPEConfig(n_tilings=4), cache=None)
    calib = pathfinder.sweep(["qwen1.5-0.5b"], ["train_4k"], [(2, 2)],
                             ppe=PPEConfig(n_tilings=4), cache=None,
                             profile=profile)
    assert len(calib.points) == len(plain.points) >= 1
    anchored = any(
        abs(c.time_s - p.time_s) > 1e-12 * max(p.time_s, 1e-12)
        for c, p in zip(calib.points, plain.points))
    assert anchored, "profile did not change sweep predictions"

    out = {
        "n_measurements": len(stats.records),
        "mre_uncalibrated": float(mre_base),
        "mre_calibrated": float(mre_cal),
        "mre_improvement": float(mre_base / max(mre_cal, 1e-9)),
        "corr_calibrated": float(corr),
        "corr_uncalibrated": float(base["overall"]["corr_log"]),
        "selected": res.selected,
        "params": {k: float(v) for k, v in res.params.items()},
        "sweep_time_plain_s": float(plain.points[0].time_s),
        "sweep_time_calibrated_s": float(calib.points[0].time_s),
    }
    if verbose:
        print(f"calibration_gain: {out['n_measurements']} GEMM "
              f"measurements on this CPU")
        print(f"  MRE uncalibrated {mre_base * 100:.1f}% -> calibrated "
              f"{mre_cal * 100:.1f}%  ({out['mre_improvement']:.1f}x, "
              f"paper err 6-18%)")
        print(f"  corr(log t) {out['corr_uncalibrated']:.3f} -> "
              f"{corr:.3f}  (paper 0.98-0.996)")
        print(f"  calibrated sweep: {out['sweep_time_plain_s']:.2f}s -> "
              f"{out['sweep_time_calibrated_s']:.2f}s predicted step")
    return out


if __name__ == "__main__":
    main()
