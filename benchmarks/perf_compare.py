"""§Perf — baseline vs hillclimb-variant comparison from dry-run artifacts.

Prints, per hillclimbed cell, the three roofline terms of the baseline and
every recorded variant, plus the bound (max term) speedup. Consumed by
EXPERIMENTS.md §4.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")

CELLS = [
    ("qwen3_moe_30b_a3b", "train_4k", "single"),
    ("mistral_large_123b", "train_4k", "single"),
    ("gemma3_27b", "prefill_32k", "single"),
]


def _terms(d: Dict) -> Dict:
    coll = sum(v for k, v in d["collectives"].items() if k != "count")
    t_c = d["flops_per_device"] / PEAK_FLOPS
    t_m = d["bytes_per_device"] / HBM_BW
    t_x = coll / ICI_BW
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "bound": max(t_c, t_m, t_x)}


def load_variants(arch: str, cell: str, mesh: str) -> List[Dict]:
    out = []
    pat = os.path.join(ART_DIR, f"{arch}__{cell}__{mesh}*.json")
    for p in sorted(glob.glob(pat)):
        with open(p) as f:
            d = json.load(f)
        if d.get("ok"):
            out.append(d)
    return out


def main(verbose: bool = True) -> Dict:
    results = {}
    for arch, cell, mesh in CELLS:
        rows = []
        for d in load_variants(arch, cell, mesh):
            t = _terms(d)
            rows.append({"variant": d.get("variant") or "baseline", **t})
        base = next((r for r in rows if r["variant"] == "baseline"), None)
        if base:
            for r in rows:
                r["bound_speedup"] = base["bound"] / r["bound"]
        rows.sort(key=lambda r: r["bound"])
        results[f"{arch}/{cell}"] = rows
        if verbose:
            print(f"== {arch} x {cell} ({mesh})")
            for r in rows:
                print(f"   {r['variant']:18s} comp {r['t_compute']:8.3f}s "
                      f"mem {r['t_memory']:8.3f}s coll "
                      f"{r['t_collective']:8.3f}s bound {r['bound']:8.3f}s "
                      f"({r.get('bound_speedup', 1):5.2f}x)")
    return results


if __name__ == "__main__":
    main()
