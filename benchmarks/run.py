"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark (us_per_call = the
benchmark's own wall time; derived = its headline reproduction metric).

    PYTHONPATH=src python -m benchmarks.run              # all
    PYTHONPATH=src python -m benchmarks.run fig9 fig10   # subset
"""

from __future__ import annotations

import sys
import time
from typing import Callable, Dict


def _bench_fig1() -> str:
    from benchmarks import fig1_intensity
    r = fig1_intensity.main(verbose=False)
    return (f"median_intensity_drop={r['medians'][0]/r['medians'][-1]:.1f}x;"
            f"spread@64k={r['spread_at_max_degree']:.1f}x")


def _bench_fig6() -> str:
    from benchmarks import fig6_gemm_validation
    r = fig6_gemm_validation.main(verbose=False)
    return f"corr={r['corr']:.3f};rel_err={r['rel_err']*100:.1f}%"


def _bench_fig8() -> str:
    from benchmarks import fig8_lm_validation
    r = fig8_lm_validation.main(verbose=False)
    return f"corr={r['corr']:.3f};rel_err={r['rel_err']*100:.0f}%"


def _bench_fig9() -> str:
    from benchmarks import fig9_tech_scaling
    r = fig9_tech_scaling.main(verbose=False)
    c = r["checks"]
    n12n7 = max(c["n12_to_n7_speedup"].values())
    return (f"n12->n7={n12n7:.2f}x;"
            f"logic_sat_n3/n1={c.get('logic_saturation_n3_n1', 0):.2f};"
            f"net_gain={c['network_gain_at_advanced_node']:.2f}x")


def _bench_fig10() -> str:
    from benchmarks import fig10_coopt
    r = fig10_coopt.main(verbose=False)
    s = max(r["strategy_speedups"])
    return f"strategy_speedup={s:.2f}x(paper ~2x)"


def _bench_fig11() -> str:
    from benchmarks import fig11_package
    r = fig11_package.main(verbose=False)
    best = (max(r["improvement"].values()) - 1) * 100
    return f"package_gain={best:.0f}%(paper <=32%)"


def _bench_perf_variants() -> str:
    from benchmarks import perf_compare
    r = perf_compare.main(verbose=False)
    best = {}
    for cell, rows in r.items():
        sp = max((row.get("bound_speedup", 1) for row in rows), default=1)
        best[cell.split("/")[0]] = sp
    return ";".join(f"{k}={v:.1f}x" for k, v in best.items()) or "no_data"


def _bench_roofline() -> str:
    from benchmarks import roofline
    r = roofline.main(verbose=False)
    n = sum(len(v) for v in r.values())
    if not n:
        return "no_dryrun_artifacts_yet"
    fracs = [row["roofline_frac"] for rows in r.values() for row in rows]
    return f"cells={n};mean_frac={sum(fracs)/len(fracs):.2f}"


def _bench_sweep_scale() -> str:
    """Batched pathfinding engine vs per-point loop (ISSUE-1 tentpole)."""
    from benchmarks import sweep_scale
    r = sweep_scale.main(verbose=False)
    return (f"speedup={r['speedup_warm']:.0f}x(>=10x);"
            f"batched_pps={r['batched_pps']:.0f};"
            f"eager_pps={r['eager_pps']:.1f}")


def _bench_crossflow_query() -> str:
    """Paper §8: CrossFlow query latency (ms .. 20 s on their machine)."""
    from repro.configs.base import SHAPE_CELLS, get_config
    from repro.core import age, lmgraph, roofline as rl, simulate, techlib
    from repro.core.parallelism import Strategy
    arch = age.generate(techlib.make_tech_config(), age.Budgets.default())
    g = lmgraph.build_graph(get_config("qwen1.5-0.5b"),
                            SHAPE_CELLS["train_4k"])
    rl.clear_cache()
    t0 = time.perf_counter()
    simulate.predict(arch, g, Strategy("RC", kp1=1, kp2=4, dp=4))
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    simulate.predict(arch, g, Strategy("RC", kp1=1, kp2=4, dp=4))
    warm = time.perf_counter() - t0
    return f"cold={cold*1e3:.0f}ms;warm={warm*1e3:.0f}ms"


BENCHES: Dict[str, Callable[[], str]] = {
    "fig1_intensity": _bench_fig1,
    "fig6_gemm_validation": _bench_fig6,
    "fig8_lm_validation": _bench_fig8,
    "fig9_tech_scaling": _bench_fig9,
    "fig10_coopt": _bench_fig10,
    "fig11_package": _bench_fig11,
    "sweep_scale": _bench_sweep_scale,
    "crossflow_query_latency": _bench_crossflow_query,
    "roofline": _bench_roofline,
    "perf_variants": _bench_perf_variants,
}


def main() -> int:
    wanted = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for name in wanted:
        keys = [k for k in BENCHES if k.startswith(name)] or [name]
        for key in keys:
            fn = BENCHES.get(key)
            t0 = time.perf_counter()
            try:
                if fn is None:
                    raise KeyError(f"unknown benchmark {key!r}")
                derived = fn()
            except Exception as e:           # noqa: BLE001
                derived = f"ERROR:{type(e).__name__}:{e}"
                failed.append(key)
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{key},{dt:.0f},{derived}", flush=True)
    if failed:
        # a raising benchmark must fail the CI smoke job, not just print
        print(f"FAILED: {','.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
