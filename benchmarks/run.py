"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark (us_per_call = the
benchmark's own wall time; derived = its headline reproduction metric).

    PYTHONPATH=src python -m benchmarks.run              # all
    PYTHONPATH=src python -m benchmarks.run fig9 fig10   # subset
    PYTHONPATH=src python -m benchmarks.run --json-dir bench_json sweep

With ``--json-dir`` every benchmark also writes ``<dir>/<name>.json``:
``{"name", "us_per_call", "derived", "ok", "data"}`` where ``data`` is the
benchmark's full result dict — the machine-readable summary consumed by
trajectory tracking (BENCH_*.json) and CI artifacts.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Callable, Dict, Optional, Tuple

BenchResult = Tuple[str, Optional[Dict]]


def _bench_fig1() -> BenchResult:
    from benchmarks import fig1_intensity
    r = fig1_intensity.main(verbose=False)
    return (f"median_intensity_drop={r['medians'][0]/r['medians'][-1]:.1f}x;"
            f"spread@64k={r['spread_at_max_degree']:.1f}x"), r


def _bench_fig6() -> BenchResult:
    from benchmarks import fig6_gemm_validation
    r = fig6_gemm_validation.main(verbose=False)
    return f"corr={r['corr']:.3f};rel_err={r['rel_err']*100:.1f}%", r


def _bench_fig8() -> BenchResult:
    from benchmarks import fig8_lm_validation
    r = fig8_lm_validation.main(verbose=False)
    return f"corr={r['corr']:.3f};rel_err={r['rel_err']*100:.0f}%", r


def _bench_fig9() -> BenchResult:
    from benchmarks import fig9_tech_scaling
    r = fig9_tech_scaling.main(verbose=False)
    c = r["checks"]
    n12n7 = max(c["n12_to_n7_speedup"].values())
    return (f"n12->n7={n12n7:.2f}x;"
            f"logic_sat_n3/n1={c.get('logic_saturation_n3_n1', 0):.2f};"
            f"net_gain={c['network_gain_at_advanced_node']:.2f}x"), r


def _bench_fig10() -> BenchResult:
    from benchmarks import fig10_coopt
    r = fig10_coopt.main(verbose=False)
    s = max(r["strategy_speedups"])
    return f"strategy_speedup={s:.2f}x(paper ~2x)", r


def _bench_fig11() -> BenchResult:
    from benchmarks import fig11_package
    r = fig11_package.main(verbose=False)
    best = (max(r["improvement"].values()) - 1) * 100
    return f"package_gain={best:.0f}%(paper <=32%)", r


def _bench_perf_variants() -> BenchResult:
    from benchmarks import perf_compare
    r = perf_compare.main(verbose=False)
    best = {}
    for cell, rows in r.items():
        sp = max((row.get("bound_speedup", 1) for row in rows), default=1)
        best[cell.split("/")[0]] = sp
    return (";".join(f"{k}={v:.1f}x" for k, v in best.items())
            or "no_data"), r


def _bench_roofline() -> BenchResult:
    from benchmarks import roofline
    r = roofline.main(verbose=False)
    n = sum(len(v) for v in r.values())
    if not n:
        return "no_dryrun_artifacts_yet", r
    fracs = [row["roofline_frac"] for rows in r.values() for row in rows]
    return f"cells={n};mean_frac={sum(fracs)/len(fracs):.2f}", r


def _bench_sweep_scale() -> BenchResult:
    """Batched pathfinding engine vs per-point loop (ISSUE-1 tentpole)."""
    from benchmarks import sweep_scale
    r = sweep_scale.main(verbose=False)
    return (f"speedup={r['speedup_warm']:.0f}x(>=10x);"
            f"batched_pps={r['batched_pps']:.0f};"
            f"eager_pps={r['eager_pps']:.1f}"), r


def _bench_sweep_shard() -> BenchResult:
    """Sharded sweep engine vs single-stream + resume (ISSUE-2 tentpole)."""
    from benchmarks import sweep_shard
    r = sweep_shard.main(verbose=False)
    return (f"speedup_vs_single={r['speedup_vs_single']:.0f}x(>=2x);"
            f"shard_gain={r['shard_gain']:.2f}x@{r['n_devices']}dev;"
            f"resume_ok={int(r['resume_ok'])}"), r


def _bench_sweep_pipeline() -> BenchResult:
    """Pipelined executor vs PR4 synchronous sharded runner (ISSUE-5)."""
    from benchmarks import sweep_pipeline
    r = sweep_pipeline.main(verbose=False)
    return (f"speedup={r['speedup']:.1f}x"
            f"(>={r['min_speedup']:g}x);"
            f"pipeline_pps={r['pipeline_pps']:.0f};"
            f"frontier_ok={int(r['frontier_ok'])};"
            f"resume_ok={int(r['resume_ok'])}"), r


def _bench_sweep_fabric() -> BenchResult:
    """Distributed fabric: 2 workers vs 1 on leased chunks (ISSUE-7)."""
    from benchmarks import sweep_fabric
    r = sweep_fabric.main(verbose=False)
    return (f"speedup={r['speedup']:.2f}x"
            f"(>={r['min_speedup']:g}x,{r['mode']});"
            f"two_worker_pps={r['two_worker_pps']:.0f};"
            f"parity_ok={int(r['parity_ok'])}"), r


def _bench_compile_ahead() -> BenchResult:
    """Compile-ahead service + bucketed dispatch vs lazy path (ISSUE-10)."""
    from benchmarks import compile_ahead
    r = compile_ahead.main(verbose=False)
    return (f"speedup={r['speedup']:.1f}x"
            f"(>={r['min_speedup']:g}x,{r['n_groups']}groups);"
            f"bucketed_pps={r['bucketed_pps']:.1f};"
            f"serial_bitwise_ok={int(r['serial_bitwise_ok'])};"
            f"parity_ok={int(r['parity_vs_lazy_ok'])}"), r


def _bench_cooptimize() -> BenchResult:
    """Sweep -> refine cross-stack co-optimization (ISSUE-3 tentpole)."""
    from benchmarks import cooptimize_refine
    r = cooptimize_refine.main(verbose=False)
    return (";".join(
        f"{s}:dom={v['n_dominating']}/{v['n_refined']}"
        f",gain={v['best_gain']:.2f}x" for s, v in r.items()), r)


def _bench_serving_traffic() -> BenchResult:
    """Traffic-driven serving sweep + inverse fleet sizing (ISSUE-6)."""
    from benchmarks import serving_traffic
    r = serving_traffic.main(verbose=False)
    top = max(r["best_devices"], key=float)
    return (f"sweep_pps={r['sweep_pps']:.0f};"
            f"query_ms={r['query_ms_per_target']:.1f};"
            f"best@{top}qps={r['best_devices'][top]}dev;"
            f"frontier_ok={int(r['frontier_ok'])}"), r


def _bench_sweep_objectives() -> BenchResult:
    """Energy/TCO objective axes end-to-end (ISSUE-8 tentpole)."""
    from benchmarks import sweep_objectives
    r = sweep_objectives.main(verbose=False)
    return (f"frontier_ok={int(r['frontier_ok'])};"
            f"energy_dom={r['n_dominating']}/{r['n_refined']};"
            f"energy_gain={r['energy_gain']:.2f}x;"
            f"size_ok={int(r['size_ok'])}"
            f"@{r['best_replicas']}rep"), r


def _bench_explore() -> BenchResult:
    """Surrogate + acquisition exploration vs exhaustive sweep (ISSUE-9)."""
    from benchmarks import explore_efficiency
    r = explore_efficiency.main(verbose=False)
    return (f"hv_train={r['train']['hv_ratio']:.3f}@"
            f"{r['train']['eval_frac']:.0%};"
            f"hv_serving={r['serving']['hv_ratio']:.3f}@"
            f"{r['serving']['eval_frac']:.0%}"
            f"(>={r['min_hv']:g}@<={r['max_eval_frac']:.0%});"
            f"order_parity_ok={int(r['fabric']['parity_ok'])}"), r


def _bench_calibration() -> BenchResult:
    """Measured GEMM calibration -> strict MRE gain (ISSUE-4 tentpole)."""
    from benchmarks import calibration_gain
    r = calibration_gain.main(verbose=False)
    return (f"mre={r['mre_uncalibrated'] * 100:.0f}%->"
            f"{r['mre_calibrated'] * 100:.0f}%"
            f"({r['mre_improvement']:.1f}x);"
            f"corr={r['corr_calibrated']:.3f}"), r


def _bench_crossflow_query() -> BenchResult:
    """Paper §8: CrossFlow query latency (ms .. 20 s on their machine)."""
    from repro.configs.base import SHAPE_CELLS, get_config
    from repro.core import age, lmgraph, roofline as rl, simulate, techlib
    from repro.core.parallelism import Strategy
    arch = age.generate(techlib.make_tech_config(), age.Budgets.default())
    g = lmgraph.build_graph(get_config("qwen1.5-0.5b"),
                            SHAPE_CELLS["train_4k"])
    rl.clear_cache()
    t0 = time.perf_counter()
    simulate.predict(arch, g, Strategy("RC", kp1=1, kp2=4, dp=4))
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    simulate.predict(arch, g, Strategy("RC", kp1=1, kp2=4, dp=4))
    warm = time.perf_counter() - t0
    return (f"cold={cold*1e3:.0f}ms;warm={warm*1e3:.0f}ms",
            {"cold_s": cold, "warm_s": warm})


BENCHES: Dict[str, Callable[[], BenchResult]] = {
    "fig1_intensity": _bench_fig1,
    "fig6_gemm_validation": _bench_fig6,
    "fig8_lm_validation": _bench_fig8,
    "fig9_tech_scaling": _bench_fig9,
    "fig10_coopt": _bench_fig10,
    "fig11_package": _bench_fig11,
    "sweep_scale": _bench_sweep_scale,
    "sweep_shard": _bench_sweep_shard,
    "sweep_pipeline": _bench_sweep_pipeline,
    "sweep_fabric": _bench_sweep_fabric,
    "compile_ahead": _bench_compile_ahead,
    "cooptimize_refine": _bench_cooptimize,
    "serving_traffic": _bench_serving_traffic,
    "sweep_objectives": _bench_sweep_objectives,
    "explore_efficiency": _bench_explore,
    "calibration_gain": _bench_calibration,
    "crossflow_query_latency": _bench_crossflow_query,
    "roofline": _bench_roofline,
    "perf_variants": _bench_perf_variants,
}


def _plain(obj):
    """Best-effort conversion of benchmark result dicts to plain Python
    types (np/jnp scalars -> float, unknown objects -> repr)."""
    if isinstance(obj, dict):
        return {str(k): _plain(v) for k, v in obj.items()}
    if isinstance(obj, (str, bool, int, float)) or obj is None:
        return obj
    if isinstance(obj, (list, tuple)):
        return [_plain(v) for v in obj]
    try:
        return float(obj)                  # np scalars, jnp scalars
    except (TypeError, ValueError):
        return repr(obj)


def _jsonable(obj):
    """Plain types + the canonical non-finite-float sanitizer (the CI
    artifacts must stay strict RFC-8259 JSON — no Infinity/NaN tokens)."""
    from repro.core.sweeprunner import json_safe
    return json_safe(_plain(obj))


def _write_json(json_dir: str, name: str, us: float, derived: str,
                ok: bool, data: Optional[Dict]) -> None:
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"{name}.json")
    with open(path, "w") as fh:
        json.dump({"name": name, "us_per_call": us, "derived": derived,
                   "ok": ok, "data": _jsonable(data)}, fh, indent=2)


REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _current_pr_tag() -> str:
    """Derive the trajectory tag from CHANGES.md (highest `PR N:` line),
    so a full-suite run after a new PR lands in its own BENCH_<tag>.json
    instead of silently overwriting the previous PR's committed entry."""
    import re
    path = os.path.join(REPO_ROOT, "CHANGES.md")
    best = 0
    try:
        with open(path) as fh:
            for line in fh:
                m = re.match(r"PR (\d+):", line)
                if m:
                    best = max(best, int(m.group(1)))
    except OSError:
        pass
    return f"PR{best}" if best else "dev"

# headline ratio per benchmark: (result-dict path, trajectory label)
_KEY_RATIOS = {
    "fig6_gemm_validation": (("rel_err",), "fig6_rel_err"),
    "fig8_lm_validation": (("rel_err",), "fig8_rel_err"),
    "sweep_scale": (("speedup_warm",), "sweep_scale_speedup"),
    "sweep_shard": (("speedup_vs_single",), "sweep_shard_speedup"),
    "sweep_pipeline": (("speedup",), "sweep_pipeline_speedup"),
    "sweep_fabric": (("speedup",), "sweep_fabric_speedup"),
    "compile_ahead": (("speedup",), "compile_ahead_speedup"),
    "calibration_gain": (("mre_improvement",), "calibration_mre_gain"),
    "explore_efficiency": (("train", "hv_ratio"), "explore_hv_train"),
}


def _dig(data, path):
    cur = data
    for k in path:
        if not isinstance(cur, dict) or k not in cur:
            return None
        cur = cur[k]
    try:
        return float(cur)
    except (TypeError, ValueError):
        return None


def _write_trajectory(tag: str, rows: Dict[str, Dict]) -> str:
    """Repo-root ``BENCH_<tag>.json``: suite timings + key speedup ratios
    (the perf-trajectory entry per PR — per-bench JSONs under --json-dir
    never land at the root, so without this the trajectory stays empty)."""
    ratios = {}
    for name, row in rows.items():
        spec = _KEY_RATIOS.get(name)
        if spec and row.get("data") is not None:
            v = _dig(row["data"], spec[0])
            if v is not None:
                ratios[spec[1]] = v
    entry = {"tag": tag,
             "suite": {name: {"us_per_call": row["us_per_call"],
                              "ok": row["ok"], "derived": row["derived"]}
                       for name, row in rows.items()},
             "ratios": ratios}
    path = os.path.join(REPO_ROOT, f"BENCH_{tag}.json")
    with open(path, "w") as fh:
        json.dump(_jsonable(entry), fh, indent=2, sort_keys=True)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run", description=__doc__)
    ap.add_argument("names", nargs="*",
                    help="benchmark name prefixes (default: all)")
    ap.add_argument("--json-dir", default=None,
                    help="also write a machine-readable <name>.json per "
                         "benchmark into this directory")
    ap.add_argument("--tag", default=None,
                    help="perf-trajectory tag: the suite summary is "
                         "written to the repo root as BENCH_<tag>.json. "
                         "Default: the current PR from CHANGES.md when "
                         "running the FULL suite, disabled for subset "
                         "runs (so a one-benchmark check never clobbers "
                         "the committed trajectory entry); --tag '' "
                         "disables entirely")
    args = ap.parse_args(argv)
    if args.tag is None:
        args.tag = "" if args.names else _current_pr_tag()
    wanted = args.names or list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    rows: Dict[str, Dict] = {}
    for name in wanted:
        keys = [k for k in BENCHES if k.startswith(name)] or [name]
        for key in keys:
            fn = BENCHES.get(key)
            t0 = time.perf_counter()
            data: Optional[Dict] = None
            ok = True
            try:
                if fn is None:
                    raise KeyError(f"unknown benchmark {key!r}")
                derived, data = fn()
            except Exception as e:           # noqa: BLE001
                derived = f"ERROR:{type(e).__name__}:{e}"
                ok = False
                failed.append(key)
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{key},{dt:.0f},{derived}", flush=True)
            rows[key] = {"us_per_call": dt, "derived": derived, "ok": ok,
                         "data": data}
            if args.json_dir:
                _write_json(args.json_dir, key, dt, derived, ok, data)
    if args.tag:
        path = _write_trajectory(args.tag, rows)
        print(f"# trajectory -> {path}", file=sys.stderr)
    if failed:
        # a raising benchmark must fail the CI smoke job, not just print
        print(f"FAILED: {','.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
