"""Throughput of the batched pathfinding engine vs. the per-point loop.

Workload: the paper's Fig. 9 technology-scaling sweep (paper LM, DP=512) —
7 logic nodes x 4 HBM generations x 3 networks, extended with budget
variants so the batch is representative of a real design-space exploration.

The per-point baseline is exactly what `benchmarks/fig9_tech_scaling.py`
does per cell: one eager `simulate.predict` per hardware point (with the
roofline cache cleared, as fig9 does).  The batched engine stacks all
hardware points into one struct-of-arrays matrix and scores them with a
single jitted vmap (`repro.core.pathfinder.BatchedEvaluator`).

Reports points/sec for both, the warm (steady-state) speedup, and the
speedup including one-off XLA compile time.  The ISSUE-1 acceptance bar is
a >= 10x warm speedup; `main()` asserts it.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict

import numpy as np

from repro.configs.base import ShapeCell, get_config
from repro.configs.paper_lm import GLOBAL_BATCH, N_NODES, SEQ_LEN
from repro.core import age, lmgraph, pathfinder, roofline, simulate, techlib
from repro.core.parallelism import Strategy
from repro.core.roofline import PPEConfig

PPE = PPEConfig(n_tilings=12)
BUDGET_VARIANTS = ((850.0, 300.0), (650.0, 250.0), (1000.0, 400.0))
MAX_EAGER_POINTS = 24              # baseline is timed on a subset this size


def _build_archs():
    """All Fig.9 hardware points x budget variants, AGE'd eagerly."""
    archs = []
    for area, power in BUDGET_VARIANTS:
        budgets = dataclasses.replace(age.Budgets.default(),
                                      proc_chip_area_mm2=area, power_w=power)
        for logic, hbm, net in itertools.product(
                techlib.LOGIC_NODES, techlib.HBM_GENERATIONS,
                techlib.NETWORK_GENERATIONS):
            tech = techlib.make_tech_config(logic, hbm, net)
            archs.append(age.generate(tech, budgets))
    return archs


def main(verbose: bool = True) -> Dict:
    cfg = get_config("paper-lm")
    cell = ShapeCell("paper", SEQ_LEN, GLOBAL_BATCH, "train")
    g = lmgraph.build_graph(cfg, cell)
    st = Strategy("RC", kp1=1, kp2=1, dp=N_NODES, lp=1)
    archs = _build_archs()
    n_total = len(archs)

    # -- per-point loop (the fig9 inner loop) ----------------------------
    n_eager = min(MAX_EAGER_POINTS, n_total)
    t0 = time.perf_counter()
    eager_rows = []
    for a in archs[:n_eager]:
        roofline.clear_cache()
        bd = simulate.predict(a, g, st, cfg=PPE)
        eager_rows.append(float(bd.total_s))
    eager_s = time.perf_counter() - t0
    eager_pps = n_eager / eager_s

    # -- batched engine --------------------------------------------------
    ev = pathfinder.BatchedEvaluator(g, st, ppe=PPE, cache=None)
    t0 = time.perf_counter()
    rows = ev.evaluate(archs)                  # includes XLA compile
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rows2 = ev.evaluate(archs)                 # steady state
    warm_s = time.perf_counter() - t0
    batched_pps = n_total / warm_s
    cold_pps = n_total / cold_s

    # agreement on the points both paths scored
    np.testing.assert_allclose(rows[:n_eager, 0], eager_rows, rtol=1e-5)
    np.testing.assert_array_equal(rows, rows2)

    speedup = batched_pps / eager_pps
    speedup_cold = cold_pps / eager_pps
    assert speedup >= 10.0, (
        f"batched engine only {speedup:.1f}x over the per-point loop "
        f"(ISSUE-1 acceptance: >= 10x)")
    out = {
        "n_points": n_total,
        "eager_pps": eager_pps,
        "batched_pps": batched_pps,
        "compile_s": cold_s,
        "speedup_warm": speedup,
        "speedup_incl_compile": speedup_cold,
    }
    if verbose:
        print(f"sweep_scale: {n_total} fig9-style points "
              f"(timed {n_eager} eager)")
        print(f"  per-point loop : {eager_pps:10.1f} points/s")
        print(f"  batched (warm) : {batched_pps:10.1f} points/s "
              f"-> {speedup:.0f}x")
        print(f"  batched (cold) : {cold_pps:10.1f} points/s "
              f"-> {speedup_cold:.1f}x (incl. {cold_s:.2f}s compile)")
    return out


if __name__ == "__main__":
    main()
