"""Compile-ahead service + cross-design bucketed dispatch (ISSUE-10).

Cold-start sweep throughput on a many-design grid: every distinct mesh
is its own design group, so the PR9 lazy path pays one XLA compile *on
the device stage's critical path* per group, while the PR10 path
(a) buckets designs that trace to the same canonical jaxpr into one
compiled megabatch parameterized by per-design coefficient packs, and
(b) AOT-compiles upcoming superbatches' executables off-path.

Both variants run in their own fresh subprocess (cold jit caches,
`clear_compiled_caches` on entry, persistent XLA cache disabled) over
the identical `SweepSpec`.

Asserts (ISSUE-10 acceptance):
  * bucketed+compile-ahead >= 2x cold-start evaluated-points/sec vs the
    lazy path on a >= 48-design-group grid (relax with
    COMPILE_AHEAD_MIN_SPEEDUP for CI's noisy shared hosts; shrink the
    grid with COMPILE_AHEAD_GROUPS for the smoke lane; each variant is
    best-of-COMPILE_AHEAD_BEST_OF fresh processes, default 2);
  * bucketed pipeline records are BIT-identical to the serial backend
    (both dispatch the very same canonical executables);
  * bucketed records match the lazy unbucketed path at rtol 1e-5 (the
    lazy path bakes design constants into each executable, so XLA is
    free to constant-fold in a different order — ~1e-7 relative).
"""

from __future__ import annotations

import json
import math
import os
import subprocess
import sys
import time
from typing import Dict, List

MARK = "COMPILE_AHEAD_RESULT:"


def _min_speedup() -> float:
    return float(os.environ.get("COMPILE_AHEAD_MIN_SPEEDUP", "2.0"))


def _n_groups() -> int:
    return int(os.environ.get("COMPILE_AHEAD_GROUPS", "48"))


def _best_of() -> int:
    # cold-start wall times on a shared host are noisy in one direction
    # (slow outliers); best-of-N fresh processes per variant removes them
    return int(os.environ.get("COMPILE_AHEAD_BEST_OF", "2"))


def _spec():
    from repro.core import sweeprunner
    # one design group per mesh: 8 x 6 = 48 distinct shapes by default.
    # all axes >= 2: a mesh axis of extent 1 drops its collective from
    # the traced graph, which is a *different* jaxpr structure (its own
    # bucket) — the interior grid shares one canonical executable, which
    # is the regime the bucketing layer exists for
    meshes = tuple((a, b) for a in (2, 4, 8, 16, 32, 64, 128, 256)
                   for b in (2, 4, 8, 16, 32, 64))[:_n_groups()]
    return sweeprunner.SweepSpec(
        arches=("qwen1.5-0.5b",), mesh_shapes=meshes, scenario="train",
        budget_scales=(0.85, 0.95, 1.05, 1.15), n_tilings=4,
        chunk_size=16)


def _records_bitwise_equal(a: List[Dict], b: List[Dict]) -> bool:
    if {r["key"] for r in a} != {r["key"] for r in b}:
        return False
    by_key = {r["key"]: r for r in b}
    for ra in a:
        rb = by_key[ra["key"]]
        for f in set(ra) | set(rb):
            va, vb = ra.get(f), rb.get(f)
            if isinstance(va, float) and isinstance(vb, float):
                if math.isnan(va) and math.isnan(vb):
                    continue
                if va != vb:
                    return False
            elif va != vb:
                return False
    return True


def measure(kind: str) -> Dict:
    from repro.core import pathfinder, sweeprunner

    assert kind in ("lazy", "bucketed"), kind
    spec = _spec()
    n_points = len(sweeprunner.enumerate_labels(spec))
    pathfinder.clear_compiled_caches()
    kwargs = dict(bucketing=False, compile_ahead=0) if kind == "lazy" \
        else {}
    c0 = pathfinder.compile_cache_stats()
    t0 = time.perf_counter()
    # one superbatch covers the default grid: every bucket sees a single
    # padded batch shape, so the cold run pays exactly one compile per
    # bucket (smaller superbatches split buckets across packs with
    # different row counts -> extra shape signatures on both variants)
    stats = sweeprunner.SweepRunner(
        spec, backend="pipeline", cache=None, superbatch=192,
        **kwargs).run()
    elapsed = time.perf_counter() - t0
    c1 = pathfinder.compile_cache_stats()
    assert stats.complete and stats.n_points_evaluated == n_points
    records = stats.records

    out = {
        "kind": kind,
        "n_points": n_points,
        "elapsed_s": elapsed,
        "pps": n_points / elapsed,
        "compile_seconds": c1["compile_seconds"] - c0["compile_seconds"],
        "stall_seconds": c1["stall_seconds"] - c0["stall_seconds"],
    }
    if kind == "bucketed":
        # the serial backend's BatchedEvaluator registers the SAME
        # ("skel", key) design vectors and dispatches the same canonical
        # bucket executables, so parity here must be exact to the bit
        serial = sweeprunner.SweepRunner(spec, backend="serial",
                                         cache=None).run()
        out["serial_bitwise_ok"] = _records_bitwise_equal(
            records, serial.records)
    out["records"] = [sweeprunner.json_safe(r) for r in records]
    return out


def _close(a, b, rtol=1e-5) -> bool:
    if isinstance(a, float) and isinstance(b, float) \
            and math.isfinite(a) and math.isfinite(b):
        return abs(a - b) <= rtol * max(abs(a), abs(b), 1e-300)
    return a == b


def _records_close(a: List[Dict], b: List[Dict]) -> bool:
    if {r["key"] for r in a} != {r["key"] for r in b}:
        return False
    by_key = {r["key"]: r for r in b}
    return all(_close(ra.get(f), by_key[ra["key"]].get(f))
               for ra in a for f in set(ra) | set(by_key[ra["key"]]))


def _run_variant(kind: str) -> Dict:
    """One cold measurement in a fresh process: empty jit caches, no
    persistent XLA cache, same forced host device count as the parent."""
    n_dev = min(4, os.cpu_count() or 1)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_dev}"
                        ).strip()
    env.pop("JAX_COMPILATION_CACHE_DIR", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.compile_ahead",
         "--measure", kind],
        env=env, capture_output=True, text=True, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(
            f"compile_ahead[{kind}] measurement failed "
            f"(exit {proc.returncode}):\n{proc.stdout}\n{proc.stderr}")
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith(MARK))
    return json.loads(line[len(MARK):])


def main(verbose: bool = True) -> Dict:
    lazy = max((_run_variant("lazy") for _ in range(_best_of())),
               key=lambda r: r["pps"])
    cand = max((_run_variant("bucketed") for _ in range(_best_of())),
               key=lambda r: r["pps"])
    speedup = cand["pps"] / lazy["pps"]
    parity_vs_lazy = _records_close(cand["records"], lazy["records"])
    r = {
        "n_groups": _n_groups(),
        "n_points": cand["n_points"],
        "lazy_pps": lazy["pps"],
        "bucketed_pps": cand["pps"],
        "speedup": speedup,
        "min_speedup": _min_speedup(),
        "lazy_compile_s": lazy["compile_seconds"],
        "bucketed_compile_s": cand["compile_seconds"],
        "bucketed_stall_s": cand["stall_seconds"],
        "serial_bitwise_ok": bool(cand["serial_bitwise_ok"]),
        "parity_vs_lazy_ok": parity_vs_lazy,
    }
    if verbose:
        print(f"compile_ahead: {r['n_groups']} design groups, "
              f"{r['n_points']} points, cold fresh-process runs")
        print(f"  lazy (PR9)     : {r['lazy_pps']:8.2f} points/s "
              f"({r['lazy_compile_s']:.0f}s compiling on-path)")
        print(f"  bucketed+AOT   : {r['bucketed_pps']:8.2f} points/s "
              f"-> {speedup:.1f}x (floor {r['min_speedup']:g}x; "
              f"{r['bucketed_compile_s']:.0f}s compiling, "
              f"{r['bucketed_stall_s']:.0f}s stalled)")
        print(f"  parity         : serial bit-identical "
              f"({'ok' if r['serial_bitwise_ok'] else 'FAIL'}), "
              f"vs lazy rtol 1e-5 "
              f"({'ok' if parity_vs_lazy else 'FAIL'})")
    assert r["serial_bitwise_ok"], \
        "bucketed pipeline records diverged from the serial backend"
    assert parity_vs_lazy, \
        "bucketed records diverged from the lazy path beyond rtol 1e-5"
    assert speedup >= _min_speedup(), (
        f"compile-ahead + bucketing only {speedup:.2f}x over the lazy "
        f"path (ISSUE-10 acceptance: >= {_min_speedup():g}x)")
    return r


if __name__ == "__main__":
    if "--measure" in sys.argv:
        kind = sys.argv[sys.argv.index("--measure") + 1]
        print(MARK + json.dumps(measure(kind)))
    else:
        main()
