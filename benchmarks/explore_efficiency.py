"""Surrogate-driven exploration vs exhaustive sweep (ISSUE-9 tentpole).

The exploration loop (repro.core.surrogate) must recover the exhaustive
sweep's Pareto frontier from a fraction of the real evaluations: per
scenario it fits an MLP ensemble on the points evaluated so far and
spends the budget on the top-acquisition chunks.  Recovery is scored by
dominated hypervolume over the scenario's canonical-signed objectives
with a shared reference point derived from the exhaustive frontier
(max + 10% margin per axis), so a missing frontier extreme costs real
volume instead of hiding behind a point count.

Asserts (ISSUE-9 acceptance):
  * per scenario (train + serving-traffic): explore recovers
    >= EXPLORE_MIN_HV (default 0.95) of the exhaustive frontier's
    hypervolume using <= EXPLORE_MAX_EVAL_FRAC (default 0.25) of the
    grid's real evaluations;
  * the surrogate's advisory chunk order (order.json) steers a 2-worker
    fabric fleet without changing results: merged records identical to
    an unordered fleet of the same size.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import Dict

MIN_HV = float(os.environ.get("EXPLORE_MIN_HV", "0.95"))
MAX_EVAL_FRAC = float(os.environ.get("EXPLORE_MAX_EVAL_FRAC", "0.25"))


def _train_spec():
    from repro.core import sweeprunner
    # 120 points: 4 meshes x 3 logic x 2 HBM x 5 budget scales
    return sweeprunner.SweepSpec(
        arches=("qwen1.5-0.5b",),
        mesh_shapes=((2, 2), (4, 1), (1, 4), (2, 1)),
        scenario="train", logic_nodes=("N12", "N7", "N5"),
        hbms=("HBM2E", "HBM3"),
        budget_scales=(0.7, 0.85, 1.0, 1.15, 1.3),
        n_tilings=4, chunk_size=1)


def _serving_spec():
    from repro.core import sweeprunner
    # 96 points with all three feasibility regimes (capacity walls, SLO
    # walls, feasible); chunk_size=2 pairs both budget scales of a config
    return sweeprunner.SweepSpec(
        arches=("qwen1.5-0.5b",),
        mesh_shapes=((2, 2), (4, 4), (2, 8)),
        scenario="serving-traffic", logic_nodes=("N7", "N5"),
        hbms=("HBM2E", "HBM3"), budget_scales=(0.9, 1.1),
        n_tilings=4, chunk_size=2,
        scenario_params={"qps": 0.1,
                         "prefill_chunk": [1024.0, 8192.0],
                         "slo_ttft_p99": [5.0, 50.0]})


def _canonical_front(front, objectives):
    import numpy as np
    from repro.core.objectives import canonical_signs
    signs = canonical_signs(objectives)
    return np.asarray([[s * float(r[o]) for s, o in zip(signs, objectives)]
                       for r in front], dtype=np.float64)


def _explore_one(tag: str, spec, cfg) -> Dict:
    """Exhaustive vs explored frontier hypervolume on one scenario."""
    from repro.core import pathfinder, surrogate, sweeprunner

    labels = sweeprunner.enumerate_labels(spec)
    n = len(labels)
    scn = spec.scenario_spec.variants()[0].resolve()
    objectives = list(scn.objectives)

    t0 = time.perf_counter()
    full = sweeprunner.SweepRunner(spec, cache=None).run()
    full_s = time.perf_counter() - t0
    assert full.complete and full.n_points_evaluated == n
    front_full = sweeprunner.pareto_records(full.records, objectives)
    assert front_full, f"{tag}: exhaustive sweep has an empty frontier"

    t0 = time.perf_counter()
    stats = surrogate.explore(spec, cfg=cfg, cache=None)
    explore_s = time.perf_counter() - t0
    frac = stats.n_points_evaluated / n
    assert frac <= MAX_EVAL_FRAC + 1e-9, (
        f"{tag}: explore spent {stats.n_points_evaluated}/{n} real "
        f"evaluations ({frac:.0%} > {MAX_EVAL_FRAC:.0%} ceiling)")

    cf = _canonical_front(front_full, objectives)
    ref = cf.max(axis=0) + 0.1 * (cf.max(axis=0) - cf.min(axis=0)) + 1e-9
    hv_full = pathfinder.hypervolume(cf, ref)
    hv_explore = pathfinder.hypervolume(
        _canonical_front(stats.frontier, objectives), ref)
    ratio = hv_explore / hv_full if hv_full > 0 else 0.0
    assert ratio >= MIN_HV, (
        f"{tag}: explored frontier recovers only {ratio:.1%} of the "
        f"exhaustive hypervolume (ISSUE-9 acceptance: >= {MIN_HV:.0%} "
        f"at <= {MAX_EVAL_FRAC:.0%} evaluations)")
    return {
        "n_points": n,
        "n_evaluated": stats.n_points_evaluated,
        "eval_frac": frac,
        "stop": stats.stop,
        "rounds": stats.rounds,
        "frontier_full": len(front_full),
        "frontier_explore": len(stats.frontier),
        "hv_full": hv_full,
        "hv_explore": hv_explore,
        "hv_ratio": ratio,
        "full_sweep_s": full_s,
        "explore_s": explore_s,
    }


def _fabric_order_parity(train_records) -> Dict:
    """Surrogate-ordered vs unordered 2-worker fleets: identical merges."""
    import json

    import numpy as np

    from repro.core import surrogate, sweepfabric, sweeprunner

    spec = sweeprunner.SweepSpec(
        arches=("qwen1.5-0.5b",), mesh_shapes=((2, 2), (4, 4)),
        scenario="train", logic_nodes=("N7", "N5"),
        budget_scales=(0.9, 1.1), n_tilings=4, chunk_size=2)
    n_chunks = len(sweeprunner.make_chunks(
        sweeprunner.enumerate_labels(spec), spec.chunk_size))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    scratch = tempfile.mkdtemp(prefix="explore_fabric_")
    worker_env = {
        "PYTHONPATH": os.pathsep.join(
            p for p in (os.path.join(root, "src"),
                        os.environ.get("PYTHONPATH", "")) if p),
        "JAX_COMPILATION_CACHE_DIR": os.path.join(scratch, "xla"),
    }

    def run(tag: str, chunk_order):
        out = os.path.join(scratch, tag)
        coord = sweepfabric.FabricCoordinator(
            spec, out, workers=2, ttl_s=60.0, poll_s=0.2, claim_batch=1,
            chunk_order=chunk_order, worker_env=worker_env)
        stats = coord.run()
        assert stats.complete, f"{tag}: fabric run incomplete"
        return out, stats.records

    try:
        # the advisory order comes from a surrogate trained on the train
        # scenario's explored records — the PR7 fabric serves
        # frontier-adjacent chunks first
        cfg = surrogate.ExploreConfig(
            surrogate=surrogate.SurrogateConfig(steps=100))
        order = surrogate.rank_chunks(spec, train_records, cfg=cfg)
        assert sorted(order) == list(range(n_chunks))
        _, rec_plain = run("plain", None)
        out_ord, rec_ord = run("ordered", order)
        with open(os.path.join(out_ord, "order.json")) as fh:
            written = json.load(fh)
        assert written["order"] == list(order)
        assert written["fingerprint"] == spec.fingerprint()
    finally:
        shutil.rmtree(scratch, ignore_errors=True)

    keys = sorted(r["key"] for r in rec_plain)
    assert keys == sorted(r["key"] for r in rec_ord)
    assert len(keys) == len(set(keys))
    by_key = {r["key"]: r for r in rec_plain}
    for rec in rec_ord:
        want = by_key[rec["key"]]
        assert set(want) == set(rec)
        for f, v in want.items():
            if isinstance(v, float) and np.isfinite(v):
                np.testing.assert_allclose(rec[f], v, rtol=1e-5)
            else:
                assert rec[f] == v, (rec["key"], f)
    return {"n_chunks": n_chunks, "order": [int(i) for i in order],
            "n_records": len(keys), "parity_ok": True}


def main(verbose: bool = True) -> Dict:
    from repro.core import surrogate, sweeprunner

    r: Dict = {"min_hv": MIN_HV, "max_eval_frac": MAX_EVAL_FRAC}

    train_spec = _train_spec()
    r["train"] = _explore_one(
        "train", train_spec,
        surrogate.ExploreConfig(
            eval_budget=max(1, int(MAX_EVAL_FRAC
                                   * len(sweeprunner.enumerate_labels(
                                       train_spec)))),
            init_chunks=8, batch_chunks=4,
            surrogate=surrogate.SurrogateConfig(steps=150)))

    serving_spec = _serving_spec()
    r["serving"] = _explore_one(
        "serving-traffic", serving_spec,
        surrogate.ExploreConfig(
            eval_budget=max(1, int(MAX_EVAL_FRAC
                                   * len(sweeprunner.enumerate_labels(
                                       serving_spec)))),
            init_chunks=6, batch_chunks=3, stagnation=6,
            surrogate=surrogate.SurrogateConfig(steps=200)))

    # re-use the train scenario's exhaustive records as surrogate food for
    # the fabric-ordering leg (what `explore --order-dir` does on disk)
    full_train = sweeprunner.SweepRunner(
        sweeprunner.SweepSpec(
            arches=("qwen1.5-0.5b",), mesh_shapes=((2, 2), (4, 4)),
            scenario="train", logic_nodes=("N7", "N5"),
            budget_scales=(0.9, 1.1), n_tilings=4, chunk_size=2),
        cache=None).run()
    r["fabric"] = _fabric_order_parity(full_train.records)

    if verbose:
        for tag in ("train", "serving"):
            s = r[tag]
            print(f"explore[{tag}]: {s['n_evaluated']}/{s['n_points']} "
                  f"evals ({s['eval_frac']:.0%}) -> HV ratio "
                  f"{s['hv_ratio']:.3f} (floor {MIN_HV:g}); frontier "
                  f"{s['frontier_explore']}/{s['frontier_full']}; "
                  f"stop={s['stop']}; full sweep {s['full_sweep_s']:.1f}s "
                  f"vs explore {s['explore_s']:.1f}s")
        f = r["fabric"]
        print(f"fabric order: {f['n_chunks']} chunks, advisory order "
              f"{f['order']}; 2-worker ordered == unordered merge over "
              f"{f['n_records']} records "
              f"({'ok' if f['parity_ok'] else 'FAIL'})")
    return r


if __name__ == "__main__":
    main()
