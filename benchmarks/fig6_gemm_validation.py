"""Paper Fig. 6/7 — GEMM validation: CrossFlow prediction vs MEASURED time.

The paper validates on P4/DGX-1; the only real hardware in this container
is its CPU, so we reproduce the *methodology*: sweep GEMM shapes, measure
wall time of jit'd jnp.dot, calibrate the cpu_host tech entry from the
best-achieved flop rate (one scalar, as the paper anchors nominal rates),
predict each shape with the hierarchical-roofline PPE, and report
correlation + mean relative error. Paper numbers: corr 0.98-0.996,
err 6-18%.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import age, roofline
from repro.core.roofline import PPEConfig

SHAPES: List[Tuple[int, int, int]] = [
    (m, n, k)
    for m in (256, 512, 1024)
    for n in (256, 512, 1024)
    for k in (256, 512, 1024, 2048)
]


def measure(m: int, n: int, k: int, reps: int = 3) -> float:
    x = jnp.ones((m, k), jnp.float32)
    w = jnp.ones((k, n), jnp.float32)
    f = jax.jit(jnp.dot)
    f(x, w).block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        f(x, w).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def measure_stream_bw(mb: int = 64, reps: int = 3) -> float:
    """Achievable main-memory bandwidth (bytes/s) from a big saxpy."""
    n = mb * 2**20 // 4
    a = jnp.ones((n,), jnp.float32)
    b = jnp.ones((n,), jnp.float32)
    f = jax.jit(lambda a, b: a * 1.5 + b)
    f(a, b).block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        f(a, b).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return 3.0 * n * 4 / best          # 2 reads + 1 write


def main(verbose: bool = True, shapes=None) -> Dict:
    shapes = shapes or SHAPES
    measured = np.asarray([measure(*s) for s in shapes])
    flops = np.asarray([2.0 * m * n * k for m, n, k in shapes])
    # calibration (paper anchors nominal rates on the hardware spec):
    # peak flop rate = best achieved; dram bw from a stream-y measurement
    peak = float((flops / measured).max()) / 0.85   # undo utilization derate
    cfg = PPEConfig(n_tilings=24, kernel_overhead_s=5e-5)

    # Two-parameter calibration (the paper calibrates its tech library from
    # hardware specs/measurements as well): peak rate from the best shape,
    # main-memory bandwidth from a 1-D fit over a calibration subset.
    cal_idx = list(range(0, len(shapes), 3))        # every 3rd shape
    best_bw, best_err = None, float("inf")
    for bw in (1e9, 2e9, 4e9, 6e9, 9e9, 12e9, 18e9):
        arch = age.cpu_host_microarch(compute_flops=peak, dram_bw=bw)
        roofline.clear_cache()
        pred = np.asarray([
            float(roofline.gemm_time(arch, *shapes[i], dtype_bytes=4,
                                     cfg=cfg)) for i in cal_idx])
        err = float(np.mean(np.abs(pred - measured[cal_idx])
                            / measured[cal_idx]))
        if err < best_err:
            best_err, best_bw = err, bw
    arch = age.cpu_host_microarch(compute_flops=peak, dram_bw=best_bw)
    roofline.clear_cache()
    predicted = np.asarray([
        float(roofline.gemm_time(arch, m, n, k, dtype_bytes=4, cfg=cfg))
        for m, n, k in shapes])
    corr = float(np.corrcoef(np.log(measured), np.log(predicted))[0, 1])
    rel_err = float(np.mean(np.abs(predicted - measured) / measured))
    if verbose:
        print("fig6: GEMM validation on this container's CPU "
              f"({len(shapes)} shapes)")
        print(f"  calibrated peak: {peak/1e9:.1f} GFLOP/s, "
              f"dram bw: {best_bw/1e9:.0f} GB/s")
        print(f"  corr(log t) = {corr:.3f}   mean rel err = "
              f"{rel_err*100:.1f}%  (paper: 0.98-0.996, 6-18%)")
        worst = np.argsort(np.abs(np.log(predicted / measured)))[-3:]
        for i in worst:
            m, n, k = shapes[i]
            print(f"  worst {m}x{n}x{k}: measured {measured[i]*1e3:.2f} ms "
                  f"predicted {predicted[i]*1e3:.2f} ms")
    return {"corr": corr, "rel_err": rel_err, "peak_gflops": peak / 1e9,
            "measured": measured.tolist(), "predicted": predicted.tolist()}


if __name__ == "__main__":
    main()
