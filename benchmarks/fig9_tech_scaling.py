"""Paper Fig. 9 — technology scaling case study.

The paper's large LM (2-layer LSTM, hidden 16K, batch 16K, vocab 800K,
seq 20) data-parallel across 512 nodes; sweep 7 logic nodes x 4 HBM
generations x 3 inter-node networks (power 300 W/node, chip 850 mm^2).

Reproduction targets (paper §9.1):
  * N12 -> N7 jump regardless of memory tech (L2-bound at N12);
  * beyond N3, logic scaling alone saturates (cache bw/capacity bound);
  * network scaling gives larger gains than logic beyond N3.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ShapeCell, get_config
from repro.configs.paper_lm import GLOBAL_BATCH, N_NODES, SEQ_LEN
from repro.core import age, lmgraph, roofline, simulate, techlib
from repro.core.parallelism import Strategy
from repro.core.roofline import PPEConfig

PPE = PPEConfig(n_tilings=12)


def iteration_time(logic: str, hbm: str, net: str,
                   strategy: Strategy = None) -> float:
    tech = techlib.make_tech_config(logic, hbm, net)
    budgets = dataclasses.replace(age.Budgets.default(),
                                  proc_chip_area_mm2=850.0, power_w=300.0)
    arch = age.generate(tech, budgets)
    cfg = get_config("paper-lm")
    cell = ShapeCell("paper", SEQ_LEN, GLOBAL_BATCH, "train")
    g = lmgraph.build_graph(cfg, cell)
    st = strategy or Strategy("RC", kp1=1, kp2=1, dp=N_NODES, lp=1)
    roofline.clear_cache()
    return float(simulate.predict(arch, g, st, cfg=PPE).total_s)


def main(verbose: bool = True, logic_nodes=None) -> Dict:
    logic_nodes = logic_nodes or techlib.LOGIC_NODES
    nets = techlib.NETWORK_GENERATIONS
    hbms = techlib.HBM_GENERATIONS
    table: Dict[str, Dict[str, Dict[str, float]]] = {}
    for net in nets:
        table[net] = {}
        for hbm in hbms:
            table[net][hbm] = {lg: iteration_time(lg, hbm, net)
                               for lg in logic_nodes}
    if verbose:
        print("fig9: iteration time (s), paper LM d512")
        for net in nets:
            print(f"-- network {net}")
            hdr = " ".join(f"{lg:>8}" for lg in logic_nodes)
            print(f"{'HBM':>7} {hdr}")
            for hbm in hbms:
                row = " ".join(f"{table[net][hbm][lg]:8.3f}"
                               for lg in logic_nodes)
                print(f"{hbm:>7} {row}")
    # paper trends
    base_net = nets[0]
    t = table[base_net]
    checks = {}
    first, second = logic_nodes[0], logic_nodes[1]
    checks["n12_to_n7_speedup"] = {h: t[h][first] / t[h][second]
                                   for h in hbms}
    # logic saturation beyond N3 at best memory (ratio N3 time / N1 time ~ 1)
    if "N3" in logic_nodes and "N1" in logic_nodes:
        checks["logic_saturation_n3_n1"] = \
            t[hbms[-1]]["N3"] / t[hbms[-1]]["N1"]
    # network scaling gain at the most advanced logic+memory
    lg = logic_nodes[-1]
    checks["network_gain_at_advanced_node"] = \
        table[nets[0]][hbms[-1]][lg] / table[nets[-1]][hbms[-1]][lg]
    if verbose:
        print("trend checks:", {k: (round(v, 3) if isinstance(v, float)
                                    else {kk: round(vv, 3)
                                          for kk, vv in v.items()})
                                for k, v in checks.items()})
    return {"table": table, "checks": checks}


if __name__ == "__main__":
    main()
