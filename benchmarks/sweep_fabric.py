"""Distributed sweep fabric scaling: 2 workers vs 1 (ISSUE-7).

The fabric's job is orchestration: keep N workers' accelerators busy with
leased chunks off one shared directory.  Its scaling is therefore measured
in the regime the design targets — device-latency-bound chunks, emulated
with the worker's ``--eval-delay`` knob (a per-chunk sleep standing in for
accelerator wall time), so the benchmark is meaningful on the 1-CPU
containers CI runs on: compute-bound workers on a single core cannot
overlap, device-bound workers can and must.  Set
``SWEEP_FABRIC_MODE=cpu`` on a multi-core host to measure real
compute-bound scaling instead (delay 0; throughput from coordinator wall
time).

Throughput is evaluated-points/sec over the fleet's evaluation window
(first evaluation timestamp to last commit timestamp across the worker
stats journals) — process spawn and XLA warmup sit outside the window and
are paid identically by both configurations, with a shared on-disk
compilation cache primed by a warmup run.

Asserts (ISSUE-7 acceptance):
  * 2-worker fabric >= 1.7x 1-worker evaluated-points/sec on the same
    grid (relax with SWEEP_FABRIC_MIN_SPEEDUP for pathological hosts);
  * both runs complete and produce the identical point set (merged
    records parity, zero duplicate keys).
"""

from __future__ import annotations

import glob
import json
import os
import shutil
import tempfile
import time
from typing import Dict, List, Tuple

DELAY_S = 0.4                   # emulated per-chunk device latency
N_SCALES = 16                   # -> 32 points, 16 chunks of 2


def _min_speedup() -> float:
    return float(os.environ.get("SWEEP_FABRIC_MIN_SPEEDUP", "1.7"))


def _mode() -> str:
    return os.environ.get("SWEEP_FABRIC_MODE", "latency")


def _spec():
    from repro.core import sweeprunner
    # one mesh shape on purpose: a second mesh means a second compiled
    # skeleton, and every worker re-traces it mid-sweep — that (identical
    # in both configurations, but serialized on a 1-core host) would
    # dominate the window and hide the orchestration scaling under test
    return sweeprunner.SweepSpec(
        arches=("qwen1.5-0.5b",), mesh_shapes=((4, 4),),
        scenario="train", logic_nodes=("N7", "N5"),
        budget_scales=tuple(round(0.7 + 0.05 * i, 2)
                            for i in range(N_SCALES)),
        n_tilings=4, chunk_size=2)


def _eval_window_s(out_dir: str) -> float:
    """Fleet evaluation window: first evaluation start to last commit."""
    t_eval, t_commit = [], []
    for path in glob.glob(os.path.join(out_dir, "workers",
                                       "stats.*.json")):
        with open(path) as fh:
            s = json.load(fh)
        t_eval += [t for _, t in s.get("evaluated", [])]
        t_commit += [t for _, t in s.get("committed", [])]
    if not t_eval or not t_commit:
        raise RuntimeError(f"no worker stats under {out_dir}")
    return max(t_commit) - min(t_eval)


def measure() -> Dict:
    import numpy as np

    from repro.core import sweepfabric, sweeprunner

    spec = _spec()
    n_points = len(sweeprunner.enumerate_labels(spec))
    n_chunks = len(sweeprunner.make_chunks(
        sweeprunner.enumerate_labels(spec), spec.chunk_size))
    mode = _mode()
    delay = 0.0 if mode == "cpu" else DELAY_S
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    scratch = tempfile.mkdtemp(prefix="sweep_fabric_")
    worker_env = {
        "PYTHONPATH": os.pathsep.join(
            p for p in (os.path.join(root, "src"),
                        os.environ.get("PYTHONPATH", "")) if p),
        # one compile cache for every worker across every run: the warmup
        # pays the cold XLA compile, the timed windows never do
        "JAX_COMPILATION_CACHE_DIR": os.path.join(scratch, "xla"),
    }

    def run(n_workers: int, eval_delay: float,
            tag: str) -> Tuple[float, float, List[Dict]]:
        out = os.path.join(scratch, tag)
        coord = sweepfabric.FabricCoordinator(
            spec, out, workers=n_workers, ttl_s=60.0, poll_s=0.2,
            claim_batch=1, eval_delay_s=eval_delay,
            worker_env=worker_env)
        t0 = time.perf_counter()
        stats = coord.run()
        wall = time.perf_counter() - t0
        assert stats.complete, f"{tag}: fabric run incomplete"
        assert stats.n_points_total == n_points
        return _eval_window_s(out), wall, stats.records

    try:
        run(1, 0.0, "warmup")                  # compile cache priming
        win1, wall1, rec1 = run(1, delay, "w1")
        win2, wall2, rec2 = run(2, delay, "w2")
    finally:
        shutil.rmtree(scratch, ignore_errors=True)

    keys1 = sorted(r["key"] for r in rec1)
    keys2 = sorted(r["key"] for r in rec2)
    assert len(keys1) == len(set(keys1)) == n_points
    assert keys1 == keys2, "1- and 2-worker point sets diverged"
    by_key = {r["key"]: r for r in rec1}
    for rec in rec2:
        want = by_key[rec["key"]]
        for f, v in want.items():
            if isinstance(v, float) and np.isfinite(v):
                np.testing.assert_allclose(rec[f], v, rtol=1e-5)
            else:
                assert rec[f] == v, (rec["key"], f)
    parity_ok = True

    pps1, pps2 = n_points / win1, n_points / win2
    speedup = pps2 / pps1
    assert speedup >= _min_speedup(), (
        f"2-worker fabric only {speedup:.2f}x over 1 worker "
        f"(ISSUE-7 acceptance: >= {_min_speedup():g}x; mode={mode})")
    return {
        "mode": mode,
        "n_points": n_points,
        "n_chunks": n_chunks,
        "eval_delay_s": delay,
        "one_worker_pps": pps1,
        "two_worker_pps": pps2,
        "one_worker_window_s": win1,
        "two_worker_window_s": win2,
        "one_worker_wall_s": wall1,
        "two_worker_wall_s": wall2,
        "speedup": speedup,
        "min_speedup": _min_speedup(),
        "parity_ok": parity_ok,
    }


def main(verbose: bool = True) -> Dict:
    r = measure()
    if verbose:
        print(f"sweep_fabric: {r['n_points']} points / {r['n_chunks']} "
              f"chunks, mode={r['mode']} "
              f"(eval_delay {r['eval_delay_s']:g}s/chunk)")
        print(f"  1 worker : {r['one_worker_pps']:8.1f} points/s "
              f"({r['one_worker_window_s']:.1f}s window, "
              f"{r['one_worker_wall_s']:.1f}s wall)")
        print(f"  2 workers: {r['two_worker_pps']:8.1f} points/s "
              f"({r['two_worker_window_s']:.1f}s window, "
              f"{r['two_worker_wall_s']:.1f}s wall) -> "
              f"{r['speedup']:.2f}x (floor {r['min_speedup']:g}x)")
        print(f"  parity   : merged records identical across fleet sizes "
              f"({'ok' if r['parity_ok'] else 'FAIL'})")
    return r


if __name__ == "__main__":
    main()
