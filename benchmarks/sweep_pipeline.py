"""Pipelined sweep executor vs the PR4 synchronous sharded path (ISSUE-5).

Both engines run the FULL sweep-runner path (label resolution, hardware
packing, batched device evaluation, record folding, JSONL streaming +
chunk checkpoints) on the same `SweepSpec` grid:

  device    the PR4 synchronous path: per chunk, host-side resolve/pack,
            one pmap-sharded `evaluate_matrix` call, then records + JSONL
            commits — all serialized on the critical path;
  pipeline  the PR5 executor (`repro.core.sweeppipeline`): a producer
            thread packs chunk N+1 (memoized skeletons, vectorized
            hardware rows, batched cache probes) while chunk N's
            superbatch runs under JAX async dispatch, and a writer thread
            commits chunk N-1 off the critical path.

Asserts (ISSUE-5 acceptance):
  * pipeline >= 5x device-backend evaluated-points/sec on the same grid
    (relax with SWEEP_PIPELINE_MIN_SPEEDUP, e.g. 3.0 for the CI smoke
    lane's noisy shared hosts);
  * both backends produce identical records;
  * ``--frontier-only`` returns the identical Pareto set as full
    materialization on train AND serving reference grids;
  * a PR4-era checkpoint directory (written by the synchronous serial
    backend) resumes under the pipeline executor with ZERO re-evaluated
    chunks and the identical point set.
"""

from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile
import time
from typing import Dict

MARK = "SWEEP_PIPELINE_RESULT:"
N_SCALES = 192                  # budget-scale axis: hardware points/skeleton


def _min_speedup() -> float:
    return float(os.environ.get("SWEEP_PIPELINE_MIN_SPEEDUP", "5.0"))


def measure() -> Dict:
    import jax
    import numpy as np

    from repro.core import pathfinder, scenarios, sweeprunner

    n_dev = jax.local_device_count()
    # serving grid: each design point is a fused prefill+decode pair — the
    # representative pathfinding workload, and the one where the PR4 path
    # pays two pmap dispatches + two host pack passes per chunk
    spec = sweeprunner.SweepSpec(
        arches=("qwen1.5-0.5b",), mesh_shapes=((4, 4), (2, 8)),
        scenario="serving", logic_nodes=("N7", "N5"),
        budget_scales=tuple(round(0.6 + 0.003 * i, 4)
                            for i in range(N_SCALES)),
        n_tilings=8, chunk_size=32)
    n_points = len(sweeprunner.enumerate_labels(spec))
    superbatch = 512

    def timed_run(backend: str, repeats: int = 4):
        """Best wall-seconds of a full checkpointed sweep (fresh out_dir
        per repeat — the engines must pay their own JSONL streaming)."""
        best, stats = float("inf"), None
        for _ in range(repeats):
            d = tempfile.mkdtemp(prefix=f"swp_{backend}_")
            try:
                t0 = time.perf_counter()
                stats = sweeprunner.SweepRunner(
                    spec, out_dir=d, backend=backend, cache=None,
                    superbatch=superbatch).run(collect=False)
                best = min(best, time.perf_counter() - t0)
                assert stats.complete
                assert stats.n_points_evaluated == n_points
            finally:
                shutil.rmtree(d, ignore_errors=True)
        return best, stats

    # warm both backends (XLA compiles, AGE'd hardware, graph caches) and
    # pin record parity between them while at it; the warm pipeline run
    # must use the timed superbatch or the timed section pays fresh
    # shape-specialized compiles
    device_warm = sweeprunner.SweepRunner(spec, backend="device",
                                          cache=None).run()
    pipe_warm = sweeprunner.SweepRunner(spec, backend="pipeline",
                                        cache=None,
                                        superbatch=superbatch).run()
    by_key = {r["key"]: r for r in device_warm.records}
    assert by_key.keys() == {r["key"] for r in pipe_warm.records}
    for rec in pipe_warm.records:
        want = by_key[rec["key"]]
        for f in ("ttft_s", "cost_device_s_per_token", "feasible"):
            a, b = want[f], rec[f]
            if isinstance(a, float) and np.isfinite(a):
                np.testing.assert_allclose(b, a, rtol=1e-5)
            else:
                assert a == b, (rec["key"], f, a, b)

    device_s, _ = timed_run("device")
    pipe_s, _ = timed_run("pipeline")
    device_pps = n_points / device_s
    pipe_pps = n_points / pipe_s
    speedup = pipe_pps / device_pps

    # -- frontier-only == full materialization ----------------------------
    frontier_ok = True
    for scenario, meshes in (("train", ((2, 2), (4, 4))),
                             ("serving", ((4, 4), (2, 8)))):
        fspec = sweeprunner.SweepSpec(
            arches=("qwen1.5-0.5b",), mesh_shapes=meshes,
            scenario=scenario, logic_nodes=("N7", "N5"),
            budget_scales=(0.8, 1.0, 1.2), n_tilings=4, chunk_size=4)
        full = sweeprunner.SweepRunner(fspec, backend="pipeline",
                                       cache=None).run()
        scn = scenarios.get_scenario(scenario)
        want = sorted(r["key"] for r in sweeprunner.pareto_records(
            full.records, scn.objectives))
        front = sweeprunner.SweepRunner(fspec, backend="pipeline",
                                        cache=None).run(frontier_only=True)
        got = sorted(r["key"] for r in front.records)
        assert front.n_frontier_overflowed == 0
        assert want, f"{scenario}: empty reference frontier"
        assert got == want, (
            f"{scenario}: frontier-only Pareto set diverged from full "
            f"materialization\n  got  {got}\n  want {want}")
        frontier_ok = frontier_ok and got == want

    # -- PR4-era checkpoints resume with zero re-evaluation ---------------
    rspec = sweeprunner.SweepSpec(
        arches=("qwen1.5-0.5b",), mesh_shapes=((2, 2), (4, 4)),
        scenario="train", logic_nodes=("N7", "N5"), n_tilings=4,
        chunk_size=1)
    with tempfile.TemporaryDirectory() as d:
        first = sweeprunner.SweepRunner(rspec, out_dir=d,
                                        backend="serial").run(max_chunks=2)
        assert first.n_chunks_evaluated == 2 and not first.complete
        second = sweeprunner.SweepRunner(rspec, out_dir=d,
                                         backend="pipeline").run(resume=True)
        assert second.n_chunks_skipped == 2, second
        assert second.complete
        keys = sorted(r["key"] for r in second.records)
        want = sorted(lb.key() for lb in sweeprunner.enumerate_labels(rspec))
        assert keys == want, "resumed point set differs from the spec"
    resume_ok = True

    assert speedup >= _min_speedup(), (
        f"pipeline executor only {speedup:.1f}x over the synchronous "
        f"sharded path (ISSUE-5 acceptance: >= {_min_speedup():g}x)")
    return {
        "n_devices": n_dev,
        "n_points": n_points,
        "device_pps": device_pps,
        "pipeline_pps": pipe_pps,
        "speedup": speedup,
        "min_speedup": _min_speedup(),
        "cache_bypassed": True,
        "frontier_ok": frontier_ok,
        "resume_ok": resume_ok,
        "compile_misses_warm": pathfinder.compile_cache_stats()["misses"],
    }


def main(verbose: bool = True) -> Dict:
    """Re-exec in a subprocess with forced host devices, parse its JSON."""
    n_dev = min(4, os.cpu_count() or 1)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_dev}"
                        ).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sweep_pipeline", "--measure"],
        env=env, capture_output=True, text=True, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sweep_pipeline measurement failed "
            f"(exit {proc.returncode}):\n{proc.stdout}\n{proc.stderr}")
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith(MARK))
    r = json.loads(line[len(MARK):])
    if verbose:
        print(f"sweep_pipeline: {r['n_points']} points, full runner path, "
              f"{r['n_devices']} forced host devices")
        print(f"  device (PR4)  : {r['device_pps']:10.0f} points/s")
        print(f"  pipeline      : {r['pipeline_pps']:10.0f} points/s "
              f"-> {r['speedup']:.1f}x (floor {r['min_speedup']:g}x)")
        print(f"  frontier-only : identical Pareto set "
              f"({'ok' if r['frontier_ok'] else 'FAIL'})")
        print(f"  resume        : PR4-era checkpoints, zero re-evaluation "
              f"({'ok' if r['resume_ok'] else 'FAIL'})")
    return r


if __name__ == "__main__":
    if "--measure" in sys.argv:
        print(MARK + json.dumps(measure()))
    else:
        main()
