"""Paper Fig. 11 — effect of multi-node packaging.

Fix the total node count (512); group 1/2/4/8/16 nodes per package with
2 TB/s intra-package links (paper's assumption) and re-run the
parallelism search per grouping.

Reproduction targets (paper §9.3): <= ~32% total improvement; marginal
beyond 4 nodes/package.
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ShapeCell, get_config
from repro.configs.paper_lm import GLOBAL_BATCH, N_NODES, SEQ_LEN
from repro.core import age, lmgraph, roofline, simulate, soe, techlib
from repro.core.parallelism import enumerate_strategies
from repro.core.placement import SystemGraph
from repro.core.roofline import PPEConfig

PPE = PPEConfig(n_tilings=12)


def best_time(nodes_per_package: int, n_devices: int = N_NODES) -> float:
    tech = techlib.make_tech_config("N7", "HBM2E", "IB-NDR-X8",
                                    intra_bw=2e12 / 8)
    arch = age.generate(tech, age.Budgets.default())
    cfg = get_config("paper-lm")
    cell = ShapeCell("paper", SEQ_LEN, GLOBAL_BATCH, "train")
    g = lmgraph.build_graph(cfg, cell)
    # system graph: (packages, nodes-per-package); intra-package dims ride
    # the fat 2 TB/s links
    if nodes_per_package == 1:
        system = None
    else:
        # near-square 2-D torus of packages x fat intra-package links
        pkgs = n_devices // nodes_per_package
        a = max(int(pkgs ** 0.5), 1)
        while pkgs % a:
            a -= 1
        system = SystemGraph(dims=(a, pkgs // a, nodes_per_package),
                             levels=("inter", "inter", "intra"))
    roofline.clear_cache()
    best = float("inf")
    for st in enumerate_strategies(n_devices, max_lp=1):
        t = float(simulate.predict(arch, g, st, system=system,
                                   cfg=PPE).total_s)
        best = min(best, t)
    return best


def main(verbose: bool = True, groupings=(1, 2, 4, 8, 16)) -> Dict:
    times = {g: best_time(g) for g in groupings}
    base = times[groupings[0]]
    improvement = {g: base / times[g] for g in groupings}
    if verbose:
        print("fig11: multi-node package study (512 nodes total)")
        for g in groupings:
            print(f"  {g:2d} nodes/package: {times[g]:.3f} s "
                  f"({(improvement[g]-1)*100:+.1f}%)")
        print(f"  max improvement: {(max(improvement.values())-1)*100:.1f}% "
              "(paper: ~32% at best; marginal beyond 4)")
    return {"times": times, "improvement": improvement}


if __name__ == "__main__":
    main()
