"""Sweep -> refine cross-stack co-optimization benchmark (ISSUE-3 tentpole).

Runs a tiny checkpointed sweep per scenario (train and serving), then the
`repro.core.cooptimize` refinement pipeline around its Pareto frontier:
batched gradient descent jointly over the hardware budget vector (eq.-6
SOE update), continuous technology knobs (DVFS voltage, HBM bandwidth /
capacity scaling), and the discrete strategy/mesh axis ranked from the
sweep's own records.

Asserts (ISSUE-3 acceptance):
  * on BOTH scenarios, the refined frontier strictly dominates at least
    one sweep frontier point (<= on every objective, < on at least one);
  * refinement consumed the checkpointed sweep with zero re-evaluation of
    scored points (seeds/candidates come from records; unimproved
    candidates are never re-scored);
  * refined records round-trip the sweep JSONL schema (`pareto_records`
    composes over sweep + refined records).
"""

from __future__ import annotations

import tempfile
from typing import Dict

STEPS = 12
STARTS = 3


def _one_scenario(scenario: str) -> Dict:
    from repro.core import cooptimize, scenarios, sweeprunner
    from repro.core.sweeprunner import SweepRunner, SweepSpec

    spec = SweepSpec(arches=("qwen1.5-0.5b",),
                     mesh_shapes=((2, 2), (4, 4)), scenario=scenario,
                     logic_nodes=("N7",), n_tilings=4, chunk_size=8)
    with tempfile.TemporaryDirectory() as d:
        SweepRunner(spec, out_dir=d, backend="serial").run()
        stats = cooptimize.refine_sweep(
            d, cooptimize.RefineConfig(top_k=2, candidates_per_seed=2,
                                       steps=STEPS, starts=STARTS))
    scn = scenarios.get_scenario(spec.scenario, slo_s=spec.slo_s,
                                 cells=spec.cells)
    assert stats.n_refined >= 1, (
        f"{scenario}: refinement produced no refined records "
        f"({stats.n_unimproved} candidates unimproved)")
    assert stats.n_dominating >= 1, (
        f"{scenario}: no refined point dominates the sweep frontier "
        f"(frontier {stats.n_frontier}, refined {stats.n_refined})")

    # refined records compose with the sweep schema: the joint frontier
    # over sweep + refined records must include refined points
    joint = sweeprunner.pareto_records(stats.frontier + stats.records,
                                       scn.objectives)
    n_refined_on_joint = sum(1 for r in joint if r.get("refined"))
    assert n_refined_on_joint >= 1, "refined points fell off the joint front"

    # headline: best improvement ratio on the primary objective among
    # refined records vs their dominated seed
    primary = scn.objectives[0]
    best_gain = 1.0
    for r in stats.records:
        if not r.get("dominates_seed"):
            continue
        for s in stats.frontier:
            sv, rv = scn.objective_values(s), scn.objective_values(r)
            if sv and rv and cooptimize.dominates(rv, sv):
                best_gain = max(best_gain, float(s[primary])
                                / max(float(r[primary]), 1e-30))
    return {
        "n_sweep_records": stats.n_records,
        "n_frontier": stats.n_frontier,
        "n_refined": stats.n_refined,
        "n_dominating": stats.n_dominating,
        "n_unimproved": stats.n_unimproved,
        "n_objective_evals": stats.n_objective_evals,
        "joint_front_refined": n_refined_on_joint,
        "primary_objective": primary,
        "best_gain": best_gain,
        "refine_s": stats.elapsed_s,
    }


def main(verbose: bool = True) -> Dict:
    out = {s: _one_scenario(s) for s in ("train", "serving")}
    if verbose:
        for s, r in out.items():
            print(f"cooptimize[{s}]: {r['n_sweep_records']} sweep records, "
                  f"frontier {r['n_frontier']} -> {r['n_refined']} refined "
                  f"({r['n_dominating']} dominating, "
                  f"{r['n_unimproved']} unimproved) "
                  f"in {r['refine_s']:.1f}s")
            print(f"  best {r['primary_objective']} gain over a dominated "
                  f"seed: {r['best_gain']:.3f}x; refined points on joint "
                  f"frontier: {r['joint_front_refined']}")
    return out


if __name__ == "__main__":
    main()
