"""Paper Fig. 8 — LM validation: CrossFlow vs measured LSTM LM step time.

Sweep (batch, hidden, vocab) for a 2-layer LSTM LM (the paper's workload,
scaled to CPU-feasible sizes), measure the jit'd JAX training-step wall
time, predict via the full CrossFlow path (lmgraph -> roofline -> event
sim), report corr + mean relative error (paper: corr 0.996, err 16%).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeCell, get_config
from repro.core import age, lmgraph, roofline, simulate
from repro.core.parallelism import Strategy
from repro.core.roofline import PPEConfig
from repro.models import build_model

SEQ = 20                           # the paper's sequence length


def measure_step(hidden: int, vocab: int, batch: int) -> float:
    cfg = dataclasses.replace(get_config("paper-lm"), d_model=hidden,
                              vocab_size=vocab)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.ones((batch, SEQ), jnp.int32)
    batch_d = {"tokens": toks, "labels": toks}

    @jax.jit
    def step(p):
        loss, _ = model.loss_fn(p, batch_d)
        return loss

    step(params).block_until_ready()
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        step(params).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def predict_step(hidden: int, vocab: int, batch: int, arch,
                 overhead: float = 5e-5) -> float:
    cfg = dataclasses.replace(get_config("paper-lm"), d_model=hidden,
                              vocab_size=vocab)
    cell = ShapeCell("lm", SEQ, batch, "prefill")   # fwd-only measurement
    g = lmgraph.build_graph(cfg, cell)
    roofline.clear_cache()
    bd = simulate.predict(arch, g, Strategy("RC"),
                          cfg=PPEConfig(n_tilings=16,
                                        kernel_overhead_s=overhead))
    return float(bd.total_s)


def main(verbose: bool = True, grid=None) -> Dict:
    grid = grid or list(itertools.product((256, 512, 768),    # hidden
                                          (4000, 12000, 24000),  # vocab
                                          (16, 32)))             # batch
    measured, predicted = [], []
    # calibrate the same way fig6 does (peak rate + per-kernel overhead)
    from benchmarks.fig6_gemm_validation import measure as m_gemm
    t = m_gemm(512, 512, 512)
    peak = 2.0 * 512**3 / t / 0.85
    overhead = max(m_gemm(32, 32, 32), 2e-5)      # sw-stack latency (paper §8)
    arch = age.cpu_host_microarch(compute_flops=peak, dram_bw=6e9)
    for hidden, vocab, batch in grid:
        measured.append(measure_step(hidden, vocab, batch))
        predicted.append(predict_step(hidden, vocab, batch, arch,
                                      overhead))
    measured = np.asarray(measured)
    predicted = np.asarray(predicted)
    corr = float(np.corrcoef(np.log(measured), np.log(predicted))[0, 1])
    rel_err = float(np.mean(np.abs(predicted - measured) / measured))
    if verbose:
        print(f"fig8: LSTM LM validation ({len(grid)} configs)")
        print(f"  corr(log t) = {corr:.3f}   mean rel err = "
              f"{rel_err*100:.0f}%  (paper: 0.996, 16%)")
    return {"corr": corr, "rel_err": rel_err,
            "measured": measured.tolist(), "predicted": predicted.tolist()}


if __name__ == "__main__":
    main()
