"""§Roofline — derive the three-term roofline per (arch x shape x mesh)
from the dry-run artifacts (artifacts/dryrun/*.json).

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = collective_bytes / (chips x 50 GB/s/link)

HLO FLOPs/bytes come from compiled.cost_analysis() (per-device in SPMD
modules) with the scan-trip-count correction applied by the dry-run;
collective bytes are parsed from the compiled HLO (also per-device), so
the per-chip terms drop the `chips x` denominators. MODEL_FLOPS = 6 N D
(N_active for MoE); the useful-compute ratio catches remat/redundancy.

Writes artifacts/roofline.md (the table EXPERIMENTS.md embeds).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link; conservative single-link term

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                      "roofline.md")


def load_cells(mesh: Optional[str] = None,
               include_variants: bool = False) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if not d.get("ok"):
            continue
        if not include_variants and d.get("variant"):
            continue
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def _analytic(cell: Dict) -> Dict:
    """Analytic cross-checks: model flops from the CrossFlow graph builder
    (handles enc-dec token asymmetry that plain 6ND overcounts) and a
    minimum-HBM-traffic estimate (CPU-backend cost_analysis counts unfused
    operand bytes, inflating the memory term ~5-20x vs a fused TPU run)."""
    from repro.configs.base import SHAPE_CELLS, get_config
    cfg = get_config(cell["arch"])
    sc = SHAPE_CELLS[cell["cell"]]
    from repro.core import lmgraph
    g = lmgraph.build_graph(cfg, sc)
    gflops = sum(n.flops * n.meta.get("repeat", 1) for n in g.nodes.values())
    n_par = cfg.param_count()
    if sc.kind == "train":
        # fp32 master+grad+adam m,v touched r/w (~24 B/param) + bf16 fwd
        # weights + activations (~16 B/token/layer-width)
        wbytes = 26.0 * n_par
        abytes = 16.0 * sc.tokens * cfg.d_model * max(cfg.n_layers, 1)
    else:
        wbytes = 2.0 * cfg.active_param_count()
        abytes = 4.0 * sc.tokens * cfg.d_model * max(cfg.n_layers, 1)
    return {"model_flops": gflops / cell["devices"],
            "min_bytes": (wbytes + abytes) / cell["devices"]}


def roofline_terms(cell: Dict) -> Dict:
    coll = cell.get("collectives", {})
    coll_bytes = sum(v for k, v in coll.items() if k != "count")
    flops = cell["flops_per_device"]
    mem = cell["bytes_per_device"]
    t_compute = flops / PEAK_FLOPS
    t_memory = mem / HBM_BW
    t_coll = coll_bytes / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    ana = _analytic(cell)
    t_memory_min = ana["min_bytes"] / HBM_BW
    # 6ND headline (the brief's formula) for the record
    mult = 6.0 if cell["cell"].startswith("train") else 2.0
    tokens = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
              "decode_32k": 128, "long_500k": 1}[cell["cell"]]
    model_flops_6nd = mult * cell["active_params"] * tokens / cell["devices"]
    useful = ana["model_flops"] / flops if flops else 0.0
    bound = max(t_compute, t_memory, t_coll)
    bound_min = max(t_compute, t_memory_min, t_coll)
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_memory_min": t_memory_min,
            "t_collective": t_coll, "dominant": dominant,
            "dominant_min": max(("compute", t_compute),
                                ("memory", t_memory_min),
                                ("collective", t_coll),
                                key=lambda kv: kv[1])[0],
            "model_flops_per_dev": ana["model_flops"],
            "model_flops_6nd": model_flops_6nd,
            "useful_ratio": useful,
            "roofline_frac": t_compute / bound if bound else 0.0,
            "roofline_frac_min": t_compute / bound_min if bound_min else 0.0,
            "step_bound_s": bound}


_ADVICE = {
    "compute": "at the compute roofline: gains need lower-precision "
               "matmuls or fewer redundant FLOPs (remat policy)",
    "memory": "HBM-bound: increase arithmetic intensity (fusion, larger "
              "tiles, bf16 caches/activations)",
    "collective": "collective-bound: reshard to cut all-gather volume, "
                  "overlap via latency-hiding, or compress gradients",
}


def build_table(mesh: str = "single") -> List[Dict]:
    rows = []
    for cell in load_cells(mesh):
        terms = roofline_terms(cell)
        rows.append({**cell, **terms,
                     "advice": _ADVICE[terms["dominant"]]})
    rows.sort(key=lambda r: (r["arch"], r["cell"]))
    return rows


def to_markdown(rows: List[Dict], mesh: str) -> str:
    lines = [
        f"### Roofline table — {mesh}-pod mesh "
        f"({'256' if mesh == 'single' else '512'} chips, TPU v5e terms)",
        "",
        "| arch | cell | strategy | t_compute (s) | t_memory (s) | "
        "t_mem_min (s) | t_collective (s) | dominant | model/HLO flops | "
        "frac | frac_min |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['strategy']} "
            f"| {r['t_compute']:.3e} | {r['t_memory']:.3e} "
            f"| {r['t_memory_min']:.3e} "
            f"| {r['t_collective']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} "
            f"| {r['roofline_frac_min']:.2f} |")
    return "\n".join(lines)


def main(verbose: bool = True, write: bool = True) -> Dict:
    out = {}
    md_parts = []
    for mesh in ("single", "multi"):
        rows = build_table(mesh)
        out[mesh] = rows
        if rows:
            md_parts.append(to_markdown(rows, mesh))
        if verbose and rows:
            print(f"roofline ({mesh}): {len(rows)} cells")
            for r in rows:
                print(f"  {r['arch']:22s} {r['cell']:12s} "
                      f"dom={r['dominant']:10s} "
                      f"frac={r['roofline_frac']:.2f} "
                      f"useful={r['useful_ratio']:.2f}")
    if write and md_parts:
        os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
        with open(OUT_MD, "w") as f:
            f.write("\n\n".join(md_parts) + "\n")
    return out


if __name__ == "__main__":
    main()
