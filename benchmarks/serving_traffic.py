"""Traffic-driven serving sweep + inverse fleet-sizing query (ISSUE-6).

Runs the continuous-batching serving scenario end-to-end through the
pipelined sweep executor with batching-policy parameters as sweep axes
(`prefill_chunk` variants ride in the cell id), then answers the inverse
question — "how many devices for X QPS under these percentile SLOs?" —
with `traffic.size_fleet` over the already-swept records.

Asserts (ISSUE-6 acceptance):
  * the swept grid exercises feasible, capacity-infeasible AND
    SLO-wall-failing points (otherwise the walls aren't being tested);
  * ``--frontier-only`` on the traffic scenario returns the identical
    Pareto set as full materialization (percentile walls are traceable);
  * the inverse query touches ZERO sweep evaluations — it is pure
    closed-form work over the records — and returns a minimal plan
    (the best candidate fails its SLOs at one replica fewer).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict

QPS_TARGETS = (2.0, 8.0, 32.0)
# tuned to the demo grid's scale: zero-load TTFT p99 bottoms out near
# 3.8 s and decode steps near 1.7 s on the reference silicon
SLO = {"ttft_p99": 30.0, "tpot_p50": 2.5}


def main(verbose: bool = True) -> Dict:
    import numpy as np

    from repro.core import pathfinder, sweeprunner, traffic

    spec = sweeprunner.SweepSpec(
        arches=("qwen1.5-0.5b",), mesh_shapes=((2, 2), (4, 4), (2, 8)),
        scenario="serving-traffic", logic_nodes=("N7", "N5"),
        budget_scales=(0.9, 1.1), n_tilings=4, chunk_size=16,
        scenario_params={"qps": 0.1,
                         "prefill_chunk": [1024.0, 8192.0],
                         "slo_ttft_p99": [5.0, 50.0]})
    n_points = len(sweeprunner.enumerate_labels(spec))

    t0 = time.perf_counter()
    full = sweeprunner.SweepRunner(spec, backend="pipeline",
                                   cache=None).run()
    sweep_s = time.perf_counter() - t0
    assert full.complete and full.n_points_evaluated == n_points
    records = full.records
    regimes = {(r["feasible"], r["slo_ok"]) for r in records}
    assert (True, True) in regimes, "no point passes the SLO walls"
    assert (False, False) in regimes, "no capacity-infeasible point"
    assert (True, False) in regimes, "no SLO-wall-failing point"

    # -- percentile walls are traceable: frontier-only == host re-filter --
    scn = spec.scenario_spec.variants()[0].resolve()
    want = sorted(r["key"] for r in sweeprunner.pareto_records(
        records, scn.objectives))
    front = sweeprunner.SweepRunner(spec, backend="pipeline",
                                    cache=None).run(frontier_only=True)
    got = sorted(r["key"] for r in front.records)
    assert front.n_frontier_overflowed == 0
    assert want and got == want, (
        f"frontier-only diverged under SLO walls\n  got  {got}\n"
        f"  want {want}")

    # -- inverse query: zero re-evaluation, brute-force minimality --------
    tm, policy, _ = traffic.split_params(
        {**traffic.PARAM_DEFAULTS,
         **{k: v for k, v in spec.scenario_params.items()
            if not isinstance(v, (list, tuple))}})
    plans = {}
    t0 = time.perf_counter()
    for qps in QPS_TARGETS:
        plans[qps] = traffic.size_fleet(records, qps, slo=SLO,
                                        traffic=tm, policy=policy)
    query_s = time.perf_counter() - t0
    for qps, plan in plans.items():
        assert plan.best is not None, f"no sizeable design at {qps} qps"
        rec = next(r for r in records if r["key"] == plan.best.key)
        c1 = traffic._record_consts(rec, tm, policy, qps)
        if plan.best.replicas > 1:
            ok_less, _ = traffic._meets(
                float(rec["prefill_s"]), float(rec["decode_step_s"]),
                dataclasses.replace(c1, qps=qps / (plan.best.replicas - 1)),
                SLO)
            assert not ok_less, f"{qps} qps plan is not minimal"
    best = plans[max(QPS_TARGETS)].best
    n_sweep_evals = sum(p.n_records for p in plans.values())
    assert n_sweep_evals and all(
        np.isfinite(p.best.per_replica_qps) for p in plans.values())

    r = {
        "n_points": n_points,
        "sweep_s": sweep_s,
        "sweep_pps": n_points / sweep_s,
        "query_ms_per_target": query_s * 1e3 / len(QPS_TARGETS),
        "qps_targets": list(QPS_TARGETS),
        "slo": dict(SLO),
        "best_devices": {f"{q:g}": p.best.devices
                         for q, p in plans.items()},
        "best_replicas": {f"{q:g}": p.best.replicas
                          for q, p in plans.items()},
        "frontier_ok": got == want,
        "regimes": sorted(map(list, regimes)),
        "compile_misses": pathfinder.compile_cache_stats()["misses"],
    }
    if verbose:
        print(f"serving_traffic: {n_points} points "
              f"({len(spec.scenario_spec.variants())} traffic variants), "
              f"{sweep_s:.1f}s sweep ({r['sweep_pps']:.0f} pts/s)")
        print(f"  frontier-only : identical Pareto set under SLO walls "
              f"({'ok' if r['frontier_ok'] else 'FAIL'})")
        for q in QPS_TARGETS:
            p = plans[q]
            print(f"  size @{q:5g} qps: {p.best.devices} devices = "
                  f"{p.best.replicas} x {p.best.devices_per_replica} "
                  f"({r['query_ms_per_target']:.1f} ms, zero sweep "
                  f"re-evaluations)")
        _ = best
    return r


if __name__ == "__main__":
    main()
