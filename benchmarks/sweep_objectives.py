"""First-class objective layer benchmark (ISSUE-8 tentpole).

Runs a tiny traffic-driven serving sweep with energy (J/token) and TCO
($/token) Pareto axes composed from the `repro.core.objectives`
registry, then exercises every downstream consumer of the axes:

  * **frontier parity** — the device-resident `--frontier-only`
    streaming reduction (traced frontier fold over canonical signed
    objective values) must reach exactly the same surviving set as the
    host-side Pareto re-filter over full materialization;
  * **cooptimize** — DVFS/budget refinement seeded from the
    frontier-only directory (zero re-evaluation) must produce at least
    one refined point that strictly dominates a sweep frontier point,
    with a strict improvement on the energy axis — the V^2
    `dynamic_energy_scale` path through `apply_tech_knobs` is what
    makes undervolting visible to the descent;
  * **$/token-capped fleet sizing** — records filtered by a $/token
    budget feed `traffic.size_fleet`, and every returned replica count
    is brute-force-verified minimal against the closed-form model
    (meets the walls at n, fails at n-1).

The operating point (4x4 mesh, qps=0.1) is a known-feasible regime for
the small configs; the default traffic qps saturates them.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict

QPS_SWEEP = 0.1       # per-replica arrival rate swept (feasible on 4x4)
QPS_TARGET = 1.0      # aggregate rate for the inverse sizing query
SLO = {"ttft_p99": 1.0e3}     # loose wall: sizing is saturation-driven
STEPS = 16
STARTS = 4


def main(verbose: bool = True) -> Dict:
    from repro.core import cooptimize, sweeprunner, traffic
    from repro.core.sweeprunner import SweepRunner, SweepSpec

    spec = SweepSpec(arches=("qwen1.5-0.5b",), mesh_shapes=((4, 4),),
                     scenario="serving-traffic",
                     logic_nodes=("N7", "N5"), hbms=("HBM2E", "HBM3"),
                     n_tilings=2, chunk_size=4,
                     scenario_params={"qps": QPS_SWEEP},
                     objectives=("energy", "cost"))
    scn = spec.scenario_spec.variants()[0].resolve()
    assert "energy_j_per_token" in scn.objectives
    assert "cost_usd_per_token" in scn.objectives

    t0 = time.perf_counter()
    full = SweepRunner(spec, backend="serial", cache=None).run()
    sweep_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    front = SweepRunner(spec, backend="pipeline", cache=None).run(
        frontier_only=True)
    frontier_s = time.perf_counter() - t0

    # streaming frontier == host-side Pareto re-filter, same keys
    want = sweeprunner.pareto_records(full.records, scn.objectives)
    assert want, "reference frontier must be non-empty"
    assert front.n_frontier_overflowed == 0
    frontier_ok = (sorted((r["key"], r["cell"]) for r in front.records)
                   == sorted((r["key"], r["cell"]) for r in want))
    assert frontier_ok, "frontier-only diverged from the host filter"

    # cooptimize seeded from the frontier records only (what the CLI's
    # frontier.jsonl fallback feeds it) -- zero re-evaluation of the sweep
    t0 = time.perf_counter()
    stats = cooptimize.refine_sweep(
        (spec, list(front.records)),
        cooptimize.RefineConfig(top_k=2, candidates_per_seed=2,
                                steps=STEPS, starts=STARTS))
    refine_s = time.perf_counter() - t0
    assert stats.n_refined >= 1, "refinement produced no refined records"
    assert stats.n_dominating >= 1, (
        f"no refined point dominates the sweep frontier "
        f"(frontier {stats.n_frontier}, refined {stats.n_refined})")
    # the dominance must include a STRICT win on the energy axis
    energy_gain = 1.0
    for r in stats.records:
        rv = scn.objective_values(r)
        if rv is None:
            continue
        for s in stats.frontier:
            sv = scn.objective_values(s)
            if sv and cooptimize.dominates(rv, sv):
                se = float(s["energy_j_per_token"])
                re_ = float(r["energy_j_per_token"])
                if re_ < se:
                    energy_gain = max(energy_gain, se / re_)
    assert energy_gain > 1.0, \
        "no dominating refined point strictly improved J/token"

    # ---- inverse sizing under a $/token budget -----------------------
    sized = [r for r in full.records
             if r.get("feasible", True) and r.get("slo_ok", True)
             and math.isfinite(float(r["cost_usd_per_token"]))]
    assert sized, "no finite-cost feasible records to size"
    costs = sorted(float(r["cost_usd_per_token"]) for r in sized)
    cap = costs[(len(costs) - 1) // 2]        # median: keeps >=1 design
    kept = [r for r in sized if float(r["cost_usd_per_token"]) <= cap]
    tm = dataclasses.replace(traffic.TrafficModel(), qps=QPS_TARGET)
    po = traffic.BatchingPolicy()
    plan = traffic.size_fleet(kept, QPS_TARGET, slo=SLO, traffic=tm,
                              policy=po)
    assert plan.best is not None, "no design under the cap is sizeable"
    # brute-force minimality: walls hold at n replicas, fail at n-1
    for cand in plan.candidates:
        rec = next(r for r in kept if r["key"] == cand.key)
        c1 = traffic._record_consts(rec, tm, po, QPS_TARGET)
        t_pf = float(rec["prefill_s"])
        t_d = float(rec["decode_step_s"])
        ok_n, _ = traffic._meets(
            t_pf, t_d,
            dataclasses.replace(c1, qps=QPS_TARGET / cand.replicas), SLO)
        assert ok_n, cand
        if cand.replicas > 1:
            ok_less, _ = traffic._meets(
                t_pf, t_d,
                dataclasses.replace(c1,
                                    qps=QPS_TARGET / (cand.replicas - 1)),
                SLO)
            assert not ok_less, cand
    size_ok = True

    out = {
        "n_records": len(full.records),
        "n_frontier": len(want),
        "frontier_ok": frontier_ok,
        "n_refined": stats.n_refined,
        "n_dominating": stats.n_dominating,
        "energy_gain": energy_gain,
        "cap_usd_per_token": cap,
        "n_under_cap": len(kept),
        "best_key": plan.best.key,
        "best_replicas": plan.best.replicas,
        "best_devices": plan.best.devices,
        "size_ok": size_ok,
        "sweep_s": sweep_s,
        "frontier_s": frontier_s,
        "refine_s": refine_s,
    }
    if verbose:
        print(f"sweep_objectives: {out['n_records']} records -> "
              f"frontier {out['n_frontier']} "
              f"(streaming parity {'ok' if frontier_ok else 'FAIL'}); "
              f"refine: {stats.n_dominating}/{stats.n_refined} dominate, "
              f"best J/token gain {energy_gain:.2f}x in {refine_s:.1f}s")
        print(f"  size@{QPS_TARGET}qps under <= {cap:.2e} $/token: "
              f"{out['best_key']} x{out['best_replicas']} replicas "
              f"({out['best_devices']} devices), minimality verified")
    return out


if __name__ == "__main__":
    main()
