"""Sharded sweep-engine throughput vs single-stream + resumability check.

Three engines score the same hardware points on one skeleton (the regime of
10^4-10^6-point design-space sweeps, where a handful of skeletons each carry
thousands of hardware/budget variants):

  single-stream  the PR-1 `BatchedEvaluator.evaluate` loop: per-point
                 MicroArch objects, per-point pack/cache-key work on the
                 Python side, one jitted vmap per call;
  matrix         `evaluate_matrix` on one device: the struct-of-arrays
                 hardware matrix enters JAX as a single array;
  sharded        `evaluate_matrix` pmap'd row-wise across local JAX devices
                 (forced host devices on CPU: this benchmark re-executes
                 itself with --xla_force_host_platform_device_count).

Asserts (ISSUE-2 acceptance):
  * sharded >= 2x single-stream throughput;
  * sharding itself beats the one-device matrix path when >1 device;
  * all three agree on the predictions;
  * an interrupted sweep resumes with ZERO re-evaluated chunks and the
    identical point set (checkpoint/resume via repro.core.sweeprunner).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict

MARK = "SWEEP_SHARD_RESULT:"
N_POINTS = 16384                # matrix-path points
N_SINGLE = 512                  # single-stream is timed on this subset


def measure() -> Dict:
    import jax
    import numpy as np

    from repro.configs.base import SHAPE_CELLS, get_config
    from repro.core import age, lmgraph, pathfinder, sweeprunner, techlib
    from repro.core.age import Budgets
    from repro.core.parallelism import Strategy
    from repro.core.roofline import PPEConfig

    n_dev = jax.local_device_count()
    ppe = PPEConfig(n_tilings=8)
    g = lmgraph.build_graph(get_config("qwen1.5-0.5b"),
                            SHAPE_CELLS["train_4k"])
    st = Strategy("RC", kp1=1, kp2=2, dp=8)
    template = age.generate(techlib.make_tech_config("N7", "HBM2E"),
                            Budgets.default())
    base = pathfinder.pack_hw(template)
    rng = np.random.default_rng(0)
    hw = (base[None, :]
          * rng.uniform(0.85, 1.15, (N_POINTS, base.shape[0]))
          ).astype(np.float32)

    ev = pathfinder.BatchedEvaluator(g, st, ppe=ppe, cache=None)

    def best_time(fn, repeats: int = 5):
        """(best wall seconds, last result) — min over repeats to shed
        scheduler noise on small shared CI hosts."""
        best, out = float("inf"), None
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best, out

    # -- single-stream: the PR-1 evaluator over MicroArch objects ---------
    archs = [pathfinder.unpack_hw(template, row) for row in hw[:N_SINGLE]]
    ev.evaluate(archs)                         # warm (compile + shapes)
    single_s, rows_single = best_time(lambda: ev.evaluate(archs), 3)
    single_pps = N_SINGLE / single_s

    # -- matrix path, one device -----------------------------------------
    ev.evaluate_matrix(template, hw, devices=1)          # warm
    matrix_s, rows_matrix = best_time(
        lambda: ev.evaluate_matrix(template, hw, devices=1))
    matrix_pps = N_POINTS / matrix_s

    # -- sharded across all local devices --------------------------------
    ev.evaluate_matrix(template, hw, devices=n_dev)      # warm
    shard_s, rows_shard = best_time(
        lambda: ev.evaluate_matrix(template, hw, devices=n_dev))
    shard_pps = N_POINTS / shard_s

    np.testing.assert_allclose(rows_matrix[:N_SINGLE], rows_single,
                               rtol=1e-5)
    np.testing.assert_allclose(rows_shard, rows_matrix, rtol=1e-5)

    # -- resumability: interrupt, resume, zero re-evaluation -------------
    spec = sweeprunner.SweepSpec(
        arches=("qwen1.5-0.5b",), mesh_shapes=((2, 2), (4, 4)),
        scenario="train", logic_nodes=("N7", "N5"), n_tilings=4,
        chunk_size=1)
    with tempfile.TemporaryDirectory() as d:
        first = sweeprunner.SweepRunner(spec, out_dir=d,
                                        backend="serial").run(max_chunks=2)
        assert first.n_chunks_evaluated == 2 and not first.complete
        second = sweeprunner.SweepRunner(spec, out_dir=d,
                                         backend="serial").run(resume=True)
        assert second.n_chunks_skipped == 2, second
        assert second.n_chunks_evaluated == second.n_chunks_total - 2
        keys = sorted(r["key"] for r in second.records)
        want = sorted(lb.key()
                      for lb in sweeprunner.enumerate_labels(spec))
        assert keys == want, "resumed point set differs from the spec"
    resume_ok = True

    speedup_vs_single = shard_pps / single_pps
    shard_gain = shard_pps / matrix_pps
    assert speedup_vs_single >= 2.0, (
        f"sharded engine only {speedup_vs_single:.1f}x over the "
        f"single-stream evaluator (ISSUE-2 acceptance: >= 2x)")
    if n_dev >= 2:
        assert shard_gain >= 1.1, (
            f"device sharding gained only {shard_gain:.2f}x over the "
            f"one-device matrix path on {n_dev} devices")
    return {
        "n_devices": n_dev,
        "n_points": N_POINTS,
        "single_stream_pps": single_pps,
        "matrix_pps": matrix_pps,
        "sharded_pps": shard_pps,
        "speedup_vs_single": speedup_vs_single,
        "shard_gain": shard_gain,
        "resume_ok": resume_ok,
    }


def main(verbose: bool = True) -> Dict:
    """Re-exec in a subprocess with forced host devices, parse its JSON."""
    n_dev = min(4, os.cpu_count() or 1)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_dev}"
                        ).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sweep_shard", "--measure"],
        env=env, capture_output=True, text=True, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sweep_shard measurement failed "
            f"(exit {proc.returncode}):\n{proc.stdout}\n{proc.stderr}")
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith(MARK))
    r = json.loads(line[len(MARK):])
    if verbose:
        print(f"sweep_shard: {r['n_points']} points on one skeleton, "
              f"{r['n_devices']} forced host devices")
        print(f"  single-stream : {r['single_stream_pps']:10.0f} points/s")
        print(f"  matrix (1 dev): {r['matrix_pps']:10.0f} points/s")
        print(f"  sharded       : {r['sharded_pps']:10.0f} points/s "
              f"-> {r['speedup_vs_single']:.0f}x vs single-stream, "
              f"{r['shard_gain']:.2f}x shard gain")
        print(f"  resume        : zero re-evaluated chunks "
              f"({'ok' if r['resume_ok'] else 'FAIL'})")
    return r


if __name__ == "__main__":
    if "--measure" in sys.argv:
        print(MARK + json.dumps(measure()))
    else:
        main()
