"""Batched serving example across three model families: dense (qwen),
hybrid (recurrentgemma: RG-LRU state + local-attention ring cache), and
ssm (xlstm: matrix/scalar recurrent state).

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import serve


def main() -> None:
    for arch in ("qwen1.5-0.5b", "recurrentgemma-2b", "xlstm-125m"):
        out = serve(arch, batch=2, prompt_len=24, gen=8, use_reduced=True)
        print(f"{arch:20s} strategy={out['plan']:18s} "
              f"prefill={out['prefill_s']:.2f}s "
              f"decode={out['decode_s']:.2f}s "
              f"({out['tok_per_s']:.1f} tok/s)")
        print(f"{'':20s} sample: {out['tokens'][0][:8].tolist()}")


if __name__ == "__main__":
    main()
