"""Quickstart: train a small qwen-family LM on the synthetic pipeline and
watch the loss descend, then decode a few tokens from it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.launch.serve import serve
from repro.launch.train import TrainConfig, train


def main() -> None:
    print("=== quickstart: train a reduced qwen1.5 for 60 steps ===")
    tc = TrainConfig(arch="qwen1.5-0.5b", steps=60, global_batch=8,
                     seq_len=64, mesh_shape=(1, 1), lr=1e-3, warmup=10,
                     use_reduced_config=True, log_every=10)
    out = train(tc)
    first, last = out["history"][0], out["final_loss"]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({(1 - last / first) * 100:.0f}% down)")
    assert last < first, "training must descend on the structured stream"

    print("=== quickstart: decode from the same family ===")
    s = serve("qwen1.5-0.5b", batch=2, prompt_len=16, gen=8,
              use_reduced=True)
    print(f"decoded {s['tokens'].shape} tokens at {s['tok_per_s']:.1f} "
          f"tok/s under strategy {s['plan']}")


if __name__ == "__main__":
    main()
