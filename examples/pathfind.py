"""DeepFlow pathfinding example — the paper's §9 workflow end to end:

1. ask CrossFlow where a workload sits across technology generations,
2. co-optimize parallelism strategy + hardware budgets with the SOE,
3. emit the sharding plan the real runtime would use on the v5e mesh.

    PYTHONPATH=src python examples/pathfind.py
"""

from repro.configs.base import SHAPE_CELLS, get_config
from repro.core import age, lmgraph, planner, simulate, soe, techlib
from repro.core.parallelism import Strategy
from repro.core.roofline import PPEConfig

PPE = PPEConfig(n_tilings=12)


def main() -> None:
    cfg = get_config("qwen3-moe-30b-a3b")
    cell = SHAPE_CELLS["train_4k"]
    g = lmgraph.build_graph(cfg, cell)
    print(f"=== pathfind: {cfg.name} x {cell.name} "
          f"({g.total_flops():.2e} flops/graph-template) ===")

    print("-- 1. technology what-if (N7 vs N3, HBM2E vs HBM3) --")
    for logic, hbm in (("N7", "HBM2E"), ("N3", "HBM2E"), ("N3", "HBM3")):
        tech = techlib.make_tech_config(logic, hbm, "IB-NDR-X8")
        arch = age.generate(tech, age.Budgets.default())
        bd = simulate.predict(arch, g, Strategy("RC", kp1=1, kp2=16, dp=16),
                              cfg=PPE)
        print(f"   {logic}/{hbm}: {float(bd.total_s)*1e3:8.1f} ms/iter "
              f"(compute {float(bd.compute_s)*1e3:.1f}, "
              f"comm {float(bd.comm_s)*1e3:.1f})")

    print("-- 2. SOE co-optimization on N7 (256 devices) --")
    tech = techlib.make_tech_config("N7", "HBM2E", "IB-NDR-X8")
    res = soe.co_optimize(tech, g, n_devices=256, search_arch=True,
                          cfg=soe.SOEConfig(steps=10, starts=2), ppe=PPE)
    print(f"   best strategy {res.strategy.name}: {res.time_s*1e3:.1f} ms; "
          f"core area frac -> {float(res.budgets.area_frac['core']):.2f}")

    print("-- 3. runtime sharding plan on the v5e production mesh --")
    plan = planner.plan(cfg, cell, (16, 16), ("data", "model"))
    print(f"   strategy {plan.strategy.name} predicted "
          f"{plan.predicted_step_s*1e3:.1f} ms/step")
    for axis, rule in plan.rules:
        print(f"   {axis:10s} -> {rule}")


if __name__ == "__main__":
    main()
