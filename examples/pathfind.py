"""DeepFlow pathfinding example — the paper's §9 workflow end to end, on
the batched pathfinding engine:

1. sweep a design space (tech nodes x HBM gens x meshes) in one batched
   evaluation and read off the Pareto frontier,
2. co-optimize parallelism strategy + hardware budgets with the batched
   multi-start SOE,
3. emit the sharding plan the real runtime would use on the v5e mesh.

    PYTHONPATH=src python examples/pathfind.py

The same flows are scriptable via the CLI:

    PYTHONPATH=src python -m repro.pathfind sweep --arch qwen3-moe-30b-a3b \
        --cell train_4k --mesh 16x16 --logic N7,N3 --hbm HBM2E,HBM3
"""

from repro.configs.base import SHAPE_CELLS, get_config
from repro.core import lmgraph, pathfinder, planner, soe, techlib
from repro.core.roofline import PPEConfig

PPE = PPEConfig(n_tilings=12)
ARCH = "qwen3-moe-30b-a3b"


def main() -> None:
    cfg = get_config(ARCH)
    cell = SHAPE_CELLS["train_4k"]
    g = lmgraph.build_graph(cfg, cell)
    print(f"=== pathfind: {cfg.name} x {cell.name} "
          f"({g.total_flops():.2e} flops/graph-template) ===")

    print("-- 1. batched design-space sweep (tech x memory x mesh) --")
    result = pathfinder.sweep(
        [ARCH], ["train_4k"], [(16, 16), (8, 8)],
        logic_nodes=("N7", "N3"), hbms=("HBM2E", "HBM3"),
        nets=("IB-NDR-X8",), ppe=PPE)
    for p in sorted(result.points, key=lambda p: p.time_s)[:4]:
        print(f"   {p.logic:>3}/{p.hbm:<5} mesh {'x'.join(map(str, p.mesh)):>5} "
              f"{p.strategy.name:<18} {p.time_s*1e3:8.1f} ms/iter")
    frontier = result.pareto(objectives=("time_s", "devices"))
    print(f"   Pareto(time, devices): {len(frontier)} of "
          f"{len(result.points)} points")
    for p in sorted(frontier, key=lambda p: p.devices):
        print(f"     d{p.devices:<4} {p.logic}/{p.hbm} "
              f"-> {p.time_s*1e3:.1f} ms")
    stats = pathfinder.cache_stats()
    print(f"   prediction cache: {stats['hits']} hits / "
          f"{stats['misses']} misses")

    print("-- 2. batched multi-start SOE co-optimization on N7 (256 dev) --")
    tech = techlib.make_tech_config("N7", "HBM2E", "IB-NDR-X8")
    res = soe.co_optimize(tech, g, n_devices=256, search_arch=True,
                          cfg=soe.SOEConfig(steps=10, starts=2), ppe=PPE)
    print(f"   best strategy {res.strategy.name}: {res.time_s*1e3:.1f} ms; "
          f"core area frac -> {float(res.budgets.area_frac['core']):.2f} "
          f"({res.n_queries} CrossFlow queries)")

    print("-- 3. runtime sharding plan on the v5e production mesh --")
    plan = planner.plan(cfg, cell, (16, 16), ("data", "model"))
    print(f"   strategy {plan.strategy.name} predicted "
          f"{plan.predicted_step_s*1e3:.1f} ms/step")
    for axis, rule in plan.rules:
        print(f"   {axis:10s} -> {rule}")


if __name__ == "__main__":
    main()
