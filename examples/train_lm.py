"""End-to-end training driver example: a ~100M-param LM trained for a few
hundred steps with checkpoint/resume, straggler watchdog, and int8
gradient compression — the full production path on a small scale.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    (defaults to 40 steps so CI stays fast; pass --steps 300 for the
    full run — ~100M params on one CPU core is slow but functional)
"""

import argparse
import dataclasses
import os
import tempfile

from repro.configs.base import get_config
from repro.launch.train import TrainConfig, train


def build_100m():
    """~100M-param member of the qwen family (vocab-dominated)."""
    import repro.configs.qwen1_5_0_5b as q
    return dataclasses.replace(
        q.CONFIG, name="qwen-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=8, d_ff=1408, vocab_size=65536)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = build_100m()
    n = cfg.param_count()
    print(f"=== train_lm: {cfg.name} ({n/1e6:.0f}M params) "
          f"for {args.steps} steps ===")

    # register the config under a temp module name via monkeypatching the
    # registry (examples are allowed to be direct):
    import repro.configs.base as base
    import sys
    import types
    mod = types.ModuleType("repro.configs.qwen_100m")
    mod.CONFIG = cfg
    sys.modules["repro.configs.qwen_100m"] = mod

    ckpt = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                         "repro_train_lm_ckpt")
    tc = TrainConfig(arch="qwen_100m", steps=args.steps,
                     global_batch=args.batch, seq_len=args.seq,
                     mesh_shape=(1, 1), lr=6e-4, warmup=20,
                     ckpt_dir=ckpt, ckpt_every=20, log_every=5,
                     grad_compression="int8")
    out = train(tc)
    h = out["history"]
    print(f"loss: {h[0]:.3f} -> {h[-1]:.3f}; checkpoints in {ckpt}; "
          f"stragglers flagged: {len(out['stragglers'])}")


if __name__ == "__main__":
    main()
