"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential recurrence with block-diagonal
recurrent weights).

mLSTM training uses the paper's *parallel form*: linear attention with a
cumulative-gate decay matrix

    D_tj = exp(F_t - F_j + i_j - m_t),  F = cumsum(log f)
    h_t  = (sum_j D_tj (q_t.k_j) v_j) / max(|sum_j D_tj (q_t.k_j)|, e^{-m_t})

evaluated chunk-wise (same memory shape as chunked attention). Decode
carries the (h, d, d') matrix state C and normalizer n — O(1) per token.

sLSTM is inherently sequential (h_{t-1} feeds the gates through recurrent
weights R), so training runs a lax.scan over time with exponential-gating
stabilizer m_t — faithful to the paper; this is the arch where the
DeepFlow planner's KP restriction note applies (DESIGN.md).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamDef


def _heads(cfg: ArchConfig) -> Tuple[int, int]:
    return cfg.n_heads, cfg.resolved_head_dim


def mlstm_defs(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    nh, hd = _heads(cfg)
    return {
        "wq": ParamDef((d, nh * hd), ("fsdp", "heads")),
        "wk": ParamDef((d, nh * hd), ("fsdp", "heads")),
        "wv": ParamDef((d, nh * hd), ("fsdp", "heads")),
        "wi": ParamDef((d, nh), ("fsdp", None), scale=0.1),
        "wf": ParamDef((d, nh), ("fsdp", None), scale=0.1),
        "bf": ParamDef((nh,), (None,), init="ones"),
        "wo": ParamDef((nh * hd, d), ("heads", "fsdp")),
        "up": ParamDef((d, 2 * d), ("fsdp", "mlp")),
        "down": ParamDef((2 * d, d), ("mlp", "fsdp")),
    }


def slstm_defs(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    nh, hd = _heads(cfg)
    return {
        "wz": ParamDef((d, nh * hd), ("fsdp", "heads")),
        "wi": ParamDef((d, nh * hd), ("fsdp", "heads"), scale=0.1),
        "wf": ParamDef((d, nh * hd), ("fsdp", "heads"), scale=0.1),
        "wo_gate": ParamDef((d, nh * hd), ("fsdp", "heads"), scale=0.1),
        # block-diagonal recurrent weights, one (hd, hd) block per head
        "rz": ParamDef((nh, hd, hd), (None, None, None), scale=hd ** -0.5),
        "ri": ParamDef((nh, hd, hd), (None, None, None), scale=0.05),
        "rf": ParamDef((nh, hd, hd), (None, None, None), scale=0.05),
        "bf": ParamDef((nh * hd,), ("heads",), init="ones"),
        "wo": ParamDef((nh * hd, d), ("heads", "fsdp")),
        "up": ParamDef((d, 2 * d), ("fsdp", "mlp")),
        "down": ParamDef((2 * d, d), ("mlp", "fsdp")),
    }


def _split_heads(x: jax.Array, nh: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, nh, -1).transpose(0, 2, 1, 3)   # (b, nh, s, hd)


# --------------------------------------------------------------------- mLSTM


def _mlstm_parallel(q, k, v, log_i, log_f, chunk: int = 512):
    """q/k/v: (b, h, s, d); log_i/log_f: (b, h, s). Chunked decay-weighted
    linear attention (causal)."""
    b, h, s, d = q.shape
    scale = d ** -0.5
    f_cum = jnp.cumsum(log_f, axis=-1)                     # F_t
    c = min(chunk, s)
    while s % c:
        c -= 1
    n_c = s // c

    def q_step(qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * c, c, axis=2) * scale
        fq = jax.lax.dynamic_slice_in_dim(f_cum, qi * c, c, axis=2)
        q_pos = qi * c + jnp.arange(c)

        def kv_step(carry, kj):
            num, den, m = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, kj * c, c, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(v, kj * c, c, axis=2)
            fk = jax.lax.dynamic_slice_in_dim(f_cum, kj * c, c, axis=2)
            ik = jax.lax.dynamic_slice_in_dim(log_i, kj * c, c, axis=2)
            k_pos = kj * c + jnp.arange(c)
            # log decay D_tj = F_t - F_j + i_j  (j <= t)
            a = fq[..., :, None] - fk[..., None, :] + ik[..., None, :]
            causal = q_pos[:, None] >= k_pos[None, :]
            a = jnp.where(causal[None, None], a, -1e30)
            m_new = jnp.maximum(m, jnp.max(a, axis=-1, keepdims=True))
            dmat = jnp.exp(a - m_new)
            qk = jnp.einsum("bhqd,bhkd->bhqk", q_blk.astype(jnp.float32),
                            k_blk.astype(jnp.float32))
            w = qk * dmat
            corr = jnp.exp(m - m_new)
            num = num * corr + jnp.einsum("bhqk,bhkd->bhqd", w,
                                          v_blk.astype(jnp.float32))
            den = den * corr[..., 0] + jnp.sum(w, axis=-1)
            return (num, den, m_new), None

        num0 = jnp.zeros((b, h, c, d), jnp.float32)
        den0 = jnp.zeros((b, h, c), jnp.float32)
        m0 = jnp.full((b, h, c, 1), -1e30, jnp.float32)
        (num, den, m), _ = jax.lax.scan(kv_step, (num0, den0, m0),
                                        jnp.arange(qi + 1))
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m[..., 0]))
        return num / denom[..., None]

    # causal chunk loop: q chunk qi only attends kv chunks <= qi. lax.scan
    # cannot have data-dependent trip counts, so scan all and mask instead.
    def q_step_full(qi):
        return q_step(qi)

    if n_c == 1:
        out = q_step_full(0)
    else:
        outs = []
        for qi in range(n_c):                 # unrolled (n_c is small: s/512)
            outs.append(q_step_full(qi))
        out = jnp.concatenate(outs, axis=2)
    return out.astype(q.dtype)


def mlstm_apply(p: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    b, s, d = x.shape
    nh, hd = _heads(cfg)
    q = _split_heads(x @ p["wq"].astype(x.dtype), nh)
    k = _split_heads(x @ p["wk"].astype(x.dtype), nh)
    v = _split_heads(x @ p["wv"].astype(x.dtype), nh)
    x32 = x.astype(jnp.float32)
    log_i = (x32 @ p["wi"].astype(jnp.float32)).transpose(0, 2, 1)  # (b,h,s)
    log_f = jax.nn.log_sigmoid(
        (x32 @ p["wf"].astype(jnp.float32)).transpose(0, 2, 1)
        + p["bf"].astype(jnp.float32)[None, :, None])
    h = _mlstm_parallel(q, k, v, log_i, log_f)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    out = h @ p["wo"].astype(x.dtype)
    # up/down projection (replaces the FFN; d_ff=0 in the config)
    u = jax.nn.gelu(out @ p["up"].astype(x.dtype))
    return u @ p["down"].astype(x.dtype)


def mlstm_prefill_state(p: Dict, x: jax.Array, cfg: ArchConfig) -> Dict:
    """Final recurrent (C, n, m) state after consuming x — so decode can
    continue after a parallel-form prefill."""
    b, s, d = x.shape
    nh, hd = _heads(cfg)
    k = _split_heads(x @ p["wk"].astype(x.dtype), nh).astype(jnp.float32)
    v = _split_heads(x @ p["wv"].astype(x.dtype), nh).astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    log_i = (x32 @ p["wi"].astype(jnp.float32)).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(
        (x32 @ p["wf"].astype(jnp.float32)).transpose(0, 2, 1)
        + p["bf"].astype(jnp.float32)[None, :, None])
    f_cum = jnp.cumsum(log_f, axis=-1)
    # weight of step j in the final state: F_T - F_j + i_j
    a = f_cum[..., -1:] - f_cum + log_i                    # (b, h, s)
    m = jnp.max(a, axis=-1)
    w = jnp.exp(a - m[..., None])
    c = jnp.einsum("bhs,bhsd,bhse->bhde", w, k, v)
    n = jnp.einsum("bhs,bhsd->bhd", w, k)
    return {"c": c, "n": n, "m": m}


def mlstm_init_state(cfg: ArchConfig, batch: int) -> Dict:
    nh, hd = _heads(cfg)
    return {"c": jnp.zeros((batch, nh, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, nh, hd), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def mlstm_decode(p: Dict, x: jax.Array, state: Dict,
                 cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    """x: (b, 1, d). Recurrent matrix-memory update (xLSTM eqs. 19-27)."""
    b, _, d = x.shape
    nh, hd = _heads(cfg)
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, nh, hd) * hd ** -0.5
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, nh, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, nh, hd)
    x32 = x[:, 0].astype(jnp.float32)
    log_i = x32 @ p["wi"].astype(jnp.float32)                 # (b, nh)
    log_f = jax.nn.log_sigmoid(x32 @ p["wf"].astype(jnp.float32)
                               + p["bf"].astype(jnp.float32))
    m_new = jnp.maximum(state["m"] + log_f, log_i)
    fg = jnp.exp(state["m"] + log_f - m_new)[..., None]
    ig = jnp.exp(log_i - m_new)[..., None]
    c = state["c"] * fg[..., None] + ig[..., None] \
        * jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32),
                     v.astype(jnp.float32))
    n = state["n"] * fg + ig * k.astype(jnp.float32)
    num = jnp.einsum("bhde,bhd->bhe", c, q.astype(jnp.float32))
    # stabilized denominator: max(|n.q|, e^{-m}) (xLSTM eq. 27 with the
    # running stabilizer factored out — matches the parallel form)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n,
                                         q.astype(jnp.float32))),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, 1, nh * hd).astype(x.dtype)
    out = h @ p["wo"].astype(x.dtype)
    u = jax.nn.gelu(out @ p["up"].astype(x.dtype))
    return u @ p["down"].astype(x.dtype), {"c": c, "n": n, "m": m_new}


# --------------------------------------------------------------------- sLSTM


def _slstm_gates(p, x32):
    z = x32 @ p["wz"].astype(jnp.float32)
    i = x32 @ p["wi"].astype(jnp.float32)
    f = x32 @ p["wf"].astype(jnp.float32) + p["bf"].astype(jnp.float32)
    o = x32 @ p["wo_gate"].astype(jnp.float32)
    return z, i, f, o


def _slstm_step(p, nh, hd, carry, zifo):
    c, n, h, m = carry                                     # (b, nh, hd) each
    z_x, i_x, f_x, o_x = zifo

    def rec(w, hh):                                        # block-diag recur
        return jnp.einsum("bhd,hde->bhe", hh, w.astype(jnp.float32))

    z = jnp.tanh(z_x + rec(p["rz"], h))
    i_t = i_x + rec(p["ri"], h)
    f_t = f_x + rec(p["rf"], h)
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_t) + m, i_t)  # stabilizer
    i_g = jnp.exp(i_t - m_new)
    f_g = jnp.exp(jax.nn.log_sigmoid(f_t) + m - m_new)
    c = f_g * c + i_g * z
    n = f_g * n + i_g
    h = jax.nn.sigmoid(o_x) * c / jnp.maximum(n, 1.0)
    return (c, n, h, m_new), h


def slstm_apply(p: Dict, x: jax.Array, cfg: ArchConfig,
                return_state: bool = False):
    b, s, d = x.shape
    nh, hd = _heads(cfg)
    x32 = x.astype(jnp.float32)
    z, i, f, o = _slstm_gates(p, x32)

    def reshape(t):                                        # (s, b, nh, hd)
        return t.reshape(b, s, nh, hd).transpose(1, 0, 2, 3)

    carry0 = tuple(jnp.zeros((b, nh, hd), jnp.float32) for _ in range(3)) \
        + (jnp.full((b, nh, hd), -1e30, jnp.float32),)
    step = lambda c, zi: _slstm_step(p, nh, hd, c, zi)
    carry, hs = jax.lax.scan(step, carry0,
                             (reshape(z), reshape(i), reshape(f), reshape(o)))
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, nh * hd).astype(x.dtype)
    out = h @ p["wo"].astype(x.dtype)
    u = jax.nn.gelu(out @ p["up"].astype(x.dtype))
    y = u @ p["down"].astype(x.dtype)
    if not return_state:
        return y
    c, n, hh, m = carry
    return y, {"c": c, "n": n, "h": hh, "m": m}


def slstm_init_state(cfg: ArchConfig, batch: int) -> Dict:
    nh, hd = _heads(cfg)
    zero = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": zero, "n": zero, "h": zero,
            "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}


def slstm_decode(p: Dict, x: jax.Array, state: Dict,
                 cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    b, _, d = x.shape
    nh, hd = _heads(cfg)
    x32 = x[:, 0].astype(jnp.float32)
    z, i, f, o = _slstm_gates(p, x32)
    carry = (state["c"], state["n"], state["h"], state["m"])
    zifo = tuple(t.reshape(b, nh, hd) for t in (z, i, f, o))
    (c, n, h, m), hh = _slstm_step(p, nh, hd, carry, zifo)
    hflat = hh.reshape(b, 1, nh * hd).astype(x.dtype)
    out = hflat @ p["wo"].astype(x.dtype)
    u = jax.nn.gelu(out @ p["up"].astype(x.dtype))
    return u @ p["down"].astype(x.dtype), {"c": c, "n": n, "h": h, "m": m}
