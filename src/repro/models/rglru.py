"""RG-LRU recurrent block (recurrentgemma, arXiv:2402.19427).

Block = input/gate projections -> short causal depthwise conv1d -> RG-LRU
diagonal linear recurrence -> output projection. The recurrence

    a_t = exp(-c * softplus(Lambda) * sigmoid(r_t))          (gated decay)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is evaluated with `jax.lax.associative_scan` (log-depth, XLA-friendly) on
the training/prefill path; decode keeps (h, conv tail) as O(1) state. The
Pallas kernel (repro.kernels.rglru) implements the same first-order scan
for the real-TPU path and is validated against the lax.scan oracle.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamDef

_C = 8.0                            # recurrentgemma's fixed scaling constant


def rglru_defs(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "in_x": ParamDef((d, w), ("fsdp", "lru")),
        "in_gate": ParamDef((d, w), ("fsdp", "lru")),
        "conv_w": ParamDef((cfg.conv1d_width, w), (None, "lru"),
                           scale=cfg.conv1d_width ** -0.5),
        "conv_b": ParamDef((w,), ("lru",), init="zeros"),
        "gate_a": ParamDef((w, w), ("lru", None), scale=w ** -0.5),
        "gate_x": ParamDef((w, w), ("lru", None), scale=w ** -0.5),
        "log_lambda": ParamDef((w,), ("lru",), init="zeros"),
        "out": ParamDef((w, d), ("lru", "fsdp")),
    }


def _gates(p: Dict, xw: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """a_t (decay) and b_t (input) of the linear recurrence, fp32."""
    x32 = xw.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["gate_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(x32 @ p["gate_x"].astype(jnp.float32))
    # softplus(log_lambda) init ~0.7; exp(-c * softplus * r) in (0, 1)
    log_a = -_C * jax.nn.softplus(p["log_lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * x32)
    return a, b


def _conv(p: Dict, x: jax.Array, tail: jax.Array = None) -> jax.Array:
    """Causal depthwise conv over seq; `tail` = last (width-1) steps from
    the previous segment (decode state)."""
    kw = p["conv_w"].shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i].astype(x.dtype)
              for i in range(kw))
    return out + p["conv_b"].astype(x.dtype)


def rglru_apply(p: Dict, x: jax.Array, cfg: ArchConfig,
                return_state: bool = False):
    """Train/prefill path. x: (b, s, d) -> (b, s, d) [, final decode state]."""
    xw_pre = x @ p["in_x"].astype(x.dtype)                   # (b, s, w)
    gate = jax.nn.gelu(x @ p["in_gate"].astype(x.dtype))
    xw = _conv(p, xw_pre)
    a, b = _gates(p, xw)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h.astype(x.dtype) * gate) @ p["out"].astype(x.dtype)
    if not return_state:
        return out
    kw = p["conv_w"].shape[0]
    state = {"h": h[:, -1],
             "conv": xw_pre[:, -(kw - 1):].astype(jnp.float32)}
    return out, state


def rglru_init_state(cfg: ArchConfig, batch: int) -> Dict:
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), jnp.float32)}


def rglru_decode(p: Dict, x: jax.Array, state: Dict,
                 cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    """One-token step. x: (b, 1, d); state: {h: (b, w), conv: (b, kw-1, w)}."""
    xw = x @ p["in_x"].astype(x.dtype)                       # (b, 1, w)
    gate = jax.nn.gelu(x @ p["in_gate"].astype(x.dtype))
    new_conv = jnp.concatenate([state["conv"][:, 1:],
                                xw.astype(jnp.float32)], axis=1)
    xw = _conv(p, xw, tail=state["conv"])
    a, b = _gates(p, xw)
    h = a[:, 0] * state["h"] + b[:, 0]                       # (b, w)
    out = (h[:, None].astype(x.dtype) * gate) @ p["out"].astype(x.dtype)
    return out, {"h": h, "conv": new_conv}
