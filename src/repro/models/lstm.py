"""The paper's validation/case-study workload: an N-layer LSTM language
model (§8-§9: 2 layers, hidden 16K, vocab 800K, seq 20). Used by the
measured-vs-predicted CPU validation (benchmarks/fig8) and runnable as a
normal arch through build_model.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.common import ParamDef


def lstm_defs(cfg: ArchConfig) -> Dict:
    h = cfg.d_model
    layers = {
        "wx": ParamDef((cfg.n_layers, h, 4 * h), ("layers", "fsdp", "mlp")),
        "wh": ParamDef((cfg.n_layers, h, 4 * h), ("layers", "fsdp", "mlp")),
        "b": ParamDef((cfg.n_layers, 4 * h), ("layers", "mlp"),
                      init="zeros"),
    }
    return {
        "embed": ParamDef((cfg.padded_vocab, h), ("vocab", "fsdp"), scale=0.02),
        "layers": layers,
        "head": ParamDef((h, cfg.padded_vocab), ("fsdp", "vocab")),
    }


def _lstm_layer(wx, wh, b, x):
    """x: (batch, seq, h) -> (batch, seq, h); lax.scan over time."""
    bsz, seq, h = x.shape
    xw = x @ wx.astype(x.dtype) + b.astype(x.dtype)      # (b, s, 4h)

    def step(carry, xt):
        hprev, cprev = carry
        gates = xt + hprev @ wh.astype(xt.dtype)
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * cprev + jax.nn.sigmoid(i) * jnp.tanh(g)
        hnew = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (hnew, c), hnew

    h0 = jnp.zeros((bsz, h), x.dtype)
    _, hs = jax.lax.scan(step, (h0, h0), jnp.swapaxes(xw, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


def forward(params: Dict, tokens: jax.Array, cfg: ArchConfig, *,
            rules=None, mesh=None, remat: bool = False) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    x = common.logical(x, ("batch", "act_seq", "act_embed"), rules, mesh)

    def body(x, lp):
        return _lstm_layer(lp[0], lp[1], lp[2], x), 0

    x, _ = jax.lax.scan(body, x, (params["layers"]["wx"],
                                  params["layers"]["wh"],
                                  params["layers"]["b"]))
    return common.mask_padded_vocab(
        (x @ params["head"].astype(x.dtype)).astype(jnp.float32),
        cfg.vocab_size)


def loss_fn(params: Dict, batch: Dict, cfg: ArchConfig, *, rules=None,
            mesh=None, remat: bool = False):
    logits = forward(params, batch["tokens"], cfg, rules=rules, mesh=mesh)
    ce = common.cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}
