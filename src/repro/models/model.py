"""Unified model API: `build_model(cfg)` -> Model with
defs / init / loss / forward / prefill-decode entry points + input_specs
for the dry-run (ShapeDtypeStruct stand-ins, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import common, encdec, lstm, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    defs: Any                                    # ParamDef tree
    loss_fn: Callable                            # (params, batch) -> loss, m
    forward: Callable
    init_cache: Optional[Callable]               # (batch, max_len) -> caches
    decode_step: Optional[Callable]              # (params, caches, tok, pos)
    prefill: Optional[Callable] = None

    def init(self, key: jax.Array, dtype=jnp.float32):
        return common.tree_init(self.defs, key, dtype)

    def abstract_params(self, dtype=jnp.float32):
        return common.tree_abstract(self.defs, dtype)

    def param_pspecs(self, rules: Dict):
        return common.tree_pspecs(self.defs, rules)


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "lstm":
        return Model(
            cfg=cfg, defs=lstm.lstm_defs(cfg),
            loss_fn=lambda p, b, **kw: lstm.loss_fn(p, b, cfg, **kw),
            forward=lambda p, b, **kw: lstm.forward(p, b["tokens"], cfg,
                                                    **kw),
            init_cache=None, decode_step=None)
    if cfg.is_encoder_decoder:
        return Model(
            cfg=cfg, defs=encdec.encdec_defs(cfg),
            loss_fn=lambda p, b, **kw: encdec.loss_fn(p, b, cfg, **kw),
            forward=lambda p, b, **kw: encdec.forward(
                p, b["frames"], b["tokens"], cfg, **kw),
            init_cache=lambda batch, max_len, dtype=jnp.bfloat16:
                encdec.init_cache(cfg, batch, max_len, dtype),
            decode_step=lambda p, c, t, pos, **kw:
                encdec.decode_step(p, c, t, pos, cfg, **kw),
            prefill=lambda p, b, **kw: encdec.prefill(p, b["frames"], cfg,
                                                      **kw))
    return Model(
        cfg=cfg, defs=transformer.lm_defs(cfg),
        loss_fn=lambda p, b, **kw: transformer.loss_fn(p, b, cfg, **kw),
        forward=lambda p, b, **kw: transformer.forward(
            p, b["tokens"], cfg, embeds=b.get("embeds"), **kw),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16:
            transformer.init_cache(cfg, batch, max_len, dtype),
        decode_step=lambda p, c, t, pos, **kw:
            transformer.decode_step(p, c, t, pos, cfg, **kw),
        prefill=lambda p, b, **kw: transformer.forward(
            p, b["tokens"], cfg, embeds=b.get("embeds"),
            caches=transformer.init_cache(
                cfg, b["tokens"].shape[0], b["tokens"].shape[1]), **kw)[1])


def input_specs(cfg: ArchConfig, cell: ShapeCell,
                dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell
    (dry-run: weak-type-correct, shardable, no device allocation)."""
    b, s = cell.global_batch, cell.seq_len
    act = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cell.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.is_encoder_decoder:
        d = min(cfg.decoder_len, s)
        specs = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), act),
            "tokens": jax.ShapeDtypeStruct((b, d), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, d), jnp.int32),
        }
        if cell.kind == "prefill":
            specs.pop("labels")
        return specs
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.frontend == "vision_stub" and cfg.n_patch_tokens:
        specs["embeds"] = jax.ShapeDtypeStruct(
            (b, min(cfg.n_patch_tokens, s), cfg.d_model), act)
    if cell.kind == "prefill":
        specs.pop("labels")
    return specs
