"""Shared model machinery: parameter definitions with logical sharding axes,
norms, positions, and the chunked (flash-semantics) attention used by every
arch on the XLA path.

Parameter handling follows the single-source-of-truth pattern: a model is
described once as a pytree of `ParamDef`s (shape + logical axes + init);
from it we derive (a) materialized params, (b) `jax.ShapeDtypeStruct`
abstract params for the dry-run, (c) `PartitionSpec`s via the logical-axis
rules emitted by the DeepFlow planner (repro.core.planner.ShardingPlan).

Logical axes used by params:
    layers   scan-stacked layer axis (never sharded)
    vocab    embedding/logits vocabulary dim        -> model
    fsdp     the weight dim sharded ZeRO-3-style    -> data (big archs)
    heads    attention projection out dim           -> model
    mlp      ffn hidden                             -> model
    experts  MoE expert axis                        -> model (EP)
and by activations:
    batch -> (pod, data);  act_seq, act_embed -> replicated;
    act_heads -> model;  kv_seq -> model only under SP (long_500k).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# ParamDef machinery
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones
    scale: Optional[float] = None   # default: 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_init(defs, key: jax.Array, dtype=jnp.float32):
    """Materialize a ParamDef tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, max(len(leaves), 1))

    def mk(d: ParamDef, k):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
        scale = d.scale if d.scale is not None else fan_in ** -0.5
        return (jax.random.normal(k, d.shape, jnp.float32) * scale
                ).astype(dtype)

    return treedef.unflatten([mk(d, k) for d, k in zip(leaves, keys)])


def tree_abstract(defs, dtype=jnp.float32):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)


def tree_pspecs(defs, rules: Dict[str, Optional[Tuple[str, ...]]]):
    """ParamDef tree -> PartitionSpec tree via logical-axis rules."""
    def spec(d: ParamDef):
        parts = []
        for ax in d.axes:
            r = rules.get(ax) if ax is not None else None
            parts.append(r if r is None or isinstance(r, str) else tuple(r))
        return P(*parts)
    return jax.tree.map(spec, defs, is_leaf=is_def)


def rules_from_plan(plan_rules) -> Dict[str, Optional[Tuple[str, ...]]]:
    base = {k: v for k, v in plan_rules}
    # param-axis defaults derived from the activation rules
    base.setdefault("layers", None)
    base.setdefault("fsdp", base.get("batch") and ("data",) or None)
    base.setdefault("act_heads", base.get("heads"))
    base.setdefault("act_embed", None)
    base.setdefault("act_seq", None)
    return base


def logical(x: jax.Array, axes: Tuple[Optional[str], ...],
            rules: Optional[Dict] = None, mesh=None) -> jax.Array:
    """Activation sharding constraint by logical axes; no-op without rules."""
    if rules is None or mesh is None:
        return x
    parts = []
    used = set()
    for ax in axes:
        r = rules.get(ax) if ax is not None else None
        if isinstance(r, str):
            r = (r,)
        if r is not None:
            # drop mesh axes the current mesh doesn't have or that an
            # earlier dim already claimed (SP variants remap act_seq)
            r = tuple(a for a in r if a in mesh.axis_names
                      and a not in used) or None
            if r:
                used.update(r)
        parts.append(r)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*parts)))


# ---------------------------------------------------------------------------
# Norms / positions / activations
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(
        jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
            ).astype(x.dtype)


def norm(kind: str, x, p) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def norm_defs(kind: str, d: int) -> Dict[str, ParamDef]:
    if kind == "rmsnorm":
        return {"scale": ParamDef((d,), (None,), init="zeros")}
    return {"scale": ParamDef((d,), (None,), init="ones"),
            "bias": ParamDef((d,), (None,), init="zeros")}


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


def activation(kind: str, x: jax.Array) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(x)
    return jax.nn.gelu(x, approximate=True)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def mask_padded_vocab(logits: jax.Array, vocab: int) -> jax.Array:
    """Set the padded vocab slots (vocab..padded) to -inf so CE/argmax
    never see them; keeps the padded (shardable) shape."""
    pad = logits.shape[-1] - vocab
    if pad <= 0:
        return logits
    return jnp.concatenate(
        [logits[..., :vocab],
         jnp.full(logits.shape[:-1] + (pad,), -1e30, logits.dtype)],
        axis=-1)


# ---------------------------------------------------------------------------
# Chunked attention (flash semantics in pure jnp — the XLA/dry-run path)
# ---------------------------------------------------------------------------


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, window: Optional[int] = None,
                      q_offset: int = 0,
                      kv_len: Optional[jax.Array] = None,
                      q_chunk: int = 1024, kv_chunk: int = 1024) -> jax.Array:
    """Online-softmax attention without materializing (sq, skv).

    q: (b, h, sq, d); k/v: (b, h_kv, skv, d). `q_offset` is the absolute
    position of q[0] (decode: cache length); `kv_len` (scalar array) masks
    cache positions >= kv_len. Memory: O(q_chunk * kv_chunk) per (b, h).
    """
    b, h, sq, d = q.shape
    _, h_kv, skv, _ = k.shape
    group = h // h_kv
    scale = d ** -0.5
    qc = min(q_chunk, sq)
    while sq % qc:
        qc -= 1
    kc = min(kv_chunk, skv)
    while skv % kc:
        kc -= 1
    n_q, n_k = sq // qc, skv // kc

    q = q.reshape(b, h_kv, group, sq, d)

    def kv_step(carry, kv_idx):
        acc, m, l, q_blk, q_pos = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, kv_idx * kc, kc, axis=2)
        v_blk = jax.lax.dynamic_slice_in_dim(v, kv_idx * kc, kc, axis=2)
        s = jnp.einsum("bgGqd,bgkd->bgGqk", q_blk.astype(jnp.float32),
                       k_blk.astype(jnp.float32)) * scale
        k_pos = kv_idx * kc + jnp.arange(kc)
        mask = jnp.ones((qc, kc), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        if kv_len is not None:
            mask &= (k_pos < kv_len)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bgGqk,bgkd->bgGqd", p,
                                      v_blk.astype(jnp.float32))
        return (acc, m_new, l, q_blk, q_pos), None

    def q_step(q_idx):
        q_blk = jax.lax.dynamic_slice_in_dim(q, q_idx * qc, qc, axis=3)
        q_pos = q_offset + q_idx * qc + jnp.arange(qc)
        acc0 = jnp.zeros((b, h_kv, group, qc, d), jnp.float32)
        m0 = jnp.full((b, h_kv, group, qc, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h_kv, group, qc, 1), jnp.float32)
        (acc, _, l, _, _), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0, q_blk, q_pos), jnp.arange(n_k))
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    if n_q == 1:
        out = q_step(0)
    else:
        outs = jax.lax.map(q_step, jnp.arange(n_q))  # (n_q, b, hkv, g, qc, d)
        out = jnp.moveaxis(outs, 0, 3).reshape(b, h_kv, group, sq, d)
    return out.reshape(b, h, sq, d)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0) -> jax.Array:
    """Mean next-token CE; logits (..., vocab) f32-safe, labels int (...,)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1).squeeze(-1)
    loss = jnp.mean(lse - picked)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
