"""repro.models — the real JAX model zoo for the 10 assigned architectures
plus the paper's LSTM LM, built from one composable layer library."""

from repro.models.model import Model, build_model, input_specs
