"""Whisper-style encoder-decoder transformer backbone.

The conv/mel audio frontend is a STUB per the assignment: the encoder
consumes precomputed (batch, frames, d_model) frame embeddings from
`input_specs()`. Sinusoidal positions on both sides (whisper uses
sinusoidal enc / learned dec — deviation noted in DESIGN.md). Decoder =
causal self-attention + cross-attention + FFN; cross K/V are computed once
at prefill and cached.

Layer stacks scan over stacked params like repro.models.transformer.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.common import ParamDef
from repro.models.transformer import attention_apply, attention_defs, \
    ffn_apply, ffn_defs, stack_defs, _adtype


def _enc_block_defs(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    return {"ln1": common.norm_defs(cfg.norm_kind, d),
            "attn": attention_defs(cfg),
            "ln2": common.norm_defs(cfg.norm_kind, d),
            "ffn": ffn_defs(cfg)}


def _dec_block_defs(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    return {"ln1": common.norm_defs(cfg.norm_kind, d),
            "self": attention_defs(cfg),
            "lnx": common.norm_defs(cfg.norm_kind, d),
            "cross": attention_defs(cfg),
            "ln2": common.norm_defs(cfg.norm_kind, d),
            "ffn": ffn_defs(cfg)}


def encdec_defs(cfg: ArchConfig) -> Dict:
    return {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "fsdp"),
                          scale=0.02),
        "enc": stack_defs(_enc_block_defs(cfg), cfg.n_encoder_layers),
        "enc_norm": common.norm_defs(cfg.norm_kind, cfg.d_model),
        "dec": stack_defs(_dec_block_defs(cfg), cfg.n_layers),
        "dec_norm": common.norm_defs(cfg.norm_kind, cfg.d_model),
    }


def _enc_block(p, x, cfg, rules, mesh):
    h = common.norm(cfg.norm_kind, x, p["ln1"])
    a, _ = attention_apply(p["attn"], h, cfg, causal=False, rules=rules,
                           mesh=mesh)
    x = x + a
    h = common.norm(cfg.norm_kind, x, p["ln2"])
    return x + ffn_apply(p["ffn"], h, cfg, rules, mesh)


def encode(params: Dict, frames: jax.Array, cfg: ArchConfig, *,
           rules=None, mesh=None) -> jax.Array:
    x = frames.astype(_adtype(cfg))
    x = x + common.sinusoidal_positions(x.shape[1], cfg.d_model
                                        ).astype(x.dtype)[None]
    x = common.logical(x, ("batch", "act_seq", "act_embed"), rules, mesh)

    def body(x, lp):
        return _enc_block(lp, x, cfg, rules, mesh), 0

    x, _ = jax.lax.scan(body, x, params["enc"])
    return common.norm(cfg.norm_kind, x, params["enc_norm"])


def _dec_block(p, x, cfg, enc_out, self_cache, cross_cache, pos, rules,
               mesh):
    h = common.norm(cfg.norm_kind, x, p["ln1"])
    a, new_self = attention_apply(p["self"], h, cfg, causal=True,
                                  cache=self_cache, pos=pos, rules=rules,
                                  mesh=mesh)
    x = x + a
    h = common.norm(cfg.norm_kind, x, p["lnx"])
    a, new_cross = attention_apply(p["cross"], h, cfg, causal=False,
                                   kv_source=enc_out, cache=cross_cache,
                                   cross_cache_only=enc_out is None,
                                   rules=rules, mesh=mesh)
    x = x + a
    h = common.norm(cfg.norm_kind, x, p["ln2"])
    return x + ffn_apply(p["ffn"], h, cfg, rules, mesh), new_self, new_cross


def _embed_tokens(params, cfg, tokens, pos0: int = 0):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_adtype(cfg))
    pe = common.sinusoidal_positions(pos0 + tokens.shape[1], cfg.d_model)
    return x + pe[pos0:pos0 + tokens.shape[1]].astype(x.dtype)[None]


def forward(params: Dict, frames: jax.Array, tokens: jax.Array,
            cfg: ArchConfig, *, rules=None, mesh=None, remat: bool = False
            ) -> jax.Array:
    """Training forward: (frame embeds, decoder tokens) -> logits."""
    enc_out = encode(params, frames, cfg, rules=rules, mesh=mesh)
    x = _embed_tokens(params, cfg, tokens)
    x = common.logical(x, ("batch", "act_seq", "act_embed"), rules, mesh)

    def body(x, lp):
        y, _, _ = _dec_block(lp, x, cfg, enc_out, None, None, None, rules,
                             mesh)
        return y, 0

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = common.norm(cfg.norm_kind, x, params["dec_norm"])
    logits = common.mask_padded_vocab(
        (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32),
        cfg.vocab_size)
    return common.logical(logits, ("batch", "act_seq", "vocab"), rules, mesh)


def init_cache(cfg: ArchConfig, batch: int, enc_len: int,
               dtype=jnp.bfloat16) -> Dict:
    nkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    L, S = cfg.n_layers, cfg.decoder_len
    return {
        "self": {"k": jnp.zeros((L, batch, nkv, S, hd), dtype),
                 "v": jnp.zeros((L, batch, nkv, S, hd), dtype)},
        "cross": {"k": jnp.zeros((L, batch, nkv, enc_len, hd), dtype),
                  "v": jnp.zeros((L, batch, nkv, enc_len, hd), dtype)},
    }


def prefill(params: Dict, frames: jax.Array, cfg: ArchConfig, *,
            rules=None, mesh=None, dtype=jnp.bfloat16) -> Dict:
    """Encode + precompute per-layer cross K/V; empty self caches."""
    enc_out = encode(params, frames, cfg, rules=rules, mesh=mesh)
    b = frames.shape[0]
    caches = init_cache(cfg, b, frames.shape[1], dtype)

    def body(_, lp):
        nkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        k = (enc_out @ lp["cross"]["wk"].astype(enc_out.dtype)
             + (lp["cross"].get("bk", jnp.zeros(())).astype(enc_out.dtype)))
        v = (enc_out @ lp["cross"]["wv"].astype(enc_out.dtype)
             + (lp["cross"].get("bv", jnp.zeros(())).astype(enc_out.dtype)))
        k = k.reshape(b, -1, nkv, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, -1, nkv, hd).transpose(0, 2, 1, 3)
        return 0, {"k": k.astype(dtype), "v": v.astype(dtype)}

    _, cross = jax.lax.scan(body, 0, params["dec"])
    caches["cross"] = cross
    return caches


def decode_step(params: Dict, caches: Dict, tokens: jax.Array,
                pos: jax.Array, cfg: ArchConfig, *, rules=None, mesh=None
                ) -> Tuple[jax.Array, Dict]:
    """One decoder token against self cache (<= decoder_len) + cross cache."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(_adtype(cfg))
    pe = common.sinusoidal_positions(cfg.decoder_len, cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(pe, pos, 1, axis=0
                                         ).astype(x.dtype)[None]

    def body(x, scanned):
        lp, sc, cc = scanned
        y, new_self, _ = _dec_block(lp, x, cfg, None, sc, cc, pos, rules,
                                    mesh)
        return y, new_self

    x, new_self = jax.lax.scan(
        body, x, (params["dec"], caches["self"], caches["cross"]))
    caches = dict(caches)
    caches["self"] = new_self
    x = common.norm(cfg.norm_kind, x, params["dec_norm"])
    logits = common.mask_padded_vocab(
        (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32),
        cfg.vocab_size)
    return logits, caches


def loss_fn(params: Dict, batch: Dict, cfg: ArchConfig, *, rules=None,
            mesh=None, remat: bool = False):
    logits = forward(params, batch["frames"], batch["tokens"], cfg,
                     rules=rules, mesh=mesh, remat=remat)
    ce = common.cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}
