"""Decoder-only LM covering the dense / GQA / local-global / MoE / hybrid
(RG-LRU) / xLSTM families through one composable block dispatcher.

Layer stacking is MaxText-style `jax.lax.scan` over *pattern groups*: the
effective per-layer kind sequence has period
lcm(|block_pattern|, |attn_pattern|); per-group params are stacked along a
leading `layers` axis and scanned (one compiled body regardless of depth —
88-layer mistral compiles the same body once). A partial remainder group
(gemma3: 62 = 6*10 + 2) is applied explicitly.

Each model exposes:
    defs(cfg)                      ParamDef tree (single source of truth)
    forward(params, batch, ...)    logits (train / prefill; optional caches)
    init_cache(cfg, batch, len)    decode caches / recurrent states
    decode_step(params, cache, tokens, pos)
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common, moe as moe_lib, rglru as rglru_lib, \
    xlstm as xlstm_lib
from repro.models.common import ParamDef

# ---------------------------------------------------------------------------
# pattern machinery
# ---------------------------------------------------------------------------


def effective_pattern(cfg: ArchConfig) -> List[Tuple[str, str]]:
    """Per-layer (block_kind, attn_kind) with the combined period."""
    period = len(cfg.block_pattern)
    if "attn" in cfg.block_pattern:
        period = math.lcm(period, len(cfg.attn_pattern))
    period = min(period, cfg.n_layers)
    return [(cfg.block_kind(i),
             cfg.attn_kind(i) if cfg.block_kind(i) == "attn" else "-")
            for i in range(period)]


def group_layout(cfg: ArchConfig) -> Tuple[List[Tuple[str, str]], int, int]:
    """(pattern, n_full_groups, n_remainder_layers)."""
    if cfg.n_layers == 0:          # dry-run probe variant (scan-correction)
        return [], 0, 0
    pat = effective_pattern(cfg)
    return pat, cfg.n_layers // len(pat), cfg.n_layers % len(pat)


def stack_defs(defs, n: int):
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes,
                           init=d.init, scale=d.scale),
        defs, is_leaf=common.is_def)


# ---------------------------------------------------------------------------
# attention / ffn blocks
# ---------------------------------------------------------------------------


def attention_defs(cfg: ArchConfig) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    defs = {
        "wq": ParamDef((d, nh * hd), ("fsdp", "heads")),
        "wk": ParamDef((d, nkv * hd), ("fsdp", "heads")),
        "wv": ParamDef((d, nkv * hd), ("fsdp", "heads")),
        "wo": ParamDef((nh * hd, d), ("heads", "fsdp")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((nh * hd,), ("heads",), init="zeros")
        defs["bk"] = ParamDef((nkv * hd,), ("heads",), init="zeros")
        defs["bv"] = ParamDef((nkv * hd,), ("heads",), init="zeros")
    return defs


def _proj(x, w, b=None):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def attention_apply(p: Dict, x: jax.Array, cfg: ArchConfig, *,
                    causal: bool = True, window: Optional[int] = None,
                    cache: Optional[Dict] = None,
                    pos: Optional[jax.Array] = None,
                    kv_source: Optional[jax.Array] = None,
                    cross_cache_only: bool = False,
                    rules=None, mesh=None
                    ) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (b, s, d). Modes:
      train:    cache=None                          -> (out, None)
      prefill:  cache={k,v empty (b,nkv,S,hd)}      -> (out, filled cache)
      decode:   cache filled, pos = current length  -> (out, updated cache)
      cross:    kv_source = encoder states; cross_cache_only reads the
                precomputed cross K/V without reprojecting (decode)
    """
    b, s, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = _proj(x, p["wq"], p.get("bq")).reshape(b, s, nh, hd)
    if cross_cache_only:
        assert cache is not None
        q = q.transpose(0, 2, 1, 3)
        out = common.chunked_attention(
            q, cache["k"].astype(x.dtype), cache["v"].astype(x.dtype),
            causal=False)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
        return _proj(out, p["wo"]), cache
    src = kv_source if kv_source is not None else x
    k = _proj(src, p["wk"], p.get("bk")).reshape(b, src.shape[1], nkv, hd)
    v = _proj(src, p["wv"], p.get("bv")).reshape(b, src.shape[1], nkv, hd)

    if cfg.rope_theta:
        qpos = (jnp.arange(s) if pos is None
                else pos + jnp.arange(s))
        kpos = jnp.arange(src.shape[1]) if pos is None else qpos
        q = common.rope(q, jnp.broadcast_to(qpos, (b, s)), cfg.rope_theta)
        k = common.rope(k, jnp.broadcast_to(kpos, (b, k.shape[1])),
                        cfg.rope_theta)

    q = q.transpose(0, 2, 1, 3)                       # (b, nh, s, hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    q = common.logical(q, ("batch", "act_heads", "act_seq", None),
                       rules, mesh)

    new_cache = None
    kv_len = None
    q_off = 0
    if cache is not None and kv_source is None:
        if pos is None:                                # prefill: write [0:s]
            W = cache["k"].shape[2]
            kk, vv = k, v
            if W < kk.shape[2]:                        # local ring: tail only
                kk, vv = kk[:, :, -W:], vv[:, :, -W:]
                # slot of absolute position p is p % W: place the tail so
                # decode's `pos % W` indexing continues consistently
                shift = (kk.shape[2] and (k.shape[2] - W) % W)
                kk = jnp.roll(kk, shift, axis=2)
                vv = jnp.roll(vv, shift, axis=2)
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], kk.astype(cache["k"].dtype), 0, axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vv.astype(cache["v"].dtype), 0, axis=2)
            new_cache = {"k": ck, "v": cv}
            # attention over just the fresh kv (standard causal prefill)
        else:                                          # decode: write at pos
            # Ring-buffer write: local-attention layers keep only a
            # window-sized cache (W < max_len) and wrap; softmax is
            # permutation-invariant so slot order inside the ring is
            # irrelevant — only validity (kv_len) matters. Full caches
            # (W == max_len) reduce to the ordinary absolute write.
            W = cache["k"].shape[2]
            wpos = pos % W
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, wpos, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, wpos, 0))
            new_cache = {"k": ck, "v": cv}
            k, v = ck.astype(x.dtype), cv.astype(x.dtype)
            kv_len = jnp.minimum(pos + 1, W)
            q_off = pos
            causal = False                 # ring entries are all <= pos
            window = None                  # the ring IS the window
    elif kv_source is not None and cache is not None:
        # cross-attention with precomputed encoder kv
        k, v = cache["k"].astype(x.dtype), cache["v"].astype(x.dtype)
        new_cache = cache

    out = common.chunked_attention(
        q, k, v, causal=causal and kv_source is None, window=window,
        q_offset=q_off, kv_len=kv_len)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    return _proj(out, p["wo"]), new_cache


def ffn_defs(cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    mult = 2 if cfg.ffn_kind == "swiglu" else 1
    return {"wi": ParamDef((d, mult * f), ("fsdp", "mlp")),
            "wo": ParamDef((f, d), ("mlp", "fsdp"))}


def ffn_apply(p: Dict, x: jax.Array, cfg: ArchConfig,
              rules=None, mesh=None) -> jax.Array:
    h = x @ p["wi"].astype(x.dtype)
    h = common.logical(h, ("batch", "act_seq", "mlp"), rules, mesh)
    if cfg.ffn_kind == "swiglu":
        u, g = jnp.split(h, 2, axis=-1)
        h = common.activation("swiglu", g) * u
    else:
        h = common.activation(cfg.ffn_kind, h)
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# block dispatch
# ---------------------------------------------------------------------------


def block_defs(cfg: ArchConfig, kind: str, attn_kind: str) -> Dict:
    d = cfg.d_model
    if kind == "attn":
        defs = {"ln1": common.norm_defs(cfg.norm_kind, d),
                "attn": attention_defs(cfg),
                "ln2": common.norm_defs(cfg.norm_kind, d)}
        defs["moe" if cfg.is_moe else "ffn"] = (
            moe_lib.moe_defs(cfg) if cfg.is_moe else ffn_defs(cfg))
        return defs
    if kind == "rglru":
        return {"ln1": common.norm_defs(cfg.norm_kind, d),
                "rec": rglru_lib.rglru_defs(cfg),
                "ln2": common.norm_defs(cfg.norm_kind, d),
                "ffn": ffn_defs(cfg)}
    if kind == "mlstm":
        return {"ln1": common.norm_defs(cfg.norm_kind, d),
                "mlstm": xlstm_lib.mlstm_defs(cfg)}
    if kind == "slstm":
        return {"ln1": common.norm_defs(cfg.norm_kind, d),
                "slstm": xlstm_lib.slstm_defs(cfg)}
    raise ValueError(kind)


def block_cache(cfg: ArchConfig, kind: str, attn_kind: str, batch: int,
                max_len: int, dtype=jnp.bfloat16) -> Optional[Dict]:
    nkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if kind == "attn":
        # local-attention layers keep a ring buffer of exactly the window
        # (attention_apply wraps the write position) — at long_500k this
        # shrinks gemma3's cache ~6x vs naive full-length caches.
        s = min(max_len, cfg.local_window) if attn_kind == "local" \
            else max_len
        return {"k": jnp.zeros((batch, nkv, s, hd), dtype),
                "v": jnp.zeros((batch, nkv, s, hd), dtype)}
    if kind == "rglru":
        return rglru_lib.rglru_init_state(cfg, batch)
    if kind == "mlstm":
        return xlstm_lib.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return xlstm_lib.slstm_init_state(cfg, batch)
    raise ValueError(kind)


def block_apply(p: Dict, x: jax.Array, cfg: ArchConfig, kind: str,
                attn_kind: str, *, cache=None, pos=None, rules=None,
                mesh=None) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    window = cfg.local_window if attn_kind == "local" else None
    if kind == "attn":
        h = common.norm(cfg.norm_kind, x, p["ln1"])
        a, new_cache = attention_apply(p["attn"], h, cfg, causal=True,
                                       window=window, cache=cache, pos=pos,
                                       rules=rules, mesh=mesh)
        x = x + a
        h = common.norm(cfg.norm_kind, x, p["ln2"])
        if cfg.is_moe:
            f, aux = moe_lib.moe_apply(p["moe"], h, cfg, rules, mesh)
        else:
            f = ffn_apply(p["ffn"], h, cfg, rules, mesh)
        x = x + f
        # residual-stream anchor: under the SP rule (act_seq -> model) the
        # o/down-proj psums lower to reduce-scatter + all-gather instead
        x = common.logical(x, ("batch", "act_seq", "act_embed"), rules, mesh)
        return x, new_cache, aux
    if kind == "rglru":
        h = common.norm(cfg.norm_kind, x, p["ln1"])
        if pos is None and cache is None:                  # train
            r, new_cache = rglru_lib.rglru_apply(p["rec"], h, cfg), None
        elif pos is None:                                  # prefill
            r, new_cache = rglru_lib.rglru_apply(p["rec"], h, cfg,
                                                 return_state=True)
        else:                                              # decode
            r, new_cache = rglru_lib.rglru_decode(p["rec"], h, cache, cfg)
        x = x + r
        h = common.norm(cfg.norm_kind, x, p["ln2"])
        return x + ffn_apply(p["ffn"], h, cfg, rules, mesh), new_cache, aux
    if kind == "mlstm":
        h = common.norm(cfg.norm_kind, x, p["ln1"])
        if pos is None:
            r = xlstm_lib.mlstm_apply(p["mlstm"], h, cfg)
            new_cache = (xlstm_lib.mlstm_prefill_state(p["mlstm"], h, cfg)
                         if cache is not None else None)
        else:
            r, new_cache = xlstm_lib.mlstm_decode(p["mlstm"], h, cache, cfg)
        return x + r, new_cache, aux
    if kind == "slstm":
        h = common.norm(cfg.norm_kind, x, p["ln1"])
        if pos is None and cache is None:                  # train
            r, new_cache = xlstm_lib.slstm_apply(p["slstm"], h, cfg), None
        elif pos is None:                                  # prefill
            r, new_cache = xlstm_lib.slstm_apply(p["slstm"], h, cfg,
                                                 return_state=True)
        else:
            r, new_cache = xlstm_lib.slstm_decode(p["slstm"], h, cache, cfg)
        return x + r, new_cache, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# full LM
# ---------------------------------------------------------------------------


def lm_defs(cfg: ArchConfig) -> Dict:
    pat, n_groups, rem = group_layout(cfg)
    group = {f"b{j}": block_defs(cfg, bk, ak)
             for j, (bk, ak) in enumerate(pat)}
    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "fsdp"),
                          scale=0.02),
        "final_norm": common.norm_defs(cfg.norm_kind, cfg.d_model),
    }
    if n_groups:
        defs["groups"] = stack_defs(group, n_groups)
    if rem:
        defs["rem"] = {f"b{j}": block_defs(cfg, *pat[j]) for j in range(rem)}
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((cfg.d_model, cfg.padded_vocab),
                                ("fsdp", "vocab"))
    return defs


def _embed(params, cfg, tokens, embeds=None, rules=None, mesh=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_adtype(cfg))
    if cfg.family in ("dense", "moe", "hybrid"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if embeds is not None:                    # vlm/audio stub front-end
        n = embeds.shape[1]
        x = jnp.concatenate([embeds.astype(x.dtype), x[:, n:]], axis=1)
    return common.logical(x, ("batch", "act_seq", "act_embed"), rules, mesh)


def _head(params, cfg, x):
    w = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = x @ w.astype(x.dtype)
    logits = common.softcap(logits.astype(jnp.float32), cfg.logits_softcap)
    return common.mask_padded_vocab(logits, cfg.vocab_size)


def _adtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def forward(params: Dict, tokens: jax.Array, cfg: ArchConfig, *,
            embeds: Optional[jax.Array] = None, caches: Optional[Dict] = None,
            rules=None, mesh=None, remat: bool = False
            ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Train (caches=None) / prefill (caches=init). Returns
    (logits, caches, aux)."""
    pat, n_groups, rem = group_layout(cfg)
    x = _embed(params, cfg, tokens, embeds, rules, mesh)
    aux_total = jnp.zeros((), jnp.float32)

    def one_group(x, gp, gcache):
        new_caches, aux = {}, jnp.zeros((), jnp.float32)
        for j, (bk, ak) in enumerate(pat):
            c = gcache.get(f"b{j}") if gcache else None
            x, nc, a = block_apply(gp[f"b{j}"], x, cfg, bk, ak, cache=c,
                                   rules=rules, mesh=mesh)
            new_caches[f"b{j}"] = nc
            aux = aux + a
        return x, new_caches, aux

    if remat == "dots":
        one_group = jax.checkpoint(
            one_group, policy=jax.checkpoint_policies.dots_saveable)
    elif remat:
        one_group = jax.checkpoint(one_group)

    if n_groups:
        gcaches = caches["groups"] if caches else None

        def body(carry, scanned):
            x, aux = carry
            gp = scanned[0]
            gc = scanned[1] if gcaches is not None else None
            x, nc, a = one_group(x, gp, gc)
            out = nc if gcaches is not None else 0
            return (x, aux + a), out

        scanned = (params["groups"], gcaches) if gcaches is not None \
            else (params["groups"], jnp.zeros((n_groups,)))
        (x, aux_total), new_g = jax.lax.scan(body, (x, aux_total), scanned)
        if caches is not None:
            caches = dict(caches)
            caches["groups"] = new_g
    if rem:
        rcache = caches.get("rem") if caches else None
        new_r = {}
        for j in range(rem):
            bk, ak = pat[j]
            c = rcache.get(f"b{j}") if rcache else None
            x, nc, a = block_apply(params["rem"][f"b{j}"], x, cfg, bk, ak,
                                   cache=c, rules=rules, mesh=mesh)
            new_r[f"b{j}"] = nc
            aux_total = aux_total + a
        if caches is not None:
            caches["rem"] = new_r

    x = common.norm(cfg.norm_kind, x, params["final_norm"])
    logits = _head(params, cfg, x)
    logits = common.logical(logits, ("batch", "act_seq", "vocab"),
                            rules, mesh)
    return logits, caches, aux_total


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    pat, n_groups, rem = group_layout(cfg)
    out: Dict[str, Any] = {}
    if n_groups:
        group = {}
        for j, (bk, ak) in enumerate(pat):
            c = block_cache(cfg, bk, ak, batch, max_len, dtype)
            group[f"b{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), c)
        out["groups"] = group
    if rem:
        out["rem"] = {f"b{j}": block_cache(cfg, *pat[j], batch, max_len,
                                           dtype) for j in range(rem)}
    return out


def decode_step(params: Dict, caches: Dict, tokens: jax.Array,
                pos: jax.Array, cfg: ArchConfig, *, rules=None, mesh=None
                ) -> Tuple[jax.Array, Dict]:
    """One-token step. tokens: (b, 1) int32; pos: scalar int32 (current
    cache length). Returns (logits (b, 1, vocab), new caches)."""
    pat, n_groups, rem = group_layout(cfg)
    x = _embed(params, cfg, tokens, None, rules, mesh)

    if n_groups:
        def body(x, scanned):
            gp, gc = scanned
            ncs = {}
            for j, (bk, ak) in enumerate(pat):
                x, nc, _ = block_apply(gp[f"b{j}"], x, cfg, bk, ak,
                                       cache=gc[f"b{j}"], pos=pos,
                                       rules=rules, mesh=mesh)
                ncs[f"b{j}"] = nc
            return x, ncs

        x, new_g = jax.lax.scan(body, x, (params["groups"],
                                          caches["groups"]))
        caches = dict(caches)
        caches["groups"] = new_g
    if rem:
        new_r = {}
        for j in range(rem):
            bk, ak = pat[j]
            x, nc, _ = block_apply(params["rem"][f"b{j}"], x, cfg, bk, ak,
                                   cache=caches["rem"][f"b{j}"], pos=pos,
                                   rules=rules, mesh=mesh)
            new_r[f"b{j}"] = nc
        caches["rem"] = new_r

    x = common.norm(cfg.norm_kind, x, params["final_norm"])
    return _head(params, cfg, x), caches


def loss_fn(params: Dict, batch: Dict, cfg: ArchConfig, *, rules=None,
            mesh=None, remat: bool = False) -> Tuple[jax.Array, Dict]:
    logits, _, aux = forward(params, batch["tokens"], cfg,
                             embeds=batch.get("embeds"), rules=rules,
                             mesh=mesh, remat=remat)
    ce = common.cross_entropy(logits, batch["labels"])
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}
