"""Mixture-of-Experts layer: top-k routing, capacity-bounded sort-based
dispatch, expert-parallel sharding over the `experts` logical axis.

Dispatch is the argsort/segment trick (no (T, E) one-hot — that would be
~10^11 elements at train_4k): flatten (token, k) assignments, sort by
expert, compute position-within-expert from segment starts, drop overflow
beyond capacity, scatter into an (E, C, d) buffer, run batched expert
GEMMs, gather back with routing weights. Everything is O(Tk log Tk) index
math + dense einsums, so it lowers cleanly under pjit at 512 devices; XLA
inserts the EP collectives from the sharding annotations (the explicit
shard_map all-to-all variant is a §Perf hillclimb lever).

Auxiliary load-balancing loss follows Switch/GShard: E * Σ_e f_e * p_e.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.common import ParamDef


def moe_defs(cfg: ArchConfig) -> Dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    mult = 2 if cfg.ffn_kind == "swiglu" else 1
    if cfg.moe_impl == "grouped_tp":
        # TP expert weights: per-expert hidden f over the model axis; the
        # expert axis stays unsharded so the grouped dispatch is local
        expert_defs = {
            "wi": ParamDef((e, d, mult * f), (None, "fsdp", "mlp")),
            "wo": ParamDef((e, f, d), (None, "mlp", "fsdp")),
        }
    else:
        # EP owns the model axis; the per-expert f dim is small
        # (768/1408) so it stays unsharded — d rides the fsdp axis
        expert_defs = {
            "wi": ParamDef((e, d, mult * f), ("experts", "fsdp", None)),
            "wo": ParamDef((e, f, d), ("experts", None, "fsdp")),
        }
    defs = {
        "router": {"w": ParamDef((d, e), ("fsdp", None), scale=d ** -0.5)},
        "experts": expert_defs,
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        defs["shared"] = {
            "wi": ParamDef((d, mult * fs), ("fsdp", "mlp")),
            "wo": ParamDef((fs, d), ("mlp", "fsdp")),
        }
    return defs


def _expert_ffn(wi: jax.Array, wo: jax.Array, x: jax.Array,
                ffn_kind: str) -> jax.Array:
    """x: (E, C, d) -> (E, C, d), batched over experts."""
    h = jnp.einsum("ecd,edf->ecf", x, wi)
    if ffn_kind == "swiglu":
        u, g = jnp.split(h, 2, axis=-1)
        h = common.activation("swiglu", g) * u
    else:
        h = common.activation(ffn_kind, h)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _grouped_dispatch(params: Dict, x: jax.Array, cfg: ArchConfig,
                      rules=None, mesh=None) -> Tuple[jax.Array, jax.Array]:
    """grouped_tp dispatch (§Perf hillclimb): tokens are processed in G
    groups aligned with the DP shards; top-k / capacity / scatter / gather
    are all *group-local* (leading G dim sharded over data), so GSPMD never
    crosses shards for the dispatch — the pathological scatter all-reduce
    of the baseline disappears. Expert weights are TP-sharded on their
    hidden f dim; the only collective left is the down-projection psum."""
    b, s, d = x.shape
    t = b * s
    kk, e = cfg.experts_per_token, cfg.n_experts
    g = cfg.moe_groups
    if not g:
        g = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1) \
            if mesh is not None else 1
    g = max(min(g, t), 1)
    while t % g:
        g -= 1
    tl = t // g                                     # tokens per group
    xt = x.reshape(g, tl, d)
    xt = common.logical(xt, ("batch", None, None), rules, mesh)

    logits = jnp.einsum("gtd,de->gte", xt,
                        params["router"]["w"].astype(x.dtype)
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, kk)           # (g, tl, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    cap = int(max(cfg.capacity_factor * tl * kk / e, 4))
    cap = -(-cap // 4) * 4
    flat_e = topi.reshape(g, tl * kk)
    flat_t = jnp.arange(tl * kk) // kk              # group-local token ids

    def dispatch_one(fe):                           # per-group index math
        order = jnp.argsort(fe, stable=True)
        se = fe[order]
        seg = jnp.searchsorted(se, jnp.arange(e), side="left")
        pos = jnp.arange(tl * kk) - seg[se]
        keep = pos < cap
        slot = jnp.where(keep, se * cap + pos, e * cap)
        return order, slot, keep

    order, slot, keep = jax.vmap(dispatch_one)(flat_e)

    buf = jnp.zeros((g, e * cap + 1, d), x.dtype)
    src = jnp.take_along_axis(
        xt, jnp.take_along_axis(flat_t[None].repeat(g, 0), order,
                                axis=1)[..., None], axis=1)
    buf = jax.vmap(lambda bb, ss, vv: bb.at[ss].set(vv, mode="drop"))(
        buf, slot, src)
    buf = buf[:, :-1].reshape(g, e, cap, d)
    buf = common.logical(buf, ("batch", None, None, None), rules, mesh)

    wi = params["experts"]["wi"].astype(x.dtype)    # (e, d, mult*f) f->model
    wo = params["experts"]["wo"].astype(x.dtype)
    h = jnp.einsum("gecd,edf->gecf", buf, wi)
    h = common.logical(h, ("batch", None, None, "mlp"), rules, mesh)
    if cfg.ffn_kind == "swiglu":
        u, gg = jnp.split(h, 2, axis=-1)
        h = common.activation("swiglu", gg) * u
    else:
        h = common.activation(cfg.ffn_kind, h)
    out_buf = jnp.einsum("gecf,efd->gecd", h, wo)   # psum over f (model)
    out_buf = common.logical(out_buf, ("batch", None, None, None), rules,
                             mesh)

    flat_out = out_buf.reshape(g, e * cap, d)
    safe_slot = jnp.clip(slot, 0, e * cap - 1)
    gathered = jnp.take_along_axis(flat_out, safe_slot[..., None], axis=1)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    unsort = jax.vmap(lambda acc, o, v: acc.at[o].set(v))(
        jnp.zeros((g, tl * kk, d), x.dtype), order, gathered)
    unsort = unsort.reshape(g, tl, kk, d)
    out = jnp.einsum("gtkd,gtk->gtd", unsort, topw.astype(x.dtype))

    if cfg.n_shared_experts:
        sh = params["shared"]
        h = xt @ sh["wi"].astype(x.dtype)
        if cfg.ffn_kind == "swiglu":
            u, gg = jnp.split(h, 2, axis=-1)
            h = common.activation("swiglu", gg) * u
        else:
            h = common.activation(cfg.ffn_kind, h)
        out = out + h @ sh["wo"].astype(x.dtype)

    density = jax.vmap(lambda fe: jnp.zeros((e,), jnp.float32)
                       .at[fe].add(1.0))(flat_e).sum(0) / (t * kk)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(density * mean_prob)
    return out.reshape(b, s, d), aux


def moe_apply(params: Dict, x: jax.Array, cfg: ArchConfig,
              rules=None, mesh=None) -> Tuple[jax.Array, jax.Array]:
    """x: (batch, seq, d) -> (out, aux_loss)."""
    if cfg.moe_impl == "grouped_tp":
        return _grouped_dispatch(params, x, cfg, rules, mesh)
    b, s, d = x.shape
    t = b * s
    kk, e = cfg.experts_per_token, cfg.n_experts
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, params["router"]["w"].astype(x.dtype)
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, kk)              # (t, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # ---- capacity-bounded sort dispatch ---------------------------------
    cap = int(max(cfg.capacity_factor * t * kk / e, 8))
    cap = -(-cap // 8) * 8                              # pad to lanes
    flat_e = topi.reshape(-1)                           # (t*k,)
    flat_t = jnp.arange(t * kk) // kk                   # source token ids
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position within expert: index in sorted order minus segment start
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(t * kk) - seg_start[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # drop -> pad

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xt[flat_t[order]], mode="drop")
    buf = buf[:-1].reshape(e, cap, d)
    buf = common.logical(buf, ("experts", "batch", None), rules, mesh)

    out_buf = _expert_ffn(params["experts"]["wi"].astype(x.dtype),
                          params["experts"]["wo"].astype(x.dtype),
                          buf, cfg.ffn_kind)
    out_buf = common.logical(out_buf, ("experts", "batch", None), rules, mesh)

    # ---- combine ---------------------------------------------------------
    flat_out = out_buf.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None],
                         flat_out[jnp.clip(slot, 0, e * cap - 1)], 0.0)
    # scatter-add back to (t, k) order then weight
    unsort = jnp.zeros((t * kk, d), x.dtype).at[order].set(gathered)
    unsort = unsort.reshape(t, kk, d)
    out = jnp.einsum("tkd,tk->td", unsort, topw.astype(x.dtype))

    if cfg.n_shared_experts:
        sh = params["shared"]
        h = xt @ sh["wi"].astype(x.dtype)
        if cfg.ffn_kind == "swiglu":
            u, g = jnp.split(h, 2, axis=-1)
            h = common.activation("swiglu", g) * u
        else:
            h = common.activation(cfg.ffn_kind, h)
        out = out + h @ sh["wo"].astype(x.dtype)

    # ---- aux load-balance loss (Switch) ----------------------------------
    density = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * kk)
    mean_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * mean_prob)
    return out.reshape(b, s, d), aux
