"""Pallas TPU kernels for the perf-critical compute layers.

  gemm.py             block-tiled GEMM; BlockSpec (bm, bn, bk) comes from
                      CrossFlow's hierarchical-roofline tiling search
  flash_attention.py  blocked online-softmax attention (causal/local/GQA)
  rglru.py            RG-LRU first-order linear-recurrence scan
  mlstm.py            xLSTM mLSTM decay-linear-attention (parallel form)
  ops.py              jit'd wrappers with use_pallas/interpret switches
  ref.py              pure-jnp oracles (the allclose targets)

Validated under interpret=True on CPU; interpret=False on real TPU.
"""

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gemm import gemm, pick_block_shape
from repro.kernels.mlstm import mlstm_parallel
from repro.kernels.rglru import rglru_scan
