"""Block-tiled GEMM Pallas TPU kernel.

The paper's central compute object is the (distributed) GEMM; CrossFlow's
hierarchical-roofline tiling search (repro.core.roofline.best_gemm_tiling)
emits an (L2, L1, L0) tile triple — the L1 triple is exactly the VMEM
working set this kernel realizes as its BlockSpec (bm, bn, bk). This is the
cross-layer tie-in: the performance model's tiling decision IS the kernel's
tiling.

Grid layout: (m/bm, n/bn, k/bk), k innermost so each (i, j) output tile
stays resident in a VMEM fp32 scratch accumulator across the contraction
(output-stationary dataflow — the MXU-friendly choice in the paper's eq. 5
reuse taxonomy). MXU alignment: (8, 128) sublane/lane multiples.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; k is the innermost grid dim."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def pick_block_shape(m: int, n: int, k: int,
                     bm: int = 256, bn: int = 256, bk: int = 512,
                     ) -> Tuple[int, int, int]:
    """Clamp requested tiles to the problem size and divisor alignment."""
    def clamp(b: int, dim: int) -> int:
        b = min(b, dim)
        while dim % b:
            b -= 1
        return max(b, 1)
    return clamp(bm, m), clamp(bn, n), clamp(bk, k)


def gemm(x: jax.Array, w: jax.Array,
         block_shape: Optional[Tuple[int, int, int]] = None,
         out_dtype=None, interpret: bool = True) -> jax.Array:
    """C[m, n] = A[m, k] @ B[k, n] via pl.pallas_call with VMEM BlockSpecs.

    `block_shape` defaults to an MXU-friendly (256, 256, 512); callers feed
    CrossFlow's `best_gemm_tiling(...)` L1 triple for the model-chosen
    tiling. interpret=True validates on CPU; real TPU sets interpret=False.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = pick_block_shape(m, n, k, *(block_shape or (256, 256, 512)))
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
