"""jit'd public wrappers for the Pallas kernels.

Every op takes `use_pallas` / `interpret` switches: the model code calls
these; on this CPU container the default path is the jnp reference (XLA) so
the 512-device dry-run can lower, while `use_pallas=True, interpret=True`
exercises the kernels for validation and `interpret=False` is the real-TPU
production path. CrossFlow's tiling search feeds `block_shape`.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gemm import gemm as gemm_pallas
from repro.kernels.rglru import rglru_scan as rglru_pallas


@functools.partial(jax.jit, static_argnames=("block_shape", "use_pallas",
                                             "interpret"))
def matmul(x: jax.Array, w: jax.Array,
           block_shape: Optional[Tuple[int, int, int]] = None,
           use_pallas: bool = False, interpret: bool = True) -> jax.Array:
    if use_pallas:
        return gemm_pallas(x, w, block_shape=block_shape,
                           interpret=interpret)
    return jnp.dot(x, w)


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                             "interpret", "block_q",
                                             "block_kv"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              window: Optional[int] = None, use_pallas: bool = False,
              interpret: bool = True, block_q: int = 128,
              block_kv: int = 128) -> jax.Array:
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=interpret)
    return ref.attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
               use_pallas: bool = False, interpret: bool = True) -> jax.Array:
    if use_pallas:
        return rglru_pallas(a, b, h0, interpret=interpret)
    return ref.rglru_scan_ref(a, b, h0)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_q", "block_kv"))
def mlstm(q: jax.Array, k: jax.Array, v: jax.Array, f_cum: jax.Array,
          log_i: jax.Array, use_pallas: bool = False,
          interpret: bool = True, block_q: int = 128,
          block_kv: int = 128) -> jax.Array:
    from repro.kernels.mlstm import mlstm_parallel
    if use_pallas:
        return mlstm_parallel(q, k, v, f_cum, log_i, block_q=block_q,
                              block_kv=block_kv, interpret=interpret)
    return ref.mlstm_parallel_ref(q, k, v, f_cum, log_i)
