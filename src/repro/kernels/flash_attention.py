"""Flash-attention Pallas TPU kernel (blocked online softmax).

Needed by the runtime for train/prefill attention at 4k-32k sequence
lengths where materializing (sq, skv) scores would blow VMEM/HBM. Supports
causal masking, GQA (kv heads shared by head groups, via the kv BlockSpec
index_map — no materialized repeat), and a local attention window
(gemma3 / recurrentgemma local layers).

Grid: (batch*heads, sq/bq, skv/bkv), kv innermost; running max m, sum l and
the output accumulator live in VMEM scratch across kv steps (the standard
online-softmax recurrence). TPU adaptation notes in DESIGN.md: block shapes
are (8,128)-aligned, the two GEMMs per block hit the MXU with fp32
accumulation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 bq: int, bkv: int, n_kv: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                          # (bq, d)
    k = k_ref[0]                          # (bkv, d)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    k_pos = kv_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                    # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                 # (bq, bkv)
    correction = jnp.exp(m_prev - m_new)   # (bq, 1)
    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * correction
                    + jax.lax.dot(p.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128,
                    scale: Optional[float] = None,
                    interpret: bool = True) -> jax.Array:
    """q: (b, h, sq, d); k/v: (b, h_kv, skv, d) with h % h_kv == 0.

    Returns (b, h, sq, d). `window`: keys with q_pos - k_pos >= window are
    masked (local attention); None = full context.
    """
    b, h, sq, d = q.shape
    _, h_kv, skv, _ = k.shape
    assert h % h_kv == 0, (h, h_kv)
    group = h // h_kv
    scale = scale if scale is not None else d ** -0.5
    bq = min(block_q, sq)
    while sq % bq:
        bq -= 1
    bkv = min(block_kv, skv)
    while skv % bkv:
        bkv -= 1
    n_kv = skv // bkv

    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * h_kv, skv, d)
    vr = v.reshape(b * h_kv, skv, d)

    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bkv=bkv, n_kv=n_kv),
        grid=(b * h, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bkv, d),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, bkv, d),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
