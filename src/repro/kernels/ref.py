"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def gemm_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)
                   ).astype(out_dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """Naive softmax attention with GQA + causal + local-window masking."""
    b, h, sq, d = q.shape
    _, h_kv, skv, _ = k.shape
    group = h // h_kv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def mlstm_parallel_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                       f_cum: jax.Array, log_i: jax.Array) -> jax.Array:
    """Naive decay-weighted linear attention (xLSTM parallel form)."""
    b, h, s, d = q.shape
    scale = d ** -0.5
    a = (f_cum[..., :, None] - f_cum[..., None, :]
         + log_i[..., None, :])                          # (b, h, s, s)
    causal = jnp.tril(jnp.ones((s, s), bool))
    a = jnp.where(causal[None, None], a, -1e30)
    m = jnp.max(a, axis=-1, keepdims=True)
    dmat = jnp.exp(a - m)
    qk = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                    k.astype(jnp.float32))
    w = qk * dmat
    num = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.sum(w, axis=-1, keepdims=True)),
                      jnp.exp(-m))
    return (num / den).astype(q.dtype)


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via lax.scan over the sequence."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (jnp.swapaxes(a32, 0, 1), jnp.swapaxes(b32, 0, 1)))
    return jnp.swapaxes(hs, 0, 1)
