"""mLSTM parallel-form Pallas TPU kernel (xLSTM matrix-memory blocks).

The xLSTM mLSTM training recurrence in parallel form is decay-weighted
linear attention:

    a_tj  = F_t - F_j + i_j            (F = cumsum log f, causal j <= t)
    w_tj  = exp(a_tj - m_t) * (q_t . k_j)
    h_t   = sum_j w_tj v_j / max(|sum_j w_tj|, exp(-m_t))

Blocked like flash attention: grid (b*h, sq/bq, skv/bkv) with kv innermost;
scratch carries the running stabilizer m, numerator acc and signed
denominator. Two MXU GEMMs per block; the decay matrix is VPU elementwise.
Oracle: repro.kernels.ref.mlstm_parallel_ref.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, fcq_ref, fck_ref, li_ref, o_ref,
                  m_ref, num_ref, den_ref, *, scale: float, bq: int,
                  bkv: int, n_kv: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    q = q_ref[0] * scale                    # (bq, d)
    k = k_ref[0]
    v = v_ref[0]
    fq = fcq_ref[0, 0]                      # (bq,)  F_t rows of the q block
    fk = fck_ref[0, 0]                      # (bkv,) F_j rows of the kv block
    ik = li_ref[0, 0]                       # (bkv,) log i_j

    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    k_pos = kv_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    a = fq[:, None] - fk[None, :] + ik[None, :]
    a = jnp.where(q_pos >= k_pos, a, NEG_INF)

    m_prev = m_ref[...]                     # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(a, axis=1, keepdims=True))
    d_mat = jnp.exp(a - m_new)
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w = qk * d_mat
    corr = jnp.exp(m_prev - m_new)
    num_ref[...] = (num_ref[...] * corr
                    + jax.lax.dot(w.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
    den_ref[...] = den_ref[...] * corr + jnp.sum(w, axis=1, keepdims=True)
    m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _done():
        denom = jnp.maximum(jnp.abs(den_ref[...]), jnp.exp(-m_ref[...]))
        o_ref[0] = (num_ref[...] / denom).astype(o_ref.dtype)


def mlstm_parallel(q: jax.Array, k: jax.Array, v: jax.Array,
                   f_cum: jax.Array, log_i: jax.Array,
                   block_q: int = 128, block_kv: int = 128,
                   interpret: bool = True) -> jax.Array:
    """q/k/v: (b, h, s, d); f_cum/log_i: (b, h, s). Returns (b, h, s, d)."""
    b, h, s, d = q.shape
    scale = d ** -0.5
    bq = min(block_q, s)
    while s % bq:
        bq -= 1
    bkv = min(block_kv, s)
    while s % bkv:
        bkv -= 1
    n_kv = s // bkv

    qr = q.reshape(b * h, s, d)
    kr = k.reshape(b * h, s, d)
    vr = v.reshape(b * h, s, d)
    fc = f_cum.reshape(b * h, 1, s).astype(jnp.float32)
    li = log_i.reshape(b * h, 1, s).astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_mlstm_kernel, scale=scale, bq=bq, bkv=bkv,
                          n_kv=n_kv),
        grid=(b * h, s // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bkv, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, 1, bq), lambda bh, qi, ki: (bh, 0, qi)),
            pl.BlockSpec((1, 1, bkv), lambda bh, qi, ki: (bh, 0, ki)),
            pl.BlockSpec((1, 1, bkv), lambda bh, qi, ki: (bh, 0, ki)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, fc, fc, li)
    return out.reshape(b, h, s, d)
