"""RG-LRU linear-recurrence scan Pallas TPU kernel.

recurrentgemma's Real-Gated Linear Recurrent Unit reduces (after gate
precomputation, done in repro.models.rglru with cheap elementwise jnp) to a
first-order diagonal linear recurrence over the sequence:

    h_t = a_t * h_{t-1} + b_t        a, b, h: (width,) per step

The kernel carries h in VMEM scratch across sequence blocks (TPU grid
iterations execute in order along the last grid dim, making a sequential
scan natural); inside a block a fori_loop walks the rows. HBM traffic is
exactly one read of (a, b) and one write of h — the roofline optimum for a
bandwidth-bound recurrence (vs. log-depth associative scans that re-stream
intermediates; DESIGN.md hardware-adaptation notes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, carry_ref, *, bt: int):
    t_i = pl.program_id(1)

    @pl.when(t_i == 0)
    def _init():
        carry_ref[...] = h0_ref[0]

    def step(i, h):
        h = a_ref[0, i] * h + b_ref[0, i]
        o_ref[0, i] = h.astype(o_ref.dtype)
        return h

    carry_ref[...] = jax.lax.fori_loop(0, bt, step, carry_ref[...])


def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
               block_t: int = 128, interpret: bool = True) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t, h_0 given. a/b: (batch, seq, width),
    h0: (batch, width). Returns h: (batch, seq, width)."""
    batch, seq, width = a.shape
    bt = min(block_t, seq)
    while seq % bt:
        bt -= 1
    return pl.pallas_call(
        functools.partial(_rglru_kernel, bt=bt),
        grid=(batch, seq // bt),
        in_specs=[
            pl.BlockSpec((1, bt, width), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, bt, width), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, width), lambda bi, ti: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, width), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, seq, width), jnp.float32),
        scratch_shapes=[pltpu.VMEM((width,), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32))
