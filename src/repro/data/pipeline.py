"""Sharded synthetic data pipeline with background prefetch.

Production-shaped: per-host sharding (each host draws only its shard of the
global batch), deterministic per-(host, step) seeding so a restarted job
regenerates byte-identical batches (exact-resume fault tolerance), and a
double-buffered background prefetch thread.

The token stream is a Zipf-ish synthetic LM distribution with a repeating
n-gram structure, so small models actually descend (quickstart's loss
curve) instead of flat-lining on uniform noise.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    zipf_a: float = 1.3
    structure_period: int = 16      # learnable n-gram period


def _host_batch(cfg: DataConfig) -> int:
    assert cfg.global_batch % cfg.host_count == 0
    return cfg.global_batch // cfg.host_count


def synth_batch(cfg: DataConfig, arch: ArchConfig, step: int) -> Dict:
    """Deterministic (host, step) -> batch. Labels are next-token shifted."""
    rng = np.random.default_rng(
        (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_index)
    b = _host_batch(cfg)
    s = cfg.seq_len + 1
    base = rng.zipf(cfg.zipf_a, size=(b, s)).astype(np.int64)
    # structured component: periodic motif the model can learn
    motif = rng.integers(0, arch.vocab_size,
                         size=(b, cfg.structure_period))
    idx = np.arange(s) % cfg.structure_period
    structured = motif[:, idx]
    choose = rng.random((b, s)) < 0.7
    toks = np.where(choose, structured, base % arch.vocab_size)
    toks = (toks % arch.vocab_size).astype(np.int32)
    out = {"tokens": jnp.asarray(toks[:, :-1]),
           "labels": jnp.asarray(toks[:, 1:])}
    if arch.is_encoder_decoder:
        d = min(arch.decoder_len, cfg.seq_len)
        out["frames"] = jnp.asarray(rng.standard_normal(
            (b, cfg.seq_len, arch.d_model), dtype=np.float32))
        out["tokens"], out["labels"] = out["tokens"][:, :d], \
            out["labels"][:, :d]
    if arch.frontend == "vision_stub" and arch.n_patch_tokens:
        out["embeds"] = jnp.asarray(rng.standard_normal(
            (b, min(arch.n_patch_tokens, cfg.seq_len), arch.d_model),
            dtype=np.float32))
    return out


class PrefetchIterator:
    """Background-thread prefetch (depth-N double buffering)."""

    def __init__(self, cfg: DataConfig, arch: ArchConfig,
                 start_step: int = 0, depth: int = 2):
        self.cfg, self.arch = cfg, arch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, self.arch, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
