from repro.data.pipeline import DataConfig, PrefetchIterator, synth_batch
