"""Sharding rules: DeepFlow ShardingPlan -> NamedShardings for params,
optimizer state, inputs and step functions.

The planner (repro.core.planner) emits logical-axis rules in the paper's
strategy vocabulary (RC kernel parallelism -> 'model' axis, DP -> pod*data,
EP/SP reusing 'model'); this module resolves them against a concrete mesh.
ZeRO-1/3 style optimizer/param sharding is expressed by the `fsdp` logical
axis -> 'data'.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.core.planner import ShardingPlan
from repro.models import common


def resolve_rules(plan: ShardingPlan, mesh: Mesh,
                  fsdp: bool = True) -> Dict[str, Optional[Tuple[str, ...]]]:
    """Plan rules -> rules dict valid on `mesh` (drop absent axes)."""
    rules = common.rules_from_plan(plan.rules)
    if not fsdp:
        rules["fsdp"] = None
    out = {}
    for k, v in rules.items():
        if v is None:
            out[k] = None
            continue
        if isinstance(v, str):
            v = (v,)
        v = tuple(a for a in v if a in mesh.axis_names)
        out[k] = v or None
    return out


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        entry = (entry,)
    n = 1
    for a in entry:
        n *= mesh.shape[a]
    return n


def guard_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop spec entries whose mesh-axis product doesn't divide the dim —
    GSPMD requires divisibility; replication is always semantically safe
    (whisper's 20 kv heads on a 16-way model axis, batch=1 cells, ...)."""
    parts = []
    for i, dim in enumerate(shape):
        entry = spec[i] if i < len(spec) else None
        if entry is not None and dim % _axis_size(mesh, entry):
            entry = None
        parts.append(entry)
    return P(*parts)


def param_shardings(model, plan: ShardingPlan, mesh: Mesh, fsdp: bool = True):
    rules = resolve_rules(plan, mesh, fsdp)
    pspecs = model.param_pspecs(rules)
    return jax.tree.map(
        lambda s, d: named(mesh, guard_spec(mesh, s, d.shape)),
        pspecs, model.defs, is_leaf=lambda x: isinstance(x, P))


def batch_shardings(cfg: ArchConfig, cell: ShapeCell, plan: ShardingPlan,
                    mesh: Mesh):
    """Input batch shardings: batch dim over DP axes; embeds likewise."""
    rules = resolve_rules(plan, mesh)
    dp = rules.get("batch")
    if dp is not None and cell.global_batch % _axis_size(mesh, dp):
        dp = None                      # batch=1 long-context cells
    bspec = P(dp)
    specs = {"tokens": named(mesh, bspec), "labels": named(mesh, bspec)}
    if cfg.is_encoder_decoder:
        specs["frames"] = named(mesh, P(dp, None, None))
    if cfg.frontend == "vision_stub" and cfg.n_patch_tokens:
        specs["embeds"] = named(mesh, P(dp, None, None))
    if cell.kind == "prefill":
        specs.pop("labels", None)
    return specs


def cache_shardings(cfg: ArchConfig, plan: ShardingPlan, mesh: Mesh,
                    caches_tree) -> object:
    """KV caches: batch over DP, heads over model; under SP the cache seq
    dim is sharded over model instead (long_500k: batch=1, kv heads few)."""
    rules = resolve_rules(plan, mesh)
    dp = rules.get("batch")
    sp = rules.get("kv_seq")
    # under SP (long_500k, batch=1) the model axis carries the cache seq
    # dim, so kv heads move to the data axis instead
    heads = rules.get("heads") if not sp else (
        ("data",) if "data" in mesh.axis_names else None)

    def spec_for(leaf):
        nd = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
        # stacked caches have a leading layers axis (never sharded)
        parts = [None] * nd
        shape = leaf.shape
        # find the (batch, heads/width, [seq, dim]) block by rank
        off = nd - 4 if nd >= 4 else max(nd - 3, 0)
        if nd >= 4:
            parts[off] = dp          # batch
            parts[off + 1] = heads   # kv heads
            if sp:
                parts[off + 2] = sp  # cache sequence (SP)
        elif nd >= 2:
            parts[off] = dp
            parts[-1] = rules.get("lru") or rules.get("heads")
        return guard_spec(mesh, P(*parts), shape)

    return jax.tree.map(lambda l: named(mesh, spec_for(l)), caches_tree)


def scalar_sharding(mesh: Mesh):
    return named(mesh, P())
