"""Collective helpers: bucketed gradient all-reduce (overlap-friendly) and
compressed psum.

Under pjit, gradient reduction is implicit in the sharding; these helpers
exist for the shard_map paths (pipeline stages, explicit-EP experiments)
and as §Perf levers — bucketing lets XLA's latency-hiding scheduler start
reducing early buckets while later ones are still being produced.
"""

from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp


def flatten_to_buckets(tree: Any, bucket_bytes: int = 4 << 20
                       ) -> Tuple[List[jax.Array], Any]:
    """Flatten a grad tree into ~bucket_bytes 1-D buckets; returns
    (buckets, spec) where spec reassembles the tree.

    Leaves are grouped **per dtype** (first-seen order): concatenating a
    mixed bf16/f32 tree directly would silently upcast every bf16 leaf to
    f32 — doubling the reduced bytes AND changing the round-tripped leaf
    dtypes.  An empty tree yields no buckets (not a spurious f32 zero
    bucket), and `unflatten_buckets` restores every leaf's exact dtype
    and shape.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = [l.reshape(-1) for l in leaves]
    # leaf indices per dtype, first-seen order
    by_dtype: dict = {}
    for i, f in enumerate(flat):
        by_dtype.setdefault(jnp.dtype(f.dtype), []).append(i)
    buckets: List[jax.Array] = []
    groups = []
    for dtype, idxs in by_dtype.items():
        big = jnp.concatenate([flat[i] for i in idxs])
        per = max(bucket_bytes // max(big.dtype.itemsize, 1), 1)
        n_buckets = max(-(-big.size // per), 1)
        buckets.extend(big[i:i + per] for i in range(0, big.size, per))
        if big.size == 0:           # zero-size leaves still need a bucket
            buckets.append(big)
        groups.append((idxs, [flat[i].size for i in idxs],
                       [leaves[i].shape for i in idxs], big.size,
                       n_buckets))
    return buckets, (treedef, len(leaves), groups)


def unflatten_buckets(buckets: List[jax.Array], spec) -> Any:
    treedef, n_leaves, groups = spec
    leaves: List[Any] = [None] * n_leaves
    pos = 0
    for idxs, sizes, shapes, total, n_buckets in groups:
        big = jnp.concatenate(buckets[pos:pos + n_buckets])[:total]
        pos += n_buckets
        off = 0
        for i, n, shp in zip(idxs, sizes, shapes):
            leaves[i] = big[off:off + n].reshape(shp)
            off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


def bucketed_psum(tree: Any, axis_name, bucket_bytes: int = 4 << 20) -> Any:
    """psum per bucket (inside shard_map) — XLA can overlap the early
    buckets' reduction with the remaining computation."""
    buckets, spec = flatten_to_buckets(tree, bucket_bytes)
    reduced = [jax.lax.psum(b, axis_name) for b in buckets]
    return unflatten_buckets(reduced, spec)


def mean_psum(tree: Any, axis_name) -> Any:
    n = jax.lax.psum(jnp.ones(()), axis_name)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, tree)
