"""Collective helpers: bucketed gradient all-reduce (overlap-friendly) and
compressed psum.

Under pjit, gradient reduction is implicit in the sharding; these helpers
exist for the shard_map paths (pipeline stages, explicit-EP experiments)
and as §Perf levers — bucketing lets XLA's latency-hiding scheduler start
reducing early buckets while later ones are still being produced.
"""

from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp


def flatten_to_buckets(tree: Any, bucket_bytes: int = 4 << 20
                       ) -> Tuple[List[jax.Array], Any]:
    """Flatten a grad tree into ~bucket_bytes 1-D buckets; returns
    (buckets, spec) where spec reassembles the tree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = [l.reshape(-1) for l in leaves]
    sizes = [f.size for f in flat]
    big = jnp.concatenate(flat) if flat else jnp.zeros((0,))
    per = max(bucket_bytes // max(big.dtype.itemsize, 1), 1)
    buckets = [big[i:i + per] for i in range(0, big.size, per)] or [big]
    return buckets, (treedef, sizes, [l.shape for l in leaves], big.size)


def unflatten_buckets(buckets: List[jax.Array], spec) -> Any:
    treedef, sizes, shapes, total = spec
    big = jnp.concatenate(buckets)[:total]
    leaves, off = [], 0
    for n, shp in zip(sizes, shapes):
        leaves.append(big[off:off + n].reshape(shp))
        off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


def bucketed_psum(tree: Any, axis_name, bucket_bytes: int = 4 << 20) -> Any:
    """psum per bucket (inside shard_map) — XLA can overlap the early
    buckets' reduction with the remaining computation."""
    buckets, spec = flatten_to_buckets(tree, bucket_bytes)
    reduced = [jax.lax.psum(b, axis_name) for b in buckets]
    return unflatten_buckets(reduced, spec)


def mean_psum(tree: Any, axis_name) -> Any:
    n = jax.lax.psum(jnp.ones(()), axis_name)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, tree)
