from repro.parallel import collectives, pipeline, sharding
