"""Pipeline parallelism (the paper's LP axis) via shard_map + ppermute.

GPipe schedule: the layer stack is cut into S stages (one per mesh 'stage'
axis index); a microbatch streams through stages with collective_permute
moving activations between neighbours. Implemented with shard_map so each
stage executes only its own parameters — the standard JAX SPMD pipeline
pattern (rotate-and-compute over S + M - 1 ticks).

The paper's DPE treats LP as a graph cut with p2p cross-edges; this module
is the runtime realization. The planner proposes LP>1 for deep models on
multi-pod meshes (candidate_strategies); the dry-run exercises it through
`pipelined_loss_fn` variants.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def stage_params_split(params_stacked: Any, n_stages: int) -> Any:
    """Reshape scan-stacked layer params (L, ...) -> (S, L/S, ...)."""
    def r(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    return jax.tree.map(r, params_stacked)


def gpipe(fn_stage: Callable, mesh: Mesh, stage_axis: str = "stage",
          n_microbatches: int = 4):
    """Wrap a per-stage apply `fn_stage(stage_params, x) -> x` into a
    GPipe pipeline over the mesh's `stage` axis.

    Returns pipelined(params_staged, x_microbatched) where
    params_staged leaves have leading dim S (sharded over stage_axis) and
    x_microbatched is (M, mb, ...) with M == n_microbatches.
    """
    s = mesh.shape[stage_axis]

    def per_device(params_local, x_all):
        # params_local: leaves (1, L/S, ...) — this device's stage params
        params_local = jax.tree.map(lambda p: p[0], params_local)
        stage_id = jax.lax.axis_index(stage_axis)
        m = x_all.shape[0]
        n_ticks = m + s - 1
        buf = jnp.zeros_like(x_all[0])
        outs = jnp.zeros_like(x_all)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if any remain)
            inject = jnp.where(t < m, t, m - 1)
            x_in = jnp.where(stage_id == 0,
                             x_all[inject].astype(buf.dtype), buf)
            y = fn_stage(params_local, x_in)
            # last stage emits finished microbatch t - (s-1)
            emit = t - (s - 1)
            emit_c = jnp.clip(emit, 0, m - 1)
            outs = jnp.where(
                (stage_id == s - 1) & (emit >= 0),
                outs.at[emit_c].set(y.astype(outs.dtype)), outs)
            # rotate activations to the next stage
            buf = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % s) for i in range(s)])
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs),
                                    jnp.arange(n_ticks))
        # every device returns outs; only the last stage's is meaningful —
        # mask + psum broadcasts it to all stages (ppermute cannot fan out)
        if s > 1:
            mask = (stage_id == s - 1).astype(outs.dtype)
            outs = jax.lax.psum(outs * mask, stage_axis)
        return outs

    pspec_params = jax.tree.map(lambda _: P(stage_axis), {"_": 0})["_"]

    def pipelined(params_staged, x_microbatched):
        in_specs = (jax.tree.map(lambda _: P(stage_axis), params_staged),
                    P())
        return shard_map(per_device, mesh=mesh, in_specs=in_specs,
                         out_specs=P(), check_rep=False)(
            params_staged, x_microbatched)

    return pipelined
