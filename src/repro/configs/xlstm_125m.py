"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (~7:1 mLSTM:sLSTM).

12L d_model=768 4H (kv=4) d_ff=0 vocab=50304. d_ff=0: the xLSTM block's
up/down projection replaces a separate FFN. sLSTM at layer indices {1, 7}.
[arXiv:2405.04517; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm", "mlstm", "mlstm", "mlstm", "mlstm"),
    ffn_kind="gelu",
    norm_kind="layernorm",
    tie_embeddings=True,
    rope_theta=0.0,                 # xLSTM uses no positional encoding
    supports_long_context=True,     # O(1) matrix/scalar recurrent state
    source="arXiv:2405.04517; unverified",
)
