"""whisper-large-v3 [audio] — enc-dec transformer backbone.

32L d_model=1280 20H (GQA kv=20) d_ff=5120 vocab=51866. Conv/audio frontend
is a STUB per the assignment: `input_specs()` supplies precomputed 1280-d
frame embeddings. [arXiv:2212.04356; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,                    # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    attn_pattern=("global",),
    qkv_bias=True,
    block_pattern=("attn",),
    is_encoder_decoder=True,
    n_encoder_layers=32,
    decoder_len=448,
    frontend="audio_stub",
    ffn_kind="gelu",
    norm_kind="layernorm",
    tie_embeddings=True,
    rope_theta=0.0,                 # sinusoidal positions, no RoPE
    supports_long_context=False,    # full-attention encoder: long_500k skipped
    source="arXiv:2212.04356; unverified",
)
