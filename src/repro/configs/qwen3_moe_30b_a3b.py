"""qwen3-moe-30b-a3b [moe] — 128 routed experts, top-8.

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                       # per-expert intermediate
    vocab_size=151936,
    n_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    n_shared_experts=0,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    tie_embeddings=False,
    supports_long_context=False,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
