"""Architecture / shape-cell config schema and registry.

Every assigned architecture gets one module in this package defining
``CONFIG = ArchConfig(...)`` with the exact published dimensions; the
registry maps ``--arch <id>`` to it. ``reduced()`` shrinks any config to a
CPU-smoke-testable size of the *same family* (same block pattern, same
attention kinds, fewer/smaller everything).
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention structure -------------------------------------------------
    attn_pattern: Tuple[str, ...] = ("global",)   # cycled over attn layers
    local_window: int = 1024
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # block structure (cycled over layers) ---------------------------------
    block_pattern: Tuple[str, ...] = ("attn",)    # attn | rglru | mlstm | slstm
    lru_width: Optional[int] = None               # rglru recurrence width
    conv1d_width: int = 4
    # MoE -------------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # scatter_ep: global scatter into an expert-sharded buffer (baseline);
    # grouped_tp: per-DP-group local dispatch + tensor-parallel expert
    # weights — the §Perf hillclimb winner (no cross-shard scatter)
    moe_impl: str = "scatter_ep"
    moe_groups: int = 0             # grouped_tp: groups (0 -> DP degree)
    # encoder-decoder ---------------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    decoder_len: int = 448          # trained decoder length (whisper: 448)
    # modality stubs ----------------------------------------------------------
    frontend: str = "none"          # none | audio_stub | vision_stub
    n_patch_tokens: int = 0         # vlm: stubbed ViT patch embeddings
    # misc --------------------------------------------------------------------
    ffn_kind: str = "swiglu"        # swiglu | gelu
    norm_kind: str = "rmsnorm"      # rmsnorm | layernorm
    tie_embeddings: bool = True
    logits_softcap: float = 0.0
    supports_long_context: bool = False
    dtype: str = "bfloat16"
    source: str = ""                # provenance tag from the assignment

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 256 multiple: MXU-aligned and divisible by the
        model mesh axis (whisper's 51866 is not). Padded logit slots are
        masked to -inf in the head."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    def attn_kind(self, layer: int) -> str:
        return self.attn_pattern[layer % len(self.attn_pattern)]

    # ---- parameter accounting (used for 6ND MODEL_FLOPS, roofline) -------
    def param_count(self) -> int:
        return _params(self, active_only=False)

    def active_param_count(self) -> int:
        return _params(self, active_only=True)


def _attn_params(cfg: ArchConfig) -> int:
    hd = cfg.resolved_head_dim
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    return q + kv + o


def _ffn_params(cfg: ArchConfig, d_ff: int) -> int:
    mult = 3 if cfg.ffn_kind == "swiglu" else 2
    return mult * cfg.d_model * d_ff


def _block_params(cfg: ArchConfig, kind: str, active_only: bool) -> int:
    d = cfg.d_model
    if kind == "attn":
        p = _attn_params(cfg)
        if cfg.is_moe:
            e_act = cfg.experts_per_token if active_only else cfg.n_experts
            p += e_act * _ffn_params(cfg, cfg.moe_d_ff)
            p += cfg.n_shared_experts * _ffn_params(cfg, cfg.moe_d_ff)
            p += d * cfg.n_experts                     # router
        else:
            p += _ffn_params(cfg, cfg.d_ff)
        return p
    if kind == "rglru":
        w = cfg.lru_width or d
        # in/out projections + gates + temporal conv (recurrentgemma block)
        p = 2 * d * w + 2 * w * w // 1 + cfg.conv1d_width * w + 2 * w
        p += _ffn_params(cfg, cfg.d_ff)
        return p
    if kind in ("mlstm", "slstm"):
        hd = cfg.resolved_head_dim
        nh = cfg.n_heads
        qkv = 3 * d * nh * hd
        gates = 3 * d * nh if kind == "mlstm" else 4 * d * nh * hd
        out = nh * hd * d
        up = 2 * d * (2 * d)                           # proj up/down block
        return qkv + gates + out + up
    raise ValueError(kind)


def _params(cfg: ArchConfig, active_only: bool) -> int:
    total = cfg.vocab_size * cfg.d_model              # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model
    layers = list(range(cfg.n_layers))
    for i in layers:
        total += _block_params(cfg, cfg.block_kind(i), active_only)
    if cfg.is_encoder_decoder:
        for i in range(cfg.n_encoder_layers):
            total += _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        total += cfg.n_layers * _attn_params(cfg)     # cross-attention
    return int(total)


# ---------------------------------------------------------------------------
# Shape cells (assigned input shapes)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.seq_len * self.global_batch


SHAPE_CELLS: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


ARCH_IDS = (
    "whisper_large_v3", "recurrentgemma_2b", "qwen1_5_0_5b", "phi3_medium_14b",
    "gemma3_27b", "mistral_large_123b", "internvl2_76b", "qwen2_moe_a2_7b",
    "qwen3_moe_30b_a3b", "xlstm_125m",
)
# external ids (--arch accepts either form)
_ALIASES = {
    "whisper-large-v3": "whisper_large_v3",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "phi3-medium-14b": "phi3_medium_14b",
    "gemma3-27b": "gemma3_27b",
    "mistral-large-123b": "mistral_large_123b",
    "internvl2-76b": "internvl2_76b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "xlstm-125m": "xlstm_125m",
    "paper-lm": "paper_lm",
}


def get_config(arch: str) -> ArchConfig:
    mod_name = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def applicable_cells(cfg: ArchConfig):
    """The shape cells this arch runs (DESIGN.md §Arch-applicability)."""
    for cell in SHAPE_CELLS.values():
        if cell.name == "long_500k" and not cfg.supports_long_context:
            continue                # pure full-attention: documented skip
        yield cell


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Shrink to a CPU-smoke size preserving the family structure."""
    scale_layers = max(len(cfg.block_pattern),
                       2 if not cfg.is_encoder_decoder else 2)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, max(scale_layers, 2)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        lru_width=128 if cfg.lru_width else None,
        local_window=32,
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        decoder_len=16,
        n_patch_tokens=min(cfg.n_patch_tokens, 8),
    )
