"""qwen1.5-0.5b [dense] — QKV bias, full attention.

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
[hf:Qwen/Qwen1.5-0.5B; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    tie_embeddings=True,
    supports_long_context=False,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
