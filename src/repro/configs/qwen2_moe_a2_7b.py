"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts.

24L d_model=2048 16H (GQA kv=16) per-expert d_ff=1408 vocab=151936.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                      # per-expert intermediate
    vocab_size=151936,
    qkv_bias=True,
    n_experts=60,
    experts_per_token=4,
    moe_d_ff=1408,
    n_shared_experts=4,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    tie_embeddings=True,
    supports_long_context=False,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)
