"""mistral-large-123b [dense].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    tie_embeddings=False,
    supports_long_context=False,
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
)
