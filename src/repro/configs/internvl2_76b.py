"""internvl2-76b [vlm] — InternViT (stub) + InternLM2 backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The ViT frontend is
a STUB per the assignment: `input_specs()` supplies precomputed patch
embeddings occupying the first `n_patch_tokens` positions.
[arXiv:2404.16821; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision_stub",
    n_patch_tokens=256,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    tie_embeddings=False,
    supports_long_context=False,
    source="arXiv:2404.16821; unverified",
)
