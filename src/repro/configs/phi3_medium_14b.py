"""phi3-medium-14b [dense] — RoPE SwiGLU GQA.

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
[arXiv:2404.14219; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    ffn_kind="swiglu",
    norm_kind="rmsnorm",
    tie_embeddings=False,
    supports_long_context=False,
    source="arXiv:2404.14219; unverified",
)
