"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2 recurrent.

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
[arXiv:2402.19427; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attn_pattern=("local",),
    local_window=2048,
    block_pattern=("rglru", "rglru", "attn"),   # 2 recurrent : 1 attention
    lru_width=2560,
    conv1d_width=4,
    ffn_kind="gelu",                # recurrentgemma uses GeGLU
    norm_kind="rmsnorm",
    tie_embeddings=True,
    supports_long_context=True,     # O(1) recurrent state + bounded window
    source="arXiv:2402.19427; hf",
)
