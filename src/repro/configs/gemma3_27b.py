"""gemma3-27b [dense] — 5 local : 1 global attention pattern, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    local_window=1024,
    ffn_kind="gelu",                # gemma GeGLU
    norm_kind="rmsnorm",
    tie_embeddings=True,
    logits_softcap=30.0,
    # long_500k RUNS: 5/6 of layers have a bounded 1024-token window; the
    # ~10 global layers hold a sharded KV cache and decode is linear.
    supports_long_context=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
