"""The paper's own case-study workload (§9): 2-layer LSTM language model,
hidden 16K, global batch 16K, vocab 800K, seq 20, across 512 nodes.

Used by the CrossFlow benchmarks (fig9/fig10/fig11) and, in reduced form, by
the measured-vs-predicted CPU validation (fig8).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paper-lm",
    family="lstm",
    n_layers=2,
    d_model=16384,                  # hidden dim
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=800000,
    block_pattern=("lstm",),
    ffn_kind="gelu",
    norm_kind="layernorm",
    tie_embeddings=False,
    rope_theta=0.0,
    supports_long_context=False,
    source="DeepFlow paper §9",
)

# the paper's iteration shape
SEQ_LEN = 20
GLOBAL_BATCH = 16384
N_NODES = 512
