from repro.optim.adamw import AdamWConfig, AdamWState, apply, global_norm, \
    init, schedule
