"""AdamW with global-norm clipping, warmup-cosine schedule, and ZeRO-1
semantics (optimizer state inherits the params' `fsdp`-sharded specs, so
under pjit the m/v moments are sharded over the data axis with no extra
code — GSPMD keeps the update local and the planner's DP axes carry only
the gradient all-reduce)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.zeros_like, params))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, state: AdamWState, params: Any, grads: Any
          ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                            + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    params_new = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    return params_new, AdamWState(step, mu, nu), \
        {"grad_norm": gnorm, "lr": lr}
