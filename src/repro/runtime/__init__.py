from repro.runtime.compression import compress, compression_ratio, \
    decompress, init_error_state
from repro.runtime.fault import PreemptionHandler, StragglerWatchdog, \
    elastic_plan
