"""Gradient compression for the DP all-reduce: int8 quantization with
error feedback.

At 1000+ nodes the gradient all-reduce over DCN dominates (the paper's §9.1
network-scaling study is exactly about this); int8 + per-block scales cuts
the payload 4x vs f32 / 2x vs bf16. Error feedback (Karimireddy et al.)
accumulates the quantization residual locally so the compressed SGD
direction stays unbiased in the long run.

Usage inside a pjit'd train step:
    comp, state = compress(grads, state)     # quantize + residual update
    comp = psum-mean over DP axes (runtime does this via sharding)
    grads = decompress(comp)
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 2048                       # elements per quantization scale


class CompressedTree(NamedTuple):
    q: Any                          # int8 payloads (same treedef)
    scales: Any                     # f32 per-block scales


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray,
                shape: Tuple[int, ...]) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress(grads: Any, error_state: Optional[Any] = None
             ) -> Tuple[CompressedTree, Any]:
    """Quantize grads (+error feedback). Returns (compressed, new_state)."""
    if error_state is None:
        error_state = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error_state)
    qs = jax.tree.map(_quantize, corrected)
    q = jax.tree.map(lambda t: t[0], qs,
                     is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    decompressed = jax.tree.map(
        lambda qq, ss, g: _dequantize(qq, ss, g.shape), q, scales, grads)
    new_err = jax.tree.map(lambda c, d: c - d, corrected, decompressed)
    return CompressedTree(q=q, scales=scales), new_err


def decompress(comp: CompressedTree, like: Any) -> Any:
    return jax.tree.map(
        lambda q, s, g: _dequantize(q, s, g.shape).astype(g.dtype),
        comp.q, comp.scales, like)


def compression_ratio(grads: Any) -> float:
    raw = sum(g.size * g.dtype.itemsize for g in jax.tree.leaves(grads))
    comp = sum(g.size * 1 + -(-g.size // BLOCK) * 4
               for g in jax.tree.leaves(grads))
    return raw / comp
