"""Fault-tolerance runtime: preemption handling, straggler watchdog,
elastic rescale bookkeeping.

On a real multi-host cluster the coordinator detects failed hosts through
collective timeouts and preemption notices arrive as SIGTERM; the
mitigation actions here are the ones a 1000+-node deployment needs:
save-and-exit on preemption, step-time anomaly detection (straggler flag +
callback), and a restart ledger that chooses the new DP degree when the
healthy-host count changes (elastic rescale, consumed by
checkpoint.restore's cross-mesh path).
"""

from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Callable, List, Optional


class PreemptionHandler:
    """SIGTERM/SIGINT -> graceful save-and-exit flag (test hook: .trigger()).

    ``on_preempt`` fires once, on the first preemption notice — fabric
    workers use it to surface "draining" immediately while the executor
    finishes committing the in-flight superbatch.
    """

    def __init__(self, install: bool = True,
                 on_preempt: Optional[Callable[[], None]] = None):
        self._flag = threading.Event()
        self._on_preempt = on_preempt
        if install:
            try:
                signal.signal(signal.SIGTERM, self._on_signal)
            except ValueError:        # not main thread (tests)
                pass

    def _on_signal(self, signum, frame):
        self.trigger()

    def trigger(self) -> None:
        first = not self._flag.is_set()
        self._flag.set()
        if first and self._on_preempt is not None:
            self._on_preempt()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than `threshold` x EMA(step time).

    In a real deployment the callback would trigger hot-spare swap-in /
    re-sharding away from the slow host; here it records the event so the
    train loop (and tests) can assert the mitigation path fires.
    """

    threshold: float = 3.0
    ema_decay: float = 0.9
    warmup_steps: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def __post_init__(self):
        self._ema: Optional[float] = None
        self._seen = 0
        self.events: List[dict] = []

    def observe(self, step: int, step_time_s: float) -> bool:
        self._seen += 1
        if self._ema is None:
            self._ema = step_time_s
            return False
        is_straggler = (self._seen > self.warmup_steps
                        and step_time_s > self.threshold * self._ema)
        if is_straggler:
            ev = {"step": step, "step_time_s": step_time_s,
                  "ema_s": self._ema}
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(step, step_time_s, self._ema)
        else:
            # stragglers don't poison the EMA
            self._ema = (self.ema_decay * self._ema
                         + (1 - self.ema_decay) * step_time_s)
        return is_straggler


def fleet_mtbf_s(device_mtbf_s: float, n_devices: float) -> float:
    """Mean time between failures of the whole fleet (independent fails)."""
    return float(device_mtbf_s) / max(float(n_devices), 1.0)


def availability(restore_s: float, mtbf_s: float) -> float:
    """Steady-state availability: fraction of wall-clock spent serving.

    Each failure costs one restore; serving has no checkpoint-write tax
    (state is reconstructible), so goodput derates by MTBF/(MTBF+restore).
    """
    return float(mtbf_s) / max(float(mtbf_s) + float(restore_s), 1e-30)


def goodput_fraction(write_s: float, restore_s: float,
                     mtbf_s: float) -> float:
    """Fraction of wall-clock doing useful training work under failures.

    Young's optimal checkpoint interval T = sqrt(2 * write * MTBF):
    the fleet loses `write_s` per interval to checkpointing and, per
    failure (rate 1/MTBF), half an interval of lost work plus a restore.
    With write_s == 0 this degrades to the serving `availability` model.
    Clipped to [0, 1] — an MTBF shorter than the recovery cost means the
    run never progresses.
    """
    write_s = max(float(write_s), 0.0)
    mtbf_s = max(float(mtbf_s), 1e-30)
    if write_s <= 0.0:
        return availability(restore_s, mtbf_s)
    interval = (2.0 * write_s * mtbf_s) ** 0.5
    frac = ((1.0 - write_s / interval)
            * (1.0 - (interval / 2.0 + float(restore_s)) / mtbf_s))
    return min(max(frac, 0.0), 1.0)


def elastic_plan(n_healthy: int, model_parallel: int,
                 global_batch: int) -> dict:
    """Choose the new mesh for a changed healthy-device count.

    Keeps the model axis intact (weights must still fit) and gives the
    largest power-of-two DP degree that divides the global batch —
    the restart then restores the latest checkpoint onto the new mesh.
    """
    assert n_healthy >= model_parallel, "cannot fit the model axis"
    dp = n_healthy // model_parallel
    while dp & (dp - 1):
        dp -= 1
    while global_batch % dp:
        dp //= 2
    return {"data": dp, "model": model_parallel,
            "devices_used": dp * model_parallel,
            "devices_idle": n_healthy - dp * model_parallel}
