"""Compute-graph structure transformation (DeepFlow paper §5.1).

Each parallelism strategy is a graph transformation:

  * data parallelism (d{DP}): every weight-gradient node gains a ring
    all-reduce across DP replicas (ring edges are cross-edges);
  * kernel parallelism RC-{KP1}-{KP2}: every GEMM node is replaced by a
    KP1 x KP2 torus of shard nodes — each shard computes an
    (m/KP1, n/KP2, k) block and activations are all-gathered along torus
    dims between consecutive GEMMs;
  * kernel parallelism CR-{KP1}: each shard computes an (m, n, k/KP1)
    outer-product partial and the outputs are all-reduced across KP1;
  * pipeline parallelism p{LP}: the graph is cut into LP stages; stage
    boundary edges become cross-edges (p2p activation sends).

Two materializations are provided:

  `shard_graph`       the scalable form used for large degrees: one
                      representative replica with per-shard kernel dims and
                      explicit `comm` nodes (the paper's §6.5 observation
                      that DP/KP replicas are homogeneous and deterministic
                      makes this sufficient for timing);
  `build_supergraph`  the explicit super-graph (every replica materialized,
                      rings/tori wired with cross-edges) — used for small
                      degrees and unit tests, faithful to paper Fig. 5.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.graph import ComputeGraph, Node
from repro.core.parallelism import Strategy


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def shard_graph(g: ComputeGraph, strategy: Strategy,
                grad_bytes: Optional[float] = None) -> ComputeGraph:
    """Produce the one-replica sharded graph with comm nodes inserted.

    Node meta flags consumed here (set by repro.core.lmgraph builders):
      shard_m / shard_n / shard_k : bool — which GEMM dims the KP strategy
          may shard for this node (e.g. recurrence GEMMs forbid k-sharding);
      weight : bool — node produces weight gradients (DP all-reduce target);
      moe    : bool — routed-expert GEMM (EP all-to-all dispatch inserted);
      no_kp  : bool — node not shardable by kernel parallelism at all.
    """
    s = strategy
    out = ComputeGraph(f"{g.name}|{s.name}")
    name_map: Dict[str, str] = {}
    total_grad_bytes = 0.0

    for name in g.topo_order():
        node = g.nodes[name]
        deps = [name_map[p] for p in dict.fromkeys(g.preds(name))]
        if node.kind == "gemm":
            meta = dict(node.meta)
            repeat = meta.get("repeat", 1)
            no_kp = meta.get("no_kp", False)
            kp1 = 1 if no_kp else s.kp1
            kp2 = 1 if no_kp else s.kp2
            b, m, n, k = node.b, node.m, node.n, node.k
            # data parallelism shards the batch-like dim (m for act GEMMs)
            bd = meta.get("batch_dim", "m")
            if not meta.get("no_dp"):
                if bd == "m":
                    m = _ceil_div(m, s.dp)
                elif bd == "b":
                    b = _ceil_div(b, s.dp)
                elif bd == "k":
                    k = _ceil_div(k, s.dp)
            if s.kind == "RC" and not no_kp:
                sm = _ceil_div(m, kp1) if meta.get("shard_m", True) else m
                sn = _ceil_div(n, kp2) if meta.get("shard_n", True) else n
                if meta.get("kp_b"):            # head-parallel batched GEMMs
                    b = _ceil_div(b, s.kp)
                sk = k
                # inner-product: gather the kp2-sharded activation first
                if meta.get("gather_act", True) and kp2 > 1:
                    ag = out.comm_op(f"{name}.ag", "allgather",
                                     size_bytes=float(sm) * sk / kp2
                                     * node.dtype_bytes * b,
                                     axis="kp2", participants=kp2, deps=deps)
                    ag.meta["repeat"] = repeat
                    deps = [ag.name]
                new = out.gemm(name, m=sm, n=sn, k=sk, b=b, deps=deps,
                               dtype_bytes=node.dtype_bytes, **meta)
            elif s.kind == "CR" and not no_kp:
                sk = _ceil_div(k, s.kp1) if meta.get("shard_k", True) else k
                new = out.gemm(name, m=m, n=n, k=sk, b=b, deps=deps,
                               dtype_bytes=node.dtype_bytes, **meta)
                if meta.get("shard_k", True) and s.kp1 > 1:
                    ar = out.comm_op(f"{name}.ar", "allreduce",
                                     size_bytes=float(m) * n * b
                                     * node.dtype_bytes,
                                     axis="kp1", participants=s.kp1,
                                     deps=[name])
                    ar.meta["repeat"] = repeat
                    name_map[name] = ar.name
                    if meta.get("weight"):
                        total_grad_bytes += (float(m) * n * b
                                             * node.dtype_bytes * repeat)
                    continue
            else:
                new = out.gemm(name, m=m, n=n, k=k, b=b, deps=deps,
                               dtype_bytes=node.dtype_bytes, **meta)
            if meta.get("weight"):
                # a weight GEMM's parameter bytes ~ n*k (m is the token dim)
                total_grad_bytes += float(new.n) * new.k \
                    * node.dtype_bytes * repeat
            # MoE dispatch: tokens cross the EP group before/after the GEMM
            if meta.get("moe") and s.ep > 1:
                a2a = out.comm_op(f"{name}.a2a", "alltoall",
                                  size_bytes=float(new.m) * new.k
                                  * node.dtype_bytes,
                                  axis="ep", participants=s.ep, deps=[name])
                a2a.meta["repeat"] = repeat
                name_map[name] = a2a.name
                continue
        elif node.kind == "elementwise":
            n_elems = _ceil_div(node.n_elems, s.dp * max(s.kp, 1))
            out.elementwise(name, n_elems=n_elems,
                            flops_per_elem=node.flops_per_elem, deps=deps,
                            dtype_bytes=node.dtype_bytes, **node.meta)
        elif node.kind == "gather":
            out.gather(name, rows=_ceil_div(node.rows, s.dp),
                       width=_ceil_div(node.width, max(s.kp, 1)), deps=deps,
                       dtype_bytes=node.dtype_bytes)
        elif node.kind == "comm":
            out.comm_op(name, node.comm, node.comm_bytes, node.comm_axis,
                        node.comm_participants, deps=deps)
        else:
            raise ValueError(node.kind)
        name_map[name] = name

    # data-parallel gradient exchange (ring all-reduce across DP replicas)
    if s.dp > 1:
        gb = grad_bytes if grad_bytes is not None else total_grad_bytes
        if gb > 0:
            sinks = [n for n in out.nodes
                     if not out.succs(n)] or list(out.nodes)[-1:]
            out.comm_op("grad.allreduce", "allreduce", size_bytes=float(gb),
                        axis="dp", participants=s.dp, deps=sinks[-1:])
    out.validate()
    return out


# ---------------------------------------------------------------------------
# Explicit super-graph (paper Fig. 5) — small degrees / unit tests
# ---------------------------------------------------------------------------


def build_supergraph(g: ComputeGraph, strategy: Strategy) -> ComputeGraph:
    """Materialize every replica: pipeline cut -> DP rings -> KP tori.

    Replica naming: ``<node>@p<stage>d<rep>r<row>c<col>``. Ring/torus edges
    are cross-edges. Feasible for small degree products (tests use <= 48).
    """
    s = strategy
    if s.devices > 4096:
        raise ValueError("explicit super-graph is for small degrees; "
                         "use shard_graph for large systems")
    out = ComputeGraph(f"{g.name}|super|{s.name}")
    order = g.topo_order()
    stages = _cut_stages(g, order, s.lp)

    def rep_name(base: str, p: int, d: int, r: int, c: int) -> str:
        return f"{base}@p{p}d{d}r{r}c{c}"

    for d in range(s.dp):
        for p, stage_nodes in enumerate(stages):
            for name in stage_nodes:
                node = g.nodes[name]
                for r in range(s.kp1):
                    for c in range(s.kp2):
                        nn = dataclasses.replace(
                            node, name=rep_name(name, p, d, r, c))
                        if node.kind == "gemm":
                            nn.m = _ceil_div(_ceil_div(node.m, s.dp), s.kp1)
                            nn.n = _ceil_div(node.n, s.kp2)
                        dev = (((p * s.dp) + d) * s.kp1 + r) * s.kp2 + c
                        nn.device = dev
                        out.add(nn)
                        # intra-replica deps
                        for pred in dict.fromkeys(g.preds(name)):
                            pred_stage = _stage_of(stages, pred)
                            pn = rep_name(pred, pred_stage, d, r, c)
                            if pn in out.nodes:
                                out.connect(pn, nn.name,
                                            cross=pred_stage != p)
                        # KP torus cross-edges (activation redistribution)
                        if node.kind == "gemm" and (s.kp1 > 1 or s.kp2 > 1):
                            for rr, cc in (((r + 1) % s.kp1, c),
                                           (r, (c + 1) % s.kp2)):
                                if (rr, cc) != (r, c):
                                    peer = rep_name(name, p, d, rr, cc)
                                    if peer in out.nodes:
                                        out.connect(nn.name, peer, cross=True)
        # DP ring cross-edges on gradient-bearing nodes
    if s.dp > 1:
        for p, stage_nodes in enumerate(stages):
            for name in stage_nodes:
                if not g.nodes[name].meta.get("weight"):
                    continue
                for d in range(s.dp):
                    for r in range(s.kp1):
                        for c in range(s.kp2):
                            a = rep_name(name, p, d, r, c)
                            bnode = rep_name(name, p, (d + 1) % s.dp, r, c)
                            if a in out.nodes and bnode in out.nodes:
                                out.connect(a, bnode, cross=True)
    return out


def _cut_stages(g: ComputeGraph, order: List[str], lp: int) -> List[List[str]]:
    """Cut the topo order into LP balanced stages by flop mass (paper §5.1:
    pipeline slices the original graph into sub-graphs)."""
    if lp <= 1:
        return [order]
    flops = [max(g.nodes[n].flops, 1.0) for n in order]
    total = sum(flops)
    target = total / lp
    stages, cur, acc = [], [], 0.0
    for name, f in zip(order, flops):
        cur.append(name)
        acc += f
        if acc >= target and len(stages) < lp - 1:
            stages.append(cur)
            cur, acc = [], 0.0
    stages.append(cur)
    while len(stages) < lp:
        stages.append([])
    return stages


def _stage_of(stages: List[List[str]], name: str) -> int:
    for i, st in enumerate(stages):
        if name in st:
            return i
    raise KeyError(name)


def stage_subgraphs(g: ComputeGraph, lp: int) -> List[ComputeGraph]:
    """Split into per-stage graphs (used by the pipeline-aware simulator)."""
    order = g.topo_order()
    stages = _cut_stages(g, order, lp)
    outs = []
    for i, names in enumerate(stages):
        sg = ComputeGraph(f"{g.name}|stage{i}")
        nameset = set(names)
        for n in names:
            node = g.nodes[n]
            deps = [p for p in dict.fromkeys(g.preds(n)) if p in nameset]
            sg.add(dataclasses.replace(node), deps)
        outs.append(sg)
    return outs
