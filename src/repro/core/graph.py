"""Compute-graph IR (DeepFlow paper §3, §5).

The ML model is described as a DAG of kernel nodes. CrossFlow transforms this
graph into a *super-graph* under a parallelism strategy (repro.core.transform),
maps it onto the system graph (repro.core.placement), times each node with the
hierarchical roofline (repro.core.roofline) and each edge with the network
model, then runs event-driven simulation (repro.core.simulate).

Node kinds and their cost semantics:

  gemm         batched GEMM  (b, m, n, k): flops = 2*b*m*n*k
  elementwise  n_elems elements, `flops_per_elem` each, rw bytes = in+out
  gather       embedding lookup: rows * width * dtype bytes moved, ~0 flops
  comm         a communication op (collective or p2p) — timed by the network
               model, not the roofline

Edges carry activation bytes; `cross=True` marks device-boundary edges
created by the graph transformation (paper Fig. 5, red edges).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Optional, Tuple

COMM_KINDS = ("allreduce", "allgather", "reducescatter", "alltoall", "p2p")


@dataclasses.dataclass
class Node:
    name: str
    kind: str                       # "gemm" | "elementwise" | "gather" | "comm"
    # gemm
    b: int = 1
    m: int = 0
    n: int = 0
    k: int = 0
    # elementwise / gather
    n_elems: int = 0
    flops_per_elem: float = 1.0
    rows: int = 0
    width: int = 0
    # comm
    comm: str = ""                  # one of COMM_KINDS
    comm_bytes: float = 0.0         # payload per participant
    comm_axis: str = ""             # logical parallel axis ("dp","kp1","kp2","lp","ep")
    comm_participants: int = 1
    dtype_bytes: int = 2
    # scheduling
    device: int = 0                 # assigned hardware node (after placement)
    meta: Dict = dataclasses.field(default_factory=dict)

    @property
    def flops(self) -> float:
        if self.kind == "gemm":
            return 2.0 * self.b * self.m * self.n * self.k
        if self.kind == "elementwise":
            return float(self.n_elems) * self.flops_per_elem
        return 0.0

    @property
    def io_bytes(self) -> float:
        """Minimum main-memory traffic (compulsory): inputs + outputs once."""
        s = self.dtype_bytes
        if self.kind == "gemm":
            return s * self.b * (self.m * self.k + self.k * self.n
                                 + self.m * self.n)
        if self.kind == "elementwise":
            return 2.0 * s * self.n_elems
        if self.kind == "gather":
            return s * self.rows * self.width * 2.0
        return 0.0


@dataclasses.dataclass
class Edge:
    src: str
    dst: str
    bytes: float = 0.0
    cross: bool = False             # crosses a device boundary
    meta: Dict = dataclasses.field(default_factory=dict)


class ComputeGraph:
    """A DAG of Nodes. Insertion order is required to be a valid topo order
    for the builders in repro.core.lmgraph (asserted in `validate`)."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self.nodes: Dict[str, Node] = {}
        self.edges: List[Edge] = []
        self._succ: Dict[str, List[str]] = {}
        self._pred: Dict[str, List[str]] = {}
        self._fingerprint: Optional[str] = None

    # -- construction -----------------------------------------------------
    def add(self, node: Node, deps: Iterable[str] = (),
            dep_bytes: float = 0.0) -> Node:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name}")
        self._fingerprint = None
        self.nodes[node.name] = node
        self._succ.setdefault(node.name, [])
        self._pred.setdefault(node.name, [])
        for d in deps:
            self.connect(d, node.name, bytes=dep_bytes)
        return node

    def gemm(self, name: str, m: int, n: int, k: int, b: int = 1,
             deps: Iterable[str] = (), dtype_bytes: int = 2, **meta) -> Node:
        return self.add(Node(name, "gemm", b=b, m=m, n=n, k=k,
                             dtype_bytes=dtype_bytes, meta=meta), deps)

    def elementwise(self, name: str, n_elems: int, flops_per_elem: float = 1.0,
                    deps: Iterable[str] = (), dtype_bytes: int = 2,
                    **meta) -> Node:
        return self.add(Node(name, "elementwise", n_elems=int(n_elems),
                             flops_per_elem=flops_per_elem,
                             dtype_bytes=dtype_bytes, meta=meta), deps)

    def gather(self, name: str, rows: int, width: int,
               deps: Iterable[str] = (), dtype_bytes: int = 2) -> Node:
        return self.add(Node(name, "gather", rows=rows, width=width,
                             dtype_bytes=dtype_bytes), deps)

    def comm_op(self, name: str, comm: str, size_bytes: float, axis: str,
                participants: int, deps: Iterable[str] = ()) -> Node:
        assert comm in COMM_KINDS, comm
        return self.add(Node(name, "comm", comm=comm, comm_bytes=size_bytes,
                             comm_axis=axis, comm_participants=participants),
                        deps)

    def connect(self, src: str, dst: str, bytes: float = 0.0,
                cross: bool = False) -> Edge:
        if src not in self.nodes or dst not in self.nodes:
            raise KeyError(f"unknown edge endpoint {src}->{dst}")
        e = Edge(src, dst, bytes=bytes, cross=cross)
        self._fingerprint = None
        self.edges.append(e)
        self._succ[src].append(dst)
        self._pred[dst].append(src)
        return e

    # -- queries ----------------------------------------------------------
    def preds(self, name: str) -> List[str]:
        return self._pred[name]

    def succs(self, name: str) -> List[str]:
        return self._succ[name]

    def topo_order(self) -> List[str]:
        """Kahn topological order (stable w.r.t. insertion order)."""
        indeg = {n: len(set(self._pred[n])) for n in self.nodes}
        order, ready = [], [n for n in self.nodes if indeg[n] == 0]
        seen_edges = set()
        indeg = {n: 0 for n in self.nodes}
        for e in self.edges:
            if (e.src, e.dst) not in seen_edges:
                seen_edges.add((e.src, e.dst))
                indeg[e.dst] += 1
        ready = [n for n in self.nodes if indeg[n] == 0]
        while ready:
            cur = ready.pop(0)
            order.append(cur)
            for s in dict.fromkeys(self._succ[cur]):
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        if len(order) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return order

    def validate(self) -> None:
        self.topo_order()

    def fingerprint(self) -> str:
        """Stable structural hash: node kinds/dims/meta + dependency wiring.

        Two graphs with the same fingerprint produce identical prediction
        traces, so this is the graph component of batched-evaluator and
        prediction-cache keys (repro.core.pathfinder).  Memoized until the
        next structural mutation (add/connect) — sweep drivers call this
        once per point."""
        if self._fingerprint is not None:
            return self._fingerprint
        import hashlib
        h = hashlib.sha1()
        index = {n: i for i, n in enumerate(self.nodes)}
        for name, node in self.nodes.items():
            h.update(repr((
                node.kind, node.b, node.m, node.n, node.k, node.n_elems,
                node.flops_per_elem, node.rows, node.width, node.comm,
                node.comm_bytes, node.comm_axis, node.comm_participants,
                node.dtype_bytes, sorted(node.meta.items()),
                sorted(index[p] for p in set(self._pred[name])),
            )).encode())
        self._fingerprint = h.hexdigest()
        return self._fingerprint

    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes.values())

    def total_io_bytes(self) -> float:
        return sum(n.io_bytes for n in self.nodes.values())

    def comm_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.kind == "comm"]

    def compute_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.kind != "comm"]

    def __len__(self) -> int:
        return len(self.nodes)

    def __repr__(self) -> str:
        return (f"ComputeGraph({self.name!r}, nodes={len(self.nodes)}, "
                f"edges={len(self.edges)}, "
                f"flops={self.total_flops():.3e})")
