"""Compute-graph builders: arch config -> CrossFlow graph (paper input (5)).

Builders produce *training* (fwd + bwd: dgrad + wgrad) or *serving* graphs.
GEMM nodes carry meta flags consumed by repro.core.transform:

  weight=True    participates in the DP gradient all-reduce;
  shard_k=False  contraction dim not shardable (stateful recurrences);
  moe=True       routed-expert GEMM (EP dispatch);
  no_kp=True     not kernel-parallelizable at all.

The per-layer subgraph is built once per distinct layer kind and replicated
`count` times via `repeat` (homogeneous layers — the same observation the
paper uses for DP/KP replicas, §6.5, keeps graphs small at 88 layers).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeCell
from repro.core.graph import ComputeGraph

DTYPE_BYTES = 2                     # bf16 activations/weights in the model


def _linear(g: ComputeGraph, name: str, tokens: int, d_in: int, d_out: int,
            deps, train: bool, **meta):
    """y = x W  (+ bwd: dgrad y W^T, wgrad x^T y)."""
    last = g.gemm(f"{name}.fwd", m=tokens, n=d_out, k=d_in, deps=deps,
                  weight=True, **meta).name
    if train:
        dg = g.gemm(f"{name}.dgrad", m=tokens, n=d_in, k=d_out, deps=[last],
                    **meta).name
        g.gemm(f"{name}.wgrad", m=d_in, n=d_out, k=tokens, deps=[last],
               batch_dim="k", **meta)     # grad bytes counted on .fwd only
        last = dg
    return last


def _attention(g: ComputeGraph, name: str, cfg: ArchConfig, batch: int,
               q_len: int, kv_len: int, deps, train: bool,
               local: bool) -> str:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    tokens, kv_tokens = batch * q_len, batch * kv_len
    last = _linear(g, f"{name}.q", tokens, d, nh * hd, deps, train)
    last_k = _linear(g, f"{name}.kv", kv_tokens, d, 2 * nkv * hd, deps, train)
    ctx = min(kv_len, cfg.local_window) if local else kv_len
    # per-sequence score/value GEMMs batched over (batch, heads);
    # causality halves the scored area during train/prefill
    causal = 0.5 if q_len == kv_len else 1.0
    ctx_eff = max(int(ctx * causal), 1)
    qk = g.gemm(f"{name}.qk", b=batch * nh, m=q_len, n=ctx_eff, k=hd,
                deps=[last, last_k], shard_m=False, shard_n=False,
                batch_dim="b", kp_b=True, gather_act=False)
    sm = g.elementwise(f"{name}.softmax",
                       n_elems=batch * nh * q_len * ctx_eff,
                       flops_per_elem=6.0, deps=[qk.name])
    av = g.gemm(f"{name}.av", b=batch * nh, m=q_len, n=hd, k=ctx_eff,
                deps=[sm.name], shard_m=False, shard_n=False, batch_dim="b",
                kp_b=True, gather_act=False)
    if train:
        # attention backward ~ 2x the fwd score/value GEMM work
        g.gemm(f"{name}.qk.bwd", b=batch * nh, m=q_len, n=ctx_eff, k=hd,
               deps=[av.name], shard_m=False, shard_n=False,
               batch_dim="b", kp_b=True, gather_act=False)
        av2 = g.gemm(f"{name}.av.bwd", b=batch * nh, m=q_len, n=hd,
                     k=ctx_eff, deps=[f"{name}.qk.bwd"],
                     shard_m=False, shard_n=False, batch_dim="b",
                     kp_b=True, gather_act=False)
        last = av2.name
    else:
        last = av.name
    return _linear(g, f"{name}.o", tokens, nh * hd, d, [last], train)


def _ffn(g: ComputeGraph, name: str, cfg: ArchConfig, tokens: int, deps,
         train: bool, d_ff: Optional[int] = None, moe: bool = False) -> str:
    d_ff = d_ff or cfg.d_ff
    mult = 2 if cfg.ffn_kind == "swiglu" else 1
    up = _linear(g, f"{name}.up", tokens, cfg.d_model, mult * d_ff, deps,
                 train, moe=moe)
    act = g.elementwise(f"{name}.act", n_elems=tokens * d_ff,
                        flops_per_elem=4.0, deps=[up])
    return _linear(g, f"{name}.down", tokens, d_ff, cfg.d_model, [act.name],
                   train, moe=moe)


def _moe(g: ComputeGraph, name: str, cfg: ArchConfig, tokens: int, deps,
         train: bool) -> str:
    # router
    r = _linear(g, f"{name}.router", tokens, cfg.d_model, cfg.n_experts,
                deps, train)
    # routed experts: per-token compute = top-k experts' FFW
    routed_tokens = tokens * cfg.experts_per_token
    last = _ffn(g, f"{name}.experts", cfg, routed_tokens, [r], train,
                d_ff=cfg.moe_d_ff, moe=True)
    if cfg.n_shared_experts:
        last_s = _ffn(g, f"{name}.shared", cfg, tokens, deps, train,
                      d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
        cmb = g.elementwise(f"{name}.combine", n_elems=tokens * cfg.d_model,
                            flops_per_elem=2.0, deps=[last, last_s])
        last = cmb.name
    return last


def _rglru(g: ComputeGraph, name: str, cfg: ArchConfig, tokens: int, deps,
           train: bool) -> str:
    w = cfg.lru_width or cfg.d_model
    xin = _linear(g, f"{name}.in", tokens, cfg.d_model, 2 * w, deps, train)
    conv = g.elementwise(f"{name}.conv", n_elems=tokens * w,
                         flops_per_elem=2.0 * cfg.conv1d_width, deps=[xin])
    gates = _linear(g, f"{name}.gates", tokens, w, 2 * w, [conv.name], train,
                    shard_k=False)     # recurrence state: k not shardable
    scan = g.elementwise(f"{name}.scan", n_elems=tokens * w,
                         flops_per_elem=8.0, deps=[gates])
    return _linear(g, f"{name}.out", tokens, w, cfg.d_model, [scan.name],
                   train)


def _xlstm_block(g: ComputeGraph, name: str, cfg: ArchConfig, kind: str,
                 tokens: int, deps, train: bool) -> str:
    hd, nh, d = cfg.resolved_head_dim, cfg.n_heads, cfg.d_model
    qkv = _linear(g, f"{name}.qkv", tokens, d, 3 * nh * hd, deps, train)
    # recurrence: mLSTM matrix memory (hd x hd per head) or sLSTM scalar.
    per_tok_flops = nh * hd * hd * 4.0 if kind == "mlstm" else nh * hd * 8.0
    rec = g.elementwise(f"{name}.rec", n_elems=tokens,
                        flops_per_elem=per_tok_flops, deps=[qkv])
    out = _linear(g, f"{name}.o", tokens, nh * hd, d, [rec.name], train)
    up = _linear(g, f"{name}.up", tokens, d, 2 * d, [out], train)
    return _linear(g, f"{name}.down", tokens, 2 * d, d, [up], train)


def _lstm_layer(g: ComputeGraph, name: str, hidden: int, batch: int,
                seq: int, deps, train: bool) -> str:
    """The paper's LSTM: per step a (batch, 4h, h) GEMM; seq-serialized,
    contraction not shardable across time (shard_k=False on the recurrence).
    DP still shards the batch rows (m)."""
    last = deps
    # input projection for the whole sequence (parallel over time)
    xw = _linear(g, f"{name}.xw", batch * seq, hidden, 4 * hidden, last, train)
    # recurrent matmul: seq sequential steps of (batch, 4h, h)
    hw = g.gemm(f"{name}.hw", b=seq, m=batch, n=4 * hidden, k=hidden,
                deps=[xw], weight=True, batch_dim="m", shard_k=False)
    ew = g.elementwise(f"{name}.gates", n_elems=batch * seq * 4 * hidden,
                       flops_per_elem=3.0, deps=[hw.name])
    if train:
        g.gemm(f"{name}.hw.bwd", b=seq, m=batch, n=hidden, k=4 * hidden,
               deps=[ew.name], batch_dim="m", shard_k=False)
        wg = g.gemm(f"{name}.hw.wgrad", m=hidden, n=4 * hidden,
                    k=batch * seq, deps=[ew.name], batch_dim="k")
        return wg.name
    return ew.name


# ---------------------------------------------------------------------------
# Public builders
# ---------------------------------------------------------------------------


def gemm_graph(m: int, n: int, k: int, train: bool = False) -> ComputeGraph:
    """A single (possibly distributed) GEMM — paper §8 GEMM validation."""
    g = ComputeGraph(f"gemm_{m}x{n}x{k}")
    g.gemm("gemm", m=m, n=n, k=k, weight=True)
    if train:
        g.gemm("gemm.dgrad", m=m, n=k, k=n, deps=["gemm"])
        g.gemm("gemm.wgrad", m=k, n=n, k=m, deps=["gemm"], weight=True,
               batch_dim="k")
    g.validate()
    return g


def build_graph(cfg: ArchConfig, cell: ShapeCell,
                layer_multiplier: bool = True) -> ComputeGraph:
    """Arch config x shape cell -> CrossFlow compute graph.

    With `layer_multiplier` the distinct layer kinds are built once and a
    `repeat` meta records multiplicity; predict_model_time expands timing.
    """
    train = cell.kind == "train"
    batch = cell.global_batch
    if cell.kind == "decode":
        q_len, kv_len = 1, cell.seq_len
    else:
        q_len = kv_len = cell.seq_len
    tokens = batch * q_len

    g = ComputeGraph(f"{cfg.name}|{cell.name}")

    if cfg.family == "lstm":
        last = g.gather("embed", rows=tokens, width=cfg.d_model).name
        for i in range(cfg.n_layers):
            last = _lstm_layer(g, f"layer{i}", cfg.d_model,
                               cell.global_batch, cell.seq_len, [last], train)
        h = _linear(g, "lm_head", tokens, cfg.d_model, cfg.vocab_size,
                    [last], train)
        g.elementwise("ce", n_elems=tokens * cfg.vocab_size,
                      flops_per_elem=4.0, deps=[h], dtype_bytes=4)
        g.validate()
        return g

    last = g.gather("embed", rows=tokens, width=cfg.d_model).name
    if cfg.is_encoder_decoder and cell.kind == "prefill":
        # serving prefill for enc-dec = encode + per-layer cross-KV project
        before = set(g.nodes)
        e = _attention(g, "enc.attn", cfg, batch, cell.seq_len,
                       cell.seq_len, [last], False, local=False)
        e = _ffn(g, "enc.ffn", cfg, cell.seq_len * batch, [e], False)
        for name in set(g.nodes) - before:
            g.nodes[name].meta["repeat"] = cfg.n_encoder_layers
        kvp = _linear(g, "cross.kv", cell.seq_len * batch, cfg.d_model,
                      2 * cfg.n_kv_heads * cfg.resolved_head_dim, [e],
                      False)
        g.nodes[kvp].meta["repeat"] = cfg.n_layers
        g.validate()
        return g
    if cfg.is_encoder_decoder:
        # encoder over seq_len frames; decoder over decoder_len tokens
        enc_tokens = (cell.seq_len * cell.global_batch
                      if cell.kind != "decode" else 0)
        dec_tokens = (min(cfg.decoder_len, cell.seq_len) * cell.global_batch
                      if cell.kind != "decode" else cell.global_batch)
        if enc_tokens:
            before = set(g.nodes)
            e = _attention(g, "enc.attn", cfg, batch, cell.seq_len,
                           cell.seq_len, [last], train, local=False)
            e = _ffn(g, "enc.ffn", cfg, enc_tokens, [e], train)
            for name in set(g.nodes) - before:
                g.nodes[name].meta["repeat"] = cfg.n_encoder_layers
            last = e
        dec_q = (1 if cell.kind == "decode"
                 else min(cfg.decoder_len, cell.seq_len))
        before = set(g.nodes)
        dec = _attention(g, "dec.self", cfg, batch, dec_q,
                         min(cfg.decoder_len, cell.seq_len), [last], train,
                         local=False)
        dec = _attention(g, "dec.cross", cfg, batch, dec_q, cell.seq_len,
                         [dec], train, local=False)
        dec = _ffn(g, "dec.ffn", cfg, dec_tokens, [dec], train)
        for name in set(g.nodes) - before:
            g.nodes[name].meta["repeat"] = cfg.n_layers
        _linear(g, "lm_head", dec_tokens, cfg.d_model, cfg.vocab_size, [dec],
                train)
        g.validate()
        return g

    # decoder-only families: build each distinct (block kind, attn kind) once
    kinds: Dict[Tuple[str, str], int] = {}
    for i in range(cfg.n_layers):
        bk = cfg.block_kind(i)
        ak = cfg.attn_kind(i) if bk == "attn" else "-"
        kinds[(bk, ak)] = kinds.get((bk, ak), 0) + 1
    for (bk, ak), count in kinds.items():
        nm = f"{bk}.{ak}" if ak != "-" else bk
        before = set(g.nodes)
        if bk == "attn":
            a = _attention(g, f"{nm}.attn", cfg, batch, q_len, kv_len,
                           [last], train, local=(ak == "local"))
            if cfg.is_moe:
                e = _moe(g, f"{nm}.moe", cfg, tokens, [a], train)
            else:
                e = _ffn(g, f"{nm}.ffn", cfg, tokens, [a], train)
        elif bk == "rglru":
            r = _rglru(g, f"{nm}.rec", cfg, tokens, [last], train)
            e = _ffn(g, f"{nm}.ffn", cfg, tokens, [r], train)
        elif bk in ("mlstm", "slstm"):
            e = _xlstm_block(g, nm, cfg, bk, tokens, [last], train)
        else:
            raise ValueError(bk)
        for name in set(g.nodes) - before:       # whole group stands for
            g.nodes[name].meta["repeat"] = count  # `count` identical layers
        last = e
    h = _linear(g, "lm_head", tokens, cfg.d_model, cfg.vocab_size, [last],
                train)
    if train or cell.kind == "prefill":
        g.elementwise("ce", n_elems=tokens * cfg.vocab_size,
                      flops_per_elem=4.0, deps=[h])
    g.validate()
    return g


def expand_repeats(g: ComputeGraph) -> float:
    """Sum of per-kind multipliers: Σ repeat over tagged sinks (timing is
    linear in layer count for homogeneous stacks)."""
    return sum(n.meta.get("repeat", 1) for n in g.nodes.values()
               if "repeat" in n.meta) or 1.0
