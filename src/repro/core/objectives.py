"""First-class objective layer: typed, composable Pareto axes.

Scenarios (repro.core.scenarios) historically hard-coded their objective
tuples ("time_s", "devices") in four parallel fold implementations.  This
module extracts the figure-of-merit into a typed registry so every layer
of the stack — scalar records, vectorized metrics folds, traced frontier
folds, cooptimize's differentiable refinement, and the CLI — composes the
SAME definition, written once against an array-module parameter ``xp``
(numpy or jax.numpy), PR6-traffic-style.

Three objective families ship through the registry:

* **energy** (J/step, J/token): dynamic energy from techlib
  energy-per-flop and DRAM/network per-byte energies applied to the
  modeled compute/communication seconds, plus static power integrated
  over wall-clock device occupancy.  Traceable through
  ``techlib.dynamic_energy_scale`` so cooptimize trades DVFS voltage
  against energy under the existing joint power clamp.
* **cost** ($/step, $/token TCO): capex amortization of the per-tech
  device cost table over ``device_lifetime_s`` plus the energy bill at
  ``energy_price_usd_per_kwh`` × ``pue``.
* **goodput** (tokens/s, maximized): throughput derated by
  checkpoint/restore/failure overheads — Young's optimal checkpoint
  interval from ``repro.checkpoint.manager`` write/restore timings and a
  fleet MTBF model from ``repro.runtime.fault``.

Every fold reads a flat ``ctx`` dict.  The contract (scenario folds build
it; see ``Scenario.with_objectives``):

hardware coefficients (from ``pathfinder.pack_hw`` columns or a traced
MicroArch):
  compute_throughput, dram_bw, net_inter_bw, energy_per_flop,
  dram_energy_per_byte, net_energy_per_byte, static_power_w,
  device_cost_usd

per-design constants:
  devices, goodput_fraction

unit values (scenario-kind specific):
  kind "step":  step_time_s, step_compute_s, step_comm_s,
                base_tokens_per_s
  kind "token": token_compute_s, token_comm_s, device_s_per_token,
                base_tokens_per_s

Dynamic energy is attributed to work actually done (underated
compute/comm seconds); static energy to wall-clock occupancy
(step_time_s / device_s_per_token), which carries the feasibility
derates — an infeasible point's +inf occupancy makes its energy +inf, so
the frontier fold's non-finite masking needs no special cases.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

# J per kWh: converts energy_price_usd_per_kwh to $/J
_J_PER_KWH = 3.6e6


@dataclasses.dataclass(frozen=True)
class Objective:
    """One registered figure of merit.

    ``fold(xp, ctx)`` is the single implementation shared by the scalar
    record path (xp=numpy over python floats), the vectorized metrics
    fold (xp=numpy over arrays), and the traced frontier/refine folds
    (xp=jax.numpy over tracers) — parity across folds by construction.
    """

    name: str                     # canonical record field name
    unit: str
    direction: str                # "min" | "max"
    description: str
    fold: Callable[..., object]   # fold(xp, ctx) -> value
    requires: Tuple[str, ...] = ()    # ctx keys read (documentation)
    deps: Tuple[str, ...] = ()        # objective names computed first
    kind: Optional[str] = None        # "step" | "token" | None (any)
    continuous: bool = True           # usable as a refine objective


def _energy_per_step(xp, ctx):
    dyn = (ctx["step_compute_s"]
           * (ctx["compute_throughput"] * ctx["energy_per_flop"]
              + ctx["dram_bw"] * ctx["dram_energy_per_byte"])
           + ctx["step_comm_s"]
           * ctx["net_inter_bw"] * ctx["net_energy_per_byte"])
    return ctx["devices"] * (dyn + ctx["step_time_s"] * ctx["static_power_w"])


def _energy_per_token(xp, ctx):
    dyn = (ctx["token_compute_s"]
           * (ctx["compute_throughput"] * ctx["energy_per_flop"]
              + ctx["dram_bw"] * ctx["dram_energy_per_byte"])
           + ctx["token_comm_s"]
           * ctx["net_inter_bw"] * ctx["net_energy_per_byte"])
    # device_s_per_token already aggregates the fleet (devices x s/token)
    return (ctx["devices"] * dyn
            + ctx["static_power_w"] * ctx["device_s_per_token"])


def _cost_per_step(xp, ctx):
    capex = (ctx["device_cost_usd"] / ctx["device_lifetime_s"]
             * ctx["devices"] * ctx["step_time_s"])
    opex = (ctx["energy_j_per_step"] * ctx["pue"]
            * ctx["energy_price_usd_per_kwh"] / _J_PER_KWH)
    return capex + opex


def _cost_per_token(xp, ctx):
    capex = (ctx["device_cost_usd"] / ctx["device_lifetime_s"]
             * ctx["device_s_per_token"])
    opex = (ctx["energy_j_per_token"] * ctx["pue"]
            * ctx["energy_price_usd_per_kwh"] / _J_PER_KWH)
    return capex + opex


def _goodput(xp, ctx):
    return ctx["base_tokens_per_s"] * ctx["goodput_fraction"]


_HW_KEYS = ("compute_throughput", "dram_bw", "net_inter_bw")
_ENERGY_KEYS = ("energy_per_flop", "dram_energy_per_byte",
                "net_energy_per_byte", "static_power_w")

REGISTRY: Dict[str, Objective] = {o.name: o for o in (
    Objective(
        name="energy_j_per_step", unit="J/step", direction="min",
        description="fleet energy per training step: dynamic "
                    "(flops + DRAM + network) on modeled busy seconds "
                    "plus static power over step wall-clock",
        fold=_energy_per_step, kind="step",
        requires=_HW_KEYS + _ENERGY_KEYS
        + ("devices", "step_time_s", "step_compute_s", "step_comm_s")),
    Objective(
        name="energy_j_per_token", unit="J/token", direction="min",
        description="fleet energy per generated token: dynamic energy on "
                    "per-token busy seconds plus static power over "
                    "device-seconds-per-token occupancy",
        fold=_energy_per_token, kind="token",
        requires=_HW_KEYS + _ENERGY_KEYS
        + ("devices", "token_compute_s", "token_comm_s",
           "device_s_per_token")),
    Objective(
        name="cost_usd_per_step", unit="$/step", direction="min",
        description="TCO per step: device capex amortized over "
                    "device_lifetime_s plus the energy bill at "
                    "energy_price_usd_per_kwh x PUE",
        fold=_cost_per_step, deps=("energy_j_per_step",), kind="step",
        requires=("device_cost_usd", "device_lifetime_s", "pue",
                  "energy_price_usd_per_kwh", "devices", "step_time_s")),
    Objective(
        name="cost_usd_per_token", unit="$/token", direction="min",
        description="TCO per token: capex amortization on "
                    "device-seconds-per-token plus the energy bill",
        fold=_cost_per_token, deps=("energy_j_per_token",), kind="token",
        requires=("device_cost_usd", "device_lifetime_s", "pue",
                  "energy_price_usd_per_kwh", "device_s_per_token")),
    Objective(
        name="goodput_tokens_per_s", unit="tokens/s", direction="max",
        description="throughput derated by checkpoint/restore/failure "
                    "overheads (Young's interval over fleet MTBF for "
                    "train; steady-state availability for serving)",
        fold=_goodput, kind=None,
        requires=("base_tokens_per_s", "goodput_fraction")),
)}

# CLI/spec shorthand per scenario kind: `--objectives energy,cost` means
# J/step + $/step on train, J/token + $/token on the serving family
ALIASES: Dict[str, Dict[str, str]] = {
    "step": {"energy": "energy_j_per_step",
             "cost": "cost_usd_per_step",
             "goodput": "goodput_tokens_per_s"},
    "token": {"energy": "energy_j_per_token",
              "cost": "cost_usd_per_token",
              "goodput": "goodput_tokens_per_s"},
}

# objective model parameters: overridable per-spec via --scenario-param
# (scalar only — these are economic/reliability constants, not sweep axes)
PARAM_DEFAULTS: Dict[str, float] = {
    "energy_price_usd_per_kwh": 0.10,
    "pue": 1.3,                              # datacenter overhead factor
    "device_lifetime_s": 5 * 365.25 * 86400.0,   # 5y amortization
    "device_mtbf_s": 2.0e7,                  # per-device, ~231 days
    "ckpt_write_gbps": 1.0,                  # per-device checkpoint write
    "ckpt_read_gbps": 2.0,                   # per-device restore read
}


def split_objective_params(params) -> Tuple[Dict[str, float],
                                            Dict[str, object]]:
    """Split a scenario param dict into (objective params, rest).

    Mirrors ``traffic.split_params`` shape-wise but must run FIRST in
    ``ScenarioSpec.resolve`` so objective knobs never reach scenarios
    that take no params.  Only EXPLICITLY-provided objective params are
    returned (``Scenario.with_objectives`` merges `PARAM_DEFAULTS`
    later) — resolve() uses emptiness to decide whether the scenario
    needs customizing at all.  Objective params are model constants, not
    design axes — a comma-list value is rejected rather than silently
    making the economy a sweep dimension.
    """
    obj: Dict[str, float] = {}
    rest: Dict[str, object] = {}
    for k, v in dict(params or {}).items():
        if k in PARAM_DEFAULTS:
            if isinstance(v, (tuple, list)):
                raise ValueError(
                    f"objective param {k!r} cannot be a sweep axis "
                    f"(got {v!r}); objective params are scalar model "
                    f"constants")
            obj[k] = float(v)
        else:
            rest[k] = v
    return obj, rest


def resolve_names(names: Sequence[str], kind: str,
                  base: Sequence[str]) -> Tuple[str, ...]:
    """Resolve user objective names to canonical record field names.

    Accepts per-kind aliases ("energy", "cost", "goodput"), canonical
    registry names valid for ``kind``, and the scenario's own base
    objective field names (e.g. "ttft_p99_s", "devices").
    """
    alias = ALIASES.get(kind, {})
    out = []
    for raw in names:
        name = alias.get(raw, raw)
        if name in REGISTRY:
            o = REGISTRY[name]
            if o.kind is not None and o.kind != kind:
                raise ValueError(
                    f"objective {name!r} is per-{o.kind}; the scenario "
                    f"is per-{kind} (use the 'energy'/'cost'/'goodput' "
                    f"aliases to get the kind-matched variant)")
        elif name not in base:
            valid = sorted(set(alias)
                           | {n for n, o in REGISTRY.items()
                              if o.kind in (None, kind)} | set(base))
            raise ValueError(f"unknown objective {raw!r}; valid: "
                             f"{', '.join(valid)}")
        if name not in out:
            out.append(name)
    if not out:
        raise ValueError("empty objective list")
    return tuple(out)


def computation_order(names: Sequence[str]) -> Tuple[Objective, ...]:
    """Registry objectives among ``names`` plus their deps, deps-first."""
    order: list = []

    def visit(name: str) -> None:
        o = REGISTRY.get(name)
        if o is None or o in order:
            return
        for d in o.deps:
            visit(d)
        order.append(o)

    for n in names:
        visit(n)
    return tuple(order)


def direction(name: str) -> str:
    o = REGISTRY.get(name)
    return o.direction if o is not None else "min"


def canonical_signs(names: Sequence[str]) -> Tuple[float, ...]:
    """+1 for minimized objectives, -1 for maximized.

    Canonical objective space is all-minimizing: frontier folds and
    ``objective_values`` emit ``sign * value`` so Pareto dominance,
    lexsort skylines, and cooptimize's descent never branch on
    direction.
    """
    return tuple(-1.0 if direction(n) == "max" else 1.0 for n in names)


def evaluate(xp, objs: Sequence[Objective], ctx: Dict[str, object]
             ) -> Dict[str, object]:
    """Evaluate registry objectives in dependency order.

    Each result is fed back into ``ctx`` so dependents (cost reads
    energy) see it; returns {name: value} for exactly ``objs``.
    """
    out: Dict[str, object] = {}
    for o in objs:
        v = o.fold(xp, ctx)
        ctx[o.name] = v
        out[o.name] = v
    return out
