"""Hierarchical roofline performance model (DeepFlow paper §6.1-§6.3).

Per compute node we estimate operational intensity at *every* level of the
memory hierarchy by searching over tiling strategies (paper: N^L random
tilings that satisfy the capacity constraint at each level, N≈20, L=3), plus
a dataflow/reuse model for the register level (paper eq. 5). Node time is
the hierarchical roofline:

    t = max( flops / compute_throughput,
             traffic_L / bw_L   for every memory level L )

All candidate evaluation is vectorized `jax.numpy`, so node timing is
differentiable w.r.t. the MicroArch parameters (used by the SOE for exact
gradients) and cheap enough to call thousands of times (paper §8: CrossFlow
queries take milliseconds).

TPU adaptation (DESIGN.md): levels are relabelled HBM -> L2(CMEM) ->
L1(VMEM) -> L0(vregs); the L1 tile triple doubles as the Pallas BlockSpec
(bm, bn, bk) recommendation surfaced through `best_gemm_tiling`.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import threading
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.age import MicroArch
from repro.core.graph import ComputeGraph, Node

DATAFLOWS = ("weight_stationary", "output_stationary", "activation_stationary")


@dataclasses.dataclass(frozen=True)
class PPEConfig:
    n_tilings: int = 24             # N per level (paper: ~20)
    kernel_overhead_s: float = 3e-6  # sw-stack launch latency (paper §8 notes)
    vector_frac: float = 1.0 / 16.0  # VPU : MXU throughput ratio (elementwise)
    seed: int = 0


def _pow2_candidates(dim: int, lo: int = 8) -> np.ndarray:
    cands = []
    d = 1
    while d <= dim:
        if d >= min(lo, dim):
            cands.append(d)
        d *= 2
    if dim not in cands:
        cands.append(dim)
    return np.asarray(sorted(set(cands)), dtype=np.int64)


@functools.lru_cache(maxsize=8192)
def _sample_nested_tilings(m: int, n: int, k: int, n_samples: int,
                           seed: int) -> np.ndarray:
    """Sample nested tiling triples for (L2, L1, L0): shape (S, 3 levels, 3).

    Hierarchy constraint: tile at level l-1 divides (<=) tile at level l.
    Mix of random power-of-two samples and square-ish heuristics.
    """
    rng = np.random.default_rng(seed)
    cm, cn, ck = _pow2_candidates(m), _pow2_candidates(n), _pow2_candidates(k)
    out = []
    for _ in range(n_samples):
        t2 = (rng.choice(cm), rng.choice(cn), rng.choice(ck))
        t1 = tuple(int(rng.choice(c[c <= t]))
                   for c, t in zip((cm, cn, ck), t2))
        t0 = tuple(int(rng.choice(c[c <= t]))
                   for c, t in zip((cm, cn, ck), t1))
        out.append((t2, t1, t0))
    # deterministic heuristics: full problem, 512/128-square MXU-aligned tiles
    for side2, side1 in ((512, 128), (1024, 256), (256, 128), (128, 128)):
        t2 = (min(m, side2), min(n, side2), min(k, side2))
        t1 = (min(m, side1), min(n, side1), min(k, side1))
        t0 = (min(m, 128), min(n, 128), min(k, 128))
        out.append((t2, t1, t0))
    arr = np.asarray(out, dtype=np.float64)    # (S, 3, 3)
    arr.setflags(write=False)                  # memoized: callers must not
    return arr                                 # mutate (lru_cache above)


def _blocked_traffic(M, N, K, tm, tn, tk, dtype_bytes):
    """Bytes moved from the level holding (M,N,K) to the level tiled (tm,tn,tk).

    Classic blocked-GEMM streaming: A re-streamed once per N-tile column,
    B once per M-tile row, C read+written once per K-tile pass.
    """
    n_restream_a = jnp.ceil(N / tn)
    n_restream_b = jnp.ceil(M / tm)
    n_c_passes = jnp.maximum(jnp.ceil(K / tk), 1.0)
    return dtype_bytes * (M * K * n_restream_a
                          + K * N * n_restream_b
                          + 2.0 * M * N * n_c_passes * 0.5 + M * N)


def _reg_traffic(flops, nx, ny, reuse):
    """Paper eq. 5: #RegAccess = #Flops * (Nx*Ny + K*Nx + K*Ny)/(2*K*Nx*Ny)."""
    k = jnp.maximum(reuse, 1.0)
    accesses = flops * (nx * ny + k * nx + k * ny) / (2.0 * k * nx * ny)
    return accesses          # in elements; caller multiplies dtype bytes


# LRU-bounded cache of scalar gemm_time results.  Mirrors the pathfinder
# PredictionCache discipline: long resumable sweeps stream millions of
# distinct (arch, shape) keys through this module, and an unbounded dict
# only got flushed wholesale at 200k entries — an eviction cliff that
# threw away every hot key too.  OrderedDict move-to-end keeps the working
# set; the cap evicts one-shot keys oldest-first.  All bookkeeping happens
# under a lock: the sweep runner's thread backend reaches the eager
# concrete path from worker threads, and an LRU (unlike the old
# insert-only dict) mutates on every *read* too.
_GEMM_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_GEMM_CACHE_MAXSIZE = 65536
_GEMM_CACHE_LOCK = threading.Lock()


def _resolve_tracer_type() -> tuple:
    """The public home of the Tracer base class has moved across JAX
    releases (``jax.core.Tracer`` is deprecated in favour of
    ``jax.extend.core`` / internal homes, and the deprecated alias is
    removed on recent versions).  Probe the known locations once at import
    and fall back to an empty tuple (-> duck-typed check) if none exist."""
    import importlib
    for mod_name in ("jax.core", "jax.extend.core", "jax._src.core"):
        try:
            t = getattr(importlib.import_module(mod_name), "Tracer", None)
        except Exception:               # deprecation shims may raise
            continue
        if isinstance(t, type):
            return (t,)
    return ()


_TRACER_TYPES = _resolve_tracer_type()


def is_tracer(v) -> bool:
    """True if ``v`` is an abstract JAX tracer (robust across JAX versions;
    used to disable host-side caching under `jax.jit` / `jax.grad`)."""
    if _TRACER_TYPES:
        return isinstance(v, _TRACER_TYPES)
    # last-resort duck typing: tracers carry an abstract value but no
    # addressable device buffer
    return hasattr(v, "aval") and not hasattr(v, "unsafe_buffer_pointer")


def _cache_key(arch: MicroArch, m, n, k, b, dtype_bytes, cfg: PPEConfig):
    vals = (arch.compute_throughput, arch.dram_bw, *arch.mem_bw,
            *arch.mem_capacity)
    if any(is_tracer(v) for v in vals):
        return None                     # under jit/grad tracing: no caching
    return (tuple(float(v) for v in vals), m, n, k, b, dtype_bytes,
            cfg.n_tilings, cfg.seed, cfg.kernel_overhead_s)


def clear_cache() -> None:
    with _GEMM_CACHE_LOCK:
        _GEMM_CACHE.clear()


def gemm_time(arch: MicroArch, m: int, n: int, k: int, b: int = 1,
              dtype_bytes: int = 2, cfg: PPEConfig = PPEConfig(),
              return_tiling: bool = False):
    """Hierarchical-roofline GEMM time on one node; vectorized tiling search."""
    m, n, k = int(max(m, 1)), int(max(n, 1)), int(max(k, 1))
    key = None
    if not return_tiling:
        key = _cache_key(arch, m, n, k, b, dtype_bytes, cfg)
        if key is not None:
            with _GEMM_CACHE_LOCK:
                hit = _GEMM_CACHE.get(key)
                if hit is not None:
                    _GEMM_CACHE.move_to_end(key)
            if hit is not None:
                return hit
    tilings = _sample_nested_tilings(m, n, k, cfg.n_tilings,
                                     seed=cfg.seed + m * 7 + n * 31 + k * 101)
    b, m, n, k = float(b), float(m), float(n), float(k)  # jnp f32 safety
    flops = 2.0 * b * m * n * k
    t2 = jnp.asarray(tilings[:, 0, :])   # (S,3)
    t1 = jnp.asarray(tilings[:, 1, :])
    t0 = jnp.asarray(tilings[:, 2, :])

    caps, bws, lats = arch.memory_hierarchy()    # L0,L1,L2,DRAM
    cap0, cap1, cap2 = caps[0], caps[1], caps[2]
    bw0, bw1, bw2, bw_dram = bws[0], bws[1], bws[2], arch.dram_bw

    def footprint(t):
        return dtype_bytes * (t[:, 0] * t[:, 2] + t[:, 2] * t[:, 1]
                              + t[:, 0] * t[:, 1])

    # capacity feasibility (soft penalty keeps the search differentiable)
    pen = (jnp.maximum(footprint(t2) / jnp.maximum(cap2, 1.0) - 1.0, 0.0)
           + jnp.maximum(footprint(t1) / jnp.maximum(cap1, 1.0) - 1.0, 0.0)
           + jnp.maximum(footprint(t0) / jnp.maximum(cap0, 1.0) - 1.0, 0.0))

    # traffic per level (paper §6.2: walk upward from main memory)
    traffic_dram = b * _blocked_traffic(m, n, k, t2[:, 0], t2[:, 1], t2[:, 2],
                                        dtype_bytes)
    n_t2 = (jnp.ceil(m / t2[:, 0]) * jnp.ceil(n / t2[:, 1])
            * jnp.ceil(k / t2[:, 2]))
    traffic_l2 = b * n_t2 * _blocked_traffic(
        t2[:, 0], t2[:, 1], t2[:, 2], t1[:, 0], t1[:, 1], t1[:, 2], dtype_bytes)
    n_t1 = n_t2 * (jnp.ceil(t2[:, 0] / t1[:, 0]) * jnp.ceil(t2[:, 1] / t1[:, 1])
                   * jnp.ceil(t2[:, 2] / t1[:, 2]))
    traffic_l1 = b * n_t1 * _blocked_traffic(
        t1[:, 0], t1[:, 1], t1[:, 2], t0[:, 0], t0[:, 1], t0[:, 2], dtype_bytes)

    # register level: dataflow reuse model (paper §6.3, eq. 5); best of 3
    nx, ny = arch.tech.compute.systolic_dims
    reuse_ws = t0[:, 2] / max(nx, 1)     # weight stationary: reuse along K
    reuse_os = t0[:, 2] / max(ny, 1)     # output stationary
    reuse_as = t0[:, 0] / max(nx, 1)     # activation stationary: reuse along M
    reuse = jnp.maximum(jnp.maximum(reuse_ws, reuse_os), reuse_as)
    traffic_l0 = _reg_traffic(flops, nx, ny, reuse) * dtype_bytes

    t_compute = flops / arch.compute_throughput
    times = jnp.stack([
        jnp.broadcast_to(t_compute, traffic_dram.shape),
        traffic_dram / bw_dram,
        traffic_l2 / jnp.maximum(bw2, 1.0),
        traffic_l1 / jnp.maximum(bw1, 1.0),
        traffic_l0 / jnp.maximum(bw0, 1.0),
    ], axis=0)
    per_candidate = jnp.max(times, axis=0) * (1.0 + 10.0 * pen)
    best = jnp.argmin(per_candidate)
    t_best = per_candidate[best] + cfg.kernel_overhead_s
    if return_tiling:
        return t_best, np.asarray(tilings[int(best)], dtype=np.int64)
    if key is not None:
        with _GEMM_CACHE_LOCK:
            _GEMM_CACHE[key] = t_best
            _GEMM_CACHE.move_to_end(key)
            while len(_GEMM_CACHE) > _GEMM_CACHE_MAXSIZE:
                _GEMM_CACHE.popitem(last=False)
    return t_best


def best_gemm_tiling(arch: MicroArch, m: int, n: int, k: int,
                     dtype_bytes: int = 2,
                     cfg: PPEConfig = PPEConfig()) -> Tuple[Tuple[int, int, int], ...]:
    """The (L2, L1, L0) tile triples minimizing predicted time.

    The L1 triple is the VMEM working set — i.e. the Pallas BlockSpec
    (bm, bn, bk) recommendation used by repro.kernels.gemm.
    """
    _, tiling = gemm_time(arch, m, n, k, dtype_bytes=dtype_bytes, cfg=cfg,
                          return_tiling=True)
    return tuple(tuple(int(x) for x in level) for level in tiling)


def elementwise_time(arch: MicroArch, n_elems: float, flops_per_elem: float,
                     dtype_bytes: int = 2, cfg: PPEConfig = PPEConfig()):
    n_elems = float(n_elems)
    flops = n_elems * flops_per_elem
    bytes_moved = 2.0 * n_elems * dtype_bytes
    t = jnp.maximum(flops / (arch.compute_throughput * cfg.vector_frac),
                    bytes_moved / arch.dram_bw)
    return t + cfg.kernel_overhead_s


def gather_time(arch: MicroArch, rows: float, width: float,
                dtype_bytes: int = 2, cfg: PPEConfig = PPEConfig()):
    bytes_moved = 2.0 * float(rows) * float(width) * dtype_bytes
    return bytes_moved / arch.dram_bw + cfg.kernel_overhead_s


def node_time(arch: MicroArch, node: Node, cfg: PPEConfig = PPEConfig()):
    """Time one compute node (comm nodes are timed by the network model)."""
    if node.kind == "gemm":
        return gemm_time(arch, node.m, node.n, node.k, b=node.b,
                         dtype_bytes=node.dtype_bytes, cfg=cfg)
    if node.kind == "elementwise":
        return elementwise_time(arch, node.n_elems, node.flops_per_elem,
                                node.dtype_bytes, cfg)
    if node.kind == "gather":
        return gather_time(arch, node.rows, node.width, node.dtype_bytes, cfg)
    if node.kind == "comm":
        raise ValueError("comm nodes are timed by repro.core.placement")
    raise ValueError(f"unknown node kind {node.kind}")


def operational_intensity(node: Node) -> float:
    """Compulsory-traffic OI (flops / main-memory bytes) — used by the
    motivation study (paper Fig. 1)."""
    io = node.io_bytes
    return node.flops / io if io else 0.0


# ---------------------------------------------------------------------------
# Memory-capacity pressure (serving scenario hook)
# ---------------------------------------------------------------------------

CAPACITY_PRESSURE_KNEE = 0.85


def capacity_pressure_derate(occupancy: float,
                             knee: float = CAPACITY_PRESSURE_KNEE) -> float:
    """Bandwidth derate for main-memory capacity pressure (KV caches).

    The hierarchical roofline above times each kernel against the *clean*
    main-memory bandwidth; when resident state (weights + KV cache in
    serving) approaches capacity, allocator fragmentation and lost
    batching/prefetch headroom erode achievable bandwidth before the
    capacity wall.  Model: no penalty below ``knee`` occupancy, a quadratic
    ramp to 1.5x between knee and full, and infeasible (inf) at >= 100%
    (the workload simply does not fit; `simulate.serving_breakdown` reports
    feasible=False).
    """
    occ = float(occupancy)
    if occ >= 1.0:
        return float("inf")
    over = max(occ - knee, 0.0) / max(1.0 - knee, 1e-9)
    return 1.0 + 0.5 * over * over


def capacity_pressure_derate_soft(occupancy,
                                  knee: float = CAPACITY_PRESSURE_KNEE):
    """Differentiable (jnp, tracer-safe) variant of
    `capacity_pressure_derate` for gradient-based refinement
    (`repro.core.cooptimize`): same quadratic ramp between ``knee`` and
    full occupancy, but the hard infeasibility wall at >= 100% becomes a
    steep quadratic barrier so gradients keep pointing back toward the
    feasible region instead of vanishing into inf."""
    occ = jnp.asarray(occupancy)
    over = jnp.maximum(occ - knee, 0.0) / max(1.0 - knee, 1e-9)
    wall = jnp.maximum(occ - 1.0, 0.0)
    return 1.0 + 0.5 * over * over + 1e3 * wall * wall
