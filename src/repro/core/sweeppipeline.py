"""Pipelined device-resident sweep executor — the 10^6-point hot path.

`sweeprunner.eval_labels` resolves labels, packs hardware vectors, runs the
batched evaluator and folds records in ONE synchronous loop per chunk, so a
sweep alternates host-side Python with device compute and JSONL writes on
the critical path.  This module rebuilds that hot path as an asynchronous,
double-buffered pipeline (`SweepRunner(backend="pipeline")`):

  * a **producer thread** resolves and packs chunk N+1 while chunk N runs:
    per-label work is reduced to dict lookups — `resolve_label` skeletons
    (scenario, parsed strategy, system graph, workload graphs, compiled-fn
    keys, record templates) are memoized per (arch, cell, mesh, strategy),
    AGE'd-and-packed hardware rows per (logic, hbm, net, scale) in a
    process-global row cache — and prediction-cache probes are batched
    into one locked pass (`PredictionCache.get_many`); the `(B, HW_DIM)`
    miss matrix is a NumPy gather over unique rows, never a per-label
    Python pack;
  * the **device stage** dispatches consecutive chunks as one *superbatch*
    under JAX async dispatch: all eval points of a design are fused into a
    single compiled per-skeleton function (a serving design's prefill and
    decode graphs cost one dispatch, not two), block-padded so successive
    packs reuse a handful of compiled shapes, and `jax.pmap`-sharded
    row-wise when the batch is large enough to amortize pmap's dispatch
    cost (below that, one jitted call keeps XLA's intra-op parallelism);
  * a **writer thread** blocks on chunk N-1's device buffers, folds
    records through the scenario's `metrics_fold` fast path and commits
    JSONL rows + checkpoint lines off the critical path, preserving chunk
    order — `resume` semantics are byte-identical to the synchronous
    backends (a crash loses at most the in-flight superbatches).

`run_frontier` is the device-resident reduction mode behind ``pathfind
sweep --frontier-only``: the scenario's objective fold
(`Scenario.frontier_fold`) and a streaming Pareto merge
(`pathfinder.frontier_merge`) are fused INTO the compiled eval fn with the
carried frontier state donated between calls, so a 10^6-point sweep pulls
only the surviving frontier (plus its raw metric rows) to host — full
per-point rows never materialize.

`benchmarks/sweep_pipeline.py` asserts the throughput gain over the PR4
synchronous sharded path and the frontier/full-materialization parity.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import queue
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import pathfinder, scenarios
from repro.core.parallelism import Strategy
from repro.core.placement import mesh_system

# design points per device dispatch: consecutive chunks are packed into one
# superbatch so per-dispatch overhead amortizes over ~10x more points than
# the default chunk size (commit granularity stays per chunk)
SUPERBATCH = 256
# superbatches packed (and AOT-submitted) ahead of the device stage: while
# the device runs superbatch N, the producer has already handed N+1..N+k's
# compiled-fn keys and padded shapes to the compile service, so a cold
# sweep's XLA compiles run off the critical path (see repro.core
# .compileahead).  0 disables lookahead (and AOT prefetch) entirely.
COMPILE_AHEAD = 2
# packed-superbatch lookahead per queue (producer -> device -> writer):
# 2 = double buffering at each stage boundary
QUEUE_DEPTH = 2
# minimum per-group batch before the pmap-sharded path pays for itself: a
# pmap dispatch costs milliseconds of host-side argument sharding, while a
# jitted call still uses every core through XLA's intra-op parallelism
PMAP_MIN_ROWS = 1024

# process-global packed-hardware rows, keyed like `sweeprunner._HW_CACHE`
# (tech axis + budget overrides + profile digest).  `pack_hw` pulls 13
# scalars out of JAX arrays (~30us of device syncs per point) — paying
# that once per process instead of once per run keeps the producer's
# per-label cost at dict-lookup speed.  LRU-capped: each entry pins a
# MicroArch, and a long-lived process sweeping many tech/scale/profile
# axes must not grow it forever (same treatment as roofline._GEMM_CACHE).
_ROW_CACHE: "collections.OrderedDict[tuple, tuple]" = \
    collections.OrderedDict()
_ROW_CACHE_MAXSIZE = 4096
_ROW_LOCK = threading.Lock()


def _row_cache_get(key) -> Optional[tuple]:
    with _ROW_LOCK:
        ent = _ROW_CACHE.get(key)
        if ent is not None:
            _ROW_CACHE.move_to_end(key)
        return ent


def _row_cache_put(key, ent: tuple) -> tuple:
    with _ROW_LOCK:
        ent = _ROW_CACHE.setdefault(key, ent)
        _ROW_CACHE.move_to_end(key)
        while len(_ROW_CACHE) > _ROW_CACHE_MAXSIZE:
            _ROW_CACHE.popitem(last=False)
        return ent


def _join_producer(producer: threading.Thread, pack_q: "queue.Queue"):
    """Join the producer, draining its bounded queue while waiting.

    An exception that escapes the consumer loop (KeyboardInterrupt landing
    outside the inner try) leaves the producer blocked in a `put()` on the
    full queue with nobody reading; a bare `join()` would then hang
    forever.  Draining between join attempts unblocks it, and the
    producer's own error check / sentinel path finishes it off.
    """
    while True:
        producer.join(timeout=0.1)
        if not producer.is_alive():
            return
        try:
            while True:
                pack_q.get_nowait()
        except queue.Empty:
            pass


@dataclasses.dataclass
class _DesignSkeleton:
    """Everything shared by labels of one (arch, cell, mesh, strategy):
    resolved once, then every label in the cell is a pair of dict hits."""

    scn: scenarios.Scenario
    cfg: object
    strategy: Strategy
    system: object
    graphs: Tuple
    evaluators: Tuple[pathfinder.BatchedEvaluator, ...]
    fold: Optional[Callable]         # device frontier-objective fold
    mfold: Optional[Callable]        # host metric fold (record fast path)
    base_fields: Dict                # record template (label-field order)
    key_pre: str                     # "arch|cell|mesh" of point_key
    key_suf: str                     # strategy part of point_key
    # scenario identity (spec params + cell variant) baked into fold/mfold;
    # groups and the frontier compile cache must not mix fold_keys even
    # when the eval-shape keys coincide (variants share graphs, not walls)
    fold_key: tuple = ()
    # systolic_dims -> per-eval-point compiled-skeleton key tuple
    skel_keys: Dict[tuple, tuple] = dataclasses.field(default_factory=dict)

    @property
    def ppd(self) -> int:
        return len(self.graphs)


@dataclasses.dataclass
class _Group:
    """One compiled-function batch inside a pack: all miss labels sharing
    a design skeleton + systolic dims."""

    skel: _DesignSkeleton
    keys: tuple                      # per-eval-point skeleton keys
    template: object                 # MicroArch supplying static leaves
    ridx: List[int] = dataclasses.field(default_factory=list)
    row_bytes: List[bytes] = dataclasses.field(default_factory=list)
    slots: List[tuple] = dataclasses.field(default_factory=list)
    gidx: List[int] = dataclasses.field(default_factory=list)
    out: object = None               # in-flight device result
    n: int = 0


@dataclasses.dataclass
class _Pack:
    """One packed superbatch: chunks + per-label resolution + cache hits
    + compiled-batch groups (built by the producer stage)."""

    chunks: List
    meta: List[List]                 # [ci][li] -> (skel, hw entry)
    cached: Dict[tuple, np.ndarray]  # (ci, li) -> (ppd, 5) f64 rows
    groups: Dict[tuple, _Group]


@dataclasses.dataclass
class _BucketOut:
    """One in-flight bucketed megabatch result, shared by every (group,
    eval-point) slice that rode in it; materialized to host once."""

    out: object                      # device array, (B, 5) or (D, B/D, 5)
    _host: Optional[np.ndarray] = None

    def rows(self) -> np.ndarray:
        if self._host is None:
            host = np.asarray(self.out, dtype=np.float64)
            self._host = host.reshape(-1, host.shape[-1])
            self.out = None
        return self._host


class PipelineExecutor:
    """Asynchronous producer -> device -> writer pipeline for one spec.

    One instance per `SweepRunner.run` call; all memoization (skeletons,
    packed hardware rows, compiled functions via the process-wide
    `pathfinder._COMPILED` LRU) is keyed so repeated runs stay warm.
    """

    def __init__(self, spec, cache=pathfinder.DEFAULT_CACHE,
                 superbatch: int = SUPERBATCH,
                 devices: Optional[int] = None,
                 threads: Optional[bool] = None,
                 compile_ahead: Optional[int] = None,
                 bucketing: Optional[bool] = None):
        from repro.core import compileahead, sweeprunner
        self.spec = spec
        self.cache = pathfinder.resolve_cache(cache)
        self.ppe = sweeprunner.spec_ppe(spec)
        self.superbatch = max(int(superbatch), spec.chunk_size, 1)
        self.devices = devices if devices is not None \
            else jax.local_device_count()
        # producer/writer threads only pay off when the host has spare
        # cores for them: on <=3 cores the GIL serializes the Python
        # stages anyway and thread churn just fights XLA's own pool, so
        # the inline mode double-buffers through JAX async dispatch alone
        self.threads = threads if threads is not None \
            else (os.cpu_count() or 1) >= 4
        self.compile_ahead = COMPILE_AHEAD if compile_ahead is None \
            else max(int(compile_ahead), 0)
        self.bucketing = compileahead.resolve_bucketed(bucketing)
        self.block = sweeprunner.SHARD_BLOCK
        self._skels: Dict[tuple, _DesignSkeleton] = {}
        self._scn_fp = json.dumps(spec.scenario_spec.to_dict(),
                                  sort_keys=True)
        self._hw: Dict[tuple, tuple] = {}
        self._rows: List[np.ndarray] = []     # unique packed hw rows
        self._rowmat: Optional[np.ndarray] = None
        # store keys the AOT service pinned on our behalf (see _prefetch);
        # the device stage releases a key's pins after its first dispatch
        self._aot_pins: "collections.Counter" = collections.Counter()
        self._pin_lock = threading.Lock()
        self._frontier_capacity: Optional[int] = None

    # -- memoized resolution ---------------------------------------------
    def _hw_entry(self, lb) -> tuple:
        """(hw arch, row index, row bytes, scale string) of one label."""
        from repro.core import sweeprunner
        hkey = (lb.logic, lb.hbm, lb.net, lb.scale)
        ent = self._hw.get(hkey)
        if ent is None:
            gkey = hkey + (self.spec.area_mm2, self.spec.power_w,
                           sweeprunner._profile_key(self.spec))
            cached = _row_cache_get(gkey)
            if cached is None:
                hw = sweeprunner._hardware(self.spec, lb.logic, lb.hbm,
                                           lb.net, lb.scale)
                row = pathfinder.pack_hw(hw)
                cached = _row_cache_put(
                    gkey, (hw, row, row.tobytes(), f"{lb.scale:g}"))
            hw, row, rbytes, scale_str = cached
            ridx = len(self._rows)
            self._rows.append(row)
            self._rowmat = None
            ent = (hw, ridx, rbytes, scale_str)
            self._hw[hkey] = ent
        return ent

    def _skeleton(self, lb) -> _DesignSkeleton:
        from repro.core import sweeprunner
        skey = (lb.arch, lb.cell, lb.mesh, lb.strategy)
        sk = self._skels.get(skey)
        if sk is None:
            hw = self._hw_entry(lb)[0]
            scn = sweeprunner.scenario_for(self.spec, lb.cell)
            cfg = get_config(lb.arch)
            st = Strategy.parse(lb.strategy)
            system = mesh_system(lb.mesh)
            dp = scenarios.DesignPoint(
                arch=lb.arch, cell=lb.cell, mesh=lb.mesh, logic=lb.logic,
                hbm=lb.hbm, net=lb.net, scale=lb.scale, strategy=st,
                cfg=cfg, hw=hw, system=system)
            eps = scn.eval_points(dp)
            evs = tuple(pathfinder.BatchedEvaluator(
                ep.graph, st, system=ep.system, ppe=self.ppe,
                pod_bw=ep.pod_bw, cache=None) for ep in eps)
            name = st.name
            mesh_str = "x".join(map(str, lb.mesh))
            base = {"arch": lb.arch, "cell": lb.cell, "mesh": mesh_str,
                    "logic": None, "hbm": None, "net": None, "scale": None,
                    "strategy": name, "devices": st.devices}
            sk = _DesignSkeleton(
                scn=scn, cfg=cfg, strategy=st, system=system,
                graphs=tuple(ep.graph for ep in eps), evaluators=evs,
                fold=scn.frontier_fold(cfg, st),
                mfold=scn.metrics_fold(cfg, st, lb.cell),
                base_fields=base,
                key_pre=f"{lb.arch}|{lb.cell}|{mesh_str}", key_suf=name,
                fold_key=(self._scn_fp, lb.cell))
            self._skels[skey] = sk
        return sk

    def _group_keys(self, sk: _DesignSkeleton, hw) -> tuple:
        sd = tuple(hw.tech.compute.systolic_dims)
        keys = sk.skel_keys.get(sd)
        if keys is None:
            keys = tuple(ev._skeleton(hw) for ev in sk.evaluators)
            sk.skel_keys[sd] = keys
        return keys

    def _design_point(self, lb, sk: _DesignSkeleton,
                      hw) -> scenarios.DesignPoint:
        return scenarios.DesignPoint(
            arch=lb.arch, cell=lb.cell, mesh=lb.mesh, logic=lb.logic,
            hbm=lb.hbm, net=lb.net, scale=lb.scale, strategy=sk.strategy,
            cfg=sk.cfg, hw=hw, system=sk.system)

    # -- compiled functions ----------------------------------------------
    def _design_scalar(self, group: _Group) -> Callable:
        """v (HW_DIM,) -> (ppd, 5) metric rows: every eval point of one
        design fused into a single traced function."""
        scalars = [ev._scalar_fn(group.template)
                   for ev in group.skel.evaluators]

        def design(v):
            return jnp.stack([f(v) for f in scalars])
        return design

    def _eval_build(self, group: _Group, n_dev: int) -> Callable:
        if n_dev > 1:
            return lambda: jax.pmap(jax.vmap(self._design_scalar(group)))
        return lambda: jax.jit(jax.vmap(self._design_scalar(group)))

    def _compiled_eval(self, group: _Group, n_dev: int) -> Callable:
        key = ("design", group.keys, n_dev)
        return pathfinder.compiled_entry(key, self._eval_build(group, n_dev))

    def _design_vectors(self, group: _Group) -> List:
        """One canonical `DesignVector` per eval point of the group's
        design, registered under the same per-evaluator skeleton keys the
        serial backend uses — so serial and pipelined sweeps share (and
        bit-match) the exact same bucket executables."""
        from repro.core import compileahead
        avals = (jax.ShapeDtypeStruct((pathfinder.HW_DIM,), jnp.float32),)
        return [compileahead.design_vector(
                    ("skel", key),
                    lambda ev=ev: ev._scalar_fn(group.template), avals)
                for key, ev in zip(group.keys, group.skel.evaluators)]

    def _frontier_build(self, group: _Group, capacity: int) -> Callable:
        def build():
            design = self._design_scalar(group)
            fold = group.skel.fold

            def step(hw, idx, state):
                rows = jax.vmap(design)(hw)                  # (B, ppd, 5)
                vals = jax.vmap(fold)(rows, hw)              # (B, n_obj)
                vals = jnp.where((idx < 0)[:, None], jnp.inf, vals)
                payload = rows.reshape(rows.shape[0], -1)
                return pathfinder.frontier_merge(state, vals, payload, idx)
            # the carried frontier state is donated: chunk N's merge reuses
            # chunk N-1's buffers instead of allocating a fresh state
            return jax.jit(step, donate_argnums=2)
        return build

    def _compiled_frontier(self, group: _Group, capacity: int) -> Callable:
        # fold_key matters here: the objective fold (SLO walls, traffic
        # consts) is traced into the step, unlike the pure eval fn
        key = ("frontier", group.keys, group.skel.fold_key, capacity)
        return pathfinder.compiled_entry(
            key, self._frontier_build(group, capacity))

    # -- packing (producer side) -----------------------------------------
    def pack(self, chunks: Sequence) -> _Pack:
        """Resolve + vectorize one superbatch of chunks: memoized skeleton
        and hardware-row lookups per label, one batched cache probe, and
        miss row-indices grouped per compiled function."""
        meta: List[List] = []
        cached: Dict[tuple, np.ndarray] = {}
        groups: Dict[tuple, _Group] = {}
        chunk_size = self.spec.chunk_size

        def group_for(sk, hw):
            # group identity includes the scenario fold_key: variants share
            # eval shapes (g.keys, so the compiled eval fn and cache rows
            # stay shared) but their folds bake different walls/consts
            keys = self._group_keys(sk, hw)
            gkey = (keys, sk.fold_key)
            g = groups.get(gkey)
            if g is None:
                g = groups.setdefault(gkey, _Group(skel=sk, keys=keys,
                                                   template=hw))
            return g

        if self.cache is None:          # lean single-pass (no probes)
            for ci, chunk in enumerate(chunks):
                base_gidx = chunk.index * chunk_size
                row_meta = []
                meta.append(row_meta)
                for li, lb in enumerate(chunk.labels):
                    ent = self._hw_entry(lb)
                    sk = self._skeleton(lb)
                    row_meta.append((sk, ent))
                    g = group_for(sk, ent[0])
                    g.ridx.append(ent[1])
                    g.slots.append((ci, li))
                    g.gidx.append(base_gidx + li)
            return _Pack(chunks=list(chunks), meta=meta, cached=cached,
                         groups=groups)

        probe_keys: List[tuple] = []
        probe_slots: List[tuple] = []
        pending: List[tuple] = []       # (slot, gidx, sk, ent)
        for ci, chunk in enumerate(chunks):
            base_gidx = chunk.index * chunk_size
            row_meta = []
            meta.append(row_meta)
            for li, lb in enumerate(chunk.labels):
                ent = self._hw_entry(lb)
                sk = self._skeleton(lb)
                slot = (ci, li)
                row_meta.append((sk, ent))
                pending.append((slot, base_gidx + li, sk, ent))
                for skel_key in self._group_keys(sk, ent[0]):
                    probe_keys.append((skel_key, ent[2]))
                    probe_slots.append(slot)
        hits: Dict[tuple, List] = {}
        for slot, row in zip(probe_slots,
                             self.cache.get_many(probe_keys)):
            hits.setdefault(slot, []).append(row)
        for slot, gidx, sk, ent in pending:
            got = hits.get(slot)
            if got is not None and all(r is not None for r in got):
                cached[slot] = np.stack(got)
                continue
            hw, ridx, rbytes, _ = ent
            g = group_for(sk, hw)
            g.ridx.append(ridx)
            g.row_bytes.append(rbytes)
            g.slots.append(slot)
            g.gidx.append(gidx)
        return _Pack(chunks=list(chunks), meta=meta, cached=cached,
                     groups=groups)

    # -- device stage -----------------------------------------------------
    def _gather(self, g: _Group) -> np.ndarray:
        """(B, HW_DIM) f32 matrix of a group's rows — one NumPy gather
        over the unique-row table, no per-label packing.

        Runs on the dispatch thread while the producer may be appending
        rows for the NEXT pack, so work off a local snapshot: every index
        this group references existed when the pack was built, and a
        concurrent append can only grow the table past what we need.
        """
        idx = np.asarray(g.ridx, dtype=np.intp)
        mat = self._rowmat
        need = int(idx.max()) + 1 if idx.size else 0
        if mat is None or mat.shape[0] < need:
            mat = np.stack(self._rows[:max(need, len(self._rows))]) \
                .astype(np.float32)
            self._rowmat = mat
        return mat[idx]

    def _pad_plan(self, n: int) -> Tuple[int, int]:
        """(n_dev, padded row target) for an ``n``-row dispatch."""
        n_dev = max(min(self.devices, n), 1)
        if n < PMAP_MIN_ROWS:
            n_dev = 1                 # jit + XLA intra-op parallelism
        quantum = n_dev * self.block
        return n_dev, -(-n // quantum) * quantum

    def _padded(self, g: _Group) -> Tuple[np.ndarray, int]:
        hw = self._gather(g)
        n = hw.shape[0]
        n_dev, target = self._pad_plan(n)
        if target != n:
            hw = np.concatenate([hw, np.repeat(hw[-1:], target - n,
                                               axis=0)])
        return hw, n_dev

    def _release_pins(self, key: tuple) -> None:
        """Release the LRU-eviction pins the AOT service took for ``key``
        (called after the key's first dispatch of this run)."""
        with self._pin_lock:
            n = self._aot_pins.pop(key, 0)
        for _ in range(n):
            pathfinder.unpin_compiled(key)

    def _release_all_pins(self) -> None:
        with self._pin_lock:
            pins, self._aot_pins = self._aot_pins, collections.Counter()
        for key, n in pins.items():
            for _ in range(n):
                pathfinder.unpin_compiled(key)

    def _bucket_plan(self, pack: _Pack) -> Dict[int, tuple]:
        """Group the pack's (group, eval-point) pairs by canonical bucket.

        Returns ``{bucket.id: (bucket, items)}`` with items
        ``(group, eval_idx, design_vector, n_rows)`` — the shared shape
        plan used by both `_prefetch` (AOT submit) and `dispatch`.
        """
        buckets: Dict[int, tuple] = {}
        for g in pack.groups.values():
            n = len(g.ridx)
            if not n:
                continue
            for e, dv in enumerate(self._design_vectors(g)):
                buckets.setdefault(dv.bucket.id, (dv.bucket, []))[1] \
                    .append((g, e, dv, n))
        return buckets

    @staticmethod
    def _bucket_args(bucket, rows: np.ndarray, didx: np.ndarray,
                     packs_by_item: List[tuple], n_dev: int) -> tuple:
        """Assemble one megabatch's argument tuple: per-row coefficient
        packs (gathered from the per-item design vectors) + the hardware
        rows, reshaped with a leading device axis when pmap-sharded."""
        packs = tuple(
            np.stack([p[c] for p in packs_by_item])[didx]
            for c in range(len(bucket.classes)))
        if n_dev > 1:
            per = rows.shape[0] // n_dev
            rows = rows.reshape(n_dev, per, rows.shape[1])
            packs = tuple(p.reshape((n_dev, per) + p.shape[1:])
                          for p in packs)
        return (packs, rows)

    def dispatch(self, pack: _Pack) -> None:
        """Launch every group's fused eval under JAX async dispatch; the
        results stay on device until `finalize` folds them.

        With bucketing (default) all (group, eval-point) pairs whose
        canonical jaxprs landed in one bucket are dispatched as a single
        megabatch through the shared bucket executable — O(shape-buckets)
        compiles per pack instead of O(designs); per-design coefficient
        packs ride along as batch inputs, so records stay bit-identical
        to per-group dispatch of the same executables."""
        from repro.core import compileahead
        if not self.bucketing:
            for g in pack.groups.values():
                g.n = len(g.ridx)
                if not g.n:
                    continue
                hw, n_dev = self._padded(g)
                fn = self._compiled_eval(g, n_dev)
                if n_dev > 1:
                    g.out = fn(jnp.asarray(
                        hw.reshape(n_dev, hw.shape[0] // n_dev,
                                   pathfinder.HW_DIM)))
                else:
                    g.out = fn(jnp.asarray(hw))
                self._release_pins(("design", g.keys, n_dev))
            return
        for g in pack.groups.values():
            g.n = len(g.ridx)
            if g.n:
                g.out = [None] * g.skel.ppd
        for bucket, items in self._bucket_plan(pack).values():
            rows = np.concatenate([self._gather(g) for g, _, _, _ in items])
            didx = np.concatenate([np.full(n, j, dtype=np.intp)
                                   for j, (_, _, _, n) in enumerate(items)])
            n = rows.shape[0]
            n_dev, target = self._pad_plan(n)
            if target != n:
                rows = np.concatenate(
                    [rows, np.repeat(rows[-1:], target - n, axis=0)])
                didx = np.concatenate(
                    [didx, np.repeat(didx[-1:], target - n)])
            packs, hw = self._bucket_args(
                bucket, rows, didx, [dv.packs for _, _, dv, _ in items],
                n_dev)
            entry = compileahead.batch_entry(bucket, n_dev)
            out = entry(packs, jnp.asarray(hw))
            self._release_pins(("cabucket", bucket.id, n_dev))
            holder = _BucketOut(out=out)
            off = 0
            for g, e, _, n_g in items:
                g.out[e] = (holder, off, off + n_g)
                off += n_g

    def finalize(self, pack: _Pack) -> List[List[Dict]]:
        """Block on the pack's device results, fold records per chunk (in
        chunk order), and publish the fresh rows to the prediction cache
        under the same per-eval-point keys the synchronous backends use.

        Metric folding is vectorized: each group's whole result batch
        goes through the scenario's `metrics_fold` in one NumPy pass, so
        the per-label Python is one dict merge + the point key."""
        md_store: List[List] = [[None] * len(c.labels)
                                for c in pack.chunks]
        rows_by_slot: Dict[tuple, np.ndarray] = {}
        puts: List[tuple] = []
        n_metrics = len(pathfinder.METRICS)
        for g in pack.groups.values():
            if not g.n:
                continue
            if isinstance(g.out, list):
                # bucketed: one (B, 5) slice per eval point, possibly from
                # different megabatches; stack to the (B, ppd, 5) layout
                out = np.stack(
                    [holder.rows()[lo:hi] for holder, lo, hi in g.out],
                    axis=1)
            else:
                out = np.asarray(g.out, dtype=np.float64)
                out = out.reshape(-1, g.skel.ppd, n_metrics)[:g.n]
            g.out = None
            if g.skel.mfold is not None:
                for (ci, li), md in zip(g.slots,
                                        g.skel.mfold(out,
                                                     self._gather(g))):
                    md_store[ci][li] = md
            else:
                for j, slot in enumerate(g.slots):
                    rows_by_slot[slot] = out[j]
            if self.cache is not None:
                for j in range(g.n):
                    for pt, skel_key in enumerate(g.keys):
                        puts.append(((skel_key, g.row_bytes[j]),
                                     out[j, pt]))
        if puts:
            self.cache.put_many(puts)
        if pack.cached:
            # cache-hit slots: batch them per skeleton through the same
            # vectorized fold (a fully-warm sweep is all hits)
            by_sk: Dict[int, tuple] = {}
            for slot, rows in pack.cached.items():
                sk, ent = pack.meta[slot[0]][slot[1]]
                if sk.mfold is None:
                    rows_by_slot[slot] = rows
                else:
                    by_sk.setdefault(id(sk), (sk, []))[1].append(
                        (slot, rows, ent[1]))
            for sk, items in by_sk.values():
                rows = np.stack([r for _, r, _ in items])
                hwm = np.stack([self._rows[ri] for _, _, ri in items])
                for ((ci, li), _, _), md in zip(items,
                                                sk.mfold(rows, hwm)):
                    md_store[ci][li] = md
        out_records: List[List[Dict]] = []
        for ci, chunk in enumerate(pack.chunks):
            recs = []
            row_meta = pack.meta[ci]
            row_md = md_store[ci]
            for li, lb in enumerate(chunk.labels):
                sk, ent = row_meta[li]
                md = row_md[li]
                if md is not None:
                    # label fields from the skeleton template (dict
                    # insertion order == DesignPoint.label_fields)
                    rec = dict(sk.base_fields)
                    rec["logic"] = lb.logic
                    rec["hbm"] = lb.hbm
                    rec["net"] = lb.net
                    rec["scale"] = lb.scale
                    rec.update(md)
                    rec["key"] = (f"{sk.key_pre}|{lb.logic}|{lb.hbm}|"
                                  f"{lb.net}|{ent[3]}|{sk.key_suf}")
                else:
                    dp = self._design_point(lb, sk, ent[0])
                    rec = sk.scn.record(dp, rows_by_slot[(ci, li)])
                    rec["key"] = dp.key()
                recs.append(rec)
            out_records.append(recs)
        return out_records

    # -- compile-ahead (producer side) -------------------------------------
    def _prefetch(self, pack: _Pack) -> None:
        """Hand the pack's compiled-fn (key, padded shape) pairs to the
        AOT compile service so the executables are warm (or at least in
        flight) by the time the device stage reaches this pack.  Runs on
        the producer side; a miss just means the device stage falls back
        to the lazy inline compile."""
        if not self.compile_ahead:
            return
        from repro.core import compileahead
        svc = compileahead.service()
        n_metrics = len(pathfinder.METRICS)

        def warm(key, build, args):
            if svc.warm(key, build, args):
                with self._pin_lock:
                    self._aot_pins[key] += 1

        def hw_aval(target, n_dev):
            if n_dev > 1:
                return jax.ShapeDtypeStruct(
                    (n_dev, target // n_dev, pathfinder.HW_DIM),
                    jnp.float32)
            return jax.ShapeDtypeStruct((target, pathfinder.HW_DIM),
                                        jnp.float32)

        if self._frontier_capacity is not None:
            capacity = self._frontier_capacity
            for g in pack.groups.values():
                n = len(g.ridx)
                if not n or g.skel.fold is None:
                    continue
                _, target = self._pad_plan(n)
                state = pathfinder.frontier_init(
                    capacity, len(g.skel.scn.objectives),
                    g.skel.ppd * n_metrics)
                st_avals = jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    state)
                warm(("frontier", g.keys, g.skel.fold_key, capacity),
                     self._frontier_build(g, capacity),
                     (jax.ShapeDtypeStruct((target, pathfinder.HW_DIM),
                                           jnp.float32),
                      jax.ShapeDtypeStruct((target,), jnp.int32),
                      st_avals))
            return
        if self.bucketing:
            for bucket, items in self._bucket_plan(pack).values():
                n = sum(ni for _, _, _, ni in items)
                n_dev, target = self._pad_plan(n)
                lead = (n_dev, target // n_dev) if n_dev > 1 else (target,)
                packs_avals = tuple(
                    jax.ShapeDtypeStruct(
                        lead + (bucket.class_sizes[c],) + tuple(shape),
                        np.dtype(dt))
                    for c, (dt, shape) in enumerate(bucket.classes))
                warm(("cabucket", bucket.id, n_dev),
                     compileahead.bucket_builder(bucket, n_dev),
                     (packs_avals, hw_aval(target, n_dev)))
        else:
            for g in pack.groups.values():
                n = len(g.ridx)
                if not n:
                    continue
                n_dev, target = self._pad_plan(n)
                warm(("design", g.keys, n_dev), self._eval_build(g, n_dev),
                     (hw_aval(target, n_dev),))

    # -- the pipeline -----------------------------------------------------
    def _pack_slices(self, chunks: Sequence) -> List[Sequence]:
        per = max(self.superbatch // max(self.spec.chunk_size, 1), 1)
        return [chunks[i:i + per] for i in range(0, len(chunks), per)]

    def run(self, chunks: Sequence, commit: Callable,
            verbose: bool = False) -> int:
        """Evaluate ``chunks``, invoking ``commit(chunk, records)`` in
        chunk order.  Returns evaluated points.

        Threaded mode runs producer/device/writer on separate threads;
        inline mode (small hosts) gets the same double buffering from JAX
        async dispatch alone: pack N+1 is resolved and dispatched before
        pack N's results are pulled, so the device is never idle while
        records fold and commit.
        """
        if not chunks:
            return 0
        slices = self._pack_slices(chunks)
        if not self.threads:
            n_points = 0
            prev: Optional[_Pack] = None
            buf: "collections.deque" = collections.deque()
            si = 0

            def flush(pack: _Pack) -> int:
                n = 0
                for chunk, recs in zip(pack.chunks, self.finalize(pack)):
                    n += len(recs)
                    commit(chunk, recs)
                return n

            try:
                while si < len(slices) or buf:
                    # pack (and AOT-submit) up to compile_ahead
                    # superbatches past the one about to dispatch, so
                    # their compiles overlap this pack's device work
                    while si < len(slices) \
                            and len(buf) <= self.compile_ahead:
                        nxt = self.pack(slices[si])
                        si += 1
                        self._prefetch(nxt)
                        buf.append(nxt)
                    pack = buf.popleft()
                    self.dispatch(pack)      # async: pack N on device ...
                    if prev is not None:
                        n_points += flush(prev)   # ... while N-1 commits
                    prev = pack
                if prev is not None:
                    n_points += flush(prev)
            finally:
                self._release_all_pins()
            return n_points
        pack_q: "queue.Queue" = queue.Queue(maxsize=QUEUE_DEPTH)
        write_q: "queue.Queue" = queue.Queue(maxsize=QUEUE_DEPTH)
        errors: List[BaseException] = []
        n_points = [0]

        def produce():
            # the deque keeps compile_ahead packed superbatches in hand
            # beyond the bounded queue: each is AOT-submitted at pack
            # time, so its compiles run while earlier packs dispatch
            buf: "collections.deque" = collections.deque()
            try:
                for sl in slices:
                    if errors:
                        break
                    pack = self.pack(sl)
                    self._prefetch(pack)
                    buf.append(pack)
                    while len(buf) > self.compile_ahead:
                        pack_q.put(buf.popleft())
                while buf and not errors:
                    pack_q.put(buf.popleft())
            except BaseException as e:      # noqa: BLE001 — re-raised below
                errors.append(e)
            finally:
                pack_q.put(None)

        def write():
            # blocks on pack N-1's device results, folds records and
            # commits JSONL while the main thread keeps dispatching; on an
            # error it keeps draining so the bounded put()s never deadlock
            while True:
                pack = write_q.get()
                if pack is None:
                    return
                if errors:
                    continue
                try:
                    for chunk, recs in zip(pack.chunks,
                                           self.finalize(pack)):
                        n_points[0] += len(recs)
                        commit(chunk, recs)
                except BaseException as e:  # noqa: BLE001 — re-raised below
                    errors.append(e)

        producer = threading.Thread(target=produce, daemon=True,
                                    name="sweep-producer")
        writer = threading.Thread(target=write, daemon=True,
                                  name="sweep-writer")
        producer.start()
        writer.start()
        try:
            while True:
                pack = pack_q.get()
                if pack is None:
                    break
                if errors:
                    continue        # drain so the producer's put()s finish
                try:
                    # async dispatch: chunk N hits the device while N+1
                    # packs (producer) and N-1 folds/commits (writer); the
                    # bounded write queue is the in-flight backpressure
                    self.dispatch(pack)
                    write_q.put(pack)
                except BaseException as e:   # noqa: BLE001
                    errors.append(e)
        except BaseException as e:           # noqa: BLE001 (interrupts)
            errors.append(e)
        finally:
            write_q.put(None)
            writer.join()
            _join_producer(producer, pack_q)
            self._release_all_pins()
        if errors:
            raise errors[0]
        return n_points[0]

    # -- frontier-only mode ----------------------------------------------
    def run_frontier(self, chunks: Sequence,
                     capacity: int = pathfinder.FRONTIER_CAPACITY,
                     state=None, on_commit: Optional[Callable] = None,
                     all_chunks: Optional[Sequence] = None,
                     ) -> Tuple[List[Dict], int, int]:
        """Device-resident streaming-frontier sweep over ``chunks``.

        Returns ``(frontier records, n_overflowed, n_points_evaluated)``.
        The prediction cache is bypassed (rows stay on device; publishing
        them would mean materializing every row on host — the exact cost
        this mode exists to avoid) and per-point results are never
        collected: only the surviving frontier's records are rebuilt, from
        the carried state's payload rows.

        ``state`` seeds the carried frontier state (host arrays from a
        prior run's checkpoint); ``on_commit(chunk_indices, host_state)``
        fires after each merged superbatch with the chunk indices it
        folded in and the state materialized to host — the checkpoint
        hook.  ``all_chunks`` is the full enumeration when ``chunks`` is
        only the pending subset: carried payload rows reference global
        point indices, so record rebuild needs every chunk, merged or not.
        """
        all_chunks = list(all_chunks) if all_chunks is not None \
            else list(chunks)
        if not all_chunks:
            return [], 0, 0
        probe = all_chunks[0].labels[0]
        sk0 = self._skeleton(probe)
        if sk0.fold is None:
            raise ValueError(
                f"scenario {sk0.scn.name!r} defines no frontier_fold; "
                f"--frontier-only needs a device-side objective fold")
        n_obj = len(sk0.scn.objectives)
        payload_dim = sk0.ppd * len(pathfinder.METRICS)
        if state is None:
            state = pathfinder.frontier_init(capacity, n_obj, payload_dim)
        else:
            state = tuple(jnp.asarray(x) for x in state)

        cache, self.cache = self.cache, None    # frontier bypasses caching
        self._frontier_capacity = capacity      # _prefetch warms step fns
        n_points = 0
        try:
            slices = self._pack_slices(chunks)

            def merge_pack(pack: _Pack, state) -> Tuple[object, int]:
                n_merged = 0
                for g in pack.groups.values():
                    n = len(g.ridx)
                    if not n:
                        continue
                    hw, _ = self._padded(g)
                    idx = np.full(hw.shape[0], -1, dtype=np.int32)
                    idx[:n] = g.gidx
                    fn = self._compiled_frontier(g, capacity)
                    # async dispatch: the merge runs on device while the
                    # next pack resolves on host
                    state = fn(jnp.asarray(hw), jnp.asarray(idx), state)
                    self._release_pins(
                        ("frontier", g.keys, g.skel.fold_key, capacity))
                    n_merged += n
                return state, n_merged

            def commit_pack(pack: _Pack, state):
                if on_commit is not None:
                    host = tuple(np.asarray(x) for x in state)
                    on_commit([c.index for c in pack.chunks], host)

            if not self.threads:
                buf: "collections.deque" = collections.deque()
                si = 0
                while si < len(slices) or buf:
                    while si < len(slices) \
                            and len(buf) <= self.compile_ahead:
                        nxt = self.pack(slices[si])
                        si += 1
                        self._prefetch(nxt)
                        buf.append(nxt)
                    pack = buf.popleft()
                    state, n = merge_pack(pack, state)
                    n_points += n
                    commit_pack(pack, state)
            else:
                pack_q: "queue.Queue" = queue.Queue(maxsize=QUEUE_DEPTH)
                errors: List[BaseException] = []

                def produce():
                    buf: "collections.deque" = collections.deque()
                    try:
                        for sl in slices:
                            if errors:
                                break
                            pack = self.pack(sl)
                            self._prefetch(pack)
                            buf.append(pack)
                            while len(buf) > self.compile_ahead:
                                pack_q.put(buf.popleft())
                        while buf and not errors:
                            pack_q.put(buf.popleft())
                    except BaseException as e:  # noqa: BLE001
                        errors.append(e)
                    finally:
                        pack_q.put(None)

                producer = threading.Thread(target=produce, daemon=True,
                                            name="sweep-producer")
                producer.start()
                try:
                    while True:
                        pack = pack_q.get()
                        if pack is None:
                            break
                        if errors:
                            continue    # drain so the producer finishes
                        try:
                            state, n = merge_pack(pack, state)
                            n_points += n
                            commit_pack(pack, state)
                        except BaseException as e:  # noqa: BLE001
                            errors.append(e)
                finally:
                    _join_producer(producer, pack_q)
                if errors:
                    raise errors[0]
        finally:
            self.cache = cache
            self._frontier_capacity = None
            self._release_all_pins()

        records, n_over = self.frontier_records(state, all_chunks)
        return records, n_over, n_points

    def frontier_records(self, state,
                         all_chunks: Sequence) -> Tuple[List[Dict], int]:
        """Rebuild the surviving frontier's result records from a carried
        frontier state's payload rows: ``(records, n_overflowed)``.

        The state may come straight off `run_frontier`, a checkpoint, or a
        cross-worker `pathfinder.frontier_merge_states` merge — payload
        rows reference global point indices, so ``all_chunks`` must be the
        FULL enumeration.  Records are re-filtered host-side in float64
        (the device merge works in f32, so razor-edge ties could otherwise
        differ from the full-materialization frontier).
        """
        from repro.core import sweeprunner
        all_chunks = list(all_chunks)
        vals, payload, idx, n_over = pathfinder.frontier_unpack(
            tuple(np.asarray(x) for x in state))
        by_index = {c.index: c for c in all_chunks}
        records: List[Dict] = []
        sk = None
        for i in np.argsort(idx):              # enumeration order
            gi = int(idx[i])
            chunk = by_index[gi // self.spec.chunk_size]
            lb = chunk.labels[gi % self.spec.chunk_size]
            sk = self._skeleton(lb)
            hw = self._hw_entry(lb)[0]
            dp = self._design_point(lb, sk, hw)
            rows = payload[i].astype(np.float64).reshape(
                sk.ppd, len(pathfinder.METRICS))
            rec = sk.scn.record(dp, rows)
            rec["key"] = dp.key()
            records.append(rec)
        if not records:
            return [], n_over
        records = sweeprunner.pareto_records(
            records, tuple(sk.scn.objectives))
        return records, n_over
