"""CrossFlow -> runtime bridge: pick the sharding plan for a real mesh.

This is where the paper's pathfinding becomes a *first-class feature* of the
training framework (DESIGN.md §2): given (arch config, shape cell, physical
mesh), the planner enumerates the parallelism strategies the runtime
supports, scores ALL of them in one batched-engine call
(`pathfinder.evaluate`: one struct-of-arrays vmapped evaluation per
skeleton, LRU prediction cache shared with sweeps and the SOE — a re-planned
(arch, cell, mesh) is free), and emits the argmin as a `ShardingPlan` that
`repro.launch` turns into PartitionSpecs. The prediction is recorded so the
dry-run can compare it against the XLA-derived roofline terms (our
validation axis).

`candidate_strategies` is also the strategy axis of the sweep engine:
`sweeprunner.enumerate_labels` calls it per (config, cell, mesh) so sweeps
only score runtime-realizable points.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeCell
from repro.core import age as age_lib
from repro.core import lmgraph, pathfinder
from repro.core.age import MicroArch
from repro.core.parallelism import Strategy
from repro.core.placement import SystemGraph, mesh_system
from repro.core.roofline import PPEConfig


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """What the runtime actually consumes."""

    arch: str
    cell: str
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    strategy: Strategy              # paper notation (RC-..-d..-p..)
    # logical-axis -> mesh-axis rules (repro.parallel.sharding consumes this)
    rules: Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...]
    predicted_step_s: float
    predicted_breakdown: Dict[str, float]
    notes: str = ""

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.mesh_axes if a in ("pod", "data"))


# Logical activation/weight axes used across repro.models (MaxText-style).
DEFAULT_RULES: Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...] = (
    ("batch", ("pod", "data")),     # activations: batch over DP axes
    ("seq", None),                  # sequence replicated (SP overrides)
    ("embed", None),                # d_model replicated on activations
    ("heads", ("model",)),          # attention heads over TP
    ("kv_heads", ("model",)),       # kv heads over TP (grouped for small kv)
    ("mlp", ("model",)),            # ffn hidden over TP
    ("vocab", ("model",)),          # embedding/logits vocab dim over TP
    ("experts", ("model",)),        # MoE experts over TP axis (EP)
    ("kv_seq", None),               # KV-cache seq dim (SP shards for 500k)
    ("lru", ("model",)),            # RG-LRU / xLSTM recurrence width
    ("stage", None),                # pipeline stage axis (LP > 1)
)


def candidate_strategies(cfg: ArchConfig, cell: ShapeCell,
                         mesh_shape: Tuple[int, ...]) -> List[Strategy]:
    """Strategies the runtime can realize on this mesh.

    The runtime maps KP -> the 'model' mesh axis and DP -> pod*data, so the
    candidates here vary how the *model* axis is used (RC head/ffn sharding,
    EP for MoE, SP for long-context) — the physical mesh stays fixed.
    """
    total = 1
    for s in mesh_shape:
        total *= s
    model = mesh_shape[-1]
    dp = total // model
    cands = [Strategy("RC", kp1=1, kp2=model, dp=dp, lp=1)]
    if cfg.is_moe:
        cands.append(Strategy("RC", kp1=1, kp2=model, dp=dp, lp=1, ep=model))
    if cell.name == "long_500k":
        cands.append(Strategy("RC", kp1=1, kp2=model, dp=dp, lp=1, sp=model))
    if cell.kind == "train" and cfg.n_layers >= 32 and len(mesh_shape) == 3:
        # pipeline over the pod axis for deep models on multi-pod meshes
        cands.append(Strategy("RC", kp1=1, kp2=model,
                              dp=dp // mesh_shape[0], lp=mesh_shape[0]))
    return cands


def plan(cfg: ArchConfig, cell: ShapeCell, mesh_shape: Tuple[int, ...],
         mesh_axes: Tuple[str, ...],
         arch_hw: Optional[MicroArch] = None,
         ppe: Optional[PPEConfig] = None) -> ShardingPlan:
    """Pick the best runtime-realizable strategy by CrossFlow prediction."""
    hw = arch_hw or age_lib.tpu_v5e_microarch()
    ppe = ppe or PPEConfig(n_tilings=8)        # fast mode for planning
    system = mesh_system(mesh_shape)
    graph = lmgraph.build_graph(cfg, cell)
    # all candidates scored in one batched-engine call (LRU-cached, so a
    # replanned (arch, cell, mesh) is free — launch/dryrun/serve re-plan)
    cands = candidate_strategies(cfg, cell, mesh_shape)
    rows = pathfinder.evaluate(
        points=[pathfinder.EvalPoint(hw, graph, st, system=system)
                for st in cands], ppe=ppe)
    best = None
    for st, row in zip(cands, rows):
        t = float(row[0])
        if best is None or t < best[0]:
            best = (t, st, row)
    assert best is not None
    t, st, row = best
    rules = list(DEFAULT_RULES)
    notes = []
    if st.sp > 1:
        rules = [(a, ("model",)) if a == "kv_seq" else (a, ax)
                 for a, ax in rules]
        notes.append("SP: kv_seq sharded over model axis for long context")
    if cfg.family in ("hybrid", "ssm"):
        notes.append("KP restricted to head/width sharding for recurrences "
                     "(contraction dim stateful; DESIGN.md applicability)")
    if cfg.is_moe and cfg.moe_impl == "scatter_ep":
        notes.append("planner recommends moe_impl='grouped_tp': the "
                     "baseline scatter-EP dispatch lowers to a replicated "
                     "buffer all-reduce under GSPMD (EXPERIMENTS.md §Perf, "
                     "25x collective reduction)")
    return ShardingPlan(
        arch=cfg.name, cell=cell.name, mesh_shape=tuple(mesh_shape),
        mesh_axes=tuple(mesh_axes), strategy=st, rules=tuple(rules),
        predicted_step_s=t,
        predicted_breakdown={
            "compute_s": float(row[1]),
            "comm_s": float(row[2]),
            "exposed_comm_s": float(row[3]),
        },
        notes="; ".join(notes))
