"""Technology components library (DeepFlow paper §4.1, Table 1).

A system is composed of primitive components: compute units (MCUs), on-chip
memory banks, off-chip memory devices, and network links. Each carries the
physical/technology parameters the micro-architecture generator engine (AGE)
needs to derive throughput / bandwidth / capacity under area, power and
perimeter budgets.

Units used throughout `repro.core`:
  area        mm^2            energy      J (joule) / pJ where noted
  power       W               frequency   Hz
  bandwidth   bytes/s         capacity    bytes
  time        s               flops       FLOP (not FLOPS)

The library ships the standard entries used by the paper's case studies
(logic nodes N12..N1, HBM2/2e/3/HBM4, InfiniBand NDR/XDR/GDR) plus two
calibration entries used by this reproduction: ``tpu_v5e`` (the dry-run /
roofline target) and ``cpu_host`` (the only *real* hardware in this container,
used for measured-vs-predicted validation, paper §8).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

# ---------------------------------------------------------------------------
# Component descriptions (paper Table 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ComputeTech:
    """A minimal compute unit (MCU): e.g. one MXU systolic array / tensor core."""

    name: str
    tech_node: str                  # e.g. "N7"
    nominal_area_mm2: float         # area of one MCU
    nominal_voltage: float          # V
    threshold_voltage: float        # V
    minimum_voltage: float          # V
    maximum_voltage: float          # V
    nominal_frequency: float        # Hz
    nominal_flops_per_cycle: float  # per MCU per cycle (MACs*2)
    energy_per_flop: float          # J at nominal voltage/frequency
    systolic_dims: tuple = (128, 128)  # (N_x, N_y) — used by the dataflow model
    max_utilization: float = 0.85   # derate (paper §4.2.1: V100 fill/drain ~85%)
    die_cost_usd: float = 4000.0    # per-device compute die cost (TCO capex)

    @property
    def nominal_flop_rate(self) -> float:
        return self.nominal_flops_per_cycle * self.nominal_frequency

    @property
    def nominal_power(self) -> float:
        return self.nominal_flop_rate * self.energy_per_flop


@dataclasses.dataclass(frozen=True)
class OnChipMemTech:
    """On-chip memory modelled at bank granularity (paper §4.1.2)."""

    name: str
    technology: str                 # "SRAM" etc.
    bank_capacity_bytes: float
    area_per_bit_mm2: float
    area_overhead_frac: float       # periphery overhead on top of cell area
    controller_area_per_bank_mm2: float
    controller_power_per_bank_w: float
    dynamic_energy_per_bit: float   # J/bit
    static_power_per_bit: float     # W/bit
    latency_s: float
    # crossbar connecting banks to the clients at the next level up
    xbar_area_per_port_mm2: float = 1e-4
    xbar_energy_per_bit: float = 5e-14

    @property
    def bank_area_mm2(self) -> float:
        return (self.bank_capacity_bytes * 8.0 * self.area_per_bit_mm2
                * (1.0 + self.area_overhead_frac))


@dataclasses.dataclass(frozen=True)
class OffChipMemTech:
    """Off-chip memory modelled at device granularity, e.g. one HBM stack."""

    name: str
    technology: str
    device_capacity_bytes: float
    device_area_mm2: float          # footprint on interposer/substrate
    device_bw_bytes: float          # peak BW per device at nominal frequency
    controller_io_area_mm2: float   # on-die controller+PHY area per device
    dynamic_energy_per_bit: float   # J/bit
    static_power_per_device_w: float
    links_per_device: int
    links_per_mm: float             # escape density along die perimeter
    nominal_voltage: float
    minimum_voltage: float
    threshold_voltage: float
    nominal_frequency: float        # per-link signalling rate
    access_latency_s: float
    cost_usd_per_gb: float = 10.0   # memory cost (TCO capex)

    @property
    def bytes_per_cycle_per_device(self) -> float:
        return self.device_bw_bytes / self.nominal_frequency


@dataclasses.dataclass(frozen=True)
class NetworkTech:
    """Intra- or inter-package link technology (paper §4.1.3)."""

    name: str
    scope: str                      # "intra_package" | "inter_package"
    nominal_bw_per_link_bytes: float
    nominal_energy_per_bit: float   # J/bit
    area_per_link_mm2: float
    links_per_mm: float             # perimeter escape density
    link_latency_s: float
    nominal_voltage: float
    minimum_voltage: float
    threshold_voltage: float
    nominal_frequency: float


@dataclasses.dataclass(frozen=True)
class TechConfig:
    """A full technology configuration: one entry per component category."""

    name: str
    compute: ComputeTech
    l2: OnChipMemTech               # second-level on-chip (TPU: CMEM / big shared)
    l1: OnChipMemTech               # first-level on-chip (TPU: VMEM)
    l0: OnChipMemTech               # register file / vregs
    dram: OffChipMemTech
    net_intra: NetworkTech
    net_inter: NetworkTech

    def memory_levels(self):
        """Off-chip -> on-chip order used by the hierarchical roofline (L=3 on-chip)."""
        return [self.l0, self.l1, self.l2]


# ---------------------------------------------------------------------------
# Voltage/frequency scaling (paper §4.4: "standard V-F-P scaling methodology")
#
# `freq_at_voltage` / `dynamic_energy_scale` are traceable (jnp inputs OK):
# the cross-stack refinement engine (repro.core.cooptimize) differentiates
# through them when its continuous DVFS knob rides along the SOE budget
# vector.  `solve_voltage_for_power` is the host-side inverse (bisection)
# used when a refined operating point is re-scored discretely.
# ---------------------------------------------------------------------------


def freq_at_voltage(v, tech_vnom: float, tech_fnom: float, vth: float):
    """Alpha-power-law (alpha=1) frequency model: f ∝ (V - Vth).

    Python floats in -> float out; jnp tracers in -> jnp scalar out.
    """
    headroom = v - vth
    denom = max(tech_vnom - vth, 1e-9)
    if isinstance(headroom, (int, float)):
        return tech_fnom * max(headroom, 0.0) / denom
    import jax.numpy as jnp
    return tech_fnom * jnp.maximum(headroom, 0.0) / denom


def dynamic_energy_scale(v: float, vnom: float) -> float:
    """Dynamic energy per op scales with V^2."""
    return (v / vnom) ** 2


def solve_voltage_for_power(power_budget: float, nominal_power: float,
                            vnom: float, vth: float, vmin: float) -> float:
    """Find operating voltage V <= Vnom such that dynamic power fits the budget.

    P(V) = P_nom * (V/Vnom)^2 * (V-Vth)/(Vnom-Vth)   (energy*V^2, rate*(V-Vth))
    Solved by bisection; clamps to [vmin, vnom].
    """
    if nominal_power <= power_budget:
        return vnom

    def p(v: float) -> float:
        return (nominal_power * dynamic_energy_scale(v, vnom)
                * max(v - vth, 0.0) / max(vnom - vth, 1e-9))

    lo, hi = vmin, vnom
    if p(lo) >= power_budget:
        return vmin
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if p(mid) > power_budget:
            hi = mid
        else:
            lo = mid
    return lo


# ---------------------------------------------------------------------------
# Standard library entries
# ---------------------------------------------------------------------------

# Logic nodes N12..N1. Paper §9: area scales 1.8x and power 1.3x per node
# (iso-performance). We anchor N12 at a V100-class tensor-core MCU.
_LOGIC_NODES = ["N12", "N7", "N5", "N3", "N2", "N1.5", "N1"]
_N12_MCU_AREA = 0.80          # mm^2 per MCU (tensor-core-bundle scale anchor)
_N12_E_FLOP = 1.10e-12        # J/flop fp16 at N12 (~V100-class efficiency)
_AREA_SCALE_PER_NODE = 1.8
_POWER_SCALE_PER_NODE = 1.3

# Per-tech cost table ($/token TCO objective, repro.core.objectives):
# wafer cost roughly doubles every two nodes while usable area shrinks,
# so the per-die cost climbs steeply toward the leading edge.
_LOGIC_DIE_USD: Dict[str, float] = {
    "N12": 2500.0, "N7": 5000.0, "N5": 8000.0, "N3": 12000.0,
    "N2": 17000.0, "N1.5": 23000.0, "N1": 30000.0,
}
_HBM_USD_PER_GB: Dict[str, float] = {
    "HBM2": 8.0, "HBM2E": 10.0, "HBM3": 12.0, "HBM4": 16.0,
}


def _logic(node: str) -> ComputeTech:
    i = _LOGIC_NODES.index(node)
    return ComputeTech(
        name=f"mcu_{node.lower()}",
        tech_node=node,
        nominal_area_mm2=_N12_MCU_AREA / (_AREA_SCALE_PER_NODE ** i),
        nominal_voltage=0.80,
        threshold_voltage=0.30,
        minimum_voltage=0.55,
        maximum_voltage=0.95,
        nominal_frequency=1.40e9,
        nominal_flops_per_cycle=512.0,      # 256 MACs/cycle
        energy_per_flop=_N12_E_FLOP / (_POWER_SCALE_PER_NODE ** i),
        systolic_dims=(16, 16),
        max_utilization=0.85,
        die_cost_usd=_LOGIC_DIE_USD[node],
    )


def _sram(node: str, bank_kib: float = 64.0) -> OnChipMemTech:
    i = _LOGIC_NODES.index(node)
    area_scale = _AREA_SCALE_PER_NODE ** (i * 0.75)   # SRAM scales worse than logic
    power_scale = _POWER_SCALE_PER_NODE ** i
    return OnChipMemTech(
        name=f"sram_{node.lower()}_{int(bank_kib)}k",
        technology="SRAM",
        bank_capacity_bytes=bank_kib * 1024,
        area_per_bit_mm2=3.0e-7 / area_scale,
        area_overhead_frac=0.30,
        controller_area_per_bank_mm2=2.0e-3 / area_scale,
        controller_power_per_bank_w=2.0e-3 / power_scale,
        dynamic_energy_per_bit=8.0e-14 / power_scale,
        static_power_per_bit=2.0e-11 / power_scale,
        latency_s=2.0e-9,
    )


def _regfile(node: str) -> OnChipMemTech:
    i = _LOGIC_NODES.index(node)
    area_scale = _AREA_SCALE_PER_NODE ** (i * 0.75)
    power_scale = _POWER_SCALE_PER_NODE ** i
    return OnChipMemTech(
        name=f"rf_{node.lower()}",
        technology="SRAM-RF",
        bank_capacity_bytes=4.0 * 1024,
        area_per_bit_mm2=8.0e-7 / area_scale,
        area_overhead_frac=0.20,
        controller_area_per_bank_mm2=5.0e-4 / area_scale,
        controller_power_per_bank_w=5.0e-4 / power_scale,
        dynamic_energy_per_bit=2.0e-14 / power_scale,
        static_power_per_bit=1.0e-11 / power_scale,
        latency_s=0.5e-9,
    )


_HBM_GENS: Dict[str, float] = {     # per-stack bandwidth (paper §9 figures are
    "HBM2": 0.45e12,                # ~2-4 stacks: HBM2 system => ~1 TB/s, etc.)
    "HBM2E": 0.90e12,
    "HBM3": 1.20e12,
    "HBM4": 1.65e12,
}
_HBM_EPB: Dict[str, float] = {      # J/bit improves with generation
    "HBM2": 4.0e-12,
    "HBM2E": 3.3e-12,
    "HBM3": 2.6e-12,
    "HBM4": 2.0e-12,
}


def _hbm(gen: str) -> OffChipMemTech:
    bw = _HBM_GENS[gen]
    return OffChipMemTech(
        name=gen.lower(),
        technology=gen,
        device_capacity_bytes=16.0 * 2**30,
        device_area_mm2=110.0,
        device_bw_bytes=bw,
        controller_io_area_mm2=12.0,
        dynamic_energy_per_bit=_HBM_EPB[gen],
        static_power_per_device_w=2.5,
        links_per_device=1024,
        links_per_mm=80.0,
        nominal_voltage=1.1,
        minimum_voltage=0.8,
        threshold_voltage=0.35,
        nominal_frequency=bw / 1024 * 8,   # per-link bit rate
        access_latency_s=120e-9,
        cost_usd_per_gb=_HBM_USD_PER_GB[gen],
    )


_NET_GENS: Dict[str, float] = {
    # inter-node network technologies (paper §9; GDR figure text uses 400 GB/s)
    "IB-NDR-X8": 100e9,
    "IB-XDR-X8": 200e9,
    "IB-GDR-X8": 400e9,
}
_NET_EPB: Dict[str, float] = {      # J/bit improves with generation — else
    "IB-NDR-X8": 5.0e-12,           # the AGE power budget caps XDR == GDR
    "IB-XDR-X8": 3.3e-12,
    "IB-GDR-X8": 2.2e-12,
}


def _inter_net(gen: str) -> NetworkTech:
    bw = _NET_GENS[gen]
    n_links = 8
    return NetworkTech(
        name=gen.lower(),
        scope="inter_package",
        nominal_bw_per_link_bytes=bw / n_links,
        nominal_energy_per_bit=_NET_EPB[gen],
        area_per_link_mm2=0.9,
        links_per_mm=0.5,
        link_latency_s=1.0e-6,
        nominal_voltage=0.9,
        minimum_voltage=0.6,
        threshold_voltage=0.3,
        nominal_frequency=bw / n_links * 8,
    )


def _intra_net(bw_per_link: float = 2e12 / 8) -> NetworkTech:
    # 2.5D-substrate / on-package links (paper §9.3 assumes 2 TB/s intra-package)
    return NetworkTech(
        name="substrate_2p5d",
        scope="intra_package",
        nominal_bw_per_link_bytes=bw_per_link,
        nominal_energy_per_bit=0.6e-12,
        area_per_link_mm2=0.05,
        links_per_mm=10.0,
        link_latency_s=20e-9,
        nominal_voltage=0.8,
        minimum_voltage=0.55,
        threshold_voltage=0.3,
        nominal_frequency=bw_per_link * 8,
    )


# --- TPU v5e calibration entry (the dry-run / roofline target) --------------
# Peak 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (per the brief).

def _tpu_v5e_compute() -> ComputeTech:
    # 4 MXUs of 128x128 @ ~0.94 GHz * 2 flops => ~197 TF/s per chip when N=4.
    f = 1.5e9
    flops_per_cycle = 128 * 128 * 2.0
    return ComputeTech(
        name="mxu_v5e",
        tech_node="N5",
        nominal_area_mm2=30.0,
        nominal_voltage=0.75,
        threshold_voltage=0.30,
        minimum_voltage=0.55,
        maximum_voltage=0.90,
        nominal_frequency=f,
        nominal_flops_per_cycle=flops_per_cycle,
        energy_per_flop=0.35e-12,
        systolic_dims=(128, 128),
        max_utilization=0.85,
        die_cost_usd=6000.0,
    )


def _tpu_v5e_hbm() -> OffChipMemTech:
    return OffChipMemTech(
        name="hbm2_v5e",
        technology="HBM2",
        device_capacity_bytes=8.0 * 2**30,
        device_area_mm2=100.0,
        device_bw_bytes=409.5e9,            # 2 stacks => 819 GB/s
        controller_io_area_mm2=10.0,
        dynamic_energy_per_bit=4.0e-12,
        static_power_per_device_w=2.0,
        links_per_device=1024,
        links_per_mm=80.0,
        nominal_voltage=1.1,
        minimum_voltage=0.8,
        threshold_voltage=0.35,
        nominal_frequency=409.5e9 / 1024 * 8,
        access_latency_s=120e-9,
        cost_usd_per_gb=8.0,
    )


def _tpu_v5e_ici() -> NetworkTech:
    return NetworkTech(
        name="ici_v5e",
        scope="inter_package",
        nominal_bw_per_link_bytes=50e9,     # per link per direction
        nominal_energy_per_bit=1.0e-12,
        area_per_link_mm2=0.4,
        links_per_mm=1.0,
        link_latency_s=0.5e-6,
        nominal_voltage=0.9,
        minimum_voltage=0.6,
        threshold_voltage=0.3,
        nominal_frequency=50e9 * 8,
    )


def _cpu_host_compute() -> ComputeTech:
    """Calibration entry for THIS container's CPU (measured-vs-predicted, §8).

    Calibrated post-hoc by `benchmarks/fig6_gemm_validation.py --calibrate`
    which measures peak achieved GEMM flops; defaults here are a reasonable
    single-core AVX2 guess (re-written by calibration).
    """
    f = 3.0e9
    return ComputeTech(
        name="cpu_host",
        tech_node="N7",
        nominal_area_mm2=8.0,
        nominal_voltage=1.0,
        threshold_voltage=0.35,
        minimum_voltage=0.7,
        maximum_voltage=1.2,
        nominal_frequency=f,
        nominal_flops_per_cycle=32.0,       # AVX2 FMA f32: 2*2*8
        energy_per_flop=5.0e-12,
        systolic_dims=(4, 8),
        max_utilization=0.90,
        die_cost_usd=1500.0,
    )


def _cpu_host_dram() -> OffChipMemTech:
    return OffChipMemTech(
        name="ddr_host",
        technology="DDR4",
        device_capacity_bytes=16.0 * 2**30,
        device_area_mm2=100.0,
        device_bw_bytes=12e9,
        controller_io_area_mm2=8.0,
        dynamic_energy_per_bit=12e-12,
        static_power_per_device_w=1.5,
        links_per_device=64,
        links_per_mm=10.0,
        nominal_voltage=1.2,
        minimum_voltage=1.0,
        threshold_voltage=0.4,
        nominal_frequency=12e9 / 64 * 8,
        access_latency_s=90e-9,
        cost_usd_per_gb=3.0,
    )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def make_tech_config(logic: str = "N7", hbm: str = "HBM2E",
                     inter_net: str = "IB-NDR-X8",
                     intra_bw: float = 2e12 / 8) -> TechConfig:
    """Compose a TechConfig from the standard library (paper case-study axes)."""
    return TechConfig(
        name=f"{logic}/{hbm}/{inter_net}",
        compute=_logic(logic),
        l2=_sram(logic, bank_kib=256.0),
        l1=_sram(logic, bank_kib=64.0),
        l0=_regfile(logic),
        dram=_hbm(hbm),
        net_intra=_intra_net(intra_bw),
        net_inter=_inter_net(inter_net),
    )


def tpu_v5e_tech() -> TechConfig:
    n = "N5"
    return TechConfig(
        name="tpu_v5e",
        compute=_tpu_v5e_compute(),
        l2=_sram(n, bank_kib=512.0),
        l1=_sram(n, bank_kib=128.0),
        l0=_regfile(n),
        dram=_tpu_v5e_hbm(),
        net_intra=_intra_net(),
        net_inter=_tpu_v5e_ici(),
    )


def cpu_host_tech() -> TechConfig:
    n = "N7"
    return TechConfig(
        name="cpu_host",
        compute=_cpu_host_compute(),
        l2=_sram(n, bank_kib=1024.0),
        l1=_sram(n, bank_kib=64.0),
        l0=_regfile(n),
        dram=_cpu_host_dram(),
        net_intra=_intra_net(16e9),
        net_inter=_inter_net("IB-NDR-X8"),
    )


# ---------------------------------------------------------------------------
# Energy/cost coefficients for the objective layer (repro.core.objectives)
#
# Both helpers are plain arithmetic over the TechConfig and two MicroArch
# scalars, so they stay traceable when cooptimize's DVFS knobs run through
# them with jnp tracers.
# ---------------------------------------------------------------------------

# static (leakage) compute power as a fraction of nominal dynamic power
LEAKAGE_FRAC = 0.15


def device_cost_usd(tech: TechConfig, dram_capacity_bytes):
    """Per-device capex: compute die plus memory at $/GB."""
    return (tech.compute.die_cost_usd
            + tech.dram.cost_usd_per_gb * dram_capacity_bytes / 2**30)


def static_power_w(tech: TechConfig, dram_capacity_bytes,
                   compute_throughput):
    """Per-device static power: DRAM refresh/standby plus logic leakage."""
    n_dev = dram_capacity_bytes / tech.dram.device_capacity_bytes
    return (tech.dram.static_power_per_device_w * n_dev
            + LEAKAGE_FRAC * compute_throughput * tech.compute.energy_per_flop)


LOGIC_NODES = list(_LOGIC_NODES)
HBM_GENERATIONS = list(_HBM_GENS)
NETWORK_GENERATIONS = list(_NET_GENS)
