"""Distributed sweep fabric: lease-based coordinator/worker execution of
the chunk protocol.

Single-host sweep throughput is bound by device evaluation itself
(~17k points/s, BENCH_PR5); the 10^6-10^7-point co-design studies the
paper's DSE case studies imply need multi-host fan-out.  This module adds
that layer WITHOUT a network dependency: the shared sweep directory is the
coordination medium, exactly like a classic filesystem work queue, and the
chunk protocol of `repro.core.sweepexec` already provides the commit
semantics (hash-keyed done-lines as the single source of truth).

Roles:

  * **Coordinator** (`FabricCoordinator`, CLI ``pathfind sweep --workers
    N``): initializes the directory (spec head + fabric.json mode record),
    optionally spawns N local worker processes, waits for global
    completion, and merges the per-worker shards into the standard
    single-host layout (``results.jsonl``/``checkpoint.jsonl``, or
    ``frontier.jsonl`` + ``frontier_state.npz`` in frontier mode) so every
    downstream consumer (``cooptimize --from``, `load_sweep`, `to_csv`)
    works unchanged.
  * **Workers** (`FabricWorker`, CLI ``pathfind sweep-worker --dir DIR``):
    plain processes — local children or an external preemptible fleet —
    that claim chunk **leases**, evaluate them on the pipelined executor,
    and stream results into per-worker journal shards.

Lease protocol (``DIR/leases/chunk_<i>.json``):

  * claim   = ``os.open(O_CREAT|O_EXCL)`` — atomic on POSIX, exactly one
    winner; the file holds ``{"worker", "expires"}``;
  * renew   = rewrite via tmp + ``os.replace`` every ttl/3 while the
    holder is alive (the heartbeat);
  * reclaim = when ``expires`` is in the past (or the file is torn and
    old), ``os.rename`` the lease to a per-claimant tombstone — rename
    is atomic, so exactly one thief wins — then claim fresh;
  * leases are **not** released after commit: claiming always checks the
    merged done-set first, so a committed chunk is never claimed again.

Crash safety is layered: the done-line protocol guarantees a chunk is
never *committed* twice even if two workers race on an expired lease
(commit-time ownership verification shrinks the race window; the
deterministic merge-on-read dedupe by chunk closes it), and per-incarnation
worker ids keep a dead worker's torn partial rows in shards whose
checkpoint never references them.  Frontier mode checkpoints each worker's
carried Pareto state per committed superbatch
(``shards/frontier_state.<wid>.npz``, PR6 machinery) and the coordinator
reduces the shard states with `pathfinder.frontier_merge_states` — an
unbounded, dedup-by-point-index skyline merge that is exactly commutative/
associative/idempotent, so merge order can never change the global
frontier.

Workers install `repro.runtime.fault.PreemptionHandler`: SIGTERM finishes
and commits the in-flight chunk/superbatch, releases unstarted leases, and
exits 0 — preemption costs at most the uncommitted tail, the "ML fleet
goodput" property the paper's fleet-efficiency thread argues for.

Fault injection (tests/CI only) is env-driven and one-shot:
``REPRO_FABRIC_KILL="<point>:<n>:<token>"`` SIGKILLs the process at the
n-th crossing of injection point ``eval`` (after evaluation, before any
write), ``post_rows`` (between row append and done-line — the torn-commit
window), or ``renew`` (mid-heartbeat, tmp written but not yet renamed);
the token file makes the kill fire once across respawns.
``REPRO_FABRIC_STALL_S`` makes a worker claim its first batch and then
stall without heartbeating — the deliberate lease-expiry victim.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
import signal
import subprocess
import sys
import time
import uuid
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import sweepexec

DEFAULT_TTL_S = 30.0
DEFAULT_POLL_S = 0.5
FABRIC_VERSION = 1


class LostLease(RuntimeError):
    """A chunk's lease was reclaimed by another worker (we stalled past
    the TTL); the holder must discard uncommitted work and rescan."""


class Preempted(RuntimeError):
    """SIGTERM arrived; in-flight work has been committed — unwind."""


def _paths(out_dir: str) -> Dict[str, str]:
    return {"spec": os.path.join(out_dir, "spec.json"),
            "fabric": os.path.join(out_dir, "fabric.json"),
            "order": os.path.join(out_dir, "order.json"),
            "leases": os.path.join(out_dir, "leases"),
            "shards": os.path.join(out_dir, "shards"),
            "workers": os.path.join(out_dir, "workers")}


# ---------------------------------------------------------------------------
# Advisory chunk order (surrogate-guided lease-queue priority)
# ---------------------------------------------------------------------------


def write_chunk_order(out_dir: str, indices: Sequence[int],
                      fingerprint: str) -> str:
    """Atomically write the directory's advisory claim order.

    ``order.json`` holds acquisition-ranked chunk indices (best first,
    from `surrogate.rank_chunks`) plus the spec fingerprint they were
    computed for.  The order is SCHEDULE-ONLY: workers consult it to
    pick what to claim next, but the lease protocol, done-set, chunk
    hashes and the deterministic first-wins shard merge are untouched —
    an ordered fleet's merged records are identical to an unordered
    fleet's (the explore benchmark asserts this), it just front-loads
    the frontier-adjacent chunks so a preempted fleet's first minutes
    are spent on the most informative points.
    """
    path = _paths(out_dir)["order"]
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump({"fingerprint": fingerprint,
                   "order": [int(i) for i in indices]}, fh)
    os.replace(tmp, path)
    return path


def load_chunk_order(out_dir: str, fingerprint: str,
                     n_chunks: int) -> Optional[List[int]]:
    """The directory's advisory claim order, or None.

    Defensive by design — the order can only ever *reorder* the scan:
    a missing/corrupt file, a fingerprint from another spec, out-of-range
    or duplicate indices are ignored (never fatal, a worker must not die
    over an advisory hint), and indices the order omits are appended in
    ascending order so every chunk is always reachable.
    """
    path = _paths(out_dir)["order"]
    if not os.path.exists(path):
        return None
    try:
        with open(path) as fh:
            payload = json.load(fh)
    except (OSError, json.JSONDecodeError):
        return None
    if payload.get("fingerprint") != fingerprint:
        return None
    seen = set()
    order: List[int] = []
    try:
        raw = [int(i) for i in payload.get("order", [])]
    except (TypeError, ValueError):
        return None
    for i in raw:
        if 0 <= i < n_chunks and i not in seen:
            seen.add(i)
            order.append(i)
    order.extend(i for i in range(n_chunks) if i not in seen)
    return order


def shard_paths(out_dir: str, worker_id: str) -> Dict[str, str]:
    shards = os.path.join(out_dir, "shards")
    return {"results": os.path.join(shards,
                                    f"results.{worker_id}.jsonl"),
            "checkpoint": os.path.join(shards,
                                       f"checkpoint.{worker_id}.jsonl"),
            "frontier": os.path.join(shards,
                                     f"frontier_state.{worker_id}.npz"),
            "stats": os.path.join(out_dir, "workers",
                                  f"stats.{worker_id}.json")}


# ---------------------------------------------------------------------------
# Fault injection (tests/CI)
# ---------------------------------------------------------------------------


class _Injector:
    """One-shot env-driven SIGKILL at a named injection point."""

    def __init__(self):
        spec = os.environ.get("REPRO_FABRIC_KILL", "")
        self.point = self.token = None
        self.n = 0
        self._count: Dict[str, int] = {}
        if spec:
            point, n, token = spec.split(":", 2)
            self.point, self.n, self.token = point, int(n), token

    def fire(self, point: str) -> None:
        if self.point != point:
            return
        self._count[point] = self._count.get(point, 0) + 1
        if self._count[point] == self.n and not os.path.exists(self.token):
            with open(self.token, "w") as fh:
                fh.write(f"{point}:{os.getpid()}\n")
            os.kill(os.getpid(), signal.SIGKILL)


# ---------------------------------------------------------------------------
# Lease manager
# ---------------------------------------------------------------------------


class LeaseManager:
    """Atomic per-chunk lease files with TTL + heartbeat renewal.

    Wall-clock (`time.time`) expiry: every party lives on the same
    filesystem host-set, and the TTL (default 30 s) dwarfs realistic
    clock skew; a wrongly-stolen lease degrades to the LostLease path,
    never to a double commit.
    """

    def __init__(self, out_dir: str, worker: str,
                 ttl_s: float = DEFAULT_TTL_S,
                 injector: Optional[_Injector] = None):
        self.dir = _paths(out_dir)["leases"]
        self.worker = worker
        self.ttl_s = float(ttl_s)
        self._inj = injector or _Injector()
        os.makedirs(self.dir, exist_ok=True)

    def _path(self, index: int) -> str:
        return os.path.join(self.dir, f"chunk_{index}.json")

    def _read(self, path: str) -> Optional[Dict]:
        try:
            with open(path) as fh:
                return json.load(fh)
        except FileNotFoundError:
            return None
        except (json.JSONDecodeError, OSError):
            return {}                      # torn write — content unusable

    def _expired(self, path: str) -> bool:
        rec = self._read(path)
        if rec is None:
            return False                   # vanished: not ours to steal
        if "expires" in rec:
            return float(rec["expires"]) < time.time()
        # torn lease: no readable expiry — fall back to file age
        try:
            return os.path.getmtime(path) + self.ttl_s < time.time()
        except OSError:
            return False

    def _create(self, index: int) -> bool:
        try:
            fd = os.open(self._path(index),
                         os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False
        with os.fdopen(fd, "w") as fh:
            json.dump({"worker": self.worker,
                       "expires": time.time() + self.ttl_s}, fh)
        return True

    def claim(self, index: int) -> bool:
        """Claim an unleased chunk (O_CREAT|O_EXCL — exactly one winner);
        False when a lease file exists, expired or not: stealing is the
        separate, deliberate `steal_expired` step."""
        return self._create(index)

    def steal_expired(self, index: int) -> bool:
        """Reclaim an expired lease: atomic rename to a per-claimant
        tombstone (exactly one thief wins the rename), then claim
        fresh."""
        path = self._path(index)
        if not self._expired(path):
            return False
        tomb = os.path.join(
            self.dir, f"tomb.{index}.{self.worker}.{uuid.uuid4().hex[:6]}")
        try:
            os.rename(path, tomb)
        except FileNotFoundError:
            pass                           # another thief won the rename
        else:
            try:
                os.unlink(tomb)
            except OSError:
                pass
        return self._create(index)

    def owns(self, index: int) -> bool:
        rec = self._read(self._path(index))
        return bool(rec) and rec.get("worker") == self.worker

    def renew(self, indices: Sequence[int]) -> List[int]:
        """Heartbeat: push the expiry of every held lease forward.
        Returns the indices whose lease we no longer own (stolen)."""
        lost: List[int] = []
        for i in indices:
            path = self._path(i)
            rec = self._read(path)
            if not rec or rec.get("worker") != self.worker:
                lost.append(i)
                continue
            tmp = f"{path}.{self.worker}.tmp"
            with open(tmp, "w") as fh:
                json.dump({"worker": self.worker,
                           "expires": time.time() + self.ttl_s}, fh)
            self._inj.fire("renew")        # kill-matrix: mid-renewal
            os.replace(tmp, path)
        return lost

    def release(self, index: int) -> None:
        """Drop a lease we still hold (uncommitted work being abandoned:
        preemption exit or a LostLease rescan)."""
        if self.owns(index):
            try:
                os.unlink(self._path(index))
            except FileNotFoundError:
                pass

    def holder(self, index: int) -> Optional[str]:
        rec = self._read(self._path(index))
        return rec.get("worker") if rec else None


# ---------------------------------------------------------------------------
# Directory initialization + merged views
# ---------------------------------------------------------------------------


def init_dir(spec, out_dir: str, frontier_only: bool = False,
             frontier_capacity: Optional[int] = None) -> Dict:
    """Create (or join) a fabric sweep directory.

    Writes the standard spec head plus ``fabric.json`` recording the
    execution mode — workers read the mode from the directory, so a fleet
    can never disagree about what it is computing.  Joining an existing
    directory verifies both.
    """
    from repro.core import pathfinder, sweeprunner
    p = _paths(out_dir)
    fp = spec.fingerprint()
    capacity = int(frontier_capacity or pathfinder.FRONTIER_CAPACITY)
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(p["leases"], exist_ok=True)
    os.makedirs(p["shards"], exist_ok=True)
    os.makedirs(p["workers"], exist_ok=True)
    head = {"mode": "frontier" if frontier_only else "full",
            "capacity": capacity, "version": FABRIC_VERSION}
    if os.path.exists(p["spec"]):
        sweepexec.check_fingerprint(p["spec"], fp)
    else:
        sweepexec.write_spec_head(p["spec"], sweeprunner.SPEC_VERSION, fp,
                                  spec.to_dict())
    if os.path.exists(p["fabric"]):
        with open(p["fabric"]) as fh:
            existing = json.load(fh)
        if existing.get("mode") != head["mode"] \
                or int(existing.get("capacity", 0)) != capacity:
            raise ValueError(
                f"fabric directory {out_dir} was initialized as "
                f"mode={existing.get('mode')}/capacity="
                f"{existing.get('capacity')}; rerun with matching flags "
                f"or use a fresh directory")
        head = existing
    else:
        tmp = p["fabric"] + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(head, fh, indent=2)
        os.replace(tmp, p["fabric"])
    return head


def load_dir(out_dir: str):
    """(spec, fabric head) of an initialized fabric directory."""
    from repro.core import sweeprunner
    p = _paths(out_dir)
    head = sweepexec.load_spec_head(p["spec"])
    spec = sweeprunner.SweepSpec.from_dict(head["spec"])
    with open(p["fabric"]) as fh:
        fabric = json.load(fh)
    return spec, fabric


def _shard_journals(out_dir: str) -> List[sweepexec.ChunkJournal]:
    """One journal per worker shard, in sorted (deterministic) order —
    the order is the dedupe tie-break, so it must never depend on
    directory enumeration order."""
    shards = _paths(out_dir)["shards"]
    out = []
    for ckpt in sorted(glob.glob(os.path.join(shards,
                                              "checkpoint.*.jsonl"))):
        wid = os.path.basename(ckpt)[len("checkpoint."):-len(".jsonl")]
        out.append(sweepexec.ChunkJournal(
            os.path.join(shards, f"results.{wid}.jsonl"), ckpt))
    return out


def global_done(out_dir: str, chunks: Sequence,
                fingerprint: str) -> Dict[int, str]:
    """Union of committed chunks across every worker shard — the claim
    check, and the completion predicate."""
    done: Dict[int, str] = {}
    for j in _shard_journals(out_dir):
        done.update(j.load_done(chunks, fingerprint))
    return done


def _frontier_shards(out_dir: str) -> List[str]:
    shards = _paths(out_dir)["shards"]
    return sorted(glob.glob(os.path.join(shards, "frontier_state.*.npz")))


def global_frontier_done(out_dir: str, chunks: Sequence, fingerprint: str,
                         capacity: int) -> Dict[int, str]:
    """Union of chunks merged into any worker's checkpointed frontier
    state (frontier mode's completion predicate)."""
    done: Dict[int, str] = {}
    for path in _frontier_shards(out_dir):
        _, d = sweepexec.load_frontier_state(path, fingerprint, capacity,
                                             chunks)
        done.update(d)
    return done


def merge_results(out_dir: str) -> Tuple[List[Dict], Dict[int, str]]:
    """Merge worker shards into top-level ``results.jsonl`` +
    ``checkpoint.jsonl`` (the single-host layout).

    Dedupe is by chunk with first-wins over the sorted shard order: even
    if an expired-lease race ever let two workers commit the same chunk,
    exactly one copy survives, deterministically.  Returns the merged
    records (without their chunk tags) and the global done-map.
    """
    from repro.core import sweeprunner
    spec, _ = load_dir(out_dir)
    fp = spec.fingerprint()
    chunks = sweeprunner.make_chunks(sweeprunner.enumerate_labels(spec),
                                     spec.chunk_size)
    journals = _shard_journals(out_dir)
    winner: Dict[int, sweepexec.ChunkJournal] = {}
    for j in journals:
        for i in j.load_done(chunks, fp):
            winner.setdefault(i, j)
    rows_by_chunk: Dict[int, List[Dict]] = {i: [] for i in winner}
    for j in journals:
        mine = {i for i, w in winner.items() if w is j}
        if not mine:
            continue
        for rec in sweepexec.iter_jsonl(j.results_path):
            if rec.get("chunk") in mine:
                rows_by_chunk[rec["chunk"]].append(rec)
    res_path = os.path.join(out_dir, "results.jsonl")
    ckpt_path = os.path.join(out_dir, "checkpoint.jsonl")
    records: List[Dict] = []
    with open(res_path + ".tmp", "w") as res, \
            open(ckpt_path + ".tmp", "w") as ckpt:
        for i in sorted(winner):
            for rec in rows_by_chunk[i]:
                res.write(sweepexec.dump_line(rec) + "\n")
                records.append({k: v for k, v in rec.items()
                                if k != "chunk"})
            ckpt.write(json.dumps(
                {"chunk": i, "hash": chunks[i].hash(fp),
                 "n": len(rows_by_chunk[i])}) + "\n")
    os.replace(res_path + ".tmp", res_path)
    os.replace(ckpt_path + ".tmp", ckpt_path)
    done = {i: chunks[i].hash(fp) for i in winner}
    return records, done


def merge_frontier(out_dir: str) -> Tuple[List[Dict], int, Dict[int, str]]:
    """Reduce every worker's checkpointed frontier state into the global
    frontier: ``(records, n_overflowed, done)``.

    The reduction is `pathfinder.frontier_merge_states` — unbounded,
    deduped by global point index, exactly order-independent — so shard
    enumeration order cannot change the result (the property suite pins
    this).  Writes ``frontier.jsonl`` and a merged ``frontier_state.npz``
    at the top level.
    """
    from repro.core import pathfinder, sweeppipeline, sweeprunner
    spec, fabric = load_dir(out_dir)
    fp = spec.fingerprint()
    capacity = int(fabric["capacity"])
    chunks = sweeprunner.make_chunks(sweeprunner.enumerate_labels(spec),
                                     spec.chunk_size)
    state = None
    done: Dict[int, str] = {}
    for path in _frontier_shards(out_dir):
        s, d = sweepexec.load_frontier_state(path, fp, capacity, chunks)
        done.update(d)
        state = s if state is None \
            else pathfinder.frontier_merge_states(state, s)
    if state is None:
        return [], 0, {}
    ex = sweeppipeline.PipelineExecutor(spec, cache=None)
    records, n_over = ex.frontier_records(state, chunks)
    front_path = os.path.join(out_dir, "frontier.jsonl")
    with open(front_path + ".tmp", "w") as fh:
        for rec in records:
            fh.write(json.dumps(sweepexec.json_safe(rec)) + "\n")
    os.replace(front_path + ".tmp", front_path)
    sweepexec.save_frontier_state(
        os.path.join(out_dir, "frontier_state.npz"), state, done,
        capacity, fp)
    return records, n_over, done


# ---------------------------------------------------------------------------
# Worker
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WorkerStats:
    """Summary of one worker incarnation (also journaled, per commit, to
    ``workers/stats.<wid>.json`` so the fault-injection suite can assert
    zero re-evaluation of committed chunks across the whole fleet)."""

    worker: str
    n_chunks_committed: int = 0
    n_points: int = 0
    n_lost_leases: int = 0
    preempted: bool = False
    elapsed_s: float = 0.0
    # per-incarnation XLA compile observability (deltas of
    # pathfinder.compile_cache_stats over this worker's lifetime)
    compile_seconds: float = 0.0
    stall_seconds: float = 0.0


class FabricWorker:
    """One lease-claiming executor process over a fabric directory."""

    def __init__(self, out_dir: str, worker_id: Optional[str] = None,
                 ttl_s: float = DEFAULT_TTL_S,
                 poll_s: float = DEFAULT_POLL_S,
                 claim_batch: Optional[int] = None,
                 superbatch: Optional[int] = None,
                 eval_delay_s: float = 0.0,
                 max_chunks: Optional[int] = None,
                 compile_cache: bool = True,
                 compile_ahead: Optional[int] = None,
                 bucketing: Optional[bool] = None,
                 on_idle: Optional[Callable[[], None]] = None):
        from repro.core import pathfinder, sweeprunner
        self.out_dir = out_dir
        self.spec, self.fabric = load_dir(out_dir)
        self.mode = self.fabric["mode"]
        self.capacity = int(self.fabric["capacity"])
        # unique id per process incarnation: a respawned worker writes a
        # FRESH shard, so a dead incarnation's torn rows sit in a shard
        # whose checkpoint never references them
        self.worker_id = worker_id or \
            f"w{os.getpid()}-{uuid.uuid4().hex[:6]}"
        self.ttl_s = float(ttl_s)
        self.poll_s = float(poll_s)
        self.superbatch = superbatch
        self.claim_batch = claim_batch or max(
            1, (superbatch or 256) // max(1, self.spec.chunk_size))
        self.eval_delay_s = float(
            os.environ.get("REPRO_FABRIC_EVAL_DELAY_S", eval_delay_s))
        self.stall_s = float(os.environ.get("REPRO_FABRIC_STALL_S", 0.0))
        self.max_chunks = max_chunks
        self.compile_cache = compile_cache
        # execution-only dispatch knobs (inherited by the process-global
        # compile-ahead service); no effect on chunk hashes or commits
        self.compile_ahead = compile_ahead
        self.bucketing = bucketing
        self._compile_base = pathfinder.compile_cache_stats()
        self.on_idle = on_idle
        self._inj = _Injector()
        self._fp = self.spec.fingerprint()
        self._chunks = sweeprunner.make_chunks(
            sweeprunner.enumerate_labels(self.spec), self.spec.chunk_size)
        # advisory surrogate work order (DIR/order.json): claims are
        # attempted acquisition-first when present and fingerprint-matched;
        # chunk identities and the commit protocol are untouched, so the
        # order can only change the schedule, never the merged results
        order = load_chunk_order(out_dir, self._fp, len(self._chunks))
        self._scan = [self._chunks[i] for i in order] \
            if order is not None else self._chunks
        self._sp = shard_paths(out_dir, self.worker_id)
        self._lease = LeaseManager(out_dir, self.worker_id, ttl_s,
                                   injector=self._inj)
        self._journal = sweepexec.ChunkJournal(self._sp["results"],
                                               self._sp["checkpoint"])
        self._evaluated: List[Tuple[int, float]] = []
        self._committed: List[Tuple[int, float]] = []
        self._last_renew = time.time()
        self._stalled_once = False

    # -- bookkeeping ------------------------------------------------------
    def _write_stats(self, stats: WorkerStats) -> None:
        from repro.core import pathfinder
        now = pathfinder.compile_cache_stats()
        stats.compile_seconds = now.get("compile_seconds", 0.0) - \
            self._compile_base.get("compile_seconds", 0.0)
        stats.stall_seconds = now.get("stall_seconds", 0.0) - \
            self._compile_base.get("stall_seconds", 0.0)
        tmp = self._sp["stats"] + ".tmp"
        with open(tmp, "w") as fh:
            json.dump({**dataclasses.asdict(stats), "pid": os.getpid(),
                       "mode": self.mode,
                       "evaluated": self._evaluated,
                       "committed": self._committed}, fh)
        os.replace(tmp, self._sp["stats"])

    def _global_done(self) -> Dict[int, str]:
        if self.mode == "frontier":
            return global_frontier_done(self.out_dir, self._chunks,
                                        self._fp, self.capacity)
        return global_done(self.out_dir, self._chunks, self._fp)

    def _heartbeat(self, held: Sequence[int]) -> None:
        if time.time() - self._last_renew < self.ttl_s / 3:
            return
        lost = self._lease.renew(held)
        self._last_renew = time.time()
        if lost:
            raise LostLease(f"leases stolen for chunks {sorted(lost)}")

    def _claim(self, done: Dict[int, str]) -> List:
        """Claim up to claim_batch pending chunks, in scan order: the
        advisory ``order.json`` ranking when present, else lowest index
        first (workers racing from opposite ends would fragment the
        shared XLA compile cache for no benefit).

        Stealing an expired lease re-checks the merged done-set right
        before and after the steal: the previous holder may have
        committed the chunk moments ago (leases are deliberately not
        released after commit), and a stale ``done`` snapshot must not
        turn that into a re-evaluation.
        """
        claimed = []
        fresh_done: Optional[Dict[int, str]] = None
        for c in self._scan:
            if len(claimed) >= self.claim_batch:
                break
            if c.index in done:
                continue
            if self._lease.claim(c.index):
                claimed.append(c)
                continue
            # lease file exists — a steal candidate only if expired
            if fresh_done is None:
                fresh_done = self._global_done()
            if c.index in fresh_done:
                continue
            if self._lease.steal_expired(c.index):
                # the holder may have committed between our done-scan and
                # the rename — verify, and stand down if so
                fresh_done = self._global_done()
                if c.index in fresh_done:
                    self._lease.release(c.index)
                else:
                    claimed.append(c)
        if claimed and self.stall_s and not self._stalled_once:
            # deliberate lease-expiry victim: hold the claims without
            # heartbeating, long past the TTL
            self._stalled_once = True
            time.sleep(self.stall_s)
        return claimed

    def _release(self, chunks: Sequence) -> None:
        for c in chunks:
            self._lease.release(c.index)

    # -- main loop --------------------------------------------------------
    def run(self) -> WorkerStats:
        from repro.core import sweeppipeline, sweeprunner
        from repro.runtime import fault
        if self.compile_cache:
            sweeprunner.enable_compilation_cache(
                os.path.join(self.out_dir, "xla_cache"))
        handler = fault.PreemptionHandler(on_preempt=lambda: print(
            f"# worker {self.worker_id}: preemption notice — committing "
            f"in-flight work, then exiting", file=sys.stderr, flush=True))
        ex = sweeppipeline.PipelineExecutor(
            self.spec, cache=None,
            superbatch=self.superbatch or sweeppipeline.SUPERBATCH,
            compile_ahead=self.compile_ahead, bucketing=self.bucketing)
        stats = WorkerStats(worker=self.worker_id)
        t0 = time.perf_counter()
        self._write_stats(stats)
        n_run = 0
        try:
            while True:
                done = self._global_done()
                if len(done) == len(self._chunks):
                    break
                if handler.preempted:
                    stats.preempted = True
                    break
                if self.max_chunks is not None \
                        and n_run >= self.max_chunks:
                    break
                claimed = self._claim(done)
                if not claimed:
                    if self.on_idle is not None:
                        self.on_idle()
                    time.sleep(self.poll_s)
                    continue
                try:
                    if self.mode == "frontier":
                        n_run += self._run_frontier_batch(
                            ex, claimed, stats, handler)
                    else:
                        n_run += self._run_full_batch(
                            ex, claimed, stats, handler)
                except LostLease:
                    stats.n_lost_leases += 1
                    self._release(claimed)
                    self._write_stats(stats)
                except Preempted:
                    stats.preempted = True
                    self._release(claimed)
                    break
        finally:
            self._journal.close()
            stats.elapsed_s = time.perf_counter() - t0
            self._write_stats(stats)
        return stats

    def _preflight(self, claimed: Sequence) -> None:
        """Verify-and-extend every claimed lease before evaluation starts:
        a worker that stalled past its TTL (or is about to pay a long cold
        compile) finds out NOW, not after burning the batch's compute."""
        lost = self._lease.renew([c.index for c in claimed])
        self._last_renew = time.time()
        if lost:
            raise LostLease(f"leases stolen before evaluation: "
                            f"{sorted(lost)}")

    def _run_full_batch(self, ex, claimed: List, stats: WorkerStats,
                        handler) -> int:
        self._preflight(claimed)
        committed: List = []

        def commit(chunk, records):
            self._inj.fire("eval")         # kill-matrix: mid-chunk
            self._evaluated.append((chunk.index, time.time()))
            if self.eval_delay_s:
                time.sleep(self.eval_delay_s)
            if not self._lease.owns(chunk.index):
                raise LostLease(f"chunk {chunk.index} lease stolen")
            self._journal.append_rows(chunk.index, records)
            self._inj.fire("post_rows")    # kill-matrix: torn commit
            self._journal.append_done(chunk.index,
                                      chunk.hash(self._fp), len(records))
            committed.append(chunk)
            stats.n_chunks_committed += 1
            stats.n_points += len(records)
            self._committed.append((chunk.index, time.time()))
            self._write_stats(stats)
            held = [c.index for c in claimed if c not in committed]
            self._heartbeat(held)
            if handler.preempted:
                # the chunk just committed; release what we haven't
                # started and exit — preemption costs zero finished work
                raise Preempted()

        try:
            ex.run(claimed, commit)
        except (LostLease, Preempted):
            for c in claimed:
                if c not in committed:
                    self._lease.release(c.index)
            raise
        return len(committed)

    def _run_frontier_batch(self, ex, claimed: List, stats: WorkerStats,
                            handler) -> int:
        """One claim batch through the device-resident frontier, carrying
        this incarnation's state across batches via its shard checkpoint
        (merged points cannot be un-merged, so the checkpoint — not
        memory — is the authority after any fault)."""
        self._preflight(claimed)
        state0, own_done = None, {}
        if os.path.exists(self._sp["frontier"]):
            state0, own_done = sweepexec.load_frontier_state(
                self._sp["frontier"], self._fp, self.capacity,
                self._chunks)
        n_batch = [0]

        def on_commit(indices, host_state):
            self._inj.fire("eval")
            now = time.time()
            self._evaluated.extend((i, now) for i in indices)
            if self.eval_delay_s:
                time.sleep(self.eval_delay_s * len(indices))
            lost = [i for i in indices if not self._lease.owns(i)]
            if lost:
                raise LostLease(f"chunks {lost} leases stolen")
            self._inj.fire("post_rows")    # pre-checkpoint window
            own_done.update(
                {i: self._chunks[i].hash(self._fp) for i in indices})
            sweepexec.save_frontier_state(
                self._sp["frontier"], host_state, own_done,
                self.capacity, self._fp)
            n_batch[0] += len(indices)
            stats.n_chunks_committed += len(indices)
            stats.n_points += sum(len(self._chunks[i].labels)
                                  for i in indices)
            now = time.time()
            self._committed.extend((i, now) for i in indices)
            self._write_stats(stats)
            held = [c.index for c in claimed
                    if c.index not in own_done]
            self._heartbeat(held)
            if handler.preempted:
                raise Preempted()

        try:
            ex.run_frontier(claimed, capacity=self.capacity, state=state0,
                            on_commit=on_commit, all_chunks=self._chunks)
        except (LostLease, Preempted):
            for c in claimed:
                if c.index not in own_done:
                    self._lease.release(c.index)
            raise
        return n_batch[0]


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FabricStats:
    """Coordinator-side summary of a fabric run (mirrors the fields the
    CLI prints for `sweeprunner.RunStats`)."""

    n_points_total: int
    n_chunks_total: int
    n_chunks_committed: int
    n_workers: int
    n_worker_exits: Dict[str, int]
    elapsed_s: float
    out_dir: str
    mode: str
    records: Optional[List[Dict]] = None
    n_frontier_overflowed: int = 0

    @property
    def complete(self) -> bool:
        return self.n_chunks_committed == self.n_chunks_total


class FabricCoordinator:
    """Initialize a fabric directory, (optionally) spawn local workers,
    wait for global completion, merge shards.

    The coordinator holds no execution state: killing and rerunning it —
    or running several — is always safe, because the directory is the
    only authority.  ``workers=0`` initializes and waits for an external
    fleet (``pathfind sweep-worker --dir DIR`` on any host sharing the
    filesystem).
    """

    def __init__(self, spec, out_dir: str, workers: int = 2,
                 ttl_s: float = DEFAULT_TTL_S,
                 poll_s: float = DEFAULT_POLL_S,
                 frontier_only: bool = False,
                 frontier_capacity: Optional[int] = None,
                 superbatch: Optional[int] = None,
                 claim_batch: Optional[int] = None,
                 compile_ahead: Optional[int] = None,
                 bucketing: Optional[bool] = None,
                 eval_delay_s: float = 0.0,
                 max_respawns: int = 0,
                 worker_env: Optional[Dict[str, str]] = None,
                 chunk_order: Optional[Sequence[int]] = None,
                 verbose: bool = False):
        self.spec = spec
        self.out_dir = out_dir
        self.workers = int(workers)
        self.ttl_s = ttl_s
        self.poll_s = poll_s
        self.frontier_only = frontier_only
        self.frontier_capacity = frontier_capacity
        self.superbatch = superbatch
        self.claim_batch = claim_batch
        self.compile_ahead = compile_ahead
        self.bucketing = bucketing
        self.eval_delay_s = eval_delay_s
        self.max_respawns = max_respawns
        self.worker_env = worker_env
        # advisory work order (surrogate.rank_chunks output): written to
        # DIR/order.json before the fleet spawns; schedule-only
        self.chunk_order = chunk_order
        self.verbose = verbose

    def worker_cmd(self) -> List[str]:
        cmd = [sys.executable, "-m", "repro.pathfind", "sweep-worker",
               "--dir", self.out_dir, "--ttl", str(self.ttl_s),
               "--poll", str(self.poll_s)]
        if self.superbatch is not None:
            cmd += ["--superbatch", str(self.superbatch)]
        if self.claim_batch is not None:
            cmd += ["--claim-batch", str(self.claim_batch)]
        if self.compile_ahead is not None:
            cmd += ["--compile-ahead", str(self.compile_ahead)]
        if self.bucketing is False:
            cmd += ["--no-bucketing"]
        if self.eval_delay_s:
            cmd += ["--eval-delay", str(self.eval_delay_s)]
        return cmd

    def _spawn(self) -> subprocess.Popen:
        env = dict(os.environ)
        if self.worker_env:
            env.update(self.worker_env)
        return subprocess.Popen(self.worker_cmd(), env=env)

    def run(self) -> FabricStats:
        from repro.core import sweeprunner
        t0 = time.perf_counter()
        init_dir(self.spec, self.out_dir,
                 frontier_only=self.frontier_only,
                 frontier_capacity=self.frontier_capacity)
        fp = self.spec.fingerprint()
        if self.chunk_order is not None:
            write_chunk_order(self.out_dir, self.chunk_order, fp)
        chunks = sweeprunner.make_chunks(
            sweeprunner.enumerate_labels(self.spec), self.spec.chunk_size)
        if self.frontier_only:
            _, fabric = load_dir(self.out_dir)

            def done_now():
                return global_frontier_done(self.out_dir, chunks, fp,
                                            int(fabric["capacity"]))
        else:
            def done_now():
                return global_done(self.out_dir, chunks, fp)

        procs = [self._spawn() for _ in range(self.workers)]
        exits: Dict[str, int] = {}
        respawns = 0
        try:
            while True:
                done = done_now()
                if self.verbose:
                    print(f"# fabric: {len(done)}/{len(chunks)} chunks "
                          f"committed", flush=True)
                if len(done) == len(chunks):
                    break
                live = []
                for pr in procs:
                    rc = pr.poll()
                    if rc is None:
                        live.append(pr)
                        continue
                    exits[str(pr.pid)] = rc
                    if respawns < self.max_respawns:
                        respawns += 1
                        live.append(self._spawn())
                procs = live
                if not procs and self.workers > 0:
                    done = done_now()
                    if len(done) == len(chunks):
                        break
                    raise RuntimeError(
                        f"all fabric workers exited with "
                        f"{len(chunks) - len(done)} chunks uncommitted "
                        f"(exit codes {exits}); rerun to resume — "
                        f"committed work is preserved")
                time.sleep(self.poll_s)
            # completion: workers exit on their own once the global
            # done-set covers the enumeration
            for pr in procs:
                pr.wait(timeout=max(60.0, 4 * self.ttl_s))
        finally:
            for pr in procs:
                if pr.poll() is None:
                    pr.terminate()
        n_over = 0
        if self.frontier_only:
            records, n_over, done = merge_frontier(self.out_dir)
        else:
            records, done = merge_results(self.out_dir)
        return FabricStats(
            n_points_total=sum(len(c.labels) for c in chunks),
            n_chunks_total=len(chunks), n_chunks_committed=len(done),
            n_workers=self.workers, n_worker_exits=exits,
            elapsed_s=time.perf_counter() - t0, out_dir=self.out_dir,
            mode="frontier" if self.frontier_only else "full",
            records=records, n_frontier_overflowed=n_over)


__all__ = [
    "DEFAULT_POLL_S", "DEFAULT_TTL_S", "FabricCoordinator",
    "FabricStats", "FabricWorker", "LeaseManager", "LostLease",
    "Preempted", "WorkerStats", "global_done", "global_frontier_done",
    "init_dir", "load_chunk_order", "load_dir", "merge_frontier",
    "merge_results", "shard_paths", "write_chunk_order",
]
