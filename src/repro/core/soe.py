"""Search and Optimization Engine (DeepFlow paper §7).

Finds the budget breakdown W* = {A_i, P_i, R_i} minimizing predicted
iteration time f(W), subject to ΣA_i <= 1, ΣP_i <= 1, ΣR_i <= 1, with the
paper's update rule (eq. 6):

    W_t   = W_{t-1} - η g_t
    Ŵ_t   = W_t / ||W_t||
    M_t   = β M_{t-1} + (1-β) Ŵ_t          (exponential averaging in
    W_t   = Project(M_t) onto C_A, C_P, C_R  parameter space, not gradients)

multi-start (S starting points), T max steps (paper: T=100, S=10).

Beyond-paper (DESIGN.md): the objective is the *differentiable* CrossFlow
path (AGE with discrete=False + roofline + fixed-order event sim), so g_t is
an exact `jax.grad` — the paper treats CrossFlow as a black box. A finite-
difference fallback (`grad_mode="fd"`) reproduces the paper's setup exactly.

Batched pathfinding (repro.core.pathfinder): in "auto" grad mode all S
starting points run as ONE `jax.vmap`-ed, `jax.jit`-ed eq.-6 update per step
— the multi-start loop is a (S, DIM) matrix iteration, not S sequential
descents.  Strategy ranking in `co_optimize` goes through the batched
evaluator's LRU prediction cache, so repeated (graph, strategy, hardware)
points across calls are free.

The discrete parallelism-strategy dimension is co-optimized by exhaustive
enumeration around the GD loop (`co_optimize`), matching the paper's §9.2
"parallelism-strategy + architecture" studies.

One-shot batched budget scans (no GD) go through
`pathfinder.evaluate_budgets`, which memoizes a jitted vmapped objective
per skeleton; `rank_strategies` shares the same LRU prediction cache as
the sweep engine (`repro.core.sweeprunner`), so strategy rankings repeated
across SOE calls, planner calls, and sweeps cost nothing.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import age as age_lib
from repro.core import simulate
from repro.core.age import Budgets, COMPONENTS, PERIM_COMPONENTS
from repro.core.graph import ComputeGraph
from repro.core.parallelism import Strategy, enumerate_strategies
from repro.core.placement import SystemGraph
from repro.core.roofline import PPEConfig
from repro.core.techlib import TechConfig

_NC = len(COMPONENTS)
_NP = len(PERIM_COMPONENTS)
_DIM = 2 * _NC + _NP


@dataclasses.dataclass
class SOEConfig:
    lr: float = 0.05
    beta: float = 0.7               # momentum / EMA discount (paper eq. 6)
    steps: int = 100                # T (paper: 100)
    starts: int = 10                # S (paper: 10)
    seed: int = 0
    grad_mode: str = "auto"         # "auto" (batched jax.grad) | "fd" (paper)
    fd_eps: float = 1e-3
    min_frac: float = 1e-3


@dataclasses.dataclass
class SOEResult:
    budgets: Budgets
    time_s: float
    strategy: Optional[Strategy]
    history: List[float]
    n_queries: int


def _project_simplexes(w: jnp.ndarray, min_frac: float) -> jnp.ndarray:
    """Project each constraint group (area, power, perimeter) onto
    {x >= min_frac, Σx <= 1} — scale-down projection (budgets may be
    under-used, never over-used)."""
    def proj(seg):
        seg = jnp.maximum(seg, min_frac)
        total = jnp.sum(seg)
        n = seg.shape[0]
        # scale only the mass above the floor so the floor is preserved
        # (and the projection is idempotent)
        alpha = (1.0 - n * min_frac) / jnp.maximum(total - n * min_frac,
                                                   1e-12)
        scaled = min_frac + (seg - min_frac) * alpha
        return jnp.where(total > 1.0, scaled, seg)
    a, p, r = w[:_NC], w[_NC:2 * _NC], w[2 * _NC:]
    return jnp.concatenate([proj(a), proj(p), proj(r)])


def eq6_update(W: jnp.ndarray, M: jnp.ndarray, G: jnp.ndarray, lr: float,
               beta: float, project: Callable) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """One batched eq.-6 step: normalized-gradient descent, W-space
    normalization, parameter-space EMA, projection.

    ``W``, ``M``, ``G`` are (S, D) stacks (S starts advancing together);
    ``project`` maps an (S, D) parameter stack onto the constraint set.
    Returns (projected parameters, new EMA state).  Shared by both SOE
    optimization paths and by the cross-stack refinement engine
    (`repro.core.cooptimize`), which applies it to the budget block of its
    joint (budget, technology-knob) parameter vector.
    """
    G = jnp.nan_to_num(G, nan=0.0, posinf=0.0, neginf=0.0)
    gnorm = jnp.linalg.norm(G, axis=1, keepdims=True)
    G = jnp.where(gnorm > 0, G / (gnorm + 1e-12), G)
    W_new = W - lr * G                                   # W_t = W_{t-1} - η g
    W_hat = W_new / (jnp.linalg.norm(W_new, axis=1, keepdims=True) + 1e-12)
    M_new = beta * M + (1.0 - beta) * W_hat              # EMA in W-space
    return project(M_new), M_new


def make_objective(tech: TechConfig, graph: ComputeGraph, strategy: Strategy,
                   system: Optional[SystemGraph] = None,
                   template: Optional[Budgets] = None,
                   ppe: PPEConfig = PPEConfig(),
                   pod_bw: Optional[float] = None) -> Callable:
    """f(W) -> predicted iteration time (differentiable jnp scalar)."""
    like = template or Budgets.default()

    def f(w: jnp.ndarray):
        budgets = Budgets.from_vector(w, like)
        arch = age_lib.generate(tech, budgets, discrete=False)
        bd = simulate.predict(arch, graph, strategy, system=system, cfg=ppe,
                              pod_bw=pod_bw)
        return bd.total_s

    return f


def _initial_starts(cfg: SOEConfig, like: Budgets) -> List[jnp.ndarray]:
    """Start 0 is the template; the rest Dirichlet draws.  Every start is
    routed through `_project_simplexes` — a raw Dirichlet draw sums to 1
    but its smallest components routinely sit below the `min_frac` floor
    the iterates are projected onto, so unprojected starts would begin
    outside the constraint set start 0 is in."""
    rng = np.random.default_rng(cfg.seed)
    starts = [like.as_vector()]
    for _ in range(1, cfg.starts):
        starts.append(jnp.asarray(rng.dirichlet(np.ones(_NC)).tolist()
                                  + rng.dirichlet(np.ones(_NC)).tolist()
                                  + rng.dirichlet(np.ones(_NP)).tolist(),
                                  dtype=jnp.float32))
    return [_project_simplexes(w, cfg.min_frac) for w in starts]


def _optimize_sequential(objective: Callable, cfg: SOEConfig, like: Budgets,
                         on_step: Optional[Callable] = None) -> SOEResult:
    """One start at a time; supports the paper-style FD gradient mode and
    arbitrary (non-traceable) objectives."""
    n_queries = 0

    if cfg.grad_mode == "fd":
        def grad_fn(w):
            nonlocal n_queries
            base = float(objective(w))
            g = np.zeros(_DIM, dtype=np.float32)
            for i in range(_DIM):
                wp = np.array(w)
                wp[i] += cfg.fd_eps
                g[i] = (float(objective(jnp.asarray(wp))) - base) / cfg.fd_eps
                n_queries += 1
            return jnp.asarray(g), base
    else:
        vg = jax.value_and_grad(objective)

        def grad_fn(w):
            nonlocal n_queries
            n_queries += 1
            val, g = vg(w)
            return g, float(val)

    project = jax.vmap(functools.partial(_project_simplexes,
                                         min_frac=cfg.min_frac))
    best_w, best_t, history = None, float("inf"), []
    for w in _initial_starts(cfg, like):
        m = w
        last = float("inf")
        for t in range(cfg.steps):
            g, val = grad_fn(w)
            history.append(val)
            if val < best_t:
                best_t, best_w = val, w
            W, M = eq6_update(w[None, :], m[None, :], g[None, :],
                              cfg.lr, cfg.beta, project)
            w, m = W[0], M[0]
            if on_step is not None:
                on_step(t, np.asarray(W))
            if abs(last - val) < 1e-7 * max(val, 1e-12):
                break
            last = val
    final_t = float(objective(best_w))
    if final_t < best_t:
        best_t = final_t
    return SOEResult(budgets=Budgets.from_vector(np.asarray(best_w), like),
                     time_s=float(best_t), strategy=None,
                     history=history, n_queries=n_queries)


def _optimize_batched(objective: Callable, cfg: SOEConfig, like: Budgets,
                      on_step: Optional[Callable] = None) -> SOEResult:
    """All S starting points advance together: one vmapped value_and_grad
    plus one vectorized eq.-6 update per step (jit-compiled).  Converged
    starts are frozen by mask so per-start early stopping matches the
    sequential semantics."""
    W = jnp.stack(_initial_starts(cfg, like))           # (S, DIM)
    vg = jax.vmap(jax.value_and_grad(objective))
    proj = jax.vmap(functools.partial(_project_simplexes,
                                      min_frac=cfg.min_frac))
    lr, beta = cfg.lr, cfg.beta

    @jax.jit
    def step(W, M, done, last):
        vals, G = vg(W)
        W_proj, M_new = eq6_update(W, M, G, lr, beta, proj)
        conv = jnp.abs(last - vals) < 1e-7 * jnp.maximum(vals, 1e-12)
        frozen = done[:, None]
        W_out = jnp.where(frozen, W, W_proj)
        M_out = jnp.where(frozen, M, M_new)
        return W_out, M_out, done | conv, vals

    M = W
    done = jnp.zeros(cfg.starts, dtype=bool)
    last = jnp.full(cfg.starts, jnp.inf)
    history: List[float] = []
    best_w, best_t = None, float("inf")
    n_queries = 0
    for t in range(cfg.steps):
        if bool(np.all(np.asarray(done))):
            break
        # the vmapped value_and_grad evaluates ALL S starts every step (the
        # done mask only freezes state), so every step costs S queries
        n_queries += cfg.starts
        W_before = W
        W, M, done, vals = step(W, M, done, last)
        if on_step is not None:
            on_step(t, np.asarray(W))
        vals_np = np.asarray(vals, dtype=np.float64)
        history.extend(float(v) for v in vals_np)
        # nan-safe argmin: one diverged start (nan objective) must not
        # blind the best-so-far tracking for the healthy starts
        finite = np.where(np.isfinite(vals_np), vals_np, np.inf)
        i = int(np.argmin(finite))
        if finite[i] < best_t:
            best_t, best_w = float(finite[i]), W_before[i]
        last = vals
    final_t = float(objective(best_w))
    if final_t < best_t:
        best_t = final_t
    return SOEResult(budgets=Budgets.from_vector(np.asarray(best_w), like),
                     time_s=float(best_t), strategy=None,
                     history=history, n_queries=n_queries)


def optimize(objective: Callable, cfg: SOEConfig = SOEConfig(),
             template: Optional[Budgets] = None,
             on_step: Optional[Callable] = None) -> SOEResult:
    """Projected GD with parameter-space exponential averaging (eq. 6).

    grad_mode="auto" runs the batched multi-start path (one vmapped update
    advances every start); "fd" or a non-traceable objective falls back to
    the sequential paper-style loop.  ``on_step(t, W)`` (host-side, W an
    (S, DIM) np array of the post-projection iterates) is invoked after
    every update — tests use it to check the constraint invariants.
    """
    like = template or Budgets.default()
    if cfg.grad_mode == "fd":
        return _optimize_sequential(objective, cfg, like, on_step=on_step)
    try:
        return _optimize_batched(objective, cfg, like, on_step=on_step)
    except (jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError, TypeError):
        # objective not jax-traceable (true black box): paper-style FD loop
        return _optimize_sequential(
            objective, dataclasses.replace(cfg, grad_mode="fd"), like,
            on_step=on_step)


def rank_strategies(tech: TechConfig, graph: ComputeGraph,
                    strategies: Sequence[Strategy],
                    system: Optional[SystemGraph] = None,
                    template: Optional[Budgets] = None,
                    ppe: PPEConfig = PPEConfig()
                    ) -> List[Tuple[float, Strategy]]:
    """Score every strategy on the template budgets, cheapest first.

    Scoring goes through the batched pathfinding engine: one struct-of-
    arrays evaluation per graph/strategy skeleton with LRU caching, so a
    re-ranking of previously seen points costs nothing.
    """
    from repro.core import pathfinder
    like = template or Budgets.default()
    # exactly the arch the per-point objective f(like.as_vector()) builds
    budgets = Budgets.from_vector(like.as_vector(), like)
    arch = age_lib.generate(tech, budgets, discrete=False)
    points = [pathfinder.EvalPoint(arch, graph, st, system=system)
              for st in strategies]
    rows = pathfinder.evaluate(points=points, ppe=ppe)
    ranked = [(float(rows[i, 0]), st) for i, st in enumerate(strategies)]
    ranked.sort(key=lambda x: x[0])
    return ranked


def co_optimize(tech: TechConfig, graph: ComputeGraph, n_devices: int,
                system: Optional[SystemGraph] = None,
                cfg: SOEConfig = SOEConfig(),
                template: Optional[Budgets] = None,
                strategies: Optional[Sequence[Strategy]] = None,
                max_strategies: int = 24,
                search_arch: bool = True,
                ppe: PPEConfig = PPEConfig()) -> SOEResult:
    """Joint (parallelism strategy x hardware budget) search (paper §9.2).

    With search_arch=False only the strategy is optimized on the template
    budgets (the paper's "parallelism strategy optimization alone" baseline).
    """
    like = template or Budgets.default()
    if strategies is None:
        strategies = list(enumerate_strategies(n_devices, max_lp=4))
    # rank strategies on template budgets, then refine the top few
    ranked = rank_strategies(tech, graph, strategies, system=system,
                             template=like, ppe=ppe)
    if not search_arch:
        t, st = ranked[0]
        return SOEResult(budgets=like, time_s=t, strategy=st, history=[],
                         n_queries=len(ranked))
    best: Optional[SOEResult] = None
    for t0, st in ranked[:max(1, max_strategies // 8)]:
        f = make_objective(tech, graph, st, system=system, template=like,
                           ppe=ppe)
        res = optimize(f, cfg=cfg, template=like)
        res = dataclasses.replace(res, strategy=st)
        if best is None or res.time_s < best.time_s:
            best = res
    assert best is not None
    return best
