"""Search and Optimization Engine (DeepFlow paper §7).

Finds the budget breakdown W* = {A_i, P_i, R_i} minimizing predicted
iteration time f(W), subject to ΣA_i <= 1, ΣP_i <= 1, ΣR_i <= 1, with the
paper's update rule (eq. 6):

    W_t   = W_{t-1} - η g_t
    Ŵ_t   = W_t / ||W_t||
    M_t   = β M_{t-1} + (1-β) Ŵ_t          (exponential averaging in
    W_t   = Project(M_t) onto C_A, C_P, C_R  parameter space, not gradients)

multi-start (S starting points), T max steps (paper: T=100, S=10).

Beyond-paper (DESIGN.md): the objective is the *differentiable* CrossFlow
path (AGE with discrete=False + roofline + fixed-order event sim), so g_t is
an exact `jax.grad` — the paper treats CrossFlow as a black box. A finite-
difference fallback (`grad_mode="fd"`) reproduces the paper's setup exactly.

The discrete parallelism-strategy dimension is co-optimized by exhaustive
enumeration around the GD loop (`co_optimize`), matching the paper's §9.2
"parallelism-strategy + architecture" studies.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import age as age_lib
from repro.core import simulate
from repro.core.age import Budgets, COMPONENTS, PERIM_COMPONENTS
from repro.core.graph import ComputeGraph
from repro.core.parallelism import Strategy, enumerate_strategies
from repro.core.placement import SystemGraph
from repro.core.roofline import PPEConfig
from repro.core.techlib import TechConfig

_NC = len(COMPONENTS)
_NP = len(PERIM_COMPONENTS)
_DIM = 2 * _NC + _NP


@dataclasses.dataclass
class SOEConfig:
    lr: float = 0.05
    beta: float = 0.7               # momentum / EMA discount (paper eq. 6)
    steps: int = 100                # T (paper: 100)
    starts: int = 10                # S (paper: 10)
    seed: int = 0
    grad_mode: str = "auto"         # "auto" (jax.grad) | "fd" (paper-style)
    fd_eps: float = 1e-3
    min_frac: float = 1e-3


@dataclasses.dataclass
class SOEResult:
    budgets: Budgets
    time_s: float
    strategy: Optional[Strategy]
    history: List[float]
    n_queries: int


def _project_simplexes(w: jnp.ndarray, min_frac: float) -> jnp.ndarray:
    """Project each constraint group (area, power, perimeter) onto
    {x >= min_frac, Σx <= 1} — scale-down projection (budgets may be
    under-used, never over-used)."""
    def proj(seg):
        seg = jnp.maximum(seg, min_frac)
        total = jnp.sum(seg)
        n = seg.shape[0]
        # scale only the mass above the floor so the floor is preserved
        # (and the projection is idempotent)
        alpha = (1.0 - n * min_frac) / jnp.maximum(total - n * min_frac,
                                                   1e-12)
        scaled = min_frac + (seg - min_frac) * alpha
        return jnp.where(total > 1.0, scaled, seg)
    a, p, r = w[:_NC], w[_NC:2 * _NC], w[2 * _NC:]
    return jnp.concatenate([proj(a), proj(p), proj(r)])


def make_objective(tech: TechConfig, graph: ComputeGraph, strategy: Strategy,
                   system: Optional[SystemGraph] = None,
                   template: Optional[Budgets] = None,
                   ppe: PPEConfig = PPEConfig(),
                   pod_bw: Optional[float] = None) -> Callable:
    """f(W) -> predicted iteration time (differentiable jnp scalar)."""
    like = template or Budgets.default()

    def f(w: jnp.ndarray):
        budgets = Budgets.from_vector(w, like)
        arch = age_lib.generate(tech, budgets, discrete=False)
        bd = simulate.predict(arch, graph, strategy, system=system, cfg=ppe,
                              pod_bw=pod_bw)
        return bd.total_s

    return f


def optimize(objective: Callable, cfg: SOEConfig = SOEConfig(),
             template: Optional[Budgets] = None) -> SOEResult:
    """Projected GD with parameter-space exponential averaging (eq. 6)."""
    like = template or Budgets.default()
    rng = np.random.default_rng(cfg.seed)
    n_queries = 0

    if cfg.grad_mode == "fd":
        def grad_fn(w):
            nonlocal n_queries
            base = float(objective(w))
            g = np.zeros(_DIM, dtype=np.float32)
            for i in range(_DIM):
                wp = np.array(w)
                wp[i] += cfg.fd_eps
                g[i] = (float(objective(jnp.asarray(wp))) - base) / cfg.fd_eps
                n_queries += 1
            return jnp.asarray(g), base
    else:
        vg = jax.value_and_grad(objective)

        def grad_fn(w):
            nonlocal n_queries
            n_queries += 1
            val, g = vg(w)
            return g, float(val)

    best_w, best_t, history = None, float("inf"), []
    for s in range(cfg.starts):
        if s == 0:
            w = _project_simplexes(like.as_vector(), cfg.min_frac)
        else:
            w = jnp.asarray(rng.dirichlet(np.ones(_NC)).tolist()
                            + rng.dirichlet(np.ones(_NC)).tolist()
                            + rng.dirichlet(np.ones(_NP)).tolist(),
                            dtype=jnp.float32)
        m = w
        last = float("inf")
        for t in range(cfg.steps):
            g, val = grad_fn(w)
            history.append(val)
            if val < best_t:
                best_t, best_w = val, w
            g = jnp.nan_to_num(g, nan=0.0, posinf=0.0, neginf=0.0)
            gnorm = jnp.linalg.norm(g)
            g = jnp.where(gnorm > 0, g / (gnorm + 1e-12), g)
            w_new = w - cfg.lr * g                       # W_t = W_{t-1} - η g
            w_hat = w_new / (jnp.linalg.norm(w_new) + 1e-12)   # normalize
            m = cfg.beta * m + (1.0 - cfg.beta) * w_hat        # EMA in W-space
            w = _project_simplexes(m, cfg.min_frac)            # project
            if abs(last - val) < 1e-7 * max(val, 1e-12):
                break
            last = val
    final_t = float(objective(best_w))
    if final_t < best_t:
        best_t = final_t
    return SOEResult(budgets=Budgets.from_vector(np.asarray(best_w), like),
                     time_s=float(best_t), strategy=None,
                     history=history, n_queries=n_queries)


def co_optimize(tech: TechConfig, graph: ComputeGraph, n_devices: int,
                system: Optional[SystemGraph] = None,
                cfg: SOEConfig = SOEConfig(),
                template: Optional[Budgets] = None,
                strategies: Optional[Sequence[Strategy]] = None,
                max_strategies: int = 24,
                search_arch: bool = True,
                ppe: PPEConfig = PPEConfig()) -> SOEResult:
    """Joint (parallelism strategy x hardware budget) search (paper §9.2).

    With search_arch=False only the strategy is optimized on the template
    budgets (the paper's "parallelism strategy optimization alone" baseline).
    """
    like = template or Budgets.default()
    if strategies is None:
        strategies = list(enumerate_strategies(n_devices, max_lp=4))
    # rank strategies on template budgets, then refine the top few
    ranked = []
    for st in strategies:
        f = make_objective(tech, graph, st, system=system, template=like,
                           ppe=ppe)
        ranked.append((float(f(like.as_vector())), st))
    ranked.sort(key=lambda x: x[0])
    if not search_arch:
        t, st = ranked[0]
        return SOEResult(budgets=like, time_s=t, strategy=st, history=[],
                         n_queries=len(ranked))
    best: Optional[SOEResult] = None
    for t0, st in ranked[:max(1, max_strategies // 8)]:
        f = make_objective(tech, graph, st, system=system, template=like,
                           ppe=ppe)
        res = optimize(f, cfg=cfg, template=like)
        res = dataclasses.replace(res, strategy=st)
        if best is None or res.time_s < best.time_s:
            best = res
    assert best is not None
    return best
