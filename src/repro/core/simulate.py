"""Event-driven end-to-end time estimation (DeepFlow paper §6.5) and the
top-level CrossFlow `predict` API.

Event-driven simulation = resource-constrained critical-path analysis.
Per the paper, simulation runs on the *original* (one-replica, sharded)
graph: DP/KP replicas are homogeneous and deterministic so their timing is
identical; only pipeline parallelism needs explicit (stage x microbatch)
event scheduling.

Resources per hardware node: one compute engine (<= k kernels at a time,
k=1) and one network engine; compute/comm overlap is a switch (default on —
matches both modern NCCL-style async collectives and XLA's latency-hiding
scheduler; CrossFlow's validation in the paper included overlapped NCCL).

Everything is `jnp`-friendly: with a fixed schedule order the accumulated
times are differentiable w.r.t. MicroArch parameters (used by the SOE).

Serving (inference) mode: `serving_breakdown` combines a prefill-graph and
a decode-graph prediction into TTFT / TPOT / tokens-per-sec-per-device with
KV-cache memory-pressure derating; the scenario registry in
`repro.core.scenarios` builds the phase graphs and drives it through the
batched pathfinding engine.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.core import placement as placement_lib
from repro.core import roofline, transform
from repro.core.age import MicroArch
from repro.core.graph import ComputeGraph
from repro.core.parallelism import Strategy
from repro.core.placement import Placement, SystemGraph
from repro.core.roofline import PPEConfig


@dataclasses.dataclass
class TimeBreakdown:
    total_s: object
    compute_s: object
    comm_s: object
    exposed_comm_s: object
    pipeline_bubble_s: object = 0.0
    per_node: Optional[Dict[str, object]] = None

    def as_floats(self) -> "TimeBreakdown":
        f = lambda x: float(x)
        return TimeBreakdown(f(self.total_s), f(self.compute_s),
                             f(self.comm_s), f(self.exposed_comm_s),
                             f(self.pipeline_bubble_s), None)


def _node_times(arch: MicroArch, g: ComputeGraph, placement: Placement,
                cfg: PPEConfig, pod_bw: Optional[float]) -> Dict[str, object]:
    times = {}
    for name, node in g.nodes.items():
        if node.kind == "comm":
            t = placement_lib.comm_time(
                arch, placement, node.comm, node.comm_bytes, node.comm_axis,
                node.comm_participants, pod_bw=pod_bw)
        else:
            t = roofline.node_time(arch, node, cfg)
        # a tagged node stands for `repeat` identical layers (lmgraph)
        times[name] = t * node.meta.get("repeat", 1)
    return times


def simulate_graph(arch: MicroArch, g: ComputeGraph, placement: Placement,
                   cfg: PPEConfig = PPEConfig(), overlap: bool = True,
                   pod_bw: Optional[float] = None,
                   keep_per_node: bool = False) -> TimeBreakdown:
    """List-schedule the sharded graph on one replica's resources.

    Two engines (compute, network); deps respected; fixed topo order so the
    schedule itself is not time-dependent (keeps the result differentiable).
    """
    times = _node_times(arch, g, placement, cfg, pod_bw)
    finish: Dict[str, object] = {}
    compute_free, net_free = jnp.asarray(0.0), jnp.asarray(0.0)
    compute_busy, comm_busy = jnp.asarray(0.0), jnp.asarray(0.0)
    for name in g.topo_order():
        node = g.nodes[name]
        ready = jnp.asarray(0.0)
        for p in dict.fromkeys(g.preds(name)):
            ready = jnp.maximum(ready, finish[p])
        dur = times[name]
        if node.kind == "comm":
            start = jnp.maximum(ready, net_free) if not overlap else ready
            # network engine serializes comms even when overlapped w/ compute
            start = jnp.maximum(start, net_free)
            net_free = start + dur
            comm_busy = comm_busy + dur
        else:
            start = jnp.maximum(ready, compute_free)
            compute_free = start + dur
            compute_busy = compute_busy + dur
        if not overlap:
            # no overlap: both engines serialize behind each other
            merged = jnp.maximum(compute_free, net_free)
            compute_free = net_free = merged
        finish[name] = start + dur
    total = jnp.asarray(0.0)
    for v in finish.values():
        total = jnp.maximum(total, v)
    exposed = jnp.maximum(total - compute_busy, 0.0)
    return TimeBreakdown(total_s=total, compute_s=compute_busy,
                         comm_s=comm_busy, exposed_comm_s=exposed,
                         per_node=times if keep_per_node else None)


def simulate_pipeline(stage_times, p2p_times, n_microbatches: int):
    """(stage x microbatch) grid event-sim, GPipe schedule (paper Fig. 5
    bottom shows the analogous backward-pass grid).

    start(s, m) = max(finish(s-1, m) + p2p(s-1), finish(s, m-1)).
    Returns makespan and bubble time.
    """
    S = len(stage_times)
    M = int(n_microbatches)
    finish = [[None] * M for _ in range(S)]
    for m in range(M):
        for s in range(S):
            ready = jnp.asarray(0.0)
            if s > 0:
                ready = jnp.maximum(ready, finish[s - 1][m] + p2p_times[s - 1])
            if m > 0:
                ready = jnp.maximum(ready, finish[s][m - 1])
            finish[s][m] = ready + stage_times[s]
    makespan = finish[S - 1][M - 1]
    work = sum(stage_times) * 0  # typing seed
    total_work = jnp.asarray(0.0)
    for s in range(S):
        total_work = total_work + stage_times[s] * M
    bubble = jnp.maximum(makespan * S - total_work, 0.0) / S
    return makespan, bubble


# ---------------------------------------------------------------------------
# Top-level CrossFlow predict
# ---------------------------------------------------------------------------


def default_system(strategy: Strategy) -> SystemGraph:
    """Balanced 2-D torus factorization (a, b), a*b = devices, a <= b."""
    n = strategy.devices
    a = max(int(n ** 0.5), 1)
    while n % a:
        a -= 1
    return SystemGraph(dims=(a, n // a), levels=("inter", "inter")) \
        if a > 1 else SystemGraph(dims=(n,), levels=("inter",))


def predict(arch: MicroArch, g: ComputeGraph, strategy: Strategy,
            system: Optional[SystemGraph] = None,
            cfg: PPEConfig = PPEConfig(), overlap: bool = True,
            n_microbatches: Optional[int] = None,
            pod_bw: Optional[float] = None,
            grad_bytes: Optional[float] = None) -> TimeBreakdown:
    """End-to-end per-iteration time for (model graph, strategy, hardware).

    This is the CrossFlow standalone entry point (paper §3.1): transform ->
    place -> roofline per node -> event-driven end-to-end estimate.
    """
    if system is None:
        system = default_system(strategy)
    pl = placement_lib.place(system, strategy)
    sharded = transform.shard_graph(g, strategy, grad_bytes=grad_bytes)

    if strategy.lp <= 1:
        return simulate_graph(arch, sharded, pl, cfg, overlap, pod_bw)

    # pipeline: per-stage time from list-scheduling each stage subgraph,
    # then the (stage x microbatch) grid sim.
    stages = transform.stage_subgraphs(sharded, strategy.lp)
    stage_bd = [simulate_graph(arch, sg, pl, cfg, overlap, pod_bw)
                for sg in stages if len(sg)]
    mb = n_microbatches or max(4 * strategy.lp, 8)
    # per-microbatch stage time: stage work divided across microbatches
    st = [bd.total_s / mb for bd in stage_bd]
    act_bytes = _stage_boundary_bytes(sharded, strategy)
    p2p = []
    for i in range(len(st) - 1):
        p2p.append(placement_lib.comm_time(arch, pl, "p2p",
                                           act_bytes / mb, "lp", 2,
                                           pod_bw=pod_bw))
    makespan, bubble = simulate_pipeline(st, p2p, mb)
    compute = sum(bd.compute_s for bd in stage_bd)
    comm = sum(bd.comm_s for bd in stage_bd)
    return TimeBreakdown(total_s=makespan, compute_s=compute, comm_s=comm,
                         exposed_comm_s=jnp.maximum(makespan - compute, 0.0),
                         pipeline_bubble_s=bubble)


def _stage_boundary_bytes(g: ComputeGraph, s: Strategy) -> float:
    """Activation bytes crossing a stage boundary ~ largest gemm output."""
    best = 0.0
    for node in g.nodes.values():
        if node.kind == "gemm":
            best = max(best, float(node.b) * node.m * node.n
                       * node.dtype_bytes)
    return best


# ---------------------------------------------------------------------------
# Serving (inference) phase model — prefill + decode
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingBreakdown:
    """Inference-mode prediction: one prefill pass + steady-state decode.

    TTFT (time to first token) is the prefill makespan; TPOT (time per
    output token) is one decode step over the whole concurrent batch,
    derated for KV-cache memory pressure
    (`roofline.capacity_pressure_derate`).  ``cost_device_s_per_token`` =
    devices * TPOT / batch is the Pareto cost axis paired with TTFT in the
    serving scenario (repro.core.scenarios).
    """

    ttft_s: float
    tpot_s: float
    tokens_per_s: float
    tokens_per_s_per_device: float
    cost_device_s_per_token: float
    weight_bytes_per_device: float
    kv_bytes_per_device: float
    hbm_occupancy: float
    kv_derate: float
    feasible: bool
    slo_ok: Optional[bool] = None


def serving_breakdown(prefill: TimeBreakdown, decode: TimeBreakdown, *,
                      batch: int, devices: int,
                      weight_bytes_per_device: float,
                      kv_bytes_per_device: float,
                      dram_capacity: float,
                      slo_s: Optional[float] = None) -> ServingBreakdown:
    """Combine per-phase CrossFlow predictions into serving metrics.

    The decode graph's attention GEMMs already charge the per-step KV-cache
    *bandwidth* (reading the whole context each token); this combinator
    adds the *capacity* dimension: per-device resident bytes (weights +
    KV) against main-memory capacity, with decode bandwidth derated near
    the wall and the point marked infeasible beyond it.
    """
    from repro.core import roofline as roofline_lib
    import math
    occ = ((weight_bytes_per_device + kv_bytes_per_device)
           / max(float(dram_capacity), 1.0))
    derate = roofline_lib.capacity_pressure_derate(occ)
    ttft = float(prefill.total_s)
    tpot = float(decode.total_s) * derate
    # both phases must produce a finite prediction (guards NaN too)
    feasible = math.isfinite(tpot) and math.isfinite(ttft)
    tokens_per_s = batch / tpot if feasible and tpot > 0 else 0.0
    per_dev = tokens_per_s / max(devices, 1)
    cost = (devices * tpot / batch) if feasible and batch else float("inf")
    return ServingBreakdown(
        ttft_s=ttft, tpot_s=tpot, tokens_per_s=tokens_per_s,
        tokens_per_s_per_device=per_dev, cost_device_s_per_token=cost,
        weight_bytes_per_device=float(weight_bytes_per_device),
        kv_bytes_per_device=float(kv_bytes_per_device),
        hbm_occupancy=float(occ), kv_derate=float(derate),
        feasible=feasible,
        slo_ok=None if slo_s is None else bool(ttft <= slo_s))
