"""Cross-stack co-optimization engine — sweep -> refine (paper §7-§9).

The sweep engine (`repro.core.sweeprunner`) brute-forces the *discrete*
cross-product (arch x mesh x tech node x strategy x budget scale); its
Pareto frontier is only as good as the grid.  This module turns the repo
from a predictor into the paper's pathfinder: it takes the frontier of a
checkpointed sweep and runs **batched gradient-based refinement** around
each frontier point, jointly over

  (a) continuous technology knobs — a DVFS operating voltage
      (`techlib.freq_at_voltage`, alpha-power-law frequency, V^2 dynamic
      energy) and HBM bandwidth / capacity scaling,
  (b) the hardware budget vector W = {A_i, P_i, R_i}, advanced by the
      *existing* vmapped eq.-6 SOE update (`soe.eq6_update`), and
  (c) the discrete parallelism-strategy / mesh-shape axis, enumerated in
      an outer loop whose candidates are ranked from the sweep's own
      records (zero re-evaluation of already-scored points) and whose
      final re-scoring shares the process-wide LRU prediction cache
      (resolved at call time, so it also hits rows published by the
      pipelined executor that produced the sweep — any backend's
      checkpoint directory works as a `--from` source).

The joint parameter vector is theta = [W (17) | u (3)] where u holds the
knobs normalized to [0, 1]; one jitted step evaluates all S starts with a
vmapped value-and-grad, applies eq. 6 to the budget block and a clipped
EMA step to the knob block, and a power-feasibility penalty couples the
two (overclocking the core or widening HBM must be paid for out of the
power simplex's headroom).  A refined point is re-scored through the
standard discrete path — AGE with floors, the DVFS voltage clamped to the
power budget via `techlib.solve_voltage_for_power` — and streamed in the
same JSONL record schema as the sweep, so `sweeprunner.pareto_records`,
`to_csv`, and the docs cookbook compose unchanged.

CLI: ``python -m repro.pathfind cooptimize --from <sweep-out-dir>``;
benchmark: `benchmarks/cooptimize_refine.py` (asserts the refined frontier
strictly dominates at least one sweep frontier point on both the train and
serving scenarios).
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import age as age_lib
from repro.core import pathfinder, scenarios, simulate, soe, sweeprunner, \
    techlib
from repro.core.age import Budgets, COMPONENTS
from repro.core.roofline import PPEConfig
from repro.core.sweeprunner import SweepSpec
from repro.core.techlib import TechConfig, dynamic_energy_scale, \
    freq_at_voltage, solve_voltage_for_power

BUDGET_DIM = soe._DIM                   # 17: {A_i, P_i, R_i}
KNOBS = ("voltage", "hbm_bw_scale", "hbm_cap_scale")
KNOB_DIM = len(KNOBS)
THETA_DIM = BUDGET_DIM + KNOB_DIM

_PF_CORE = COMPONENTS.index("core")     # power-frac offsets into W
_PF_DRAM = COMPONENTS.index("dram")


@dataclasses.dataclass(frozen=True)
class RefineConfig:
    """Knobs of the sweep->refine pipeline (defaults fit a CLI session)."""

    top_k: int = 4                  # frontier seeds to refine
    candidates_per_seed: int = 2    # discrete (mesh, strategy) peers each
    steps: int = 24                 # GD steps (T)
    starts: int = 4                 # multi-start batch (S)
    lr: float = 0.05
    beta: float = 0.7               # eq.-6 EMA discount
    seed: int = 0
    min_frac: float = 1e-3          # budget simplex floor
    scale_lo: float = 0.5           # HBM bw/capacity scaling bounds
    scale_hi: float = 2.0
    power_penalty: float = 25.0     # objective multiplier per unit excess


@dataclasses.dataclass
class RefineStats:
    """What one `refine_sweep` call did."""

    scenario: str
    n_records: int                  # sweep records loaded (scored points)
    n_frontier: int                 # sweep Pareto-frontier size
    n_seeds: int                    # frontier points refined
    n_candidates: int               # discrete candidates refined in total
    n_refined: int                  # refined records emitted
    n_unimproved: int               # candidates where GD never beat theta0
    n_dominating: int               # refined records dominating >=1 seed
    n_objective_evals: int          # continuous-objective evaluations
    elapsed_s: float
    out_path: Optional[str]
    records: List[Dict]             # the refined records, stream order
    frontier: List[Dict]            # the sweep frontier records used


# ---------------------------------------------------------------------------
# Technology knobs (continuous, traceable)
# ---------------------------------------------------------------------------


def knob_bounds(tech: TechConfig, cfg: RefineConfig
                ) -> Tuple[Tuple[float, float], ...]:
    """Physical (lo, hi) per knob, ordered like KNOBS."""
    c = tech.compute
    return ((c.minimum_voltage, c.maximum_voltage),
            (cfg.scale_lo, cfg.scale_hi),
            (cfg.scale_lo, cfg.scale_hi))


def knobs_from_unit(u, tech: TechConfig, cfg: RefineConfig):
    """Map the normalized knob block u in [0,1]^3 to physical values."""
    bounds = knob_bounds(tech, cfg)
    return tuple(lo + u[i] * (hi - lo)
                 for i, (lo, hi) in enumerate(bounds))


def unit_from_knobs(vals: Sequence[float], tech: TechConfig,
                    cfg: RefineConfig) -> np.ndarray:
    bounds = knob_bounds(tech, cfg)
    return np.asarray([(v - lo) / max(hi - lo, 1e-9)
                       for v, (lo, hi) in zip(vals, bounds)],
                      dtype=np.float32)


def nominal_knobs(tech: TechConfig) -> Tuple[float, float, float]:
    """The identity operating point: nominal voltage, unscaled HBM."""
    return (tech.compute.nominal_voltage, 1.0, 1.0)


def apply_tech_knobs(arch, tech: TechConfig, voltage, hbm_bw_scale,
                     hbm_cap_scale):
    """DVFS + HBM scaling on an AGE'd MicroArch (traceable).

    The voltage knob moves the compute operating point along the
    alpha-power-law f(V) curve relative to nominal (`freq_at_voltage`);
    the HBM knobs scale main-memory bandwidth and capacity (a stack-count
    / generation interpolation).  The embedded tech config's
    energy-per-flop is rescaled by the V^2 dynamic-energy law, so energy
    objectives (`pathfinder.hw_coeffs` reads ``arch.tech``) see the DVFS
    operating point — both in the traced refinement and when re-scoring a
    realized theta.  At the nominal point (Vnom, 1, 1) this is the
    identity, so a refinement started there reproduces the seed.
    """
    c = tech.compute
    f_ratio = freq_at_voltage(voltage, c.nominal_voltage, 1.0,
                              c.threshold_voltage)
    e_scale = dynamic_energy_scale(voltage, c.nominal_voltage)
    tech_v = dataclasses.replace(tech, compute=dataclasses.replace(
        c, energy_per_flop=c.energy_per_flop * e_scale))
    return dataclasses.replace(
        arch,
        tech=tech_v,
        compute_throughput=arch.compute_throughput * f_ratio,
        core_frequency=arch.core_frequency * f_ratio,
        dram_bw=arch.dram_bw * hbm_bw_scale,
        dram_capacity=arch.dram_capacity * hbm_cap_scale)


def power_excess(w, tech: TechConfig, voltage, hbm_bw_scale, hbm_cap_scale):
    """Fraction of the node power budget the knobs overdraw (traceable).

    Core dynamic power scales as V^2 * f(V) (`dynamic_energy_scale` x the
    alpha-power-law rate); HBM power is dominated by bandwidth with a
    static floor per stack.  The knobs may spend the power simplex's
    *unused* mass (1 - sum P_i) for free; anything beyond that is excess,
    which the refinement objective penalizes multiplicatively.
    """
    c = tech.compute
    f_ratio = freq_at_voltage(voltage, c.nominal_voltage, 1.0,
                              c.threshold_voltage)
    core_scale = dynamic_energy_scale(voltage, c.nominal_voltage) * f_ratio
    dram_scale = 0.8 * hbm_bw_scale + 0.2 * hbm_cap_scale
    pf = w[soe._NC:2 * soe._NC]
    headroom = jnp.maximum(1.0 - jnp.sum(pf), 0.0)
    extra = (pf[_PF_CORE] * (core_scale - 1.0)
             + pf[_PF_DRAM] * (dram_scale - 1.0))
    return jnp.maximum(extra - headroom, 0.0)


def feasible_knobs(tech: TechConfig, budgets: Budgets, v_request: float,
                   s_bw: float, s_cap: float,
                   cfg: RefineConfig = RefineConfig()
                   ) -> Tuple[float, float, float]:
    """Clamp requested knobs to what the power budget affords.

    The knobs' only free funding is the power simplex's unused mass
    (1 - sum P_i).  The HBM overdraw (bandwidth-dominated, static floor
    per stack — the same 0.8/0.2 split `power_excess` penalizes) gets
    first claim, with the *bandwidth* scale shrunk until it fits
    (capacity is usually the binding serving constraint, so it is
    sacrificed last); the remaining headroom caps the DVFS voltage via
    `techlib.solve_voltage_for_power`, which inverts the V^2*(V-Vth)
    power curve (anchored so scale(Vnom) = 1) to the highest voltage
    whose relative core power fits.  Undervolting is always allowed;
    overclocking requires the budget vector to have granted real
    headroom — the cross-stack trade the refiner exploits.  Without this
    joint clamp the realized point could spend the same headroom twice
    (once on HBM, once on the core) and exceed the node power budget.
    """
    c = tech.compute
    pf = {k: float(v) for k, v in budgets.power_frac.items()}
    headroom = max(1.0 - sum(pf.values()), 0.0)
    pf_dram = pf.get("dram", 0.0)
    dram_over = pf_dram * (0.8 * s_bw + 0.2 * s_cap - 1.0)
    if dram_over > headroom and pf_dram > 0.0:
        s_bw = max((headroom / pf_dram + 1.0 - 0.2 * s_cap) / 0.8,
                   cfg.scale_lo)
        dram_over = pf_dram * (0.8 * s_bw + 0.2 * s_cap - 1.0)
    remaining = max(headroom - max(dram_over, 0.0), 0.0)
    share = pf.get("core", 0.0)
    if share <= 0.0:
        return c.nominal_voltage, float(s_bw), float(s_cap)
    allowed = (share + remaining) / share       # relative core power cap
    scale_at = lambda v: (dynamic_energy_scale(v, c.nominal_voltage)
                          * freq_at_voltage(v, c.nominal_voltage, 1.0,
                                            c.threshold_voltage))
    v_cap = solve_voltage_for_power(
        allowed, float(scale_at(c.maximum_voltage)), c.maximum_voltage,
        c.threshold_voltage, c.minimum_voltage)
    v = float(min(max(v_request, c.minimum_voltage), v_cap))
    return v, float(s_bw), float(s_cap)


def feasible_voltage(tech: TechConfig, budgets: Budgets,
                     v_request: float) -> float:
    """Voltage-only view of `feasible_knobs` (HBM at nominal scale)."""
    return feasible_knobs(tech, budgets, v_request, 1.0, 1.0)[0]


# ---------------------------------------------------------------------------
# Continuous refinement (budget block: eq. 6; knob block: clipped EMA GD)
# ---------------------------------------------------------------------------


def make_refine_objective(tech: TechConfig, like: Budgets,
                          scn: scenarios.Scenario,
                          dp: scenarios.DesignPoint, ppe: PPEConfig,
                          norms: Sequence[float], cfg: RefineConfig,
                          profile: Optional[Dict] = None):
    """f(theta) -> scalar: the differentiable cross-stack objective.

    Sums this scenario's continuous objectives, each normalized by the
    seed record's value (so multi-objective scenarios trade off at the
    seed's operating point), and multiplies in the power-excess penalty.
    ``profile`` (a calibration-profile dict embedded in the sweep spec)
    anchors every candidate MicroArch to measured efficiencies, so the
    refinement optimizes the calibrated model, not the nominal one.
    """
    eps = scn.eval_points(dp)
    fold = scn.refine_objectives(dp)
    # abs(): canonical objective values are negative for max-direction
    # objectives (goodput) — the norm must stay a positive magnitude
    norms = [max(abs(float(n)), 1e-30) for n in norms]

    def f(theta):
        w = theta[:BUDGET_DIM]
        v, s_bw, s_cap = knobs_from_unit(theta[BUDGET_DIM:], tech, cfg)
        budgets = Budgets.from_vector(w, like)
        arch = age_lib.generate(tech, budgets, discrete=False)
        arch = apply_tech_knobs(arch, tech, v, s_bw, s_cap)
        if profile is not None:
            from repro.calibrate import profiles as profiles_lib
            arch = profiles_lib.apply_profile(arch, profile)
        bds = [simulate.predict(arch, ep.graph, ep.strategy,
                                system=ep.system, cfg=ppe,
                                pod_bw=ep.pod_bw) for ep in eps]
        objs = fold(bds, pathfinder.hw_ctx(arch))
        scalar = sum(o / n for o, n in zip(objs, norms))
        pen = power_excess(w, tech, v, s_bw, s_cap)
        return scalar * (1.0 + cfg.power_penalty * pen)

    return f


def initial_thetas(tech: TechConfig, like: Budgets,
                   cfg: RefineConfig) -> np.ndarray:
    """(S, THETA_DIM) start stack: start 0 is the seed operating point
    (projected template budgets, nominal knobs); the rest pair projected
    Dirichlet budget draws with uniform knob positions."""
    rng = np.random.default_rng(cfg.seed)
    u0 = unit_from_knobs(nominal_knobs(tech), tech, cfg)
    w0 = np.asarray(soe._project_simplexes(like.as_vector(), cfg.min_frac),
                    dtype=np.float32)
    rows = [np.concatenate([w0, u0])]
    nc, nper = soe._NC, soe._NP
    for _ in range(1, max(cfg.starts, 1)):
        draw = np.concatenate(
            [rng.dirichlet(np.ones(nc)), rng.dirichlet(np.ones(nc)),
             rng.dirichlet(np.ones(nper))]).astype(np.float32)
        # blend toward the seed budgets: a raw Dirichlet draw routinely
        # starves some component to ~0 and lands on an inf/NaN objective,
        # wasting the start for the whole descent
        w = np.asarray(soe._project_simplexes(
            jnp.asarray(0.5 * w0 + 0.5 * draw), cfg.min_frac),
            dtype=np.float32)
        u = np.clip(u0 + rng.uniform(-0.25, 0.25, KNOB_DIM), 0.0,
                    1.0).astype(np.float32)
        rows.append(np.concatenate([w, u]))
    return np.stack(rows)


def refine_theta(objective, theta0s: np.ndarray, cfg: RefineConfig
                 ) -> Tuple[np.ndarray, float, int]:
    """Batched multi-start descent on theta; returns (best theta, best
    value, #objective evaluations).

    Every start advances in one jitted step: vmapped value-and-grad, the
    shared eq.-6 update (`soe.eq6_update`) on the budget block, and a
    normalized-gradient EMA step clipped to [0,1] on the knob block.
    Start 0 is evaluated before any update, so the returned best is never
    worse than the seed operating point.
    """
    W = jnp.asarray(theta0s, dtype=jnp.float32)         # (S, THETA_DIM)
    S = W.shape[0]
    vg = jax.vmap(jax.value_and_grad(objective))
    proj_w = jax.vmap(functools.partial(soe._project_simplexes,
                                        min_frac=cfg.min_frac))
    B, lr, beta = BUDGET_DIM, cfg.lr, cfg.beta

    @jax.jit
    def step(W, M, done, last):
        vals, G = vg(W)
        Ww, Mw = soe.eq6_update(W[:, :B], M[:, :B], G[:, :B], lr, beta,
                                proj_w)
        Gu = G[:, B:]
        gn = jnp.linalg.norm(Gu, axis=1, keepdims=True)
        Gu = jnp.where(gn > 0, Gu / (gn + 1e-12), Gu)
        Mu = beta * M[:, B:] + (1.0 - beta) * (W[:, B:] - lr * Gu)
        W_proj = jnp.concatenate([Ww, jnp.clip(Mu, 0.0, 1.0)], axis=1)
        M_new = jnp.concatenate([Mw, Mu], axis=1)
        conv = jnp.abs(last - vals) < 1e-7 * jnp.maximum(vals, 1e-12)
        frozen = done[:, None]
        return (jnp.where(frozen, W, W_proj), jnp.where(frozen, M, M_new),
                done | conv, vals)

    M = W
    done = jnp.zeros(S, dtype=bool)
    last = jnp.full(S, jnp.inf)
    best_theta, best_val = np.asarray(W[0]), float("inf")
    n_evals = 0
    for _ in range(cfg.steps):
        if bool(np.all(np.asarray(done))):
            break
        n_evals += S
        W_before = W
        W, M, done, vals = step(W, M, done, last)
        # nan-safe argmin (a diverged start must not blind best tracking)
        vals_np = np.asarray(vals, dtype=np.float64)
        finite = np.where(np.isfinite(vals_np), vals_np, np.inf)
        i = int(np.argmin(finite))
        if finite[i] < best_val:
            best_val, best_theta = float(finite[i]), np.asarray(W_before[i])
        last = vals
    return best_theta, best_val, n_evals


# ---------------------------------------------------------------------------
# Discrete realization + record schema
# ---------------------------------------------------------------------------


def realize_theta(tech: TechConfig, like: Budgets, theta: np.ndarray,
                  cfg: RefineConfig, profile: Optional[Dict] = None):
    """Re-materialize a refined theta as concrete hardware: discrete AGE
    (floors applied) + the knob transform, with the knobs jointly clamped
    to the power budget via `feasible_knobs`.  Returns (MicroArch,
    Budgets, knob dict).  ``profile`` applies the same calibration the
    continuous objective optimized, so re-scoring stays consistent."""
    w = np.asarray(theta[:BUDGET_DIM], dtype=np.float64)
    budgets = Budgets.from_vector(w, like)
    v_req, s_bw, s_cap = knobs_from_unit(theta[BUDGET_DIM:], tech, cfg)
    v, s_bw, s_cap = feasible_knobs(tech, budgets, float(v_req),
                                    float(s_bw), float(s_cap), cfg)
    arch = age_lib.generate(tech, budgets, discrete=True)
    arch = apply_tech_knobs(arch, tech, v, float(s_bw), float(s_cap))
    if profile is not None:
        from repro.calibrate import profiles as profiles_lib
        arch = profiles_lib.apply_profile(arch, profile)
    knobs = {"voltage": float(v), "hbm_bw_scale": float(s_bw),
             "hbm_cap_scale": float(s_cap)}
    return arch, budgets, knobs


def _budget_fields(budgets: Budgets) -> Dict[str, Dict[str, float]]:
    rnd = lambda d: {k: round(float(v), 5) for k, v in d.items()}
    return {"area_frac": rnd(budgets.area_frac),
            "power_frac": rnd(budgets.power_frac),
            "perim_frac": rnd(budgets.perim_frac)}


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """a Pareto-dominates b: <= on every objective, < on at least one
    (ties on all objectives dominate neither way)."""
    return all(x <= y for x, y in zip(a, b)) \
        and any(x < y for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# The sweep -> refine pipeline
# ---------------------------------------------------------------------------


def _candidate_rank(scn: scenarios.Scenario, seed_vals):
    """Sort key: objectives normalized by the seed's values, summed.

    Values are canonical (max-direction objectives already negated), so
    smaller is uniformly better; the seed norm is an absolute magnitude.
    """
    def key(rec):
        vs = scn.objective_values(rec)
        return sum(v / max(abs(s), 1e-30) for v, s in zip(vs, seed_vals))
    return key


def refine_sweep(src: Union[str, Tuple[SweepSpec, List[Dict]]],
                 cfg: RefineConfig = RefineConfig(),
                 out_path: Optional[str] = None,
                 verbose: bool = False) -> RefineStats:
    """Refine the Pareto frontier of a (checkpointed) sweep.

    ``src`` is either a sweep out-dir (spec + finished-chunk records are
    loaded via `sweeprunner.load_sweep`; refined records stream to
    ``DIR/refined.jsonl`` unless ``out_path`` overrides) or an in-memory
    ``(spec, records)`` pair.  Already-scored sweep points are never
    re-evaluated: frontier seeds and their discrete (mesh, strategy)
    candidates are selected and ranked purely from the loaded records, the
    continuous search only evaluates novel theta points, and a candidate
    whose descent never left the seed operating point is reported as
    unimproved instead of being re-scored.
    """
    t0 = time.perf_counter()
    if isinstance(src, str):
        spec, records = sweeprunner.load_sweep(src)
        if out_path is None:
            out_path = os.path.join(src, "refined.jsonl")
    else:
        spec, records = src
    # objectives/SLO walls are variant-independent, so any variant of the
    # spec's ScenarioSpec works for frontier filtering; per-candidate
    # scoring below re-resolves the exact variant from each record's cell
    scn = spec.scenario_spec.variants()[0].resolve()
    frontier = sweeprunner.pareto_records(records, scn.objectives)
    seeds = sorted(frontier, key=lambda r: scn.objective_values(r))
    seeds = seeds[:max(cfg.top_k, 0)]
    ppe = sweeprunner.spec_ppe(spec)
    seed_vals = [scn.objective_values(r) for r in frontier]

    out_fh = None
    if out_path is not None:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        out_fh = open(out_path, "w")

    refined: List[Dict] = []
    n_candidates = n_unimproved = n_dominating = n_evals = 0
    tried: set = set()
    try:
        for seed in seeds:
            sv = scn.objective_values(seed)
            peers = [r for r in records
                     if all(r.get(f) == seed.get(f) for f in
                            ("arch", "cell", "logic", "hbm", "net", "scale"))
                     and scn.objective_values(r) is not None]
            peers.sort(key=_candidate_rank(scn, sv))
            for ci, cand in enumerate(peers[:max(cfg.candidates_per_seed,
                                                 1)]):
                if cand["key"] in tried:
                    continue
                tried.add(cand["key"])
                n_candidates += 1
                lb = sweeprunner.label_from_record(cand)
                scn_pt = sweeprunner.scenario_for(spec, lb.cell)
                dp = sweeprunner.resolve_label(spec, lb)
                tech = techlib.make_tech_config(lb.logic, lb.hbm, lb.net)
                like = spec.budgets(lb.scale)
                norms = [float(cand[f])
                         for f in scn_pt.refine_objective_fields]
                f = make_refine_objective(tech, like, scn_pt, dp, ppe,
                                          norms, cfg,
                                          profile=spec.profile)
                theta0s = initial_thetas(tech, like, cfg)
                theta, val, evals = refine_theta(f, theta0s, cfg)
                n_evals += evals
                if np.array_equal(theta, theta0s[0]):
                    # descent never beat the seed operating point: the
                    # seed record already covers it — re-scoring would
                    # re-evaluate an already-scored sweep point
                    n_unimproved += 1
                    continue
                arch, budgets, knobs = realize_theta(tech, like, theta, cfg,
                                                     profile=spec.profile)
                dp_r = dataclasses.replace(dp, hw=arch)
                rows = pathfinder.evaluate(
                    points=scn_pt.eval_points(dp_r), ppe=ppe)
                rec = scn_pt.record(dp_r, rows)
                rec["key"] = dp_r.key() + f"#refined{len(refined)}"
                rec["seed_key"] = seed["key"]
                rec["candidate_key"] = cand["key"]
                rec["refined"] = True
                rec["knobs"] = knobs
                rec["budgets"] = _budget_fields(budgets)
                rec["refine_objective"] = float(val)
                rv = scn.objective_values(rec)
                rec["dominates_seed"] = bool(
                    rv is not None
                    and any(dominates(rv, s) for s in seed_vals if s))
                if rec["dominates_seed"]:
                    n_dominating += 1
                refined.append(rec)
                if out_fh is not None:
                    out_fh.write(json.dumps(sweeprunner.json_safe(rec))
                                 + "\n")
                    out_fh.flush()
                if verbose:
                    print(f"# refined {cand['key']} -> "
                          f"{rec['key']}: objective {val:.4g} "
                          f"(dominates_seed={rec['dominates_seed']})",
                          flush=True)
    finally:
        if out_fh is not None:
            out_fh.close()

    return RefineStats(
        scenario=scn.name, n_records=len(records),
        n_frontier=len(frontier), n_seeds=len(seeds),
        n_candidates=n_candidates, n_refined=len(refined),
        n_unimproved=n_unimproved, n_dominating=n_dominating,
        n_objective_evals=n_evals, elapsed_s=time.perf_counter() - t0,
        out_path=out_path, records=refined, frontier=frontier)
