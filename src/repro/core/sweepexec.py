"""Executor-service core shared by the local and distributed sweep
frontends.

`SweepRunner` (single host) and `repro.core.sweepfabric` (lease-based
coordinator/worker fleet) execute the same chunk protocol: deterministic
enumeration keyed by the spec fingerprint, per-chunk JSONL commits whose
done-line is the single source of truth, crash-torn-tail tolerance, and an
atomically-checkpointed carried frontier state for ``--frontier-only``
sweeps.  This module is that protocol, factored out of the two frontends
so their durability semantics cannot diverge:

  * `iter_jsonl` / `json_safe` / `dump_line` — THE JSONL reader/writer
    pair (blank/torn lines skipped on read, RFC-8259-strict on write);
  * `ChunkJournal` — append-only results+checkpoint stream for one
    writer: rows first, then the hash-keyed done-line, so a crash can
    only ever leave rows of an *unfinished* chunk behind (`load_done`
    verifies hashes against the current enumeration, `compact` drops
    orphaned rows, `read_records` returns the committed view);
  * spec heads (`write_spec_head` / `load_spec_head` /
    `check_fingerprint`) — the resume identity of a sweep directory;
  * frontier-state checkpoints (`save_frontier_state` /
    `load_frontier_state`) — the carried device-resident Pareto state
    plus the set of chunks already merged into it (merged points cannot
    be un-merged, so a mismatch is fatal rather than re-evaluated).

Nothing here imports JAX or resolves design points: this layer owns
*durability*, the executors own *evaluation*.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np


def iter_jsonl(path: str):
    """Parsed records of a JSONL file, skipping blank lines and the
    crash-torn tail line an interrupted writer can leave behind.  THE one
    reader shared by committed-view reads, resume compaction, and
    `load_sweep` — torn-line semantics must not diverge between them."""
    if not os.path.exists(path):
        return
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except json.JSONDecodeError:
                continue


def json_safe(obj):
    """Replace non-finite floats with None so the streamed JSONL stays
    RFC-8259 valid (json.dumps would otherwise emit the non-standard
    ``Infinity`` token for infeasible serving points, which jq /
    JSON.parse / strict parsers reject).  In-memory records keep their
    real inf values; only the serialized form is sanitized."""
    if isinstance(obj, dict):
        return {k: json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_safe(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def dump_line(row: Dict) -> str:
    """One JSONL line for a result row: strict dump first (one C-speed
    pass for the overwhelmingly common all-finite record), sanitizing
    fallback for rows carrying inf/nan metrics."""
    try:
        return json.dumps(row, allow_nan=False)
    except ValueError:
        return json.dumps(json_safe(row))


# ---------------------------------------------------------------------------
# Spec heads (the resume identity of a sweep directory)
# ---------------------------------------------------------------------------

def write_spec_head(path: str, version: int, fingerprint: str,
                    spec_dict: Dict) -> None:
    """Atomically (re)write a sweep directory's spec.json head."""
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump({"version": version, "fingerprint": fingerprint,
                   "spec": spec_dict}, fh, indent=2)
    os.replace(tmp, path)


def load_spec_head(path: str) -> Dict:
    if not os.path.exists(path):
        raise FileNotFoundError(f"cannot resume: {path} does not exist")
    with open(path) as fh:
        return json.load(fh)


def check_fingerprint(path: str, fingerprint: str) -> Dict:
    """Load a spec head and require its fingerprint to match — a resumed
    or joined execution must present the identical spec."""
    head = load_spec_head(path)
    if head.get("fingerprint") != fingerprint:
        raise ValueError(
            f"cannot resume: sweep spec changed "
            f"(checkpoint {head.get('fingerprint')}, now {fingerprint})")
    return head


# ---------------------------------------------------------------------------
# Chunk journal (results.jsonl + checkpoint.jsonl of ONE writer)
# ---------------------------------------------------------------------------


class ChunkJournal:
    """Append-only results + checkpoint stream for one writer.

    The commit protocol every frontend shares: `append_rows` streams a
    chunk's records (tagged with the chunk index), then `append_done`
    writes the hash-keyed done-line.  Only chunks whose done-line is
    present count as committed — `load_done` hash-verifies them against
    the current enumeration, and `compact` rewrites the results stream
    keeping committed rows only (what resume does with the partial rows a
    crash leaves behind).  Fabric workers keep one journal per worker
    *shard*; the merged view unions the shards' done sets.
    """

    def __init__(self, results_path: str, checkpoint_path: str):
        self.results_path = results_path
        self.checkpoint_path = checkpoint_path
        self._res_fh = None
        self._ckpt_fh = None

    # -- writing ----------------------------------------------------------
    def open(self) -> "ChunkJournal":
        if self._res_fh is None:
            self._res_fh = open(self.results_path, "a")
            self._ckpt_fh = open(self.checkpoint_path, "a")
        return self

    def close(self) -> None:
        if self._res_fh is not None:
            self._res_fh.close()
            self._ckpt_fh.close()
            self._res_fh = self._ckpt_fh = None

    def append_rows(self, chunk_index: int, records: Sequence[Dict]) -> None:
        self.open()
        for rec in records:
            self._res_fh.write(dump_line({"chunk": chunk_index, **rec})
                               + "\n")
        self._res_fh.flush()

    def append_done(self, chunk_index: int, chunk_hash: str,
                    n: int) -> None:
        """The commit point: after this line is durable the chunk is
        finished forever (resume will never re-evaluate it)."""
        self.open()
        self._ckpt_fh.write(json.dumps(
            {"chunk": chunk_index, "hash": chunk_hash, "n": n}) + "\n")
        self._ckpt_fh.flush()

    def commit(self, chunk_index: int, chunk_hash: str,
               records: Sequence[Dict]) -> None:
        self.append_rows(chunk_index, records)
        self.append_done(chunk_index, chunk_hash, len(records))

    # -- reading ----------------------------------------------------------
    def load_done(self, chunks: Sequence, fingerprint: str) -> Dict[int, str]:
        """Finished chunks recorded in this journal, hash-verified against
        the current enumeration (a stale/corrupt line is just treated as
        not-done and re-evaluated)."""
        done: Dict[int, str] = {}
        by_index = {c.index: c for c in chunks}
        for rec in iter_jsonl(self.checkpoint_path):
            c = by_index.get(rec.get("chunk"))
            if c is not None and rec.get("hash") == c.hash(fingerprint):
                done[c.index] = rec["hash"]
        return done

    def compact(self, done: Dict[int, str]) -> None:
        """Drop rows from unfinished chunks (crash between row append and
        done-line append) so resumed output has no duplicates."""
        if not os.path.exists(self.results_path):
            return
        tmp = self.results_path + ".tmp"
        with open(tmp, "w") as dst:
            for rec in iter_jsonl(self.results_path):
                if rec.get("chunk") in done:
                    dst.write(json.dumps(rec) + "\n")
        os.replace(tmp, self.results_path)

    def read_records(self,
                     done: Optional[Dict[int, str]] = None) -> List[Dict]:
        """All streamed records; with ``done`` given, only rows of
        committed chunks (the merged-read equivalent of `compact`)."""
        out = []
        for rec in iter_jsonl(self.results_path):
            if done is None or rec.get("chunk") in done:
                out.append(rec)
        return out


# ---------------------------------------------------------------------------
# Frontier-state checkpoints (carried device-resident Pareto state)
# ---------------------------------------------------------------------------


def save_frontier_state(path: str, state, done: Dict[int, str],
                        capacity: int, fingerprint: str) -> None:
    """Atomically persist a carried frontier state plus the set of merged
    (committed) chunks — THE frontier-mode checkpoint.  Written after
    every committed superbatch, so a SIGKILL loses at most the in-flight
    packs and a resume continues from the merged state with zero
    re-evaluation (the chunked-sweep semantics)."""
    vals, payload, idx, overflow = state
    order = sorted(done)
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        np.savez(fh, vals=vals, payload=payload, idx=idx,
                 overflow=overflow,
                 done_idx=np.asarray(order, dtype=np.int64),
                 done_hash=np.asarray([done[i] for i in order]),
                 fingerprint=np.asarray(fingerprint),
                 capacity=np.asarray(int(capacity)))
    os.replace(tmp, path)


def load_frontier_state(path: str, fingerprint: str, capacity: int,
                        chunks: Sequence):
    """(carried state, done chunks) of a frontier-state checkpoint.

    Unlike `ChunkJournal.load_done`, a mismatched chunk is fatal rather
    than re-evaluated: its points are already folded into the carried
    state and cannot be dropped again."""
    z = np.load(path)
    if z["fingerprint"].item() != fingerprint:
        raise ValueError("cannot resume: frontier state belongs to a "
                         "different spec fingerprint")
    if int(z["capacity"]) != int(capacity):
        raise ValueError(
            f"cannot resume: frontier capacity changed (checkpoint "
            f"{int(z['capacity'])}, now {capacity}); rerun with the "
            f"original --frontier-capacity")
    by_index = {c.index: c for c in chunks}
    done: Dict[int, str] = {}
    for i, h in zip(z["done_idx"].tolist(), z["done_hash"].tolist()):
        c = by_index.get(int(i))
        if c is None or c.hash(fingerprint) != str(h):
            raise ValueError(
                f"cannot resume: frontier state does not match the "
                f"current enumeration (chunk {i}); merged points "
                f"cannot be un-merged — rerun in a fresh directory")
        done[int(i)] = str(h)
    state = (z["vals"], z["payload"], z["idx"], z["overflow"])
    return state, done
