"""repro.core — CrossFlow + DeepFlow (the paper's contribution).

CrossFlow (standalone performance model):
    techlib     technology components library          (paper §4.1)
    age         micro-architecture generator engine    (paper §4.2-4.4)
    graph       compute-graph IR                       (paper §3, §5)
    transform   super-graph transformation             (paper §5.1)
    placement   device mapping + routing               (paper §5.2)
    roofline    hierarchical roofline PPE              (paper §6.1-6.4)
    simulate    event-driven end-to-end estimation     (paper §6.5) + predict()

DeepFlow (search on top of CrossFlow):
    soe         projected-GD budget search             (paper §7)
    pathfinder  batched/vmapped design-space sweeps + LRU prediction cache
    scenarios   workload-scenario registry (train / prefill+decode serving)
    sweepexec   executor-service core shared by the sweep frontends: chunk
                journal (JSONL commit protocol), spec heads, frontier-state
                checkpoints — durability, not evaluation
    sweeprunner sharded, chunked, resumable sweep engine (JSONL streaming,
                checkpoint/resume, thread/process/pmap-device fan-out)
    sweepfabric distributed sweep fabric: lease-based coordinator/worker
                execution of the chunk protocol over a shared sweep dir
                (TTL + heartbeat leases, per-worker shards merged on read,
                order-independent cross-worker frontier merge)
    cooptimize  cross-stack sweep -> refine engine: batched GD over hardware
                budgets (eq. 6) + continuous technology knobs (DVFS voltage,
                HBM bw/capacity) with a discrete strategy/mesh outer loop
    planner     CrossFlow -> runtime ShardingPlan bridge (this repo's closing
                of the loop: pathfinding drives the real pjit configuration)
"""

from repro.core import age, cooptimize, graph, lmgraph, parallelism, \
    pathfinder, placement, roofline, scenarios, simulate, soe, sweepexec, \
    sweepfabric, sweeprunner, techlib, transform
from repro.core.age import Budgets, MicroArch
from repro.core.graph import ComputeGraph
from repro.core.parallelism import Strategy
from repro.core.simulate import predict
