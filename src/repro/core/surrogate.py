"""Learned surrogate + acquisition-driven exploration — the "DeepFlow"
loop over the CrossFlow analytical core.

The paper's headline contribution is ML-automated design-space
exploration: instead of exhaustively enumerating every grid point, a
cheap learned model predicts the objective vector of unevaluated points
and real (pipeline) evaluations are spent only where the model says a
point is promising or uncertain.  This module is that loop:

  * `Featurizer` — deterministic featurization of enumerated
    `PointLabel`s (arch/cell/strategy one-hots, mesh + parallelism
    numerics, budget scale, scenario-variant overrides, and the AGE'd
    hardware's `pathfinder.pack_hw` leaf vector in log space),
    standardized over the spec's full enumeration so evaluated and
    unevaluated labels featurize identically;
  * `build_dataset` / `load_training_records` — sweep JSONL rows into
    (X, Y, feasible) training sets.  Rows are read through
    `sweepexec.iter_jsonl` (blank/torn lines skipped) filtered to
    hash-verified committed chunks — the durability reader, never an
    ad-hoc file parse.  Objective targets are `canonical_signs`-signed
    (all-minimizing) via the scenario's own `objective_values`, so
    infeasible/SLO-violating/non-finite rows become classifier-only
    examples exactly where frontiers would drop them;
  * `fit_surrogate` / `predict` — an ensemble of small MLPs trained as
    one jit(vmap) batch in the `soe._optimize_batched` idiom (vmapped
    ``value_and_grad`` + a single jitted update advancing every member,
    convergence-frozen by mask, nan-safe best tracking) with
    bootstrap-resampled rows per member.  Ensemble spread is the
    epistemic uncertainty; a shared feasibility logit is the classifier
    target.  No dependencies beyond numpy + jax;
  * `ucb_acquisition` / `epi_acquisition` — multi-objective acquisition
    over the signed axes: scores are dominance *margins* against the
    current Pareto frontier (min over frontier of the max per-axis
    excess), so they are invariant under `canonical_signs` flips and
    under frontier permutation, and exact ties score exactly equal;
  * `explore` — the search loop: seed chunks, fit, rank every pending
    chunk by its best label's acquisition, spend real
    `pathfinder.evaluate` label-mode calls on the top chunks, repeat
    until the eval budget or frontier stagnation fires.  Output uses
    the standard sweep-dir layout (spec head + `ChunkJournal` commits
    with unchanged chunk hashes), so an explored directory is just a
    partial sweep: `--resume`, `load_sweep`, `cooptimize --from` and
    fleet sizing all work on it, and real evaluations route through the
    live prediction cache (`pathfinder.DEFAULT_CACHE`), so any point
    already scored this process joins the training set at zero device
    cost;
  * `rank_chunks` / `order_fabric_dir` — the fabric work order: rank a
    directory's chunks from already-scored records and write
    ``order.json`` (`sweepfabric.write_chunk_order`), so lease-claiming
    workers serve frontier-adjacent chunks first.  The order is
    advisory and schedule-only — fingerprints, chunk hashes, the lease
    protocol and the deterministic shard merge are untouched, so an
    ordered fleet produces records identical to an unordered one.
"""

from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import objectives as objectives_lib
from repro.core import pathfinder, sweepexec, sweeprunner
from repro.core.parallelism import Strategy
from repro.core.traffic import decode_variant

# ---------------------------------------------------------------------------
# Featurization
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Featurizer:
    """Deterministic label -> feature-vector map for one sweep spec.

    Vocabularies and standardization moments come from the spec's FULL
    enumeration (`from_spec`), not from whichever subset happens to be
    evaluated — an unevaluated label must featurize identically before
    and after it is scored, or acquisition ranking would drift between
    rounds.  Labels from *other* specs (seed training rows) still
    transform: unknown vocabulary values one-hot to all-zeros.
    """

    arch_vocab: Tuple[str, ...]
    cell_vocab: Tuple[str, ...]
    strategy_vocab: Tuple[str, ...]
    variant_keys: Tuple[str, ...]
    mesh_rank: int
    mean: np.ndarray
    std: np.ndarray

    @staticmethod
    def from_spec(spec, labels: Optional[Sequence] = None) -> "Featurizer":
        labels = list(labels) if labels is not None \
            else sweeprunner.enumerate_labels(spec)
        if not labels:
            raise ValueError("spec enumerates no labels to featurize")
        cells, keys = set(), set()
        for lb in labels:
            base, over = decode_variant(lb.cell)
            cells.add(base)
            keys.update(over)
        fz = Featurizer(
            arch_vocab=tuple(sorted({lb.arch for lb in labels})),
            cell_vocab=tuple(sorted(cells)),
            strategy_vocab=tuple(sorted({lb.strategy for lb in labels})),
            variant_keys=tuple(sorted(keys)),
            mesh_rank=max(len(lb.mesh) for lb in labels),
            mean=np.zeros(0), std=np.ones(0))
        raw = fz._raw(spec, labels)
        std = raw.std(axis=0)
        return dataclasses.replace(fz, mean=raw.mean(axis=0),
                                   std=np.maximum(std, 1e-9))

    @property
    def dim(self) -> int:
        return (len(self.arch_vocab) + len(self.cell_vocab)
                + len(self.strategy_vocab) + len(self.variant_keys)
                + self.mesh_rank + 1        # mesh dims + product
                + 8                         # strategy numerics
                + 1                         # budget scale
                + pathfinder.HW_DIM)

    def _raw(self, spec, labels: Sequence) -> np.ndarray:
        a_ix = {a: i for i, a in enumerate(self.arch_vocab)}
        c_ix = {c: i for i, c in enumerate(self.cell_vocab)}
        s_ix = {s: i for i, s in enumerate(self.strategy_vocab)}
        v_ix = {k: i for i, k in enumerate(self.variant_keys)}
        na, nc, ns, nv = (len(a_ix), len(c_ix), len(s_ix), len(v_ix))
        mesh0 = na + nc + ns + nv
        strat0 = mesh0 + self.mesh_rank + 1
        scale0 = strat0 + 8
        hw0 = scale0 + 1
        out = np.zeros((len(labels), self.dim), dtype=np.float64)
        strategies: Dict[str, Strategy] = {}
        # AGE'd hardware is memoized per process (`sweeprunner._hardware`)
        # but pack it once per distinct tech point here anyway
        hw_vecs: Dict[tuple, np.ndarray] = {}
        for i, lb in enumerate(labels):
            row = out[i]
            base, over = decode_variant(lb.cell)
            if lb.arch in a_ix:
                row[a_ix[lb.arch]] = 1.0
            if base in c_ix:
                row[na + c_ix[base]] = 1.0
            if lb.strategy in s_ix:
                row[na + nc + s_ix[lb.strategy]] = 1.0
            for k, v in over.items():
                if k in v_ix:
                    row[na + nc + ns + v_ix[k]] = float(v)
            mesh = tuple(lb.mesh)[:self.mesh_rank]
            for j, d in enumerate(mesh):
                row[mesh0 + j] = math.log2(max(int(d), 1))
            row[mesh0 + self.mesh_rank] = math.log2(
                max(int(np.prod(mesh)) if mesh else 1, 1))
            st = strategies.get(lb.strategy)
            if st is None:
                st = strategies.setdefault(lb.strategy,
                                           Strategy.parse(lb.strategy))
            row[strat0:strat0 + 8] = (
                math.log2(st.kp1), math.log2(st.kp2), math.log2(st.dp),
                math.log2(st.lp), float(st.ep), float(st.sp),
                math.log2(st.devices), 1.0 if st.kind == "CR" else 0.0)
            row[scale0] = float(lb.scale)
            hk = (lb.logic, lb.hbm, lb.net, lb.scale)
            hv = hw_vecs.get(hk)
            if hv is None:
                hw = sweeprunner._hardware(spec, lb.logic, lb.hbm, lb.net,
                                           lb.scale)
                # leaves span ~17 decades (bytes vs seconds): log10
                hv = hw_vecs.setdefault(
                    hk, np.log10(np.abs(np.asarray(
                        pathfinder.pack_hw(hw), dtype=np.float64)) + 1e-30))
            row[hw0:hw0 + pathfinder.HW_DIM] = hv
        return out

    def transform(self, spec, labels: Sequence) -> np.ndarray:
        """Standardized (N, dim) feature matrix for labels."""
        return (self._raw(spec, labels) - self.mean) / self.std

    def transform_records(self, spec, records: Sequence[Mapping]
                          ) -> np.ndarray:
        return self.transform(
            spec, [sweeprunner.label_from_record(r) for r in records])


# ---------------------------------------------------------------------------
# Training-set ingestion (sweep JSONL rows through the durability reader)
# ---------------------------------------------------------------------------


def load_training_records(out_dir: str) -> Tuple[object, List[Dict]]:
    """(spec, committed records) of a sweep directory, for training.

    Rows stream through `sweepexec.iter_jsonl` — the torn-line-tolerant
    reader every durability consumer shares — filtered to hash-verified
    committed chunks, exactly as `sweeprunner.load_sweep` / resume do
    (an interrupted writer's torn tail line or partial chunk never
    reaches the training set).  A frontier-only directory falls back to
    its materialized ``frontier.jsonl``.  Fabric directories should be
    merged first (the coordinator does this on completion).
    """
    head = sweepexec.load_spec_head(os.path.join(out_dir, "spec.json"))
    spec = sweeprunner.SweepSpec.from_dict(head["spec"])
    fp = spec.fingerprint()
    res = os.path.join(out_dir, "results.jsonl")
    ckpt = os.path.join(out_dir, "checkpoint.jsonl")
    records: List[Dict] = []
    if os.path.exists(ckpt):
        chunks = sweeprunner.make_chunks(
            sweeprunner.enumerate_labels(spec), spec.chunk_size)
        done = sweepexec.ChunkJournal("", ckpt).load_done(chunks, fp)
        records = [{k: v for k, v in rec.items() if k != "chunk"}
                   for rec in sweepexec.iter_jsonl(res)
                   if rec.get("chunk") in done]
    if not records:
        records = list(sweepexec.iter_jsonl(
            os.path.join(out_dir, "frontier.jsonl")))
    return spec, records


def dedupe_records(records: Sequence[Mapping]) -> List[Dict]:
    """First-wins dedupe by record key (seed rows + freshly committed
    rows can overlap when exploring a previously-swept spec)."""
    seen, out = set(), []
    for r in records:
        k = r.get("key")
        if k is None or k not in seen:
            seen.add(k)
            out.append(dict(r))
    return out


@dataclasses.dataclass
class Dataset:
    """Featurized training set: regression targets in canonical-signed
    *standardized* space (NaN where the row is classifier-only), plus the
    feasibility labels."""

    X: np.ndarray                   # (N, D) standardized features
    Y: np.ndarray                   # (N, K) standardized canonical targets
    feasible: np.ndarray            # (N,) bool
    objectives: Tuple[str, ...]
    signs: Tuple[float, ...]
    y_mean: np.ndarray              # (K,) canonical-space moments
    y_std: np.ndarray


def build_dataset(spec, records: Sequence[Mapping],
                  featurizer: Optional[Featurizer] = None
                  ) -> Tuple[Featurizer, Dataset]:
    """Featurize scored records into a `Dataset` under ``spec``'s axes.

    Objective targets go through the scenario's own `objective_values`
    (canonical `canonical_signs`-signed, None for infeasible / SLO-wall /
    missing / non-finite rows — the same filter every frontier applies),
    so the regression head never trains on values a frontier would drop;
    those rows keep their features as feasibility-classifier negatives.
    """
    fz = featurizer or Featurizer.from_spec(spec)
    scn = spec.scenario_spec.variants()[0].resolve()
    objectives = tuple(scn.objectives)
    signs = objectives_lib.canonical_signs(objectives)
    n, k = len(records), len(objectives)
    Y = np.full((n, k), np.nan, dtype=np.float64)
    feas = np.zeros(n, dtype=bool)
    scns: Dict[str, object] = {}
    for i, rec in enumerate(records):
        cell = str(rec.get("cell", ""))
        s = scns.get(cell)
        if s is None:
            try:
                s = scns.setdefault(cell,
                                    sweeprunner.scenario_for(spec, cell))
            except Exception:
                s = scns.setdefault(cell, scn)
        vs = s.objective_values(rec)
        if vs is not None:
            Y[i] = vs
            feas[i] = True
    if feas.any():
        y_mean = np.nanmean(Y[feas], axis=0)
        y_std = np.maximum(np.nanstd(Y[feas], axis=0), 1e-9)
    else:
        y_mean, y_std = np.zeros(k), np.ones(k)
    X = fz.transform_records(spec, records)
    return fz, Dataset(X=X, Y=(Y - y_mean) / y_std, feasible=feas,
                       objectives=objectives, signs=tuple(signs),
                       y_mean=y_mean, y_std=y_std)


# ---------------------------------------------------------------------------
# Ensemble surrogate (jit(vmap) MLPs in the soe batched-GD idiom)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SurrogateConfig:
    ensemble: int = 4               # bootstrap members (epistemic spread)
    hidden: int = 32
    steps: int = 300
    lr: float = 0.01
    l2: float = 1e-4
    seed: int = 0


@dataclasses.dataclass
class SurrogateModel:
    """Fitted ensemble: flattened member params + everything needed to
    map predictions back to raw objective units."""

    params: np.ndarray              # (M, P) flattened member params
    featurizer: Featurizer
    objectives: Tuple[str, ...]
    signs: Tuple[float, ...]
    y_mean: np.ndarray
    y_std: np.ndarray
    hidden: int
    loss: float                     # final mean training loss

    @property
    def n_objectives(self) -> int:
        return len(self.objectives)


def _param_count(d: int, h: int, k: int) -> int:
    return d * h + h + h * (k + 1) + (k + 1)


def _forward_np(theta: np.ndarray, X: np.ndarray, d: int, h: int,
                k: int) -> Tuple[np.ndarray, np.ndarray]:
    o = 0
    W1 = theta[o:o + d * h].reshape(d, h); o += d * h
    b1 = theta[o:o + h]; o += h
    W2 = theta[o:o + h * (k + 1)].reshape(h, k + 1); o += h * (k + 1)
    b2 = theta[o:o + k + 1]
    out = np.tanh(X @ W1 + b1) @ W2 + b2
    return out[:, :k], out[:, k]


def fit_surrogate(spec, records: Sequence[Mapping],
                  cfg: SurrogateConfig = SurrogateConfig(),
                  featurizer: Optional[Featurizer] = None
                  ) -> SurrogateModel:
    """Fit the bootstrap MLP ensemble on scored records.

    All M members train as ONE batch — ``jax.vmap(jax.value_and_grad)``
    over the stacked flattened params plus a single jitted update, with
    per-member convergence freezing and nan-safe best tracking, the
    `soe._optimize_batched` machinery (the eq.-6 unit-norm/simplex
    projection is budget-space-specific, so the update here is Adam on
    unconstrained weights).  Each member sees its own with-replacement
    bootstrap resample; the spread of member predictions is the
    epistemic uncertainty `predict` reports.  Loss = masked MSE on the
    standardized canonical objectives (feasible rows only) + BCE on the
    feasibility logit (all rows) + L2.
    """
    fz, ds = build_dataset(spec, records, featurizer=featurizer)
    n, d = ds.X.shape
    if n == 0:
        raise ValueError("no records to fit a surrogate on")
    k, h, m = len(ds.objectives), cfg.hidden, max(cfg.ensemble, 1)
    p = _param_count(d, h, k)
    rng = np.random.default_rng(cfg.seed)
    W0 = rng.normal(0.0, 1.0 / math.sqrt(d + 1), size=(m, p))
    IDX = rng.integers(0, n, size=(m, n))       # bootstrap resamples
    X = jnp.asarray(ds.X, dtype=jnp.float32)
    Yt = jnp.asarray(np.nan_to_num(ds.Y, nan=0.0), dtype=jnp.float32)
    Msk = jnp.asarray(np.isfinite(ds.Y), dtype=jnp.float32)
    F = jnp.asarray(ds.feasible, dtype=jnp.float32)
    idx = jnp.asarray(IDX)
    lr, l2 = cfg.lr, cfg.l2

    def loss(theta, rows):
        o = 0
        W1 = theta[o:o + d * h].reshape(d, h); o += d * h
        b1 = theta[o:o + h]; o += h
        W2 = theta[o:o + h * (k + 1)].reshape(h, k + 1); o += h * (k + 1)
        b2 = theta[o:o + k + 1]
        out = jnp.tanh(X[rows] @ W1 + b1) @ W2 + b2
        pred, logit = out[:, :k], out[:, k]
        msk = Msk[rows]
        mse = jnp.sum(msk * (pred - Yt[rows]) ** 2) \
            / jnp.maximum(jnp.sum(msk), 1.0)
        f = F[rows]
        bce = jnp.mean(jnp.maximum(logit, 0.0) - logit * f
                       + jnp.log1p(jnp.exp(-jnp.abs(logit))))
        return mse + bce + l2 * jnp.mean(theta ** 2)

    vg = jax.vmap(jax.value_and_grad(loss))

    @jax.jit
    def step(W, Ma, Va, t, done, last):
        vals, G = vg(W, idx)
        Ma2 = 0.9 * Ma + 0.1 * G
        Va2 = 0.999 * Va + 0.001 * G * G
        mh = Ma2 / (1.0 - 0.9 ** t)
        vh = Va2 / (1.0 - 0.999 ** t)
        W2 = W - lr * mh / (jnp.sqrt(vh) + 1e-8)
        conv = jnp.abs(last - vals) < 1e-7 * jnp.maximum(vals, 1e-9)
        frozen = done[:, None]
        return (jnp.where(frozen, W, W2), jnp.where(frozen, Ma, Ma2),
                jnp.where(frozen, Va, Va2), done | conv, vals)

    W = jnp.asarray(W0, dtype=jnp.float32)
    Ma = jnp.zeros_like(W)
    Va = jnp.zeros_like(W)
    done = jnp.zeros(m, dtype=bool)
    last = jnp.full(m, jnp.inf)
    best = np.asarray(W, dtype=np.float64)
    best_vals = np.full(m, np.inf)
    for t in range(1, cfg.steps + 1):
        if bool(np.all(np.asarray(done))):
            break
        W_before = W
        W, Ma, Va, done, vals = step(W, Ma, Va, jnp.float32(t), done, last)
        # nan-safe per-member best: one diverged member must not blind
        # the healthy ones (same contract as soe._optimize_batched)
        v = np.asarray(vals, dtype=np.float64)
        v = np.where(np.isfinite(v), v, np.inf)
        better = v < best_vals
        if better.any():
            best_vals[better] = v[better]
            best[better] = np.asarray(W_before, dtype=np.float64)[better]
        last = vals
    fin = best_vals[np.isfinite(best_vals)]
    return SurrogateModel(
        params=best, featurizer=fz, objectives=ds.objectives,
        signs=ds.signs, y_mean=ds.y_mean, y_std=ds.y_std, hidden=h,
        loss=float(fin.mean()) if fin.size else float("inf"))


def predict(model: SurrogateModel, X: np.ndarray
            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(mu, sigma, p_feasible) over standardized feature rows.

    ``mu`` is in RAW objective units (signed back out of canonical
    space), ``sigma`` the ensemble's epistemic spread (objective units,
    sign-free), ``p_feasible`` the mean classifier probability.
    Inference is plain NumPy on purpose: row counts change every
    exploration round, and recompiling a jitted forward per shape would
    cost more than the matmuls it saves.
    """
    d = len(model.featurizer.mean)
    k, h = model.n_objectives, model.hidden
    mus, logits = [], []
    for theta in model.params:
        mu, logit = _forward_np(theta, X, d, h, k)
        mus.append(mu)
        logits.append(logit)
    mu_std = np.mean(mus, axis=0)
    sig_std = np.std(mus, axis=0)
    mu_can = mu_std * model.y_std + model.y_mean
    sigma = (sig_std + 1e-9) * model.y_std
    signs = np.asarray(model.signs)
    p = 1.0 / (1.0 + np.exp(-np.mean(logits, axis=0)))
    return mu_can * signs, sigma, p


# ---------------------------------------------------------------------------
# Multi-objective acquisition over canonical-signed axes
# ---------------------------------------------------------------------------


_erf = np.vectorize(math.erf)


def _canonical(vals, signs) -> np.ndarray:
    v = np.asarray(vals, dtype=np.float64)
    if v.ndim == 1:
        v = v.reshape(1, -1) if v.size else v.reshape(0, 0)
    if signs is None:
        return v
    return v * np.asarray(signs, dtype=np.float64)


def dominance_margin(z: np.ndarray, front: np.ndarray) -> np.ndarray:
    """Per-candidate dominance margin against a canonical frontier.

    ``margin_i = min over frontier rows f of (max over axes j of
    z_ij - f_j)`` — negative iff the candidate would enter the frontier
    (it beats some frontier point on its worst axis), with magnitude the
    depth of the improvement.  Min/max over the frontier *set* makes the
    margin independent of frontier row order, and exactly-tied
    candidates get exactly equal margins — the two invariants the
    property suite pins.  An empty frontier means everything improves
    (margin -inf).
    """
    z = np.asarray(z, dtype=np.float64)
    if front.size == 0:
        return np.full(z.shape[0], -np.inf)
    diff = z[:, None, :] - front[None, :, :]
    return np.min(np.max(diff, axis=2), axis=1)


def ucb_acquisition(mu, sigma, frontier, signs=None,
                    kappa: float = 1.0) -> np.ndarray:
    """Optimistic (UCB) Pareto acquisition; higher = more worth a real
    evaluation.

    The optimistic candidate ``mu*signs - kappa*|sigma|`` (canonical
    all-minimizing space, so subtracting uncertainty is optimism on
    every axis regardless of the objective's direction) is scored by its
    negated dominance margin against the frontier.  Sign flips via
    `canonical_signs` cancel exactly (mu and frontier flip together,
    sigma is sign-free), so the ranking is invariant under re-expressing
    a min objective as a max one.
    """
    z = _canonical(mu, signs) - float(kappa) * np.abs(
        np.asarray(sigma, dtype=np.float64))
    return -dominance_margin(z, _canonical(frontier, signs))


def epi_acquisition(mu, sigma, frontier, signs=None) -> np.ndarray:
    """Expected Pareto improvement; higher = more worth a real
    evaluation.

    The dominance margin ``m`` of the mean prediction is treated as a
    Gaussian with the ensemble's aggregate spread ``s`` (RMS over axes);
    the score is the classic expected improvement of ``-m`` over 0:
    ``EI = (-m) * Phi(-m/s) + s * phi(m/s)`` — strictly positive
    whenever there is uncertainty, dominated by ``-m`` when the model is
    confident.  Shares `dominance_margin`'s sign-flip and permutation
    invariants.
    """
    m = dominance_margin(_canonical(mu, signs),
                         _canonical(frontier, signs))
    if np.all(np.isinf(m)):        # empty frontier: everything improves
        return np.full(m.shape, np.inf)
    s = np.sqrt(np.mean(np.square(np.asarray(sigma, dtype=np.float64)),
                        axis=1)) + 1e-12
    u = -m / s
    cdf = 0.5 * (1.0 + _erf(u / math.sqrt(2.0)))
    pdf = np.exp(-0.5 * np.square(u)) / math.sqrt(2.0 * math.pi)
    return (-m) * cdf + s * pdf


def feasibility_weighted(acq: np.ndarray, p_feasible: np.ndarray
                         ) -> np.ndarray:
    """Discount acquisition by the classifier head: a point predicted
    infeasible is pulled toward the round's worst finite score (never
    below it) — scale-free, so the discount cannot flip the ranking
    invariants of the underlying acquisition."""
    a = np.asarray(acq, dtype=np.float64)
    p = np.clip(np.asarray(p_feasible, dtype=np.float64), 0.0, 1.0)
    finite = a[np.isfinite(a)]
    floor = float(finite.min()) if finite.size else 0.0
    return np.where(np.isfinite(a), p * a + (1.0 - p) * floor, a)


def chunk_scores(chunks: Sequence, label_scores: np.ndarray
                 ) -> Dict[int, float]:
    """Per-chunk acquisition = the best label score inside the chunk
    (``label_scores`` aligned with the concatenated chunk labels, i.e.
    `enumerate_labels` order).  -inf labels (already evaluated) never
    lift a chunk."""
    out: Dict[int, float] = {}
    off = 0
    for c in chunks:
        n = len(c.labels)
        seg = label_scores[off:off + n]
        out[c.index] = float(np.max(seg)) if n else -np.inf
        off += n
    return out


# ---------------------------------------------------------------------------
# The explore loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExploreConfig:
    eval_budget: Optional[int] = None   # max real-evaluated points
    eval_frac: float = 0.25             # budget as fraction of the grid
    init_chunks: int = 4                # seed evaluations (spread evenly)
    batch_chunks: int = 4               # top-acquisition chunks per round
    stagnation: int = 3                 # stop after N frontier-stable rounds
    acquisition: str = "ucb"            # "ucb" | "epi"
    kappa: float = 1.0                  # UCB exploration weight
    min_fit_rows: int = 8               # rows needed before the first fit
    surrogate: SurrogateConfig = SurrogateConfig()


@dataclasses.dataclass
class ExploreStats:
    objectives: Tuple[str, ...]
    n_points_total: int
    n_chunks_total: int
    n_points_evaluated: int
    n_chunks_evaluated: int
    n_chunks_skipped: int               # committed before this run
    rounds: int
    stop: str                           # "budget"|"stagnation"|"exhausted"
    elapsed_s: float
    out_dir: Optional[str]
    records: List[Dict]                 # committed rows (this dir)
    frontier: List[Dict]                # pareto over records (+ seed rows)


def explore(spec, out_dir: Optional[str] = None,
            cfg: ExploreConfig = ExploreConfig(),
            resume: bool = False,
            train_records: Optional[Sequence[Mapping]] = None,
            cache=pathfinder.DEFAULT_CACHE,
            verbose: bool = False) -> ExploreStats:
    """Acquisition-driven search replacing exhaustive enumeration.

    Rounds of: fit the surrogate on every committed row (plus optional
    seed ``train_records``), rank pending chunks by their best label's
    feasibility-weighted acquisition against the current Pareto
    frontier, spend real label-mode `pathfinder.evaluate` calls on the
    top ``batch_chunks``, commit them through the standard
    `ChunkJournal` protocol.  Stops when the eval budget is exhausted,
    the frontier key-set has not changed for ``stagnation`` rounds, or
    the grid runs out.  The output directory is a normal partial sweep
    (same spec head, chunk hashes and commit protocol as `SweepRunner`),
    so resume / `load_sweep` / `cooptimize --from` all apply; pass
    ``resume=True`` to continue an interrupted exploration with zero
    re-evaluation.
    """
    t0 = time.perf_counter()
    labels = sweeprunner.enumerate_labels(spec)
    chunks = sweeprunner.make_chunks(labels, spec.chunk_size)
    fp = spec.fingerprint()
    scn = spec.scenario_spec.variants()[0].resolve()
    objectives = tuple(scn.objectives)
    budget = int(cfg.eval_budget) if cfg.eval_budget is not None \
        else max(1, math.ceil(cfg.eval_frac * len(labels)))

    done: Dict[int, str] = {}
    journal: Optional[sweepexec.ChunkJournal] = None
    committed: List[Dict] = []
    if out_dir is not None:
        os.makedirs(out_dir, exist_ok=True)
        spec_path = os.path.join(out_dir, "spec.json")
        res_path = os.path.join(out_dir, "results.jsonl")
        ckpt_path = os.path.join(out_dir, "checkpoint.jsonl")
        journal = sweepexec.ChunkJournal(res_path, ckpt_path)
        if resume:
            sweepexec.check_fingerprint(spec_path, fp)
            done = journal.load_done(chunks, fp)
            journal.compact(done)
            committed = [{k: v for k, v in r.items() if k != "chunk"}
                         for r in journal.read_records(done)]
        elif os.path.exists(ckpt_path):
            raise FileExistsError(
                f"{out_dir} already holds a checkpointed sweep; pass "
                f"resume=True (CLI: --resume) to continue it, or point "
                f"--out at a fresh directory")
        sweepexec.write_spec_head(spec_path, sweeprunner.SPEC_VERSION, fp,
                                  spec.to_dict())
        journal.open()
    elif resume:
        raise ValueError("resume=True requires an out_dir")

    fz = Featurizer.from_spec(spec, labels)
    Xall = fz.transform(spec, labels)
    evaluated = np.zeros(len(labels), dtype=bool)
    spans: Dict[int, slice] = {}
    off = 0
    for c in chunks:
        spans[c.index] = slice(off, off + len(c.labels))
        off += len(c.labels)
    for i in done:
        evaluated[spans[i]] = True

    seed_rows = dedupe_records(train_records or [])
    n_skipped = len(done)
    n_eval_points = 0
    n_eval_chunks = 0
    rounds = 0
    stagnant = 0
    stop = "exhausted"
    prev_front_keys: Optional[frozenset] = None

    def pending() -> List:
        return [c for c in chunks if c.index not in done]

    def run_chunks(batch: Sequence) -> None:
        nonlocal n_eval_points, n_eval_chunks
        for c in batch:
            recs = pathfinder.evaluate(spec=spec, labels=c.labels,
                                       cache=cache)
            if journal is not None:
                journal.commit(c.index, c.hash(fp), recs)
            committed.extend(
                {k: v for k, v in r.items() if k != "chunk"}
                for r in recs)
            done[c.index] = c.hash(fp)
            evaluated[spans[c.index]] = True
            n_eval_points += len(recs)
            n_eval_chunks += 1
            if verbose:
                print(f"# explore: chunk {c.index} evaluated "
                      f"({len(recs)} points)", flush=True)

    def train_rows() -> List[Dict]:
        return dedupe_records(committed + seed_rows)

    def spread(cands: Sequence, n: int) -> List:
        if n >= len(cands):
            return list(cands)
        ix = np.unique(np.linspace(0, len(cands) - 1, n).round()
                       .astype(int))
        return [cands[i] for i in ix]

    try:
        # -- seed evaluations: an even spread, only as many as the
        #    training floor demands (seed train_records count toward it)
        while n_eval_points < budget and pending():
            rows = train_rows()
            feasible = sum(
                1 for r in rows
                if sweeprunner.pareto_records([r], objectives))
            if len(rows) >= cfg.min_fit_rows and feasible >= 1:
                break
            want = max(cfg.init_chunks, 1)
            batch = []
            for c in spread(pending(), want):
                if n_eval_points + sum(len(b.labels) for b in batch) \
                        + len(c.labels) > budget:
                    continue        # the budget is a hard ceiling
                batch.append(c)
            if not batch:
                break
            run_chunks(batch)
            if len(train_rows()) == len(rows):
                break                       # nothing new came back: bail

        while pending() and n_eval_points < budget \
                and stagnant < cfg.stagnation:
            rounds += 1
            rows = train_rows()
            model = fit_surrogate(spec, rows, cfg=cfg.surrogate,
                                  featurizer=fz)
            front = sweeprunner.pareto_records(rows, objectives)
            fvals = np.asarray(
                [[float(r[o]) for o in objectives] for r in front],
                dtype=np.float64).reshape(len(front), len(objectives))
            mask = ~evaluated
            mu, sigma, p = predict(model, Xall[mask])
            if not len(front):
                # no feasible point yet: the frontier acquisitions are
                # degenerate, so chase predicted feasibility instead
                acq = p.copy()
            elif cfg.acquisition == "epi":
                acq = epi_acquisition(mu, sigma, fvals, model.signs)
            else:
                acq = ucb_acquisition(mu, sigma, fvals, model.signs,
                                      kappa=cfg.kappa)
            if len(front):
                acq = feasibility_weighted(acq, p)
            scores = np.full(len(labels), -np.inf)
            scores[mask] = acq
            ranked = sweeprunner.order_chunks(
                pending(), chunk_scores(chunks, scores))
            batch = []
            points = 0
            for c in ranked:
                if len(batch) >= cfg.batch_chunks:
                    break
                if n_eval_points + points + len(c.labels) > budget \
                        and batch:
                    break
                batch.append(c)
                points += len(c.labels)
            if not batch or n_eval_points + len(batch[0].labels) > budget:
                stop = "budget"
                break
            run_chunks(batch)
            keys = frozenset(
                r.get("key") for r in sweeprunner.pareto_records(
                    train_rows(), objectives))
            if prev_front_keys is not None and keys == prev_front_keys:
                stagnant += 1
            else:
                stagnant = 0
            prev_front_keys = keys
            if verbose:
                print(f"# explore: round {rounds} -> "
                      f"{n_eval_points}/{budget} points, frontier "
                      f"{len(keys)} keys, stagnant {stagnant}",
                      flush=True)
        if stagnant >= cfg.stagnation:
            stop = "stagnation"
        elif not pending():
            stop = "exhausted"
        elif stop != "budget" and n_eval_points >= budget:
            stop = "budget"
    finally:
        if journal is not None:
            journal.close()

    frontier = sweeprunner.pareto_records(train_rows(), objectives)
    return ExploreStats(
        objectives=objectives, n_points_total=len(labels),
        n_chunks_total=len(chunks), n_points_evaluated=n_eval_points,
        n_chunks_evaluated=n_eval_chunks, n_chunks_skipped=n_skipped,
        rounds=rounds, stop=stop, elapsed_s=time.perf_counter() - t0,
        out_dir=out_dir, records=committed, frontier=frontier)


# ---------------------------------------------------------------------------
# Fabric work order (surrogate-guided lease-queue priority)
# ---------------------------------------------------------------------------


def rank_chunks(spec, records: Sequence[Mapping],
                cfg: ExploreConfig = ExploreConfig()) -> List[int]:
    """Acquisition-ranked chunk indices of ``spec`` (best first), from
    already-scored records — the input to `sweepfabric.write_chunk_order`.
    Every chunk ranks (a fabric serves the full enumeration regardless);
    the order only decides what the fleet's first minutes are spent on.
    """
    labels = sweeprunner.enumerate_labels(spec)
    chunks = sweeprunner.make_chunks(labels, spec.chunk_size)
    fz = Featurizer.from_spec(spec, labels)
    rows = dedupe_records(records)
    scn = spec.scenario_spec.variants()[0].resolve()
    objectives = tuple(scn.objectives)
    model = fit_surrogate(spec, rows, cfg=cfg.surrogate, featurizer=fz)
    front = sweeprunner.pareto_records(rows, objectives)
    fvals = np.asarray(
        [[float(r[o]) for o in objectives] for r in front],
        dtype=np.float64).reshape(len(front), len(objectives))
    mu, sigma, p = predict(model, fz.transform(spec, labels))
    if cfg.acquisition == "epi":
        acq = epi_acquisition(mu, sigma, fvals, model.signs)
    else:
        acq = ucb_acquisition(mu, sigma, fvals, model.signs,
                              kappa=cfg.kappa)
    acq = feasibility_weighted(acq, p)
    ordered = sweeprunner.order_chunks(chunks, chunk_scores(chunks, acq))
    return [c.index for c in ordered]


def order_fabric_dir(fabric_dir: str, records: Sequence[Mapping],
                     cfg: ExploreConfig = ExploreConfig()) -> List[int]:
    """Rank an initialized fabric directory's chunks and write its
    ``order.json`` (advisory, fingerprint-guarded, schedule-only — see
    `sweepfabric.write_chunk_order`).  Returns the written order."""
    from repro.core import sweepfabric
    spec, _ = sweepfabric.load_dir(fabric_dir)
    order = rank_chunks(spec, records, cfg=cfg)
    sweepfabric.write_chunk_order(fabric_dir, order, spec.fingerprint())
    return order


__all__ = [
    "Dataset", "ExploreConfig", "ExploreStats", "Featurizer",
    "SurrogateConfig", "SurrogateModel", "build_dataset", "chunk_scores",
    "dedupe_records", "dominance_margin", "epi_acquisition", "explore",
    "feasibility_weighted", "fit_surrogate", "load_training_records",
    "order_fabric_dir", "predict", "rank_chunks", "ucb_acquisition",
]
