"""Traffic-driven serving: arrival processes, continuous batching, SLOs.

The static serving scenario scores one resident batch per design — a
per-device metric.  Capacity planning needs the *system* question: given a
request arrival process (QPS, prompt/output length distributions) and a
continuous-batching server (JetStream-style prefill -> insert-into-slot ->
generate), what are the TTFT/TPOT *percentiles*, and how many devices does
it take to serve X QPS inside an SLO?  This module holds the analytic
occupancy model that answers both, layered on the same prefill/decode phase
costs `simulate.serving_breakdown` uses.

Model (documented here once; every consumer shares `continuous_batching_stats`):

  * Requests arrive Poisson at ``qps``; prompt and output lengths are
    lognormal with configured mean and coefficient of variation (cv=0 means
    deterministic).
  * The decode engine steps ``slots`` sequences at once (the decode cell's
    global batch); each step costs the capacity-derated decode-step time
    ``t_d``.  Prefill work is *chunked* into ``prefill_chunk``-token pieces
    that ride along decode steps (chunked prefill), each stretching its
    carrier step by ``t_chunk = prefill_chunk * t_prefill / prefill_tokens``.
  * With chunk arrival rate ``lam_c = qps * chunks_per_req`` the mean step
    time has the closed form ``t_step = t_d / (1 - lam_c * t_chunk)`` and
    the maximum sustainable arrival rate is::

        qps_max = slots / ((chunks_per_req + output_mean) * t_d
                           + slots * chunks_per_req * t_chunk)

    ``util = qps / qps_max`` is the Erlang utilization; ``util >= 1`` is the
    feasibility wall.
  * A request holds a slot for ``(chunks_per_req + output_len)`` steps; slot
    contention is approximated as an M/M/c queue: the Erlang-C waiting
    probability plus an exponential tail give closed-form queue-wait
    percentiles.  TTFT percentiles add the prompt's own chunked-prefill
    completion at the matching prompt-length percentile (quantiles combined
    additively — a standard conservative approximation).
  * TPOT percentiles come from the two-point step-time mixture: a fraction
    ``f = lam_c * t_d / (1 - lam_c * t_chunk)`` of steps carry a prefill
    chunk (cost ``t_d + t_chunk``), the rest cost ``t_d``.

Everything downstream of the two phase costs is arithmetic in the array
module ``xp`` (NumPy or jax.numpy), so the scalar record path, the
pipelined executor's vectorized fold, and the jit/vmap-traced frontier fold
share one op-for-op implementation — the parity and traceability contracts
fall out by construction.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

# percentiles reported for TTFT / TPOT; field names use pXX suffixes
PERCENTILES: Tuple[float, ...] = (0.50, 0.99)
PCT_NAMES: Tuple[str, ...] = tuple(f"p{int(round(p * 100))}"
                                   for p in PERCENTILES)

_EPS = 1e-12


def _norm_ppf(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation).

    Max abs error ~1.15e-9 — far below the fidelity of the queueing
    approximations consuming it; avoids a scipy dependency.
    """
    if not 0.0 < p < 1.0:
        raise ValueError(f"percentile must be in (0, 1), got {p}")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                * q + c[5]) / ((((d[0] * q + d[1]) * q + d[2]) * q
                                + d[3]) * q + 1)
    if p > phigh:
        q = math.sqrt(-2 * math.log(1 - p))
        return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                 * q + c[5]) / ((((d[0] * q + d[1]) * q + d[2]) * q
                                 + d[3]) * q + 1)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
            * r + a[5]) * q / (((((b[0] * r + b[1]) * r + b[2]) * r
                                 + b[3]) * r + b[4]) * r + 1)


def lognormal_quantile(mean: float, cv: float, p: float) -> float:
    """Quantile of a lognormal given its mean and coefficient of variation.

    cv == 0 degenerates to the deterministic distribution (quantile = mean).
    """
    if mean <= 0:
        raise ValueError(f"length mean must be positive, got {mean}")
    if cv <= 0:
        return float(mean)
    s2 = math.log1p(cv * cv)
    mu = math.log(mean) - 0.5 * s2
    return float(math.exp(mu + math.sqrt(s2) * _norm_ppf(p)))


# ---------------------------------------------------------------------------
# Typed traffic / batching-policy parameters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    """Request arrival process: Poisson QPS + lognormal length mixes."""

    qps: float = 8.0                # request arrivals per second (Poisson)
    prompt_mean: float = 2048.0     # mean prompt tokens
    prompt_cv: float = 1.0          # prompt-length coefficient of variation
    output_mean: float = 256.0      # mean generated tokens
    output_cv: float = 1.0          # output-length coefficient of variation

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "TrafficModel":
        return cls(**{f.name: float(d[f.name]) for f in
                      dataclasses.fields(cls) if f.name in d})


@dataclasses.dataclass(frozen=True)
class BatchingPolicy:
    """Continuous-batching server policy knobs (the sweepable axes)."""

    prefill_chunk: float = 512.0    # tokens per interleaved prefill chunk

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "BatchingPolicy":
        return cls(**{f.name: float(d[f.name]) for f in
                      dataclasses.fields(cls) if f.name in d})


# parameter names ScenarioSpec accepts for the traffic scenario, with
# defaults — SLO walls default to None (= no wall)
PARAM_DEFAULTS: Dict[str, Optional[float]] = {
    **TrafficModel().to_dict(), **BatchingPolicy().to_dict(),
    "slo_ttft_p50": None, "slo_ttft_p99": None,
    "slo_tpot_p50": None, "slo_tpot_p99": None,
}
SLO_KEYS: Tuple[str, ...] = ("slo_ttft_p50", "slo_ttft_p99",
                             "slo_tpot_p50", "slo_tpot_p99")


def split_params(params: Mapping) -> Tuple[TrafficModel, BatchingPolicy,
                                           Dict[str, float]]:
    """(traffic, policy, slo walls) from one flat ScenarioSpec param dict."""
    unknown = set(params) - set(PARAM_DEFAULTS)
    if unknown:
        raise KeyError(f"unknown traffic scenario params {sorted(unknown)}; "
                       f"known: {sorted(PARAM_DEFAULTS)}")
    slo = {k[len("slo_"):]: float(params[k]) for k in SLO_KEYS
           if params.get(k) is not None}
    return (TrafficModel.from_dict(params), BatchingPolicy.from_dict(params),
            slo)


# ---------------------------------------------------------------------------
# The analytic continuous-batching model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeConsts:
    """Per-design host constants for `continuous_batching_stats`.

    Everything here is independent of the hardware vector, so folds can
    close over one instance and trace only the two phase-cost inputs.
    """

    qps: float                      # arrival rate (requests/s)
    slots: int                      # decode batch slots (decode cell batch)
    prefill_tokens: float           # tokens scored by the prefill graph
    chunk: float                    # prefill chunk size (tokens)
    chunks_per_req: float           # E[prompt]/chunk + 1 (ceil bound)
    output_mean: float              # E[output tokens]
    prompt_q: Tuple[float, ...]     # prompt-length quantiles @ PERCENTILES
    lgamma: Tuple[float, ...]       # log(k!) for k = 0..slots
    devices: float                  # devices per replica (for cost fields)


def build_consts(traffic: TrafficModel, policy: BatchingPolicy, *,
                 slots: int, prefill_tokens: float,
                 devices: float) -> ServeConsts:
    chunk = max(float(policy.prefill_chunk), 1.0)
    return ServeConsts(
        qps=float(traffic.qps), slots=int(slots),
        prefill_tokens=max(float(prefill_tokens), 1.0), chunk=chunk,
        chunks_per_req=float(traffic.prompt_mean) / chunk + 1.0,
        output_mean=max(float(traffic.output_mean), 1.0),
        prompt_q=tuple(lognormal_quantile(traffic.prompt_mean,
                                          traffic.prompt_cv, p)
                       for p in PERCENTILES),
        lgamma=tuple(math.lgamma(k + 1) for k in range(int(slots) + 1)),
        devices=float(devices))


def _erlang_c_log_pwait(xp, log_a, rho, c: ServeConsts):
    """log P(wait) of an M/M/c queue via a log-space Erlang-C sum.

    ``slots`` is a static Python int, so the k-sum unrolls at trace time
    (<= a few hundred fused scalar ops under vmap — negligible next to the
    graph evaluation itself).
    """
    B = c.slots
    wait_t = B * log_a - c.lgamma[B] - xp.log(1.0 - rho)
    terms = [k * log_a - c.lgamma[k] for k in range(B)] + [wait_t]
    lt = xp.stack(terms)
    m = xp.max(lt, axis=0)
    lse = m + xp.log(xp.sum(xp.exp(lt - m), axis=0))
    return wait_t - lse


def continuous_batching_stats(xp, t_prefill_s, t_decode_step_s,
                              c: ServeConsts,
                              mask_infeasible: bool = True
                              ) -> Dict[str, object]:
    """All traffic metrics from the two phase costs, in array module `xp`.

    ``t_prefill_s`` is the prefill-graph batch time (``prefill_tokens``
    tokens), ``t_decode_step_s`` the capacity-derated decode-step time.
    Both may be arrays (vectorized fold), 0-d np scalars (record path), or
    traced jnp values (frontier/refine folds) — the arithmetic is
    identical, which is what makes record/metrics_fold parity and
    frontier-fold traceability hold by construction.  Infeasible inputs
    (non-finite costs or ``util >= 1``) are computed on clamped values and
    masked out at the end; ``mask_infeasible=False`` skips the masking and
    returns the smooth clamped values instead (for gradient-based
    refinement, which adds its own soft barrier on ``util``).
    """
    finite = xp.isfinite(t_prefill_s) & xp.isfinite(t_decode_step_s)
    t_pf = xp.where(finite, t_prefill_s, 1.0)
    t_d = xp.where(finite, t_decode_step_s, 1.0)

    c_tok = t_pf / c.prefill_tokens              # prefill seconds per token
    t_chunk = c.chunk * c_tok                    # one interleaved chunk
    lam_c = c.qps * c.chunks_per_req             # chunk arrivals per second
    m_steps = c.chunks_per_req + c.output_mean   # slot-holding steps/request

    qps_max = c.slots / (m_steps * t_d
                         + c.slots * c.chunks_per_req * t_chunk)
    util = c.qps / qps_max
    feasible = finite & (util < 1.0)

    # clamped copies keep the queue math finite on infeasible points; the
    # final where() masks them to inf/0 anyway
    rho = xp.minimum(util, 1.0 - 1e-9)
    t_step = t_d / xp.maximum(1.0 - lam_c * t_chunk, _EPS)
    s_mean = m_steps * t_step                    # mean slot-holding time
    frac_chunk = xp.clip(lam_c * t_step, 0.0, 1.0)   # steps carrying a chunk

    log_a = xp.log(xp.maximum(rho * c.slots, _EPS))
    log_pw = _erlang_c_log_pwait(xp, log_a, rho, c)
    wait_scale = s_mean / (c.slots * (1.0 - rho))

    out: Dict[str, object] = {}
    for p, nm, lq in zip(PERCENTILES, PCT_NAMES, c.prompt_q):
        wait_q = wait_scale * xp.maximum(log_pw - math.log(1.0 - p), 0.0)
        own_prefill = (lq / c.chunk + 1.0) * t_step
        ttft = wait_q + own_prefill
        tpot = xp.where(frac_chunk > 1.0 - p, t_d + t_chunk, t_d)
        if mask_infeasible:
            ttft = xp.where(feasible, ttft, xp.inf)
            tpot = xp.where(feasible, tpot, xp.inf)
        out[f"ttft_{nm}_s"] = ttft
        out[f"tpot_{nm}_s"] = tpot

    goodput = c.qps * c.output_mean              # output tokens/s served
    out["util"] = util
    out["qps_max"] = xp.where(finite, qps_max, 0.0)
    served = xp.where(feasible, goodput, 0.0) if mask_infeasible \
        else goodput * xp.ones_like(util)
    out["tokens_per_s"] = served
    out["tokens_per_s_per_device"] = served / max(c.devices, 1.0)
    # device-seconds per output token *at capacity* — the fleet-sizing cost
    cost = c.devices / xp.maximum(qps_max * c.output_mean, _EPS)
    out["cost_device_s_per_token"] = xp.where(feasible, cost, xp.inf) \
        if mask_infeasible else cost
    out["feasible"] = feasible
    return out


def slo_ok(stats: Mapping, slo: Mapping[str, float], xp=np):
    """Elementwise SLO-wall check: True where every configured percentile
    wall holds (``slo`` keys like ``"ttft_p99"`` in seconds).  Infeasible
    points carry inf percentiles and therefore fail every wall."""
    ok = stats["feasible"]
    for key, wall in slo.items():
        ok = ok & (stats[f"{key}_s"] <= wall)
    return ok


# ---------------------------------------------------------------------------
# Scenario-variant suffix codec (batching-policy sweep axes)
# ---------------------------------------------------------------------------
#
# Swept scenario params ride inside the cell-id string as a "@k=v,..."
# suffix, so `point_key`, chunk hashes, and checkpoint resume all work
# unchanged.  The codec lives here (pure string <-> floats) and is shared
# by scenarios.ScenarioSpec and the fleet-sizing query.


def encode_variant(cell_id: str, overrides: Mapping[str, float]) -> str:
    if not overrides:
        return cell_id
    body = ",".join(f"{k}={float(v):g}" for k, v in sorted(overrides.items()))
    return f"{cell_id}@{body}"


def decode_variant(cell_id: str) -> Tuple[str, Dict[str, float]]:
    base, _, body = cell_id.partition("@")
    if not body:
        return base, {}
    out: Dict[str, float] = {}
    for item in body.split(","):
        k, _, v = item.partition("=")
        if not _ or not k:
            raise ValueError(f"malformed scenario-variant suffix in "
                             f"cell id {cell_id!r}")
        out[k] = float(v)
    return base, out


# ---------------------------------------------------------------------------
# Inverse query: minimum fleet size serving X QPS inside the SLOs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetCandidate:
    """One swept design's answer to the sizing query."""

    key: str
    replicas: int                   # replicas of the swept configuration
    devices_per_replica: int
    devices: int                    # replicas * devices_per_replica
    per_replica_qps: float
    metrics: Dict[str, float]       # traffic stats at the chosen size
    rank_value: Optional[float] = None  # objective column under rank_by


# objective-record column behind each `size_fleet(rank_by=...)` choice;
# None = the default total-device-count ranking (no column needed)
RANK_COLUMNS: Dict[str, Optional[str]] = {
    "devices": None,
    "cost_per_token": "cost_usd_per_token",
    "energy_per_token": "energy_j_per_token",
}


@dataclasses.dataclass
class FleetPlan:
    qps: float
    slo: Dict[str, float]
    best: Optional[FleetCandidate]
    candidates: List[FleetCandidate]     # feasible, sorted by devices
    n_records: int
    n_sized: int                    # records that could meet the SLOs
    n_unsizeable: int               # designs no replica count can save
    n_evals: int                    # closed-form model evaluations spent


def _record_consts(rec: Mapping, traffic: TrafficModel,
                   policy: BatchingPolicy, qps: float) -> ServeConsts:
    """Per-record ServeConsts: cell shapes + any swept-variant overrides
    carried in the record's cell id."""
    from repro.configs.base import SHAPE_CELLS
    base, over = decode_variant(str(rec["cell"]))
    cells = base.split("+")
    if len(cells) != 2:
        raise ValueError(f"fleet sizing needs a prefill+decode record, "
                         f"got cell {rec['cell']!r}")
    tr = dataclasses.replace(
        traffic, qps=qps,
        **{k: v for k, v in over.items()
           if k in {f.name for f in dataclasses.fields(TrafficModel)}
           and k != "qps"})
    po = BatchingPolicy.from_dict({**policy.to_dict(),
                                   **{k: v for k, v in over.items()
                                      if k in policy.to_dict()}})
    pc, dc = SHAPE_CELLS[cells[0]], SHAPE_CELLS[cells[1]]
    return build_consts(tr, po, slots=dc.global_batch,
                        prefill_tokens=float(pc.global_batch) * pc.seq_len,
                        devices=float(rec["devices"]))


def _meets(t_pf: float, t_d: float, c: ServeConsts,
           slo: Mapping[str, float]):
    st = continuous_batching_stats(np, np.float64(t_pf), np.float64(t_d), c)
    ok = bool(np.asarray(slo_ok(st, slo)))
    return ok, {k: (bool(v) if k == "feasible" else float(np.asarray(v)))
                for k, v in st.items()}


def size_fleet(records: Sequence[Mapping], qps: float, *,
               slo: Mapping[str, float],
               traffic: TrafficModel = TrafficModel(),
               policy: BatchingPolicy = BatchingPolicy(),
               top_k: int = 5, max_replicas: int = 1 << 20,
               rank_by: str = "devices") -> FleetPlan:
    """Minimum device count serving ``qps`` under percentile SLO walls.

    For each swept record carrying its phase costs (``prefill_s``,
    capacity-derated ``decode_step_s``), the offered load is split across
    ``n`` identical replicas (per-replica arrival rate ``qps / n``) and the
    closed-form model decides SLO attainment.  Every traffic metric
    improves monotonically as per-replica load drops, so the minimal
    feasible ``n`` is found by doubling + bisection — no sweep point is
    ever re-evaluated.  Designs whose zero-load limit already violates an
    SLO can never be saved by adding replicas and are skipped.

    ``rank_by`` picks the best/candidate ordering: ``devices`` (default,
    total fleet size) or a per-token objective column the sweep carried —
    ``cost_per_token`` ($/token, `cost_usd_per_token`) /
    ``energy_per_token`` (J/token, `energy_j_per_token`), both from a
    sweep run with ``--objectives cost,energy``.  Ranking reads the
    already-streamed objective columns — zero re-evaluation either way;
    candidates missing the column sort last, and a record set carrying
    the column nowhere raises (the sweep was run without the objective).
    """
    slo = dict(slo)
    bad = set(slo) - {k[len("slo_"):] for k in SLO_KEYS}
    if bad:
        raise KeyError(f"unknown SLO keys {sorted(bad)}")
    if rank_by not in RANK_COLUMNS:
        raise ValueError(f"unknown rank_by {rank_by!r}; choose from "
                         f"{sorted(RANK_COLUMNS)}")
    rank_col = RANK_COLUMNS[rank_by]
    if rank_col is not None:
        sized = [r for r in records
                 if "prefill_s" in r and "decode_step_s" in r]
        if sized and not any(r.get(rank_col) is not None for r in sized):
            raise ValueError(
                f"rank_by={rank_by!r} needs the {rank_col!r} objective "
                f"column, which no record carries; rerun the sweep with "
                f"--objectives energy,cost")
    cands: List[FleetCandidate] = []
    n_evals = n_unsizeable = 0
    seen = 0
    for rec in records:
        if "prefill_s" not in rec or "decode_step_s" not in rec:
            continue                    # not a traffic-scenario record
        seen += 1
        t_pf, t_d = rec["prefill_s"], rec["decode_step_s"]
        if t_pf is None or t_d is None or \
                not (math.isfinite(float(t_pf))
                     and math.isfinite(float(t_d))):
            n_unsizeable += 1           # capacity-infeasible design
            continue
        t_pf, t_d = float(t_pf), float(t_d)
        c1 = _record_consts(rec, traffic, policy, qps)
        # zero-load limit: lam_c -> 0, wait -> 0; unreachable SLOs fail here
        c0 = dataclasses.replace(c1, qps=min(qps * 1e-9, 1e-9))
        ok0, _ = _meets(t_pf, t_d, c0, slo)
        n_evals += 1
        if not ok0:
            n_unsizeable += 1
            continue
        n = 1
        ok, st = _meets(t_pf, t_d, c1, slo)
        n_evals += 1
        while not ok and n < max_replicas:          # doubling phase
            n *= 2
            ok, st = _meets(t_pf, t_d,
                            dataclasses.replace(c1, qps=qps / n), slo)
            n_evals += 1
        if not ok:
            n_unsizeable += 1
            continue
        lo = n // 2                                  # bisect (lo fails)
        while n - lo > 1:
            mid = (lo + n) // 2
            okm, stm = _meets(t_pf, t_d,
                              dataclasses.replace(c1, qps=qps / mid), slo)
            n_evals += 1
            if okm:
                n, st = mid, stm
            else:
                lo = mid
        dev = int(rec["devices"])
        rank_val = None
        if rank_col is not None:
            v = rec.get(rank_col)
            if v is not None and math.isfinite(float(v)):
                rank_val = float(v)
        cands.append(FleetCandidate(
            key=str(rec.get("key", "")), replicas=n, devices_per_replica=dev,
            devices=n * dev, per_replica_qps=qps / n, metrics=st,
            rank_value=rank_val))
    if rank_col is None:
        cands.sort(key=lambda c: (c.devices, c.replicas, c.key))
    else:
        # objective-ranked: missing columns last, devices as tie-break
        cands.sort(key=lambda c: (c.rank_value is None,
                                  c.rank_value if c.rank_value is not None
                                  else 0.0, c.devices, c.replicas, c.key))
    return FleetPlan(qps=float(qps), slo=slo,
                     best=cands[0] if cands else None,
                     candidates=cands[:max(top_k, 0)], n_records=seen,
                     n_sized=len(cands), n_unsizeable=n_unsizeable,
                     n_evals=n_evals)
