"""Micro-Architecture Generator Engine (AGE) — DeepFlow paper §4.

Given (technology config, architecture template, area/power/perimeter budget
breakdown), derive the micro-architectural parameters consumed by the
performance prediction engine:

  * compute throughput (paper eq. 1, voltage-frequency scaled),
  * per-level on-chip memory capacity + bandwidth (eqs. 2-3, crossbar +
    controller overheads included),
  * main-memory capacity + bandwidth (eq. 4),
  * intra- and inter-package network bandwidth.

All arithmetic is written in `jax.numpy` so the whole AGE is differentiable
w.r.t. the budget fractions — this is what lets the Search-and-Optimization
Engine (repro.core.soe) use *exact* `jax.grad` gradients instead of the
paper's black-box numeric ones (a beyond-paper improvement recorded in
DESIGN.md). Set ``discrete=True`` to apply floors (reporting mode).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp

from repro.core import techlib
from repro.core.techlib import TechConfig

# Component keys, in the order used by budget vectors (SOE optimizes this
# flat vector; keep the order stable).
COMPONENTS = ("core", "l2", "l1", "l0", "dram", "net_intra", "net_inter")
# Perimeter is only consumed by off-die interfaces.
PERIM_COMPONENTS = ("dram", "net_intra", "net_inter")


@dataclasses.dataclass(frozen=True)
class Budgets:
    """Hardware resource allocation (paper §4.3, Fig. 4)."""

    node_area_mm2: float = 1230.0       # package/substrate budget
    proc_chip_area_mm2: float = 815.0   # compute die budget
    power_w: float = 300.0
    # fractional breakdowns over COMPONENTS; need not sum exactly to 1
    area_frac: Dict[str, float] = dataclasses.field(default_factory=dict)
    power_frac: Dict[str, float] = dataclasses.field(default_factory=dict)
    perim_frac: Dict[str, float] = dataclasses.field(default_factory=dict)

    @staticmethod
    def default() -> "Budgets":
        return Budgets(
            area_frac={"core": 0.35, "l2": 0.14, "l1": 0.10, "l0": 0.20,
                       "dram": 0.05, "net_intra": 0.06, "net_inter": 0.10},
            power_frac={"core": 0.50, "l2": 0.12, "l1": 0.10, "l0": 0.08,
                        "dram": 0.12, "net_intra": 0.03, "net_inter": 0.05},
            perim_frac={"dram": 0.50, "net_intra": 0.20, "net_inter": 0.30},
        )

    def as_vector(self) -> jnp.ndarray:
        """Flatten to the SOE parameter vector W = {A_i, P_i, R_i} (paper §7)."""
        a = [self.area_frac.get(c, 0.0) for c in COMPONENTS]
        p = [self.power_frac.get(c, 0.0) for c in COMPONENTS]
        r = [self.perim_frac.get(c, 0.0) for c in PERIM_COMPONENTS]
        return jnp.asarray(a + p + r, dtype=jnp.float32)

    @staticmethod
    def from_vector(w, like: "Budgets") -> "Budgets":
        n = len(COMPONENTS)
        a = {c: w[i] for i, c in enumerate(COMPONENTS)}
        p = {c: w[n + i] for i, c in enumerate(COMPONENTS)}
        r = {c: w[2 * n + i] for i, c in enumerate(PERIM_COMPONENTS)}
        return Budgets(node_area_mm2=like.node_area_mm2,
                       proc_chip_area_mm2=like.proc_chip_area_mm2,
                       power_w=like.power_w,
                       area_frac=a, power_frac=p, perim_frac=r)


@dataclasses.dataclass(frozen=True)
class MicroArch:
    """AGE output: the parameters the performance model consumes.

    Bandwidths are aggregate bytes/s per accelerator node; capacities bytes.
    Fields may be python floats or jnp scalars (when traced by the SOE).
    """

    tech: TechConfig
    n_mcu: object
    core_frequency: object
    compute_throughput: object          # flops/s, after max_utilization derate
    mem_capacity: tuple                 # (L0, L1, L2) bytes
    mem_bw: tuple                       # (L0, L1, L2) bytes/s
    mem_latency: tuple                  # (L0, L1, L2) s
    dram_capacity: object
    dram_bw: object
    dram_latency: float
    net_intra_bw: object                # per-link effective bytes/s
    net_intra_links: object
    net_intra_latency: float
    net_inter_bw: object                # per-link effective bytes/s
    net_inter_links: object
    net_inter_latency: float

    def memory_hierarchy(self):
        """(capacity, bw, latency) per level, L0 (regs) .. L3 (DRAM)."""
        caps = list(self.mem_capacity) + [self.dram_capacity]
        bws = list(self.mem_bw) + [self.dram_bw]
        lats = list(self.mem_latency) + [self.dram_latency]
        return caps, bws, lats


def _smooth_floor(x, discrete: bool):
    return jnp.floor(x) if discrete else x


def _power_limited_voltage(p_budget, p_nominal, vnom, vth, vmin):
    """Differentiable fixed-point solve of P(V)=Pb (see techlib docstring).

    P(V) = Pnom * (V/Vnom)^2 * (V-Vth)/(Vnom-Vth); 20 unrolled iterations of
    V <- Vth + (Vnom-Vth) * (Pb/Pnom) * (Vnom/V)^2, clipped to [vmin, vnom].
    """
    ratio = jnp.clip(p_budget / jnp.maximum(p_nominal, 1e-12), 1e-6, 1.0)
    v = jnp.asarray(vnom, dtype=jnp.float32)
    for _ in range(20):
        v_new = vth + (vnom - vth) * ratio * (vnom / jnp.maximum(v, 1e-6)) ** 2
        v = jnp.clip(v_new, vmin, vnom)
    return v


def generate(tech: TechConfig, budgets: Budgets,
             discrete: bool = True) -> MicroArch:
    """Run the AGE (paper §4.4): budgets + tech -> micro-arch parameters."""
    af, pf, rf = budgets.area_frac, budgets.power_frac, budgets.perim_frac
    chip_area = budgets.proc_chip_area_mm2
    power = budgets.power_w
    perimeter = 4.0 * jnp.sqrt(chip_area)

    # ---- Core (paper §4.4.1, eq. 1) ------------------------------------
    c = tech.compute
    a_core = af.get("core", 0.0) * chip_area
    p_core = pf.get("core", 0.0) * power
    n_mcu = _smooth_floor(a_core / c.nominal_area_mm2, discrete)
    n_mcu = jnp.maximum(n_mcu, 1e-3)
    p_nominal = n_mcu * c.nominal_power
    v_op = _power_limited_voltage(p_core, p_nominal, c.nominal_voltage,
                                  c.threshold_voltage, c.minimum_voltage)
    f_op = (c.nominal_frequency * (v_op - c.threshold_voltage)
            / (c.nominal_voltage - c.threshold_voltage))
    # If even Vmin overflows the power budget, shed MCUs (paper: "reduce the
    # number of MCUs till we satisfy the total power budget").
    p_at_vmin = (n_mcu * c.nominal_power
                 * (v_op / c.nominal_voltage) ** 2
                 * (f_op / c.nominal_frequency))
    shed = jnp.clip(p_core / jnp.maximum(p_at_vmin, 1e-12), 0.0, 1.0)
    n_eff = n_mcu * shed
    n_eff = _smooth_floor(n_eff, discrete)
    n_eff = jnp.maximum(n_eff, 1e-3)
    throughput = (n_eff * c.nominal_flops_per_cycle * f_op
                  * c.max_utilization)                       # eq. 1 (+derate)

    # ---- On-chip memory levels (paper §4.4.2, eqs. 2-3) -----------------
    caps, bws, lats = [], [], []
    n_clients = n_eff     # crossbar ports scale with #MCUs (paper §9.1 insight)
    for name in ("l0", "l1", "l2"):
        m: techlib.OnChipMemTech = getattr(tech, name)
        a_m = af.get(name, 0.0) * chip_area
        p_m = pf.get(name, 0.0) * power
        per_bank = (m.bank_area_mm2 + m.controller_area_per_bank_mm2
                    + n_clients * m.xbar_area_per_port_mm2)
        n_banks = _smooth_floor(a_m / per_bank, discrete)
        n_banks = jnp.maximum(n_banks, 1e-3)
        capacity = n_banks * m.bank_capacity_bytes
        p_static = (m.static_power_per_bit * capacity * 8.0
                    + n_banks * m.controller_power_per_bank_w)       # eq. 2
        p_dyn = jnp.maximum(p_m - p_static, 0.0)
        bw_bits = p_dyn / (m.dynamic_energy_per_bit + m.xbar_energy_per_bit)
        bws.append(bw_bits / 8.0)                                     # eq. 3
        caps.append(capacity)
        lats.append(m.latency_s)

    # ---- Main memory (paper §4.4.3, eq. 4) ------------------------------
    d = tech.dram
    a_ctrl = af.get("dram", 0.0) * chip_area
    p_dram = pf.get("dram", 0.0) * power
    perim_links = rf.get("dram", 0.0) * perimeter * d.links_per_mm
    n_dev = jnp.minimum(
        jnp.minimum((budgets.node_area_mm2 - chip_area) / d.device_area_mm2,
                    a_ctrl / d.controller_io_area_mm2),
        perim_links / d.links_per_device)                             # eq. 4
    n_dev = jnp.maximum(_smooth_floor(n_dev, discrete), 1e-3)
    dram_capacity = n_dev * d.device_capacity_bytes
    bw_nom = n_dev * d.device_bw_bytes
    p_static_dram = n_dev * d.static_power_per_device_w
    p_dyn_dram = jnp.maximum(p_dram - p_static_dram, 0.0)
    dram_bw = jnp.minimum(bw_nom, p_dyn_dram / (d.dynamic_energy_per_bit * 8.0))

    # ---- Networks (paper §4.4.4) ----------------------------------------
    def _net(n: techlib.NetworkTech, key: str):
        a_n = af.get(key, 0.0) * chip_area
        p_n = pf.get(key, 0.0) * power
        n_links = jnp.minimum(a_n / n.area_per_link_mm2,
                              rf.get(key, 0.0) * perimeter * n.links_per_mm)
        n_links = jnp.maximum(_smooth_floor(n_links, discrete), 1e-3)
        bw_nom_total = n_links * n.nominal_bw_per_link_bytes
        bw_pow = p_n / (n.nominal_energy_per_bit * 8.0)
        bw_total = jnp.minimum(bw_nom_total, bw_pow)
        return bw_total / n_links, n_links          # effective per-link BW

    intra_bw, intra_links = _net(tech.net_intra, "net_intra")
    inter_bw, inter_links = _net(tech.net_inter, "net_inter")

    return MicroArch(
        tech=tech,
        n_mcu=n_eff,
        core_frequency=f_op,
        compute_throughput=throughput,
        mem_capacity=tuple(caps),
        mem_bw=tuple(bws),
        mem_latency=tuple(lats),
        dram_capacity=dram_capacity,
        dram_bw=dram_bw,
        dram_latency=d.access_latency_s,
        net_intra_bw=intra_bw,
        net_intra_links=intra_links,
        net_intra_latency=tech.net_intra.link_latency_s,
        net_inter_bw=inter_bw,
        net_inter_links=inter_links,
        net_inter_latency=tech.net_inter.link_latency_s,
    )


def fixed_microarch(tech: TechConfig, *, compute_flops: float, dram_bw: float,
                    dram_capacity: float, net_inter_bw: float,
                    net_inter_links: float = 4.0,
                    net_intra_bw: Optional[float] = None,
                    l2_bytes: float = 128 * 2**20, l2_bw: Optional[float] = None,
                    l1_bytes: float = 128 * 2**20, l1_bw: Optional[float] = None,
                    l0_bytes: float = 256 * 2**10, l0_bw: Optional[float] = None,
                    ) -> MicroArch:
    """Bypass the AGE with *known* hardware (TPU v5e, CPU host): used when we
    model existing silicon rather than explore hypothetical budgets."""
    l2_bw = l2_bw if l2_bw is not None else dram_bw * 6.0
    l1_bw = l1_bw if l1_bw is not None else dram_bw * 24.0
    l0_bw = l0_bw if l0_bw is not None else compute_flops * 2.0  # regs feed MXU
    return MicroArch(
        tech=tech,
        n_mcu=4.0,
        core_frequency=tech.compute.nominal_frequency,
        compute_throughput=compute_flops * tech.compute.max_utilization,
        mem_capacity=(l0_bytes, l1_bytes, l2_bytes),
        mem_bw=(l0_bw, l1_bw, l2_bw),
        mem_latency=(0.5e-9, 5e-9, 15e-9),
        dram_capacity=dram_capacity,
        dram_bw=dram_bw,
        dram_latency=tech.dram.access_latency_s,
        net_intra_bw=net_intra_bw if net_intra_bw is not None else net_inter_bw,
        net_intra_links=4.0,
        net_intra_latency=tech.net_intra.link_latency_s,
        net_inter_bw=net_inter_bw,
        net_inter_links=net_inter_links,
        net_inter_latency=tech.net_inter.link_latency_s,
    )


def tpu_v5e_microarch() -> MicroArch:
    """The dry-run/roofline target: 197 TF bf16, 819 GB/s HBM, 50 GB/s ICI."""
    return fixed_microarch(
        techlib.tpu_v5e_tech(),
        compute_flops=197e12,
        dram_bw=819e9,
        dram_capacity=16.0 * 2**30,
        net_inter_bw=50e9,
        net_inter_links=4.0,
        l1_bytes=128 * 2**20,           # VMEM
    )


def cpu_host_microarch(compute_flops: float = 5.0e10,
                       dram_bw: float = 1.2e10) -> MicroArch:
    """Calibratable model of THIS container's CPU (validation hardware)."""
    return fixed_microarch(
        techlib.cpu_host_tech(),
        compute_flops=compute_flops,
        dram_bw=dram_bw,
        dram_capacity=16.0 * 2**30,
        net_inter_bw=10e9,
        l2_bytes=32 * 2**20, l2_bw=dram_bw * 6,
        l1_bytes=1 * 2**20, l1_bw=dram_bw * 20,
        l0_bytes=64 * 2**10,
    )
