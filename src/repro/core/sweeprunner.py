"""Sharded, chunked, resumable design-space sweep engine.

`pathfinder.sweep()` scores one in-memory cross-product; the co-design
studies the paper automates (§7, §9) — and the sweep sizes DFModel/COSMIC
report — need 10^4-10^6 points, hours of wall time, and fault tolerance.
This module scales the batched engine into a *sweep runner*:

  * the (arch x cell x mesh x tech x budget-scale x strategy) cross-product
    is enumerated deterministically and partitioned into fixed-size
    **chunks** of design points;
  * chunks execute on a pluggable backend — the default is the
    asynchronous double-buffered pipeline of `repro.core.sweeppipeline`
    (`backend="pipeline"`: producer/device/writer overlap, superbatched
    fused dispatch, device-resident `--frontier-only` reduction); the
    synchronous engines remain as `"device"` (per-chunk `jax.pmap` over
    the struct-of-arrays hardware matrix), `"thread"` / `"process"`
    (parallel `BatchedEvaluator` calls) and `"serial"`;
  * results **stream** to ``results.jsonl`` as chunks complete (plus a CSV
    view via `to_csv`), so a crashed sweep loses only uncommitted work —
    at most one chunk on the synchronous backends, at most the in-flight
    superbatches (a few chunks of lookahead) on the pipeline;
  * an append-only ``checkpoint.jsonl`` records every finished chunk keyed
    on the sweep-spec fingerprint and a hash of the chunk's point keys (the
    same identity scheme as `PredictionCache`); `run(resume=True)` skips
    checkpointed chunks with **zero re-evaluation** and drops partial rows
    from an interrupted chunk.

Workload semantics (training step time vs prefill+decode serving) come from
the scenario registry in `repro.core.scenarios`.  The CLI front-end is
``python -m repro.pathfind sweep [--scenario serving] [--out DIR]
[--resume]``; `benchmarks/sweep_shard.py` measures sharded-vs-single-stream
throughput and asserts resumability.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor, \
    as_completed
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.core import age as age_lib
from repro.core import pathfinder, scenarios, sweepexec, techlib
from repro.core.age import Budgets
from repro.core.parallelism import Strategy
from repro.core.placement import mesh_system
from repro.core.roofline import PPEConfig
# JSONL reader/writer semantics live in the shared executor-service core
# (repro.core.sweepexec) so the local and fabric frontends cannot diverge;
# re-exported here because they predate that split and are imported widely.
from repro.core.sweepexec import iter_jsonl as _iter_jsonl  # noqa: F401
from repro.core.sweepexec import json_safe  # noqa: F401

SPEC_VERSION = 1


# ---------------------------------------------------------------------------
# Sweep specification (fully serializable — the resume identity)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Everything that determines a sweep's point set, JSON-serializable.

    The fingerprint of the canonical JSON form keys the checkpoint: a
    resumed run must present the identical spec, and any change to the
    enumerated cross-product changes the per-chunk hashes too.
    """

    arches: Tuple[str, ...]
    mesh_shapes: Tuple[Tuple[int, ...], ...]
    # scenario may be passed as a `scenarios.ScenarioSpec`; __post_init__
    # normalizes it into the serialized (name, cells, slo_s, params) form
    scenario: str = "train"
    cells: Tuple[str, ...] = ()            # scenario cell override
    logic_nodes: Tuple[str, ...] = ("N7",)
    hbms: Tuple[str, ...] = ("HBM2E",)
    nets: Tuple[str, ...] = ("IB-NDR-X8",)
    budget_scales: Tuple[float, ...] = (1.0,)
    area_mm2: Optional[float] = None
    power_w: Optional[float] = None
    slo_s: Optional[float] = None
    n_tilings: int = 8
    chunk_size: int = 32
    # embedded calibration profile dict (repro.calibrate.profiles) — part
    # of the spec so the fingerprint (= resume identity) changes with the
    # calibration; None keys byte-identical specs to pre-profile sweeps
    profile: Optional[Dict] = None
    # typed scenario params (`scenarios.ScenarioSpec.params`); list-valued
    # entries are sweep axes.  None is dropped from the serialized form so
    # param-less specs fingerprint byte-identically to pre-PR6 checkpoints
    scenario_params: Optional[Dict] = None
    # composed Pareto objective set (`repro.core.objectives` names /
    # aliases); None = scenario defaults, dropped from the serialized form
    # so objective-less specs fingerprint byte-identically to pre-PR8
    # checkpoints
    objectives: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if isinstance(self.scenario, scenarios.ScenarioSpec):
            ss = self.scenario
            object.__setattr__(self, "scenario", ss.name)
            if ss.cells:
                object.__setattr__(self, "cells", tuple(ss.cells))
            if ss.slo_s is not None:
                object.__setattr__(self, "slo_s", float(ss.slo_s))
            if ss.params:
                object.__setattr__(
                    self, "scenario_params",
                    {k: (list(v) if isinstance(v, tuple) else v)
                     for k, v in ss.params})
            if ss.objectives is not None:
                object.__setattr__(self, "objectives",
                                   tuple(ss.objectives))
        if self.objectives is not None:
            object.__setattr__(self, "objectives",
                               tuple(str(o) for o in self.objectives))

    @property
    def scenario_spec(self) -> scenarios.ScenarioSpec:
        """The typed scenario-construction view of this spec."""
        return scenarios.ScenarioSpec(
            name=self.scenario, cells=self.cells, slo_s=self.slo_s,
            params=self.scenario_params or (),
            objectives=self.objectives)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["mesh_shapes"] = [list(m) for m in self.mesh_shapes]
        for k in ("arches", "cells", "logic_nodes", "hbms", "nets",
                  "budget_scales"):
            d[k] = list(d[k])
        if d.get("profile") is None:      # keep old fingerprints stable
            d.pop("profile", None)
        sp = d.get("scenario_params")
        if sp is None:                    # ditto for pre-PR6 checkpoints
            d.pop("scenario_params", None)
        else:
            d["scenario_params"] = {
                k: (list(v) if isinstance(v, (list, tuple)) else v)
                for k, v in sp.items()}
        if d.get("objectives") is None:   # ditto for pre-PR8 checkpoints
            d.pop("objectives", None)
        else:
            d["objectives"] = list(d["objectives"])
        return d

    @staticmethod
    def from_dict(d: Dict) -> "SweepSpec":
        d = dict(d)
        d["arches"] = tuple(d["arches"])
        d["mesh_shapes"] = tuple(tuple(int(x) for x in m)
                                 for m in d["mesh_shapes"])
        for k in ("cells", "logic_nodes", "hbms", "nets"):
            d[k] = tuple(d.get(k) or ())
        d["budget_scales"] = tuple(float(s)
                                   for s in d.get("budget_scales") or (1.0,))
        d.setdefault("profile", None)
        d.setdefault("scenario_params", None)
        d.setdefault("objectives", None)
        if d["objectives"] is not None:
            d["objectives"] = tuple(d["objectives"])
        return SweepSpec(**d)

    def fingerprint(self) -> str:
        blob = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def resolved_arches(self) -> Tuple[str, ...]:
        out: List[str] = []
        for a in self.arches:
            if a == "all":
                out.extend(ARCH_IDS)
            else:
                out.append(a)
        return tuple(dict.fromkeys(out))

    def budgets(self, scale: float = 1.0) -> Budgets:
        b = Budgets.default()
        if self.area_mm2 is not None:
            b = dataclasses.replace(b, proc_chip_area_mm2=self.area_mm2)
        if self.power_w is not None:
            b = dataclasses.replace(b, power_w=self.power_w)
        if scale != 1.0:
            b = dataclasses.replace(
                b, power_w=b.power_w * scale,
                proc_chip_area_mm2=b.proc_chip_area_mm2 * scale,
                node_area_mm2=b.node_area_mm2 * scale)
        return b


@dataclasses.dataclass(frozen=True)
class PointLabel:
    """One enumerated design point, strings-only (checkpointable)."""

    arch: str
    cell: str                       # cell name, or "prefill+decode" pair id
    mesh: Tuple[int, ...]
    logic: str
    hbm: str
    net: str
    scale: float
    strategy: str                   # Strategy.name notation

    def key(self) -> str:
        return scenarios.point_key(self.arch, self.cell, self.mesh,
                                   self.logic, self.hbm, self.net,
                                   self.scale, self.strategy)


@dataclasses.dataclass(frozen=True)
class Chunk:
    index: int
    labels: Tuple[PointLabel, ...]

    def hash(self, spec_fp: str) -> str:
        blob = spec_fp + ":" + str(self.index) + ":" + \
            ",".join(lb.key() for lb in self.labels)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]


def scenario_for(spec: SweepSpec, cell_id: str) -> scenarios.Scenario:
    """The scenario instance scoring one enumerated cell id of a spec
    (cells plus any swept scenario-param overrides carried in the cell
    id's ``@k=v,...`` variant suffix)."""
    return spec.scenario_spec.for_cell_id(cell_id).resolve()


_scenario_for = scenario_for


def enumerate_labels(spec: SweepSpec) -> List[PointLabel]:
    """Deterministic cross-product of the sweep axes.

    Strategy candidates come from `planner.candidate_strategies` on the
    scenario's primary (last) cell, so the point set matches what the
    runtime can realize on each mesh.  A train-kind scenario with several
    `spec.cells` sweeps each cell as its own axis value (serving scenarios
    consume their cell pair as one unit); list-valued scenario params
    expand into variants whose cell ids carry the swept values as a
    ``@k=v,...`` suffix.
    """
    from repro.configs.base import SHAPE_CELLS
    from repro.core import planner

    base = scenarios.ScenarioSpec(name=spec.scenario).resolve()
    if isinstance(base, scenarios.TrainScenario) and len(spec.cells) > 1:
        variants = [scenarios.ScenarioSpec(name=spec.scenario,
                                           cells=(c,)).resolve()
                    for c in spec.cells]
    else:
        variants = [v.resolve() for v in spec.scenario_spec.variants()]
    labels: List[PointLabel] = []
    for arch in spec.resolved_arches():
        cfg = get_config(arch)
        for scn in variants:
            if not scn.applicable(cfg):
                continue
            primary = SHAPE_CELLS[scn.cells(cfg)[-1]]
            cell_id = scn.cell_id()
            for mesh in spec.mesh_shapes:
                for st in planner.candidate_strategies(cfg, primary,
                                                       tuple(mesh)):
                    for logic in spec.logic_nodes:
                        for hbm in spec.hbms:
                            for net in spec.nets:
                                for scale in spec.budget_scales:
                                    labels.append(PointLabel(
                                        arch=arch, cell=cell_id,
                                        mesh=tuple(mesh), logic=logic,
                                        hbm=hbm, net=net,
                                        scale=float(scale),
                                        strategy=st.name))
    return labels


def make_chunks(labels: Sequence[PointLabel], size: int) -> List[Chunk]:
    size = max(int(size), 1)
    return [Chunk(i // size, tuple(labels[i:i + size]))
            for i in range(0, len(labels), size)]


def order_chunks(chunks: Sequence[Chunk],
                 scores: Mapping[int, float]) -> List[Chunk]:
    """Schedule-only reordering: highest score first, index tie-break.

    Chunk identities (index, labels, hash) are untouched, so spec
    fingerprints, checkpoint done-lines and resume semantics cannot
    change — only the order work is *attempted* in (the surrogate's
    acquisition ranking feeds this).  Unscored / non-finite-scored
    chunks sort last, in index order; exact score ties fall back to
    index order, so a permutation of equal-scored inputs cannot change
    the output.
    """
    def key(c: Chunk):
        s = scores.get(c.index)
        if s is None or not np.isfinite(s):
            return (1, 0.0, c.index)
        return (0, -float(s), c.index)
    return sorted(chunks, key=key)


# ---------------------------------------------------------------------------
# Chunk evaluation (shared by every backend; used by worker processes)
# ---------------------------------------------------------------------------

# AGE'd hardware points are immutable; memoize per process.
_HW_CACHE: Dict[tuple, object] = {}
_HW_LOCK = threading.Lock()


def _profile_key(spec: SweepSpec) -> Optional[str]:
    """Digest of the embedded profile for hardware-cache keys.

    `_hardware` runs once per resolved point, so the digest is memoized
    on the (frozen, but __dict__-carrying) spec instance — re-serializing
    the profile dict per point would put json+sha1 in the hot chunk loop.
    """
    if spec.profile is None:
        return None
    cached = spec.__dict__.get("_profile_digest")
    if cached is None:
        cached = hashlib.sha1(json.dumps(spec.profile, sort_keys=True)
                              .encode()).hexdigest()[:12]
        object.__setattr__(spec, "_profile_digest", cached)
    return cached


def _hardware(spec: SweepSpec, logic: str, hbm: str, net: str,
              scale: float):
    key = (logic, hbm, net, scale, spec.area_mm2, spec.power_w,
           _profile_key(spec))
    with _HW_LOCK:
        hw = _HW_CACHE.get(key)
    if hw is None:
        tech = techlib.make_tech_config(logic, hbm, net)
        hw = age_lib.generate(tech, spec.budgets(scale))
        if spec.profile is not None:
            from repro.calibrate import profiles as profiles_lib
            hw = profiles_lib.apply_profile(hw, spec.profile)
        with _HW_LOCK:
            hw = _HW_CACHE.setdefault(key, hw)
    return hw


def spec_ppe(spec: SweepSpec) -> PPEConfig:
    """The PPE config a spec's points are scored with: tiling samples from
    the spec, kernel overhead from the embedded calibration profile."""
    ppe = PPEConfig(n_tilings=spec.n_tilings)
    if spec.profile is not None:
        from repro.calibrate import profiles as profiles_lib
        ppe = profiles_lib.ppe_with_profile(ppe, spec.profile)
    return ppe


def resolve_label(spec: SweepSpec, lb: PointLabel) -> scenarios.DesignPoint:
    """Resolve one enumerated label into a live `DesignPoint` (AGE'd
    hardware memoized per process; used by chunk evaluation and by the
    cooptimize refinement engine when re-seeding from sweep records)."""
    return scenarios.DesignPoint(
        arch=lb.arch, cell=lb.cell, mesh=lb.mesh, logic=lb.logic,
        hbm=lb.hbm, net=lb.net, scale=lb.scale,
        strategy=Strategy.parse(lb.strategy), cfg=get_config(lb.arch),
        hw=_hardware(spec, lb.logic, lb.hbm, lb.net, lb.scale),
        system=mesh_system(lb.mesh))


# pmap padding quantum for the device backend: per-skeleton miss counts
# vary chunk to chunk (cache hits, mixed scenarios), so pad each batch to a
# multiple of SHARD_BLOCK x devices and reuse a handful of compiled shapes
# instead of recompiling per distinct count.
SHARD_BLOCK = 8


def _eval_labels_impl(spec: SweepSpec, labels: Sequence[PointLabel],
                      cache=pathfinder.DEFAULT_CACHE,
                      shard_devices: bool = False) -> List[Dict]:
    """Score one chunk of labels -> result records (one batched call).

    The label-mode worker behind `pathfinder.evaluate` (the documented
    entry point).  ``cache`` defaults to the `pathfinder.DEFAULT_CACHE`
    sentinel, which resolves the live prediction cache at CALL time — an
    import-time default would pin whatever singleton existed when this
    module loaded, so `pathfinder.set_prediction_cache` replacement would
    silently stop reaching sweeps (regression-tested).  ``cache=None``
    disables caching.
    """
    cache = pathfinder.resolve_cache(cache)
    ppe = spec_ppe(spec)
    dps, scns, spans = [], [], []
    points: List[pathfinder.EvalPoint] = []
    for lb in labels:
        dp = resolve_label(spec, lb)
        scn = _scenario_for(spec, lb.cell)
        eps = scn.eval_points(dp)
        spans.append((len(points), len(points) + len(eps)))
        points.extend(eps)
        dps.append(dp)
        scns.append(scn)
    rows = pathfinder.evaluate(points=points, ppe=ppe, cache=cache,
                               shard_devices=shard_devices,
                               shard_block=SHARD_BLOCK)
    out = []
    for dp, scn, (lo, hi) in zip(dps, scns, spans):
        rec = scn.record(dp, rows[lo:hi])
        rec["key"] = dp.key()
        out.append(rec)
    return out


def eval_labels(spec: SweepSpec, labels: Sequence[PointLabel],
                cache=pathfinder.DEFAULT_CACHE,
                shard_devices: bool = False) -> List[Dict]:
    """Deprecated alias — use ``pathfinder.evaluate(spec=..., labels=...)``
    (one documented facade over the three historical eval entry points)."""
    import warnings
    warnings.warn("sweeprunner.eval_labels is deprecated; use "
                  "pathfinder.evaluate(spec=..., labels=...)",
                  DeprecationWarning, stacklevel=2)
    return _eval_labels_impl(spec, labels, cache=cache,
                             shard_devices=shard_devices)


def _process_eval(spec_dict: Dict, chunk_index: int,
                  labels: Tuple[PointLabel, ...]) -> Tuple[int, List[Dict]]:
    """Worker-process entry.  The chunk's labels travel with the task
    (plain string dataclasses pickle cheaply) — re-enumerating the whole
    cross-product per chunk would cost O(n_chunks x n_points)."""
    return chunk_index, _eval_labels_impl(SweepSpec.from_dict(spec_dict),
                                          labels)


# ---------------------------------------------------------------------------
# The runner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunStats:
    """What one `SweepRunner.run` call did (resume accounting included).

    ``cache_hits``/``cache_misses`` are this run's prediction-cache delta
    and ``compile_hits``/``compile_misses`` the compiled-evaluator-store
    delta (`pathfinder.compile_cache_stats`), so cache efficacy is visible
    per sweep instead of only as process-lifetime totals.
    ``compile_seconds`` is wall time this run spent inside XLA
    lower+compile (wherever it ran — compile-ahead service threads or the
    dispatch path) and ``stall_seconds`` the part that actually blocked
    evaluation (the device stage waiting on a compile); a healthy
    compile-ahead run shows compile_seconds > 0 with stall_seconds near 0.
    In frontier mode (``frontier_only``) ``records`` holds just the
    surviving Pareto frontier and ``n_frontier_overflowed`` counts
    candidates the bounded device-resident state had to drop (0 = the
    frontier is exact).
    """

    n_points_total: int
    n_chunks_total: int
    n_chunks_skipped: int
    n_chunks_evaluated: int
    n_points_evaluated: int
    elapsed_s: float
    backend: str
    out_dir: Optional[str]
    records: Optional[List[Dict]] = None
    cache_hits: int = 0
    cache_misses: int = 0
    compile_hits: int = 0
    compile_misses: int = 0
    compile_seconds: float = 0.0
    stall_seconds: float = 0.0
    frontier_only: bool = False
    n_frontier_overflowed: int = 0

    @property
    def complete(self) -> bool:
        return (self.n_chunks_skipped + self.n_chunks_evaluated
                == self.n_chunks_total)


def pick_backend(backend: str = "auto") -> str:
    """``auto`` resolves to the pipelined executor: it shards across every
    local JAX device internally AND overlaps host packing / device compute
    / JSONL commits, so it subsumes both previous auto choices (the
    ``device`` pmap fan-out and the ``thread`` pool)."""
    if backend != "auto":
        return backend
    return "pipeline"


def enable_compilation_cache(cache_dir: str) -> bool:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Compiled XLA executables are serialized to disk and reloaded by later
    processes, so CLI cold starts and ``--resume`` invocations skip the
    multi-second per-skeleton compiles (trace time is not cached — only
    the XLA compile).  The setting is process-global and sticky: if a
    cache dir is already configured (by the user or an earlier sweep in
    this process) it is left alone and False is returned.
    """
    import jax
    if jax.config.jax_compilation_cache_dir:
        return False
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    return True


class SweepRunner:
    """Chunked, fanned-out, checkpointed executor for one `SweepSpec`.

    Layout of ``out_dir`` (all appends flushed per chunk):

      spec.json         {"version", "fingerprint", "spec": {...}}
      results.jsonl     one record per design point, tagged with its chunk
      checkpoint.jsonl  one line per *finished* chunk: {"chunk","hash","n"}

    The done-line is written after the chunk's rows, so a crash can only
    leave rows from an unfinished chunk behind; resume compacts them away
    before continuing.
    """

    def __init__(self, spec: SweepSpec, out_dir: Optional[str] = None,
                 backend: str = "auto", workers: Optional[int] = None,
                 cache=pathfinder.DEFAULT_CACHE,
                 compile_cache: bool = False,
                 superbatch: Optional[int] = None,
                 compile_ahead: Optional[int] = None,
                 bucketing: Optional[bool] = None):
        self.spec = spec
        self.out_dir = out_dir
        self.backend = pick_backend(backend)
        self.workers = workers or min(4, os.cpu_count() or 1)
        # DEFAULT_CACHE sentinel: resolve the live singleton at call time
        # (an import-time `pathfinder.prediction_cache()` default froze
        # the cache object at module load — see eval_labels)
        self.cache = pathfinder.resolve_cache(cache)
        # opt-in persistent XLA compilation cache under out_dir (the CLI
        # enables it): resumed / repeated sweeps skip cold compiles
        self.compile_cache = compile_cache
        self.superbatch = superbatch
        # compile-ahead lookahead depth / cross-design bucketing (None =
        # module defaults; execution-only knobs — no effect on chunk
        # hashes, point keys, records, or resume)
        self.compile_ahead = compile_ahead
        self.bucketing = bucketing
        self._fp = spec.fingerprint()

    # -- persistence ------------------------------------------------------
    @staticmethod
    def from_dir(out_dir: str, **kwargs) -> "SweepRunner":
        """Rebuild a runner from a previous run's spec.json (CLI --resume
        does this, so a resumed sweep needs no re-specified axes)."""
        with open(os.path.join(out_dir, "spec.json")) as fh:
            head = json.load(fh)
        spec = SweepSpec.from_dict(head["spec"])
        return SweepRunner(spec, out_dir=out_dir, **kwargs)

    def _paths(self):
        d = self.out_dir
        return (os.path.join(d, "spec.json"),
                os.path.join(d, "results.jsonl"),
                os.path.join(d, "checkpoint.jsonl"))

    def _write_spec(self, spec_path: str):
        sweepexec.write_spec_head(spec_path, SPEC_VERSION, self._fp,
                                  self.spec.to_dict())

    def _journal(self) -> sweepexec.ChunkJournal:
        _, res_path, ckpt_path = self._paths()
        return sweepexec.ChunkJournal(res_path, ckpt_path)

    def _load_done(self, spec_path: str, ckpt_path: str,
                   chunks: List[Chunk]) -> Dict[int, str]:
        """Finished chunks from a previous run, hash-verified against the
        current enumeration (a stale/corrupt line is just re-evaluated)."""
        sweepexec.check_fingerprint(spec_path, self._fp)
        return sweepexec.ChunkJournal("", ckpt_path).load_done(
            chunks, self._fp)

    def _compact_results(self, res_path: str, done: Dict[int, str]):
        """Drop rows from unfinished chunks (crash between row append and
        done-line append) so resumed output has no duplicates."""
        sweepexec.ChunkJournal(res_path, "").compact(done)

    def read_results(self) -> List[Dict]:
        """All records currently streamed to results.jsonl."""
        _, res_path, _ = self._paths()
        return list(_iter_jsonl(res_path))

    # -- execution --------------------------------------------------------
    def _stat_snapshot(self) -> Tuple[Dict, Dict]:
        cache_stats = self.cache.stats if self.cache is not None \
            else {"hits": 0, "misses": 0}
        return cache_stats, pathfinder.compile_cache_stats()

    def _stat_delta(self, before: Tuple[Dict, Dict]) -> Dict[str, float]:
        c0, k0 = before
        c1, k1 = self._stat_snapshot()
        return {"cache_hits": c1["hits"] - c0["hits"],
                "cache_misses": c1["misses"] - c0["misses"],
                "compile_hits": k1["hits"] - k0["hits"],
                "compile_misses": k1["misses"] - k0["misses"],
                "compile_seconds": k1.get("compile_seconds", 0.0)
                - k0.get("compile_seconds", 0.0),
                "stall_seconds": k1.get("stall_seconds", 0.0)
                - k0.get("stall_seconds", 0.0)}

    def run(self, resume: bool = False, max_chunks: Optional[int] = None,
            collect: bool = True, verbose: bool = False,
            frontier_only: bool = False,
            frontier_capacity: int = pathfinder.FRONTIER_CAPACITY
            ) -> RunStats:
        """Execute (or continue) the sweep.

        resume      skip chunks recorded in checkpoint.jsonl (zero
                    re-evaluation); requires the identical spec.
        max_chunks  stop after N chunks (benchmarks/tests simulate an
                    interrupted sweep with this).
        collect     return the accumulated records on RunStats.records.
        frontier_only
                    device-resident streaming-Pareto mode: per-point rows
                    never materialize on host; RunStats.records holds only
                    the frontier (written to DIR/frontier.jsonl, no
                    results/checkpoint stream, incompatible with resume).
        """
        if self.compile_cache and self.out_dir is not None:
            enable_compilation_cache(os.path.join(self.out_dir,
                                                  "xla_cache"))
        if frontier_only:
            return self._run_frontier(max_chunks=max_chunks,
                                      capacity=frontier_capacity,
                                      resume=resume)
        t0 = time.perf_counter()
        stats0 = self._stat_snapshot()
        labels = enumerate_labels(self.spec)
        chunks = make_chunks(labels, self.spec.chunk_size)
        done: Dict[int, str] = {}
        journal: Optional[sweepexec.ChunkJournal] = None
        memory_rows: List[Dict] = []

        if self.out_dir is not None:
            os.makedirs(self.out_dir, exist_ok=True)
            spec_path, res_path, ckpt_path = self._paths()
            if resume:
                done = self._load_done(spec_path, ckpt_path, chunks)
                self._compact_results(res_path, done)
            elif os.path.exists(ckpt_path):
                # never silently destroy a previous sweep's checkpoints: a
                # forgotten --resume must not cost hours of finished chunks
                raise FileExistsError(
                    f"{self.out_dir} already holds a checkpointed sweep; "
                    f"pass resume=True (CLI: --resume) to continue it, or "
                    f"point --out at a fresh directory")
            self._write_spec(spec_path)
            journal = self._journal().open()
        elif resume:
            raise ValueError("resume=True requires an out_dir")

        pending = [c for c in chunks if c.index not in done]
        if max_chunks is not None:
            pending = pending[:max_chunks]

        n_eval_points = 0

        def commit(chunk: Chunk, records: List[Dict]):
            nonlocal n_eval_points
            n_eval_points += len(records)
            if journal is not None:
                journal.commit(chunk.index, chunk.hash(self._fp), records)
            else:
                memory_rows.extend(records)
            if verbose:
                print(f"# chunk {chunk.index} done "
                      f"({len(records)} points)", flush=True)

        try:
            self._execute(pending, commit)
        finally:
            if journal is not None:
                journal.close()

        records: Optional[List[Dict]] = None
        if collect:
            if self.out_dir is not None:
                records = [{k: v for k, v in r.items() if k != "chunk"}
                           for r in self.read_results()]
            else:
                records = memory_rows
        return RunStats(
            n_points_total=len(labels), n_chunks_total=len(chunks),
            n_chunks_skipped=len(done), n_chunks_evaluated=len(pending),
            n_points_evaluated=n_eval_points,
            elapsed_s=time.perf_counter() - t0, backend=self.backend,
            out_dir=self.out_dir, records=records,
            **self._stat_delta(stats0))

    def _frontier_state_path(self) -> str:
        return os.path.join(self.out_dir, "frontier_state.npz")

    def _save_frontier_state(self, path: str, state, done: Dict[int, str],
                             capacity: int):
        """Atomically persist the carried frontier state plus the set of
        merged (committed) chunks — THE frontier-mode checkpoint.  Written
        after every committed superbatch, so a SIGKILL loses at most the
        in-flight packs and `run(resume=True)` continues from the merged
        state with zero re-evaluation (the chunked-sweep semantics)."""
        sweepexec.save_frontier_state(path, state, done, capacity,
                                      self._fp)

    def _load_frontier_state(self, spec_path: str, state_path: str,
                             ckpt_path: str, chunks: List[Chunk],
                             capacity: int):
        """(carried state, done chunks) of an interrupted frontier sweep.

        Unlike `_load_done`, a mismatched chunk is fatal rather than
        re-evaluated: its points are already folded into the carried state
        and cannot be dropped again."""
        if os.path.exists(ckpt_path):
            raise ValueError(
                f"{self.out_dir} holds a full-sweep checkpoint, not a "
                f"frontier-state checkpoint; resume it without "
                f"--frontier-only, or point --out at a fresh directory")
        sweepexec.check_fingerprint(spec_path, self._fp)
        if not os.path.exists(state_path):
            return None, {}             # spec written, nothing merged yet
        return sweepexec.load_frontier_state(state_path, self._fp,
                                             capacity, chunks)

    def _run_frontier(self, max_chunks: Optional[int], capacity: int,
                      resume: bool) -> RunStats:
        """Frontier-only mode: stream every point through the fused
        device-resident Pareto reduction; only the surviving records come
        back to host (DIR/frontier.jsonl when an out_dir is set).  The
        carried state checkpoints to DIR/frontier_state.npz per committed
        superbatch, so an interrupted frontier sweep resumes with zero
        re-evaluation."""
        from repro.core import sweeppipeline
        t0 = time.perf_counter()
        stats0 = self._stat_snapshot()
        labels = enumerate_labels(self.spec)
        chunks = make_chunks(labels, self.spec.chunk_size)
        state0 = None
        done: Dict[int, str] = {}
        state_path = None
        if self.out_dir is not None:
            # validate the destination BEFORE evaluating anything: a
            # guard that fires after the sweep would discard hours of
            # frontier compute
            spec_path, _, ckpt_path = self._paths()
            state_path = self._frontier_state_path()
            if resume:
                state0, done = self._load_frontier_state(
                    spec_path, state_path, ckpt_path, chunks, capacity)
            else:
                os.makedirs(self.out_dir, exist_ok=True)
                if os.path.exists(ckpt_path):
                    raise FileExistsError(
                        f"{self.out_dir} already holds a checkpointed "
                        f"sweep; frontier-only output would shadow it — "
                        f"point --out at a fresh directory")
                if os.path.exists(state_path):
                    raise FileExistsError(
                        f"{self.out_dir} already holds a frontier-state "
                        f"checkpoint; pass resume=True (CLI: --resume) to "
                        f"continue it, or point --out at a fresh "
                        f"directory")
            self._write_spec(spec_path)
        elif resume:
            raise ValueError("resume=True requires an out_dir")
        pending = [c for c in chunks if c.index not in done]
        if max_chunks is not None:
            pending = pending[:max_chunks]
        ex = sweeppipeline.PipelineExecutor(self.spec, cache=self.cache,
                                            superbatch=self.superbatch
                                            or sweeppipeline.SUPERBATCH,
                                            compile_ahead=self.compile_ahead,
                                            bucketing=self.bucketing)
        on_commit = None
        if state_path is not None:
            committed = dict(done)
            by_index = {c.index: c for c in chunks}

            def on_commit(indices, host_state):
                for i in indices:
                    committed[i] = by_index[i].hash(self._fp)
                self._save_frontier_state(state_path, host_state,
                                          committed, capacity)
        records, n_over, n_points = ex.run_frontier(
            pending, capacity=capacity, state=state0, on_commit=on_commit,
            all_chunks=chunks)
        if self.out_dir is not None:
            front_path = os.path.join(self.out_dir, "frontier.jsonl")
            tmp = front_path + ".tmp"
            with open(tmp, "w") as fh:
                for rec in records:
                    fh.write(json.dumps(json_safe(rec)) + "\n")
            os.replace(tmp, front_path)
        return RunStats(
            n_points_total=len(labels), n_chunks_total=len(chunks),
            n_chunks_skipped=len(done), n_chunks_evaluated=len(pending),
            n_points_evaluated=n_points,
            elapsed_s=time.perf_counter() - t0, backend="pipeline",
            out_dir=self.out_dir, records=records,
            frontier_only=True, n_frontier_overflowed=n_over,
            **self._stat_delta(stats0))

    def _execute(self, pending: List[Chunk], commit):
        from repro.core import compileahead
        spec = self.spec
        if self.backend == "pipeline":
            from repro.core import sweeppipeline
            ex = sweeppipeline.PipelineExecutor(
                spec, cache=self.cache,
                superbatch=self.superbatch or sweeppipeline.SUPERBATCH,
                compile_ahead=self.compile_ahead, bucketing=self.bucketing)
            ex.run(pending, commit)
        elif self.backend in ("serial", "device"):
            shard = self.backend == "device"
            # the synchronous backends evaluate through BatchedEvaluator,
            # which honors the process-wide bucketing default — scope an
            # explicit runner-level override around the run
            scoped = self.bucketing is not None
            prev = compileahead.set_bucketing_default(self.bucketing) \
                if scoped else None
            try:
                for c in pending:
                    commit(c, _eval_labels_impl(spec, c.labels,
                                                cache=self.cache,
                                                shard_devices=shard))
            finally:
                if scoped:
                    compileahead.set_bucketing_default(prev)
        elif self.backend == "thread":
            with ThreadPoolExecutor(self.workers) as ex:
                futs = {ex.submit(_eval_labels_impl, spec, c.labels,
                                  self.cache): c
                        for c in pending}
                for f in as_completed(futs):
                    commit(futs[f], f.result())
        elif self.backend == "process":
            import multiprocessing as mp
            ctx = mp.get_context("spawn")     # fork deadlocks under JAX
            spec_dict = spec.to_dict()
            by_index = {c.index: c for c in pending}
            with ProcessPoolExecutor(self.workers, mp_context=ctx) as ex:
                futs = [ex.submit(_process_eval, spec_dict, c.index,
                                  c.labels)
                        for c in pending]
                for f in as_completed(futs):
                    idx, records = f.result()
                    commit(by_index[idx], records)
        else:
            raise ValueError(f"unknown backend {self.backend!r}; expected "
                             "pipeline|serial|thread|process|device|auto")


# ---------------------------------------------------------------------------
# Output helpers
# ---------------------------------------------------------------------------

LABEL_FIELDS = ("arch", "cell", "mesh", "logic", "hbm", "net", "scale",
                "strategy", "devices")


def label_from_record(rec: Dict) -> PointLabel:
    """Rebuild the enumerated `PointLabel` of one result record (the
    inverse of `DesignPoint.label_fields`); `repro.core.cooptimize` uses
    this to re-resolve frontier records into live design points."""
    return PointLabel(
        arch=str(rec["arch"]), cell=str(rec["cell"]),
        mesh=tuple(int(x) for x in str(rec["mesh"]).split("x")),
        logic=str(rec["logic"]), hbm=str(rec["hbm"]), net=str(rec["net"]),
        scale=float(rec["scale"]), strategy=str(rec["strategy"]))


def load_sweep(out_dir: str) -> Tuple[SweepSpec, List[Dict]]:
    """Load a checkpointed sweep's (spec, finished-chunk records).

    Only rows belonging to hash-verified finished chunks are returned (a
    crash-torn partial chunk is dropped exactly as `run(resume=True)`
    would), so consumers like ``pathfind cooptimize --from DIR`` seed from
    already-scored points with zero re-evaluation.
    """
    runner = SweepRunner.from_dir(out_dir, backend="serial")
    spec_path, res_path, ckpt_path = runner._paths()
    chunks = make_chunks(enumerate_labels(runner.spec),
                         runner.spec.chunk_size)
    done = runner._load_done(spec_path, ckpt_path, chunks)
    records = [{k: v for k, v in rec.items() if k != "chunk"}
               for rec in _iter_jsonl(res_path)
               if rec.get("chunk") in done]
    return runner.spec, records


def csv_fields(scenario: scenarios.Scenario) -> Tuple[str, ...]:
    return LABEL_FIELDS + tuple(scenario.fields)


def to_csv(records: Sequence[Dict], scenario: scenarios.Scenario) -> str:
    fields = csv_fields(scenario)

    def fmt(v):
        if isinstance(v, bool) or v is None:
            return str(v)
        if isinstance(v, float):
            return f"{v:.6e}" if (v and abs(v) < 1e-2) else f"{v:g}"
        return str(v)

    lines = [",".join(fields)]
    for r in records:
        lines.append(",".join(fmt(r.get(f)) for f in fields))
    return "\n".join(lines)


def pareto_records(records: Sequence[Dict],
                   objectives: Sequence[str]) -> List[Dict]:
    """Non-dominated subset of result records over numeric objective
    fields, in input order.

    Infeasible serving points (``feasible: false``), SLO-wall violations
    (``slo_ok: false`` — percentile SLOs are feasibility walls, matching
    the scenarios' `objective_values`/`frontier_fold`), and records whose
    objective values are missing/None (what `json_safe` writes for
    non-finite metrics) or non-finite are excluded up front — an unusable
    design can otherwise survive the frontier on its one finite objective
    (e.g. best TTFT with infinite cost).  The dominance check is a sorted
    incremental skyline over NumPy rows (each candidate is compared only
    against the running frontier, which transitivity makes sufficient), so
    runner-scale record sets (10^4-10^6 points) do not pay the O(n^2)
    pure-Python loop of `pathfinder.pareto_front`.

    Tie semantics: records exactly equal on ALL objectives do not dominate
    each other — every copy of a non-dominated point is kept, and the
    result order (input order) is deterministic regardless of how the
    lexsort breaks ties.  Regression tests pin this contract to
    `pathfinder.pareto_front`.

    Objective directions come from the `repro.core.objectives` registry:
    max-direction objectives (goodput) are sign-flipped into canonical
    minimizing space before the skyline.  The default all-minimizing path
    is untouched (records never multiply by the +1 signs).
    """
    from repro.core import objectives as objectives_lib
    signs = objectives_lib.canonical_signs(objectives)

    def objvals(r) -> Optional[List[float]]:
        try:
            vs = [float(r[k]) for k in objectives]
        except (KeyError, TypeError, ValueError):
            return None
        return vs if all(np.isfinite(v) for v in vs) else None

    recs, rows = [], []
    for r in records:
        if not r.get("feasible", True) or r.get("slo_ok") is False:
            continue
        vs = objvals(r)
        if vs is not None:
            recs.append(r)
            rows.append(vs)
    if not recs:
        return []
    vals = np.asarray(rows, dtype=np.float64)
    if any(s < 0 for s in signs):
        vals = vals * np.asarray(signs, dtype=np.float64)
    order = np.lexsort(vals.T[::-1])       # by first objective, then rest
    front = np.empty((0, vals.shape[1]))
    keep: List[int] = []
    for i in order:
        v = vals[i]
        if front.size and bool(np.any(
                np.all(front <= v, axis=1) & np.any(front < v, axis=1))):
            continue
        keep.append(int(i))
        front = np.vstack([front, v])
    return [recs[i] for i in sorted(keep)]
