"""Device mapping and routing engine (DeepFlow paper §5.2).

Maps the transformed (super-)graph onto the *system graph* — a (possibly
hierarchical) torus of hardware nodes — and derives the effective bandwidth
of every communication operation:

  * greedy dimension-ordered mapping: walk the parallel dims in a chosen
    order, laying shards onto adjacent hardware nodes, wrapping around to the
    next torus dim when one fills; all permutations of the parallel dims are
    tried and the best (lowest estimated comm cost) is kept (paper: 4! = 24);
  * X-Y (dimension-ordered) routing to map logical edges to physical paths;
  * link sharing: a physical link shared by E logical edges has its
    bandwidth derated by E (paper §6.4).

Collectives are modelled as ring algorithms along their parallel axis (the
paper's DP/KP transformation wires rings/tori), with per-hop distance taken
from the mapping: time(allreduce, S, p) = 2 (p-1)/p * S / bw_eff + lat terms.

Batched-engine integration (post-PR-1): `place()` and the mapping search
run host-side NumPy ONCE per skeleton, but `comm_time` /
`Placement.effective_bw` are pure arithmetic in the MicroArch's numeric
leaves — they are traced inside `pathfinder.BatchedEvaluator`'s
`jax.jit(jax.vmap(...))` (and `jax.pmap` in `evaluate_matrix`), so one
placement serves thousands of vmapped hardware points and stays
differentiable for the SOE's exact gradients.  `SystemGraph` is frozen /
hashable because it is part of the compiled-skeleton and prediction-cache
keys.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.age import MicroArch
from repro.core.parallelism import Strategy


@dataclasses.dataclass(frozen=True)
class SystemGraph:
    """A hierarchical torus: `dims` per-level node counts (innermost last).

    `level_of_dim` tags each torus dim with the network level that its links
    belong to: "intra" (in-package / ICI) or "inter" (between packages / DCN).
    """

    dims: Tuple[int, ...] = (16, 16)
    levels: Tuple[str, ...] = ("inter", "inter")

    @property
    def n_nodes(self) -> int:
        out = 1
        for d in self.dims:
            out *= d
        return out

    def coords(self, rank: int) -> Tuple[int, ...]:
        cs = []
        for d in reversed(self.dims):
            cs.append(rank % d)
            rank //= d
        return tuple(reversed(cs))

    def torus_distance(self, a: int, b: int) -> int:
        ca, cb = self.coords(a), self.coords(b)
        hops = 0
        for x, y, d in zip(ca, cb, self.dims):
            delta = abs(x - y)
            hops += min(delta, d - delta)
        return hops


def single_pod_system(side: int = 16) -> SystemGraph:
    return SystemGraph(dims=(side, side), levels=("inter", "inter"))


def multi_pod_system(pods: int = 2, side: int = 16) -> SystemGraph:
    return SystemGraph(dims=(pods, side, side),
                       levels=("pod", "inter", "inter"))


def mesh_system(mesh_shape: Tuple[int, ...]) -> SystemGraph:
    """SystemGraph for a runtime mesh shape: 3-d meshes are multi-pod
    (pod dim on the slower fabric); 1-/2-d meshes are tori of the same
    dims, so the system node count always equals the mesh device count."""
    if len(mesh_shape) == 3:
        return SystemGraph(dims=tuple(mesh_shape),
                           levels=("pod", "inter", "inter"))
    return SystemGraph(dims=tuple(mesh_shape),
                       levels=("inter",) * len(mesh_shape))


@dataclasses.dataclass
class AxisMapping:
    """Where one parallel axis landed in physical space."""

    axis: str
    degree: int
    ring_hop_distance: float        # mean physical hops between ring neighbours
    link_sharing: float             # logical edges per physical link
    level: str                      # "intra" | "inter" | "pod"


@dataclasses.dataclass
class Placement:
    system: SystemGraph
    strategy: Strategy
    order: Tuple[str, ...]
    axis_maps: Dict[str, AxisMapping]

    def effective_bw(self, arch: MicroArch, axis: str,
                     pod_bw: Optional[float] = None) -> Tuple[float, float]:
        """(effective bytes/s per ring direction, per-hop latency) for an axis."""
        am = self.axis_maps[axis]
        if am.level == "pod":
            bw = pod_bw if pod_bw is not None else arch.net_inter_bw * 0.5
            lat = arch.net_inter_latency * 4.0
        elif am.level == "intra":
            bw, lat = arch.net_intra_bw, arch.net_intra_latency
        else:
            bw, lat = arch.net_inter_bw, arch.net_inter_latency
        # wormhole-routed ring: an edge's bandwidth is limited by its most
        # contended link; with stride-s embedding, hop distance == #rings
        # sharing each link, so the derate is max(hop, sharing), not the
        # product (each of `hop` links carries `sharing` edges in parallel).
        derate = max(am.ring_hop_distance, am.link_sharing, 1.0)
        return bw / derate, lat * max(am.ring_hop_distance, 1.0)


_PARALLEL_AXES = ("kp2", "kp1", "dp", "lp")


def _axis_degrees(s: Strategy) -> Dict[str, int]:
    return {"kp2": s.kp2, "kp1": s.kp1, "dp": s.dp, "lp": s.lp}


def _map_order(system: SystemGraph, s: Strategy,
               order: Sequence[str]) -> Dict[str, AxisMapping]:
    """Lay out axes along the linearized torus in `order`; derive per-axis
    ring-neighbour distance and sharing from strides (X-Y routed)."""
    degrees = _axis_degrees(s)
    maps: Dict[str, AxisMapping] = {}
    stride = 1
    for axis in order:
        deg = degrees[axis]
        if deg == 1:
            maps[axis] = AxisMapping(axis, 1, 0.0, 1.0, "inter")
            continue
        # ring neighbours are `stride` ranks apart in the linearization;
        # distance = torus hops between rank 0 and rank `stride`.
        samples = []
        for i in range(min(deg, 8)):
            a = (i * stride) % system.n_nodes
            b = ((i + 1) * stride) % system.n_nodes
            samples.append(system.torus_distance(a, b))
        hop = float(np.mean(samples)) if samples else 1.0
        # multi-hop neighbours force `hop` rings through shared links
        sharing = max(hop, 1.0)
        # which network level carries this axis: the OUTERMOST torus dim
        # the axis occupies decides (links of outer dims are the slower
        # fabric: pod > inter > intra in the hierarchy).
        span = stride * deg
        cums = [1]
        for d in reversed(system.dims):
            cums.append(cums[-1] * d)
        level = "inter"
        for i in range(len(system.dims)):          # i = 0 -> innermost dim
            lo, hi = cums[i], cums[i + 1]
            if stride < hi and span > lo:          # axis overlaps dim i
                level = system.levels[len(system.dims) - 1 - i]
        if level not in ("pod", "inter", "intra"):
            level = "inter"
        maps[axis] = AxisMapping(axis, deg, hop, sharing, level)
        stride *= deg
    # ep/sp reuse the kernel-parallel placement
    kp_map = maps.get("kp1") if s.kp1 >= s.kp2 else maps.get("kp2")
    base = kp_map or AxisMapping("kp", 1, 1.0, 1.0, "inter")
    maps["ep"] = dataclasses.replace(base, axis="ep", degree=max(s.ep, 1))
    maps["sp"] = dataclasses.replace(base, axis="sp", degree=max(s.sp, 1))
    return maps


def _mapping_cost(maps: Dict[str, AxisMapping],
                  traffic_weight: Dict[str, float]) -> float:
    """Estimated comm cost: sum over axes of traffic * derate (for ranking
    the 24 orderings)."""
    cost = 0.0
    for axis, w in traffic_weight.items():
        am = maps.get(axis)
        if am is None or am.degree <= 1:
            continue
        cost += w * max(am.ring_hop_distance, 1.0) * max(am.link_sharing, 1.0)
    return cost


def place(system: SystemGraph, strategy: Strategy,
          traffic_weight: Optional[Dict[str, float]] = None) -> Placement:
    """Greedy mapping, all (<=24) axis orderings tried (paper §5.2)."""
    tw = traffic_weight or {"kp2": 4.0, "kp1": 4.0, "dp": 2.0, "lp": 1.0}
    best: Optional[Tuple[float, Tuple[str, ...], Dict[str, AxisMapping]]] = None
    for order in itertools.permutations(_PARALLEL_AXES):
        maps = _map_order(system, strategy, order)
        cost = _mapping_cost(maps, tw)
        if best is None or cost < best[0]:
            best = (cost, order, maps)
    assert best is not None
    return Placement(system=system, strategy=strategy, order=best[1],
                     axis_maps=best[2])


# ---------------------------------------------------------------------------
# Collective timing (ring algorithms on the mapped axes)
# ---------------------------------------------------------------------------


def comm_time(arch: MicroArch, placement: Placement, comm: str,
              size_bytes: float, axis: str, participants: int,
              pod_bw: Optional[float] = None, parallel_rings: int = 2):
    """Time one communication op. `size_bytes` is the per-participant payload
    (all-reduce: full gradient buffer; all-gather: the local shard).
    `parallel_rings`: bidirectional torus rings split the payload (NCCL /
    ICI both run >= 2 concurrent rings per axis)."""
    p = max(int(participants), 1)
    if p == 1 or size_bytes <= 0:
        return 0.0
    bw, lat = placement.effective_bw(arch, axis, pod_bw=pod_bw)
    bw = bw * max(parallel_rings, 1)
    steps = p - 1
    if comm == "allreduce":
        vol = 2.0 * steps / p * size_bytes
        return vol / bw + 2.0 * steps * lat
    if comm in ("allgather", "reducescatter"):
        vol = steps / p * size_bytes * p if comm == "allgather" else size_bytes
        # allgather input is the local shard; total received = (p-1)*shard
        vol = steps * size_bytes if comm == "allgather" else \
            steps / p * size_bytes
        return vol / bw + steps * lat
    if comm == "alltoall":
        vol = steps / p * size_bytes
        return vol / bw + steps * lat
    if comm == "p2p":
        return size_bytes / bw + lat
    raise ValueError(comm)
