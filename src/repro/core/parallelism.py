"""Parallelism strategy space (DeepFlow paper §3.3).

A strategy is written ``RC-{KP1}-{KP2}-d{DP}-p{LP}`` or ``CR-{KP1}-d{DP}-p{LP}``:

  * RC (Row-Column / inner-product distributed GEMM): the first matrix is
    sharded KP1 ways across rows (M) and the second KP2 ways across columns
    (N). Each worker owns an (M/KP1, N/KP2) output block and the full
    contraction dim; activations are all-gathered along the torus dims.
  * CR (Column-Row / outer-product): the first matrix is cut KP1 ways across
    columns (K) and the second across rows (K); each worker produces a full
    (M, N) partial product that must be all-reduced.
  * DP: number of model replicas / data shards (ring all-reduce of grads).
  * LP: number of pipeline stages.
  * EP (extension, not in the paper's notation): expert parallelism for MoE
    archs — routed experts sharded EP ways, all-to-all dispatch.
  * SP (extension): sequence sharding for long-context cells.

Total device count = KP1 * KP2 * DP * LP (EP/SP reuse the KP axis).
"""

from __future__ import annotations

import dataclasses
import itertools
import re
from typing import Iterator, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Strategy:
    kind: str = "RC"        # "RC" | "CR"
    kp1: int = 1
    kp2: int = 1            # ignored for CR
    dp: int = 1
    lp: int = 1
    ep: int = 1             # expert parallel degree (<= kp1*kp2)
    sp: int = 1             # sequence parallel degree (<= kp1*kp2)

    def __post_init__(self):
        assert self.kind in ("RC", "CR"), self.kind
        if self.kind == "CR":
            object.__setattr__(self, "kp2", 1)

    @property
    def kp(self) -> int:
        return self.kp1 * self.kp2

    @property
    def devices(self) -> int:
        return self.kp1 * self.kp2 * self.dp * self.lp

    @property
    def name(self) -> str:
        if self.kind == "RC":
            s = f"RC-{self.kp1}-{self.kp2}-d{self.dp}-p{self.lp}"
        else:
            s = f"CR-{self.kp1}-d{self.dp}-p{self.lp}"
        if self.ep > 1:
            s += f"-e{self.ep}"
        if self.sp > 1:
            s += f"-s{self.sp}"
        return s

    @staticmethod
    def parse(text: str) -> "Strategy":
        """Parse the paper's notation, e.g. 'RC-4-2-d3-p2' or 'CR-8-d64-p1'."""
        m = re.fullmatch(
            r"(RC|CR)-(\d+)(?:-(\d+))?-d(\d+)-p(\d+)(?:-e(\d+))?(?:-s(\d+))?",
            text.strip())
        if not m:
            raise ValueError(f"bad strategy spec: {text!r}")
        kind, kp1, kp2, dp, lp, ep, sp = m.groups()
        if kind == "RC" and kp2 is None:
            raise ValueError(f"RC needs two kernel-parallel degrees: {text!r}")
        return Strategy(kind=kind, kp1=int(kp1),
                        kp2=int(kp2 or 1), dp=int(dp), lp=int(lp),
                        ep=int(ep or 1), sp=int(sp or 1))


def _divisors(x: int) -> List[int]:
    out = [d for d in range(1, x + 1) if x % d == 0]
    return out


def enumerate_strategies(n_devices: int,
                         max_lp: int = 8,
                         kinds: Tuple[str, ...] = ("RC", "CR"),
                         allow_ep: bool = False,
                         pow2_only: bool = True) -> Iterator[Strategy]:
    """All factorizations KP1*KP2*DP*LP == n_devices (paper's search space)."""
    degrees = [d for d in _divisors(n_devices)
               if not pow2_only or (d & (d - 1)) == 0]
    for lp in degrees:
        if lp > max_lp:
            continue
        rem1 = n_devices // lp
        for dp in _divisors(rem1):
            if pow2_only and dp & (dp - 1):
                continue
            kp = rem1 // dp
            if "CR" in kinds:
                yield Strategy("CR", kp1=kp, dp=dp, lp=lp)
            if "RC" in kinds:
                for kp1 in _divisors(kp):
                    if pow2_only and kp1 & (kp1 - 1):
                        continue
                    s = Strategy("RC", kp1=kp1, kp2=kp // kp1, dp=dp, lp=lp)
                    yield s
                    if allow_ep and kp > 1:
                        yield dataclasses.replace(s, ep=kp)


def mesh_factorization(strategy: Strategy,
                       mesh_shape: Tuple[int, ...]) -> Optional[dict]:
    """Check a strategy fits a physical mesh; return the axis assignment.

    The runtime mesh exposes ('pod', 'data', 'model') (or ('data','model')).
    DP*LP must cover pod*data and KP must equal the model axis (the planner
    in repro.core.planner relies on this invariant).
    """
    total = 1
    for s in mesh_shape:
        total *= s
    if strategy.devices != total:
        return None
    model = mesh_shape[-1]
    if strategy.kp != model:
        return None
    return {"model": strategy.kp, "data_pipe": strategy.dp * strategy.lp}
