"""Batched pathfinding engine — vectorized design-space sweeps over CrossFlow.

The paper's headline contribution is *automated* exploration of the
technology x hardware x software stack (§7, §9), which only pays off when the
evaluator can score thousands of candidate points cheaply (cf. DFModel,
COSMIC).  The per-point path (`simulate.predict`) walks the compute graph in
eager `jnp`, so a sweep costs O(points x graph-size) Python dispatches.

This module exploits the observation that for a fixed *skeleton* —
(compute graph, parallelism strategy, system graph, PPE config) — the whole
CrossFlow pipeline (AGE -> roofline -> placement -> event-driven sim) is pure
traceable `jax.numpy` code in the MicroArch's numeric leaves.  So:

  * `BatchedEvaluator` stacks MicroArch candidates into a struct-of-arrays
    hardware matrix and scores all of them with ONE `jax.jit(jax.vmap(...))`
    call per skeleton (compiled functions are cached per skeleton);
  * `evaluate_budgets` does the same over SOE budget vectors, batching
    through the differentiable AGE (`age.generate(discrete=False)`);
  * an LRU `PredictionCache` keyed on (graph fingerprint, strategy, system,
    ppe, hardware point) makes repeated points across SOE multi-starts and
    planner calls free;
  * `BatchedEvaluator.evaluate_matrix` is the matrix-native fast path: an
    (N, HW_DIM) struct-of-arrays hardware matrix is scored without building
    per-point MicroArch objects, optionally `jax.pmap`-sharded row-wise
    across every local device (the 10^4-10^6-point sweep regime of
    repro.core.sweeprunner);
  * `sweep` cross-products arches x shape cells x mesh shapes x techlib
    nodes and returns every point plus the Pareto frontier.

`benchmarks/sweep_scale.py` measures the resulting throughput (points/sec)
against the per-point loop on the Fig. 9 tech-scaling sweep;
`benchmarks/sweep_shard.py` measures the sharded matrix path against the
single-stream evaluator.  For chunked, checkpointed, resumable sweeps (and
the serving scenario) see `repro.core.sweeprunner` / `repro.core.scenarios`.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import os
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import age as age_lib
from repro.core import simulate
from repro.core import techlib as techlib_lib
from repro.core.age import Budgets, MicroArch
from repro.core.graph import ComputeGraph
from repro.core.parallelism import Strategy
from repro.core.placement import SystemGraph
from repro.core.roofline import PPEConfig
from repro.core.techlib import TechConfig

# ---------------------------------------------------------------------------
# Struct-of-arrays hardware points
# ---------------------------------------------------------------------------

# The MicroArch leaves the performance model actually consumes.  Everything
# else on MicroArch (n_mcu, link counts, on-chip latencies) is either unused
# by `simulate.predict` or static per technology entry and taken from the
# batch's template arch.
HW_FIELDS: Tuple[str, ...] = (
    "compute_throughput",
    "mem_capacity_l0", "mem_capacity_l1", "mem_capacity_l2",
    "mem_bw_l0", "mem_bw_l1", "mem_bw_l2",
    "dram_capacity", "dram_bw",
    "net_intra_bw", "net_inter_bw",
    "net_intra_latency", "net_inter_latency",
    # energy/cost coefficients for the objective layer
    # (repro.core.objectives).  Appended AFTER the performance leaves so
    # `unpack_hw`'s positional reads — and every persisted payload that
    # slices the first 13 columns — stay valid.
    "energy_per_flop", "dram_energy_per_byte", "net_energy_per_byte",
    "static_power_w", "device_cost_usd",
)
HW_DIM = len(HW_FIELDS)

# columns of the energy/cost coefficient block (ctx keys for objectives)
HW_COEFF_FIELDS: Tuple[str, ...] = HW_FIELDS[13:]


def hw_coeffs(arch: MicroArch) -> Dict[str, object]:
    """Energy/cost coefficients of one hardware point, keyed per HW_FIELDS.

    The single definition shared by `pack_hw` (host floats into the
    struct-of-arrays matrix) and cooptimize's traced refine ctx (jnp
    tracers when the DVFS knobs ride through `arch.tech`): per-flop and
    per-byte dynamic energies, aggregate static power, and device capex
    from the per-tech cost table.  Plain arithmetic — traceable.
    """
    t = arch.tech
    return {
        "energy_per_flop": t.compute.energy_per_flop,
        "dram_energy_per_byte": t.dram.dynamic_energy_per_bit * 8.0,
        "net_energy_per_byte": t.net_inter.nominal_energy_per_bit * 8.0,
        "static_power_w": techlib_lib.static_power_w(
            t, arch.dram_capacity, arch.compute_throughput),
        "device_cost_usd": techlib_lib.device_cost_usd(
            t, arch.dram_capacity),
    }


def hw_ctx(arch: MicroArch) -> Dict[str, object]:
    """Objective-fold hardware ctx for a (possibly traced) MicroArch.

    The refine-path analogue of reading `pack_hw` columns: the hardware
    keys of the `repro.core.objectives` ctx contract, live-valued so
    cooptimize differentiates energy/cost through the DVFS knobs.
    """
    ctx = hw_coeffs(arch)
    ctx["compute_throughput"] = arch.compute_throughput
    ctx["dram_bw"] = arch.dram_bw
    ctx["net_inter_bw"] = arch.net_inter_bw
    ctx["dram_capacity"] = arch.dram_capacity
    return ctx


def pack_hw(arch: MicroArch) -> np.ndarray:
    """Flatten the batchable MicroArch leaves into a (HW_DIM,) f32 vector.

    Host-side (NumPy): packing thousands of points must not pay per-leaf
    JAX dispatch; the batch crosses into JAX once, already stacked.
    """
    coeffs = hw_coeffs(arch)
    return np.asarray([
        float(arch.compute_throughput),
        float(arch.mem_capacity[0]),
        float(arch.mem_capacity[1]),
        float(arch.mem_capacity[2]),
        float(arch.mem_bw[0]),
        float(arch.mem_bw[1]),
        float(arch.mem_bw[2]),
        float(arch.dram_capacity),
        float(arch.dram_bw),
        float(arch.net_intra_bw),
        float(arch.net_inter_bw),
        float(arch.net_intra_latency),
        float(arch.net_inter_latency),
    ] + [float(coeffs[k]) for k in HW_COEFF_FIELDS], dtype=np.float32)


def unpack_hw(template: MicroArch, v) -> MicroArch:
    """Rebuild a MicroArch from a (HW_DIM,) vector; static leaves (tech,
    latencies of on-chip levels, link counts) come from `template`."""
    return dataclasses.replace(
        template,
        compute_throughput=v[0],
        mem_capacity=(v[1], v[2], v[3]),
        mem_bw=(v[4], v[5], v[6]),
        dram_capacity=v[7],
        dram_bw=v[8],
        net_intra_bw=v[9],
        net_inter_bw=v[10],
        net_intra_latency=v[11],
        net_inter_latency=v[12],
    )


def _hw_key(arch: MicroArch) -> bytes:
    """Hashable identity of one hardware point (cache key component)."""
    return pack_hw(arch).tobytes()


# The five timing components one prediction returns (TimeBreakdown order).
METRICS: Tuple[str, ...] = ("total_s", "compute_s", "comm_s",
                            "exposed_comm_s", "pipeline_bubble_s")


def _breakdown_row(bd: simulate.TimeBreakdown) -> np.ndarray:
    return np.asarray([float(bd.total_s), float(bd.compute_s),
                       float(bd.comm_s), float(bd.exposed_comm_s),
                       float(bd.pipeline_bubble_s)], dtype=np.float64)


# ---------------------------------------------------------------------------
# LRU prediction cache
# ---------------------------------------------------------------------------


class PredictionCache:
    """LRU cache of prediction rows keyed on (skeleton, hardware point).

    Thread-safe: the sweep runner (repro.core.sweeprunner) shares one cache
    across worker threads, so all bookkeeping happens under a lock.
    """

    def __init__(self, maxsize: int = 65536):
        self.maxsize = maxsize
        self._data: "collections.OrderedDict" = collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key) -> Optional[np.ndarray]:
        with self._lock:
            row = self._data.get(key)
            if row is None:
                self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return row

    def get_many(self, keys: Sequence) -> List[Optional[np.ndarray]]:
        """Batched lookup: one lock pass for a whole hardware matrix.

        The pipelined sweep executor probes thousands of keys per chunk;
        per-key `get` calls would take and release the lock (and bump the
        LRU bookkeeping) once per point.
        """
        out: List[Optional[np.ndarray]] = []
        with self._lock:
            for key in keys:
                row = self._data.get(key)
                if row is None:
                    self.misses += 1
                else:
                    self._data.move_to_end(key)
                    self.hits += 1
                out.append(row)
        return out

    def put(self, key, row: np.ndarray) -> None:
        with self._lock:
            self._data[key] = row
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)

    def put_many(self, pairs: Sequence[Tuple]) -> None:
        """Batched insert (one lock pass); same LRU semantics as `put`."""
        with self._lock:
            for key, row in pairs:
                self._data[key] = row
                self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.hits = 0
            self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    @property
    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "size": len(self._data)}


_PREDICTION_CACHE = PredictionCache()

# sentinel meaning "use whatever prediction_cache() returns at CALL time".
# A plain `cache=_PREDICTION_CACHE` default would freeze the singleton at
# import time, so replacing the module-level cache (tests, embedding apps)
# would silently leave default-arg callers on the dead object.  `None`
# still means "no cache at all".
DEFAULT_CACHE = object()


def resolve_cache(cache) -> Optional[PredictionCache]:
    """Map the `DEFAULT_CACHE` sentinel to the live singleton (late
    binding); pass real caches and None (= caching disabled) through."""
    return prediction_cache() if cache is DEFAULT_CACHE else cache


def prediction_cache() -> PredictionCache:
    return _PREDICTION_CACHE


def set_prediction_cache(cache: PredictionCache) -> PredictionCache:
    """Replace the process-wide prediction cache (takes effect for every
    default-arg caller immediately — see `DEFAULT_CACHE`)."""
    global _PREDICTION_CACHE
    _PREDICTION_CACHE = cache
    return cache


def cache_stats() -> Dict[str, int]:
    return _PREDICTION_CACHE.stats


def clear_prediction_cache() -> None:
    _PREDICTION_CACHE.clear()


# ---------------------------------------------------------------------------
# Batched evaluator (one skeleton, many hardware points)
# ---------------------------------------------------------------------------

# LRU of jitted per-skeleton evaluation functions.  Each entry captures a
# compiled XLA executable plus the closed-over graph, so unlike the
# lightweight PredictionCache this must stay small and evict.  Guarded by a
# lock so thread-parallel sweep workers get one wrapped function per
# skeleton (jit/pmap wrapping is lazy, so holding the lock is cheap; the
# actual XLA compile happens at first call, outside the lock).
_COMPILED: "collections.OrderedDict[tuple, Callable]" = \
    collections.OrderedDict()
_COMPILED_MAXSIZE = int(os.environ.get("REPRO_COMPILED_MAXSIZE", "128"))
_COMPILED_LOCK = threading.Lock()
# Pin counts per store key.  A pinned entry is never evicted by the LRU
# sweep — the AOT compile service pins a key from the moment it is queued
# until its first dispatch, so an executable compiled off-path can't be
# popped (and silently recompiled on-path) between build and use.  The
# store may transiently exceed maxsize while pins are held.
_COMPILED_PINS: Dict[tuple, int] = {}
# hit/miss counts over EVERY compiled-function store that goes through
# `_compiled_get_or_create` (skeleton evaluators, budget fns, the pipelined
# design/frontier fns).  A miss = one wrapped fn built, i.e. one XLA
# compile per input shape at first call; the sweep runner surfaces the
# per-run delta so compile churn is visible from the CLI summary line.
# `compile_seconds` accumulates wall time spent inside XLA lower+compile
# (wherever it runs: AOT service threads or the dispatch path);
# `stall_seconds` counts only the time a *dispatching* caller was blocked
# waiting for a compile — the number compile-ahead exists to drive to zero.
_COMPILE_STATS = {"hits": 0, "misses": 0,
                  "compile_seconds": 0.0, "stall_seconds": 0.0}


def compile_cache_stats() -> Dict[str, float]:
    """Process-wide compiled-evaluator cache counters.

    ``hits``/``misses`` count store lookups (ints); ``compile_seconds`` /
    ``stall_seconds`` are cumulative wall-clock floats (see comments on
    `_COMPILE_STATS`).
    """
    with _COMPILED_LOCK:
        return dict(_COMPILE_STATS)


def set_compiled_maxsize(n: int) -> int:
    """Set the compiled-function LRU capacity; returns the previous value.

    Also configurable at process start via env ``REPRO_COMPILED_MAXSIZE``.
    Pinned (AOT-queued / in-flight) entries are exempt from eviction, so
    the store may transiently hold more than ``n`` entries.
    """
    global _COMPILED_MAXSIZE
    if n <= 0:
        raise ValueError(f"compiled maxsize must be positive, got {n}")
    with _COMPILED_LOCK:
        prev, _COMPILED_MAXSIZE = _COMPILED_MAXSIZE, n
        _evict_locked(_COMPILED)
    return prev


def compiled_maxsize() -> int:
    return _COMPILED_MAXSIZE


def pin_compiled(key: tuple) -> None:
    """Protect `key` from LRU eviction until the matching `unpin_compiled`.

    Reentrant (a pin count is kept).  Pinning a key that is not in the
    store yet is allowed — the AOT service pins at submit time, before the
    wrapped function has been built.
    """
    with _COMPILED_LOCK:
        _COMPILED_PINS[key] = _COMPILED_PINS.get(key, 0) + 1


def unpin_compiled(key: tuple) -> None:
    with _COMPILED_LOCK:
        n = _COMPILED_PINS.get(key, 0) - 1
        if n > 0:
            _COMPILED_PINS[key] = n
        else:
            _COMPILED_PINS.pop(key, None)
        _evict_locked(_COMPILED)


def _evict_locked(store: "collections.OrderedDict") -> None:
    # Caller holds _COMPILED_LOCK.  Evict oldest unpinned entries until the
    # store fits; pinned entries are skipped (and keep their LRU position).
    excess = len(store) - _COMPILED_MAXSIZE
    if excess <= 0:
        return
    for key in list(store):
        if excess <= 0:
            break
        if _COMPILED_PINS.get(key):
            continue
        del store[key]
        excess -= 1


def _add_compile_seconds(dt: float, stalled: bool) -> None:
    with _COMPILED_LOCK:
        _COMPILE_STATS["compile_seconds"] += dt
        if stalled:
            _COMPILE_STATS["stall_seconds"] += dt


def _add_stall_seconds(dt: float) -> None:
    with _COMPILED_LOCK:
        _COMPILE_STATS["stall_seconds"] += dt


def _compiled_get_or_create(store: "collections.OrderedDict", key: tuple,
                            build: Callable[[], Callable]) -> Callable:
    with _COMPILED_LOCK:
        fn = store.get(key)
        if fn is not None:
            store.move_to_end(key)
            _COMPILE_STATS["hits"] += 1
            return fn
        fn = build()
        store[key] = fn
        _COMPILE_STATS["misses"] += 1
        _evict_locked(store)
        return fn


class CompiledEntry:
    """A `_COMPILED` store value that can hold ahead-of-time executables.

    Wraps a lazy jit/pmap transform (``wrapper``) plus a table of
    `.lower().compile()`-ed executables keyed by input shape signature.
    Dispatch prefers a finished AOT executable; if a compile for the
    needed signature is in flight (AOT service), the caller blocks on it
    (counted as stall_seconds) instead of compiling a duplicate; on a
    plain miss it compiles inline (counted as compile+stall) — the
    graceful-fallback lazy path.  One compile per (key, signature) per
    process: `compile_for` dedupes via per-signature events.
    """

    def __init__(self, key: tuple, wrapper: Callable):
        self.key = key
        self.wrapper = wrapper
        self.aot: Dict[tuple, Callable] = {}
        self._inflight: Dict[tuple, threading.Event] = {}
        self._lock = threading.Lock()

    @staticmethod
    def signature(args: tuple) -> tuple:
        leaves = jax.tree_util.tree_leaves(args)
        return tuple((tuple(l.shape), str(np.asarray(l).dtype) if not
                      hasattr(l, "dtype") else str(l.dtype)) for l in leaves)

    def _avals(self, args: tuple):
        return jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), args)

    def compile_for(self, args: tuple, stalled: bool = False) -> None:
        """Ensure an executable exists for the shape signature of `args`.

        `args` may be concrete arrays or `jax.ShapeDtypeStruct`s.  Safe to
        call from any thread; concurrent calls for one signature collapse
        into a single compile (the rest wait).
        """
        sig = self.signature(args)
        with self._lock:
            if sig in self.aot:
                return
            ev = self._inflight.get(sig)
            if ev is None:
                ev = self._inflight[sig] = threading.Event()
                owner = True
            else:
                owner = False
        if not owner:
            t0 = time.perf_counter()
            ev.wait()
            if stalled:
                _add_stall_seconds(time.perf_counter() - t0)
            return
        t0 = time.perf_counter()
        try:
            exe = self.wrapper.lower(*self._avals(args)).compile()
            self.aot[sig] = exe
            _add_compile_seconds(time.perf_counter() - t0, stalled)
        except Exception:
            # Graceful fallback: leave no executable; __call__ will run the
            # lazy wrapper (which compiles on first call as before).
            _add_compile_seconds(time.perf_counter() - t0, stalled)
        finally:
            with self._lock:
                self._inflight.pop(sig, None)
            ev.set()

    def __call__(self, *args):
        sig = self.signature(args)
        exe = self.aot.get(sig)
        if exe is None:
            with self._lock:
                ev = self._inflight.get(sig)
            if ev is not None:
                t0 = time.perf_counter()
                ev.wait()
                _add_stall_seconds(time.perf_counter() - t0)
                exe = self.aot.get(sig)
            if exe is None:
                self.compile_for(args, stalled=True)
                exe = self.aot.get(sig)
        if exe is None:
            return self.wrapper(*args)
        return exe(*args)


def compiled_entry(key: tuple,
                   build_wrapper: Callable[[], Callable]) -> CompiledEntry:
    """Get-or-create a `CompiledEntry` in the process-wide `_COMPILED` LRU.

    Like `_compiled_get_or_create` but the stored value is an AOT-capable
    entry (see `CompiledEntry`); hit/miss accounting is shared.
    """
    return _compiled_get_or_create(
        _COMPILED, key, lambda: CompiledEntry(key, build_wrapper()))


def clear_compiled_caches() -> None:
    """Drop every cached jitted/pmapped evaluation function (benchmarks use
    this to measure cold-compile paths; also frees the closed-over graphs).
    Pins are dropped too, and the compile-ahead bucket registry is reset so
    canonical executables are rebuilt from scratch."""
    with _COMPILED_LOCK:
        _COMPILED.clear()
        _BUDGET_COMPILED.clear()
        _COMPILED_PINS.clear()
    from . import compileahead
    compileahead._clear_registries()


def _skeleton_key(graph_fp: str, strategy: Strategy,
                  system: SystemGraph, ppe: PPEConfig, overlap: bool,
                  n_microbatches: Optional[int], pod_bw: Optional[float],
                  systolic_dims: tuple) -> tuple:
    return (graph_fp, strategy, system, ppe, overlap, n_microbatches,
            pod_bw, tuple(systolic_dims))


class BatchedEvaluator:
    """Scores many MicroArch candidates on one (graph, strategy, system).

    The scalar prediction is traced once per skeleton, `jax.vmap`-ed over the
    hardware matrix and `jax.jit`-ed; compiled functions are cached
    process-wide so repeated evaluators on the same skeleton are free.
    """

    def __init__(self, graph: ComputeGraph, strategy: Strategy,
                 system: Optional[SystemGraph] = None,
                 ppe: PPEConfig = PPEConfig(), overlap: bool = True,
                 n_microbatches: Optional[int] = None,
                 pod_bw: Optional[float] = None,
                 cache: Optional[PredictionCache] = DEFAULT_CACHE,
                 bucketed: Optional[bool] = None):
        self.graph = graph
        self.strategy = strategy
        self.system = system or simulate.default_system(strategy)
        self.ppe = ppe
        self.overlap = overlap
        self.n_microbatches = n_microbatches
        self.pod_bw = pod_bw
        self.cache = resolve_cache(cache)
        self.bucketed = bucketed
        self._graph_fp = graph.fingerprint()

    # -- compiled path ----------------------------------------------------
    def _skeleton(self, template: MicroArch) -> tuple:
        return _skeleton_key(self._graph_fp, self.strategy, self.system,
                             self.ppe, self.overlap, self.n_microbatches,
                             self.pod_bw,
                             template.tech.compute.systolic_dims)

    def _scalar_fn(self, template: MicroArch) -> Callable:
        def scalar(v):
            arch = unpack_hw(template, v)
            bd = simulate.predict(
                arch, self.graph, self.strategy, system=self.system,
                cfg=self.ppe, overlap=self.overlap,
                n_microbatches=self.n_microbatches, pod_bw=self.pod_bw)
            return jnp.stack([
                jnp.asarray(bd.total_s, dtype=jnp.float32),
                jnp.asarray(bd.compute_s, dtype=jnp.float32),
                jnp.asarray(bd.comm_s, dtype=jnp.float32),
                jnp.asarray(bd.exposed_comm_s, dtype=jnp.float32),
                jnp.asarray(bd.pipeline_bubble_s, dtype=jnp.float32),
            ])
        return scalar

    def _use_bucketed(self) -> bool:
        from repro.core import compileahead
        return compileahead.resolve_bucketed(self.bucketed)

    def _compiled(self, template: MicroArch,
                  bucketed: Optional[bool] = None) -> Callable:
        key = self._skeleton(template)
        use = self._use_bucketed() if bucketed is None else bucketed
        if use:
            from repro.core import compileahead
            return compileahead.design_batch_fn(
                ("skel", key), lambda: self._scalar_fn(template),
                (jax.ShapeDtypeStruct((HW_DIM,), jnp.float32),), n_dev=1)
        return _compiled_get_or_create(
            _COMPILED, key,
            lambda: jax.jit(jax.vmap(self._scalar_fn(template))))

    def _compiled_sharded(self, template: MicroArch, n_dev: int,
                          bucketed: Optional[bool] = None) -> Callable:
        key = self._skeleton(template) + ("pmap", n_dev)
        use = self._use_bucketed() if bucketed is None else bucketed
        if use:
            from repro.core import compileahead
            return compileahead.design_batch_fn(
                ("skel", self._skeleton(template)),
                lambda: self._scalar_fn(template),
                (jax.ShapeDtypeStruct((HW_DIM,), jnp.float32),), n_dev=n_dev)
        return _compiled_get_or_create(
            _COMPILED, key,
            lambda: jax.pmap(jax.vmap(self._scalar_fn(template))))

    # -- public API -------------------------------------------------------
    def evaluate(self, archs: Sequence[MicroArch],
                 min_batch_jit: int = 2,
                 shard_devices: bool = False,
                 shard_block: int = 0) -> np.ndarray:
        """Score MicroArch candidates -> (B, 5) rows ordered like METRICS.

        Cached points are returned for free; only misses are evaluated, in a
        single vmapped call (or eagerly when fewer than `min_batch_jit`
        misses remain — avoids paying XLA compile time for one-off points).
        With ``shard_devices`` the miss batch is split across all local JAX
        devices via `evaluate_matrix` (pmap over the hardware matrix);
        ``shard_block`` is forwarded as its padding block so sweeps with
        varying per-call miss counts reuse a few compiled shapes.
        """
        archs = list(archs)
        if not archs:
            return np.zeros((0, len(METRICS)), dtype=np.float64)
        sd0 = tuple(archs[0].tech.compute.systolic_dims)
        for a in archs:
            if tuple(a.tech.compute.systolic_dims) != sd0:
                raise ValueError("mixed systolic dims in one batch; group "
                                 "points with evaluate_points() instead")
        out = np.zeros((len(archs), len(METRICS)), dtype=np.float64)
        skel = self._skeleton(archs[0])
        vecs = [pack_hw(a) for a in archs]
        misses: List[int] = []
        keys: List[Optional[tuple]] = []
        for i, a in enumerate(archs):
            key = (skel, vecs[i].tobytes()) if self.cache is not None \
                else None
            keys.append(key)
            row = self.cache.get(key) if self.cache is not None else None
            if row is None:
                misses.append(i)
            else:
                out[i] = row
        if not misses:
            return out
        if shard_devices and len(misses) >= max(min_batch_jit,
                                                jax.local_device_count()):
            rows = self.evaluate_matrix(archs[0],
                                        np.stack([vecs[i] for i in misses]),
                                        block=shard_block)
        elif len(misses) >= min_batch_jit or self._use_bucketed():
            # With bucketing on, even tiny miss batches go through the
            # shared canonical executable: the compile is amortized across
            # every design in the bucket, and rows stay bit-identical to
            # the batched/pipelined paths (the eager fallback differs at
            # float32 rounding).
            fn = self._compiled(archs[0])
            hw = jnp.asarray(np.stack([vecs[i] for i in misses]))
            rows = np.asarray(fn(hw), dtype=np.float64)
        else:
            rows = np.stack([self._eager_row(archs[i]) for i in misses])
        for j, i in enumerate(misses):
            out[i] = rows[j]
            if self.cache is not None:
                self.cache.put(keys[i], rows[j])
        return out

    def evaluate_matrix(self, template: MicroArch, hw_matrix,
                        devices: Optional[int] = None,
                        block: int = 0) -> np.ndarray:
        """Score an (N, HW_DIM) struct-of-arrays hardware matrix directly.

        The matrix-native fast path for sweeps at the 10^4-10^6 point scale
        (repro.core.sweeprunner): no per-point MicroArch objects, no
        per-point cache keys — the batch enters JAX as one array.  With
        ``devices`` > 1 (default: every local JAX device) the matrix is
        sharded row-wise across devices with `jax.pmap`, which on CPU hosts
        means one XLA executable per device thread running concurrently.

        ``block`` > 0 pads N up to a multiple of ``block`` x devices so
        successive chunks of a sweep share one compiled shape (jit/pmap
        specialize per input shape; without padding every distinct chunk
        size would recompile).  Padding rows replicate the last point and
        are sliced off the result.
        """
        hw = np.asarray(hw_matrix, dtype=np.float32)
        n = hw.shape[0]
        if n == 0:
            return np.zeros((0, len(METRICS)), dtype=np.float64)
        if hw.ndim != 2 or hw.shape[1] != HW_DIM:
            raise ValueError(f"hw_matrix must be (N, {HW_DIM}), "
                             f"got {hw.shape}")
        n_dev = devices if devices is not None else jax.local_device_count()
        n_dev = max(min(n_dev, n), 1)
        quantum = n_dev * max(block, 1)
        target = -(-n // quantum) * quantum
        if target != n:
            hw = np.concatenate(
                [hw, np.repeat(hw[-1:], target - n, axis=0)])
        # template+matrix mode is ONE design over a huge hardware batch:
        # there is nothing for cross-design bucketing to amortize, and the
        # parameterized bucket executable pays per-row coefficient gathers
        # plus lost constant folding at warm runtime (~16x slower on 16k
        # rows) — always dispatch the legacy baked executable here
        if n_dev > 1:
            fn = self._compiled_sharded(template, n_dev, bucketed=False)
            rows = fn(jnp.asarray(hw.reshape(n_dev, target // n_dev,
                                             HW_DIM)))
            rows = np.asarray(rows, dtype=np.float64).reshape(
                target, len(METRICS))
        else:
            fn = self._compiled(template, bucketed=False)
            rows = np.asarray(fn(jnp.asarray(hw)), dtype=np.float64)
        return rows[:n]

    def _eager_row(self, arch: MicroArch) -> np.ndarray:
        bd = simulate.predict(arch, self.graph, self.strategy,
                              system=self.system, cfg=self.ppe,
                              overlap=self.overlap,
                              n_microbatches=self.n_microbatches,
                              pod_bw=self.pod_bw)
        return _breakdown_row(bd)


# ---------------------------------------------------------------------------
# Heterogeneous point sets (different graphs / strategies / systems)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EvalPoint:
    """One (hardware, workload, strategy, system) candidate."""

    arch: MicroArch
    graph: ComputeGraph
    strategy: Strategy
    system: Optional[SystemGraph] = None
    pod_bw: Optional[float] = None


def _evaluate_points_impl(points: Sequence[EvalPoint],
                          ppe: PPEConfig = PPEConfig(),
                          cache: Optional[PredictionCache] = DEFAULT_CACHE,
                          min_batch_jit: int = 4,
                          shard_devices: bool = False,
                          shard_block: int = 0) -> np.ndarray:
    """Score a heterogeneous candidate list -> (N, 5) metric matrix.

    Points are grouped by skeleton (graph fingerprint, strategy, system,
    ppe); each group is one struct-of-arrays batch.  Hardware-only axes
    (techlib nodes, budget variants) therefore collapse into single vmapped
    calls, while structure-changing axes (strategy, mesh) form their own
    groups and still benefit from the LRU cache.  ``shard_devices`` fans
    each group's hardware matrix across local JAX devices (see
    `BatchedEvaluator.evaluate_matrix`).
    """
    out = np.zeros((len(points), len(METRICS)), dtype=np.float64)
    groups: Dict[tuple, List[int]] = {}
    evaluators: Dict[tuple, BatchedEvaluator] = {}
    for i, p in enumerate(points):
        ev = BatchedEvaluator(p.graph, p.strategy, system=p.system, ppe=ppe,
                              pod_bw=p.pod_bw, cache=cache)
        key = ev._skeleton(p.arch)
        groups.setdefault(key, []).append(i)
        evaluators.setdefault(key, ev)
    for key, idxs in groups.items():
        ev = evaluators[key]
        rows = ev.evaluate([points[i].arch for i in idxs],
                           min_batch_jit=min_batch_jit,
                           shard_devices=shard_devices,
                           shard_block=shard_block)
        for j, i in enumerate(idxs):
            out[i] = rows[j]
    return out


def evaluate(points: Optional[Sequence[EvalPoint]] = None, *,
             spec=None, labels=None,
             template: Optional[MicroArch] = None, matrix=None,
             graph: Optional[ComputeGraph] = None,
             strategy: Optional[Strategy] = None,
             system: Optional[SystemGraph] = None,
             pod_bw: Optional[float] = None,
             ppe: PPEConfig = PPEConfig(),
             cache: Optional[PredictionCache] = DEFAULT_CACHE,
             min_batch_jit: int = 4,
             shard_devices: bool = False,
             shard_block: int = 0,
             devices: Optional[int] = None) -> np.ndarray:
    """Score candidates — THE eval entry point, in one of three modes.

    Exactly one mode per call (mixing raises ``ValueError``):

    * **points mode** — ``evaluate(points=[EvalPoint, ...])``: a
      heterogeneous candidate list, grouped by skeleton so hardware-only
      axes collapse into single vmapped calls; returns an ``(N, 5)``
      float64 matrix ordered like `METRICS`.
    * **label mode** — ``evaluate(spec=SweepSpec, labels=[PointLabel,
      ...])``: resolves sweep labels through their scenario (PPE/profile
      come from the spec, not the ``ppe`` argument) and returns the
      scenario's *result records* (list of dicts), exactly what
      `SweepRunner` commits per chunk.
    * **matrix mode** — ``evaluate(template=MicroArch, matrix=(N,
      HW_DIM), graph=..., strategy=...)``: the matrix-native fast path;
      rows enter JAX as one array, optionally pmap-sharded row-wise
      across ``devices`` with ``shard_block`` padding.

    Supersedes the three historical entry points
    (`sweeprunner.eval_labels`, `evaluate_points`,
    `BatchedEvaluator.evaluate_matrix`), which remain as thin
    deprecation wrappers.
    """
    n_modes = sum((points is not None,
                   spec is not None or labels is not None,
                   template is not None or matrix is not None))
    if n_modes != 1:
        raise ValueError(
            "evaluate() takes exactly one of: points=..., "
            "(spec=..., labels=...), or (template=..., matrix=...)")
    if points is not None:
        return _evaluate_points_impl(points, ppe=ppe, cache=cache,
                                     min_batch_jit=min_batch_jit,
                                     shard_devices=shard_devices,
                                     shard_block=shard_block)
    if matrix is not None or template is not None:
        if template is None or matrix is None or graph is None \
                or strategy is None:
            raise ValueError("matrix mode needs template=, matrix=, "
                             "graph= and strategy=")
        ev = BatchedEvaluator(graph, strategy, system=system, ppe=ppe,
                              pod_bw=pod_bw, cache=cache)
        return ev.evaluate_matrix(template, matrix, devices=devices,
                                  block=shard_block)
    if spec is None or labels is None:
        raise ValueError("label mode needs both spec= and labels=")
    from repro.core import sweeprunner   # lazy: sweeprunner imports us
    return sweeprunner._eval_labels_impl(spec, labels, cache=cache,
                                         shard_devices=shard_devices)


def evaluate_points(points: Sequence[EvalPoint],
                    ppe: PPEConfig = PPEConfig(),
                    cache: Optional[PredictionCache] = DEFAULT_CACHE,
                    min_batch_jit: int = 4,
                    shard_devices: bool = False,
                    shard_block: int = 0) -> np.ndarray:
    """Deprecated alias — use ``evaluate(points=...)`` (one documented
    facade over the three historical eval entry points)."""
    import warnings
    warnings.warn("pathfinder.evaluate_points is deprecated; use "
                  "pathfinder.evaluate(points=...)",
                  DeprecationWarning, stacklevel=2)
    return _evaluate_points_impl(points, ppe=ppe, cache=cache,
                                 min_batch_jit=min_batch_jit,
                                 shard_devices=shard_devices,
                                 shard_block=shard_block)


# ---------------------------------------------------------------------------
# Budget-space batching (the SOE axis)
# ---------------------------------------------------------------------------


_BUDGET_COMPILED: "collections.OrderedDict[tuple, Callable]" = \
    collections.OrderedDict()


def evaluate_budgets(tech: TechConfig, graph: ComputeGraph,
                     strategy: Strategy, budget_vectors,
                     system: Optional[SystemGraph] = None,
                     template: Optional[Budgets] = None,
                     ppe: PPEConfig = PPEConfig(),
                     pod_bw: Optional[float] = None) -> jnp.ndarray:
    """Score a (B, DIM) stack of SOE budget vectors in one vmapped call.

    The budget-space analogue of `BatchedEvaluator.evaluate`: goes through
    the differentiable AGE (`discrete=False`), so the result is also
    differentiable w.r.t. the budget stack.  (`soe.optimize` builds its own
    vmapped value_and_grad over the same objective for the GD loop; use
    this for one-shot batched budget scans.)  The jitted function is
    memoized per (tech, graph, strategy, system, ppe, template) skeleton.
    """
    like = template or Budgets.default()
    key = (tech, graph.fingerprint(), strategy, system, ppe, pod_bw,
           like.node_area_mm2, like.proc_chip_area_mm2, like.power_w)

    def build():
        def f(w):
            budgets = Budgets.from_vector(w, like)
            arch = age_lib.generate(tech, budgets, discrete=False)
            bd = simulate.predict(arch, graph, strategy, system=system,
                                  cfg=ppe, pod_bw=pod_bw)
            return bd.total_s

        return jax.jit(jax.vmap(f))

    fn = _compiled_get_or_create(_BUDGET_COMPILED, key, build)
    return fn(jnp.asarray(budget_vectors, dtype=jnp.float32))


# ---------------------------------------------------------------------------
# Pareto frontier
# ---------------------------------------------------------------------------


def pareto_front(points: Sequence, objectives: Sequence[Callable]) -> List:
    """Non-dominated subset minimizing every objective (callables on points).

    O(n^2); returns points in input order.  A point is kept iff no other
    point is <= on all objectives and < on at least one.  Tie semantics:
    points exactly equal on ALL objectives do not dominate each other, so
    every copy of a non-dominated point survives, independent of input
    order (same contract as `sweeprunner.pareto_records`; regression tests
    pin the two to each other).  Points with any non-finite objective are
    excluded — NaN compares false against everything, so such a point can
    never be dominated and would otherwise pollute the frontier.
    """
    vals = [tuple(float(obj(p)) for obj in objectives) for p in points]
    finite = [all(np.isfinite(v) for v in vi) for vi in vals]
    keep = []
    for i, vi in enumerate(vals):
        if not finite[i]:
            continue
        dominated = False
        for j, vj in enumerate(vals):
            if j == i or not finite[j]:
                continue
            if all(a <= b for a, b in zip(vj, vi)) \
                    and any(a < b for a, b in zip(vj, vi)):
                dominated = True
                break
        if not dominated:
            keep.append(points[i])
    return keep


def hypervolume(vals, ref) -> float:
    """Dominated hypervolume of objective rows against a reference corner.

    ``vals`` is (N, K) in canonical all-minimizing space (apply
    `objectives.canonical_signs` to max-direction axes first) and ``ref``
    the (K,) worst corner; the result is the exact volume of the union of
    boxes ``[v, ref]`` — the standard frontier-quality scalar the explore
    benchmark compares surrogate-guided search against exhaustive sweeps
    with.  Computed by recursive dimension-sweep slicing: exact for any
    K, O(N^2) per level, intended for frontier-sized sets (hundreds of
    points), not raw sweep clouds.  Rows with any non-finite coordinate
    or outside the reference box contribute nothing; dominated rows are
    harmless (their boxes are subsets).
    """
    ref = np.asarray(ref, dtype=np.float64).reshape(-1)
    v = np.asarray(vals, dtype=np.float64).reshape(-1, ref.shape[0])
    keep = np.all(np.isfinite(v), axis=1) & np.all(v < ref, axis=1)
    v = v[keep]
    if not v.size:
        return 0.0

    def hv(rows: np.ndarray, r: np.ndarray) -> float:
        if rows.shape[1] == 1:
            return float(r[0] - rows[:, 0].min())
        rows = rows[np.argsort(rows[:, 0], kind="stable")]
        total = 0.0
        for i in range(rows.shape[0]):
            hi = rows[i + 1, 0] if i + 1 < rows.shape[0] else r[0]
            width = hi - rows[i, 0]
            if width > 0.0:
                # slab [rows[i,0], hi): its cross-section is dominated by
                # exactly the points entered so far
                total += width * hv(rows[:i + 1, 1:], r[1:])
        return total

    return hv(v, ref)


# ---------------------------------------------------------------------------
# Device-resident streaming Pareto frontier (carried across chunks)
# ---------------------------------------------------------------------------

# Default capacity of the carried frontier state (number of non-dominated
# candidates held on device).  Real sweep frontiers are tiny next to the
# point count; overflow is detected and reported, never silent.
FRONTIER_CAPACITY = 512


def frontier_init(capacity: int, n_obj: int,
                  payload_dim: int) -> Tuple[jnp.ndarray, ...]:
    """Empty carried frontier state for `frontier_merge`.

    ``(vals, payload, idx, overflow)``: objective rows (+inf = empty slot),
    an opaque per-point payload (the raw metric rows, so surviving records
    can be rebuilt without ever materializing the full sweep), the global
    point index (-1 = empty), and a scalar count of finite candidates that
    were dropped because the frontier outgrew ``capacity``.
    """
    return (jnp.full((capacity, n_obj), jnp.inf, dtype=jnp.float32),
            jnp.zeros((capacity, payload_dim), dtype=jnp.float32),
            jnp.full((capacity,), -1, dtype=jnp.int32),
            jnp.zeros((), dtype=jnp.int32))


def frontier_merge(state: Tuple, vals: jnp.ndarray, payload: jnp.ndarray,
                   idx: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    """One streaming-skyline step: merge a batch into the carried state.

    Pure jnp (traceable; the pipelined executor jits this fused behind the
    batched evaluation with the state buffers donated).  Dominance follows
    `pareto_front`: a candidate is dropped iff some other candidate is <=
    on all objectives and < on at least one; exact ties never dominate
    each other, and rows with any non-finite objective (infeasible points,
    padding, empty slots) never enter the frontier.  A carried point can
    still be evicted by a later batch — the state always holds the skyline
    of everything seen so far, truncated to capacity in full lexicographic
    order (all objectives, then global point index; ``overflow`` counts
    what the truncation dropped).  The full-lex key makes the kept set a
    canonical function of the surviving point set — independent of how
    points are arranged across state slots and batch rows — because a
    dominator always sorts strictly before anything it dominates, and the
    point index breaks exact-tie races deterministically.  (Which points
    *survive* can still depend on merge history once overflow drops a
    future dominator — any bounded streaming skyline has that limit, which
    is why ``overflow > 0`` flags the frontier as inexact and the
    cross-worker coordinator merges with the unbounded
    `frontier_merge_states` instead.)
    """
    svals, spay, sidx, overflow = state
    capacity = svals.shape[0]
    av = jnp.concatenate([svals, jnp.asarray(vals, dtype=jnp.float32)])
    ap = jnp.concatenate([spay, jnp.asarray(payload, dtype=jnp.float32)])
    ai = jnp.concatenate([sidx, jnp.asarray(idx, dtype=jnp.int32)])
    finite = jnp.all(jnp.isfinite(av), axis=1) & (ai >= 0)
    # pairwise dominance: dominated[i] iff some finite j <= i on all
    # objectives and < on one ((CAP+B)^2 x K ops — trivial on device)
    le = jnp.all(av[None, :, :] <= av[:, None, :], axis=-1)
    lt = jnp.any(av[None, :, :] < av[:, None, :], axis=-1)
    dominated = jnp.any(le & lt & finite[None, :], axis=1)
    keep = finite & ~dominated
    # survivors first in full lex order (objectives, then point index),
    # empties pushed to +inf / INT32_MAX; lexsort's primary key is LAST
    masked = jnp.where(keep[:, None], av, jnp.inf)
    idx_key = jnp.where(keep, ai, jnp.iinfo(jnp.int32).max)
    order = jnp.lexsort((idx_key,) + tuple(
        masked[:, k] for k in range(av.shape[1] - 1, -1, -1)))
    kept_beyond = jnp.sum(keep) - jnp.minimum(jnp.sum(keep), capacity)
    order = order[:capacity]
    mask = keep[order]
    return (jnp.where(mask[:, None], av[order], jnp.inf),
            jnp.where(mask[:, None], ap[order], 0.0),
            jnp.where(mask, ai[order], -1),
            overflow + kept_beyond.astype(jnp.int32))


def frontier_unpack(state: Tuple) -> Tuple[np.ndarray, np.ndarray,
                                           np.ndarray, int]:
    """Pull a carried frontier state to host -> (vals, payload, idx,
    n_overflowed) with empty slots stripped."""
    vals, payload, idx, overflow = (np.asarray(x) for x in state)
    live = idx >= 0
    return (vals[live].astype(np.float64), payload[live], idx[live],
            int(overflow))


def frontier_merge_states(a: Tuple, b: Tuple) -> Tuple[np.ndarray, ...]:
    """Merge two carried frontier states host-side — the coordinator's
    cross-worker reduction.

    Unlike the streaming `frontier_merge`, this merge is **unbounded**: it
    dedupes by global point index (the same point checkpointed by two
    incarnations of a worker is one point), drops dominated points with
    the exact f32 semantics of the device merge, and keeps EVERY survivor,
    growing the state instead of truncating to a capacity.  That makes the
    live set exactly commutative, associative, and idempotent — any merge
    order over any partition of worker states yields the same global
    frontier, which the fabric's property tests pin.  (A bounded merge
    cannot promise this: once truncation drops a not-yet-needed dominator,
    which points survive depends on merge history.  Workers' own overflow
    counters are summed through, so ``overflow > 0`` still flags that some
    worker's *local* frontier was inexact — the same contract as a
    single-host run.)

    Slot layout of the result is canonical: survivors in full
    lexicographic order (objectives, then point index), padded to the
    larger input's capacity.  States must agree on objective and payload
    dimensions (same sweep spec).
    """
    av, ap, ai, ao = (np.asarray(x) for x in a)
    bv, bp, bi, bo = (np.asarray(x) for x in b)
    if av.shape[1:] != bv.shape[1:] or ap.shape[1:] != bp.shape[1:]:
        raise ValueError(
            f"frontier states disagree on objective/payload shape: "
            f"{av.shape[1:]}/{ap.shape[1:]} vs {bv.shape[1:]}/"
            f"{bp.shape[1:]} — were they produced by the same spec?")
    vals = np.concatenate([av, bv]).astype(np.float32)
    pay = np.concatenate([ap, bp]).astype(np.float32)
    idx = np.concatenate([ai, bi]).astype(np.int32)
    live = (idx >= 0) & np.all(np.isfinite(vals), axis=1)
    # dedupe by global point index: re-merging a state that already holds
    # a point must be a no-op (the duplicate rows are the same evaluated
    # point, so which copy survives is immaterial)
    first: Dict[int, int] = {}
    for k in np.flatnonzero(live):
        first.setdefault(int(idx[k]), int(k))
    ks = np.asarray(sorted(first.values()), dtype=np.int64)
    n = len(ks)
    cap = max(av.shape[0], bv.shape[0], n)
    overflow = np.asarray(int(ao) + int(bo), dtype=np.int32)
    if n:
        v = vals[ks]
        le = np.all(v[None, :, :] <= v[:, None, :], axis=-1)
        lt = np.any(v[None, :, :] < v[:, None, :], axis=-1)
        dominated = np.any(le & lt, axis=1)
        ks = ks[~dominated]
        # canonical slot order: full lex (objectives, then point index)
        v = vals[ks]
        order = np.lexsort((idx[ks],) + tuple(
            v[:, k] for k in range(v.shape[1] - 1, -1, -1)))
        ks = ks[order]
        n = len(ks)
    out_v = np.full((cap, vals.shape[1]), np.inf, dtype=np.float32)
    out_p = np.zeros((cap, pay.shape[1]), dtype=np.float32)
    out_i = np.full((cap,), -1, dtype=np.int32)
    out_v[:n] = vals[ks]
    out_p[:n] = pay[ks]
    out_i[:n] = idx[ks]
    return out_v, out_p, out_i, overflow


# ---------------------------------------------------------------------------
# Design-space sweep driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One evaluated design point of a `sweep()`."""

    arch: str                       # model architecture id
    cell: str                       # shape cell name
    mesh: Tuple[int, ...]
    logic: str
    hbm: str
    net: str
    strategy: Strategy
    time_s: float
    compute_s: float
    comm_s: float
    exposed_comm_s: float
    devices: int
    power_w: float
    chip_area_mm2: float

    def metric(self, name: str) -> float:
        return float(getattr(self, name))

    def as_csv_row(self) -> str:
        return (f"{self.arch},{self.cell},{'x'.join(map(str, self.mesh))},"
                f"{self.logic},{self.hbm},{self.net},{self.strategy.name},"
                f"{self.time_s:.6e},{self.compute_s:.6e},{self.comm_s:.6e},"
                f"{self.devices},{self.power_w:g},{self.chip_area_mm2:g}")


CSV_HEADER = ("arch,cell,mesh,logic,hbm,net,strategy,time_s,compute_s,"
              "comm_s,devices,power_w,chip_area_mm2")


@dataclasses.dataclass
class SweepResult:
    points: List[SweepPoint]
    n_evaluations: int

    def pareto(self, objectives: Sequence[str] = ("time_s", "devices")
               ) -> List[SweepPoint]:
        objs = [(lambda p, k=k: p.metric(k)) for k in objectives]
        return pareto_front(self.points, objs)

    def best(self) -> SweepPoint:
        return min(self.points, key=lambda p: p.time_s)

    def to_csv(self) -> str:
        return "\n".join([CSV_HEADER] + [p.as_csv_row()
                                         for p in self.points])


def _default_strategies(cfg, cell, mesh_shape) -> List[Strategy]:
    from repro.core import planner     # lazy: planner imports pathfinder
    return planner.candidate_strategies(cfg, cell, mesh_shape)


def sweep(arches: Sequence[str], cells: Sequence[str],
          mesh_shapes: Sequence[Tuple[int, ...]],
          logic_nodes: Sequence[str] = ("N7",),
          hbms: Sequence[str] = ("HBM2E",),
          nets: Sequence[str] = ("IB-NDR-X8",),
          budgets: Optional[Budgets] = None,
          ppe: PPEConfig = PPEConfig(n_tilings=8),
          strategies_fn: Optional[Callable] = None,
          cache: Optional[PredictionCache] = DEFAULT_CACHE,
          profile=None) -> SweepResult:
    """Cross-product design-space sweep (the paper's §9 studies, batched).

    arches x cells define workload graphs, mesh_shapes define systems and
    candidate strategies, (logic, hbm, net) triples define AGE'd hardware.
    All hardware points sharing a skeleton are scored in one vmapped call.
    ``profile`` (a `repro.calibrate` profile / dict / path) anchors every
    hardware point and the PPE kernel overhead to measured efficiencies.
    """
    from repro.configs.base import SHAPE_CELLS, get_config
    from repro.core import lmgraph, techlib
    from repro.core.placement import mesh_system

    budgets = budgets or Budgets.default()
    strategies_fn = strategies_fn or _default_strategies
    if profile is not None:
        from repro.calibrate import profiles as profiles_lib
        profile = profiles_lib.coerce(profile)
        ppe = profiles_lib.ppe_with_profile(ppe, profile)

    tech_axis = list(itertools.product(logic_nodes, hbms, nets))
    hw_axis = []
    for logic, hbm, net in tech_axis:
        tech = techlib.make_tech_config(logic, hbm, net)
        hw = age_lib.generate(tech, budgets)
        if profile is not None:
            from repro.calibrate import profiles as profiles_lib
            hw = profiles_lib.apply_profile(hw, profile)
        hw_axis.append(((logic, hbm, net), hw))

    points: List[EvalPoint] = []
    labels: List[tuple] = []
    for arch_name in arches:
        cfg = get_config(arch_name)
        for cell_name in cells:
            cell = SHAPE_CELLS[cell_name]
            graph = lmgraph.build_graph(cfg, cell)
            for mesh in mesh_shapes:
                system = mesh_system(tuple(mesh))
                for st in strategies_fn(cfg, cell, tuple(mesh)):
                    for (logic, hbm, net), hw in hw_axis:
                        points.append(EvalPoint(hw, graph, st,
                                                system=system))
                        labels.append((arch_name, cell_name, tuple(mesh),
                                       logic, hbm, net, st))
    rows = evaluate(points=points, ppe=ppe, cache=cache)
    out = []
    for (arch_name, cell_name, mesh, logic, hbm, net, st), row in zip(labels,
                                                                      rows):
        out.append(SweepPoint(
            arch=arch_name, cell=cell_name, mesh=mesh, logic=logic, hbm=hbm,
            net=net, strategy=st, time_s=float(row[0]),
            compute_s=float(row[1]), comm_s=float(row[2]),
            exposed_comm_s=float(row[3]), devices=st.devices,
            power_w=float(budgets.power_w),
            chip_area_mm2=float(budgets.proc_chip_area_mm2)))
    return SweepResult(points=out, n_evaluations=len(out))
