"""Compile-ahead subsystem: cross-design bucketed executables + AOT service.

Cold-start sweeps on realistic multi-arch x multi-mesh x multi-strategy
grids are *compile-bound*: every distinct design group pays a lazy XLA
compile on the device stage's critical path, so wall time scales with
O(designs), not with evaluation work.  This module removes that scaling in
two coordinated layers:

1. **Cross-design bucketing.**  Design evaluation functions for different
   (mesh, strategy, tech) designs of the same scenario cell trace to
   jaxprs that are *structurally identical* — the designs differ only in
   the scalar literals and closed-over constants baked into the trace
   (mesh extents, link counts, coefficient tables).  `design_vector`
   traces a design's scalar function once, **canonicalizes** the jaxpr by
   abstracting every literal operand and constvar into a positional input
   slot, and fingerprints the remaining pure structure.  Designs with
   equal fingerprints share one `Bucket`; each design is reduced to a
   small packed coefficient vector (`DesignVector.packs`).  One compiled
   executable per (bucket, device layout) then serves *every* member
   design — O(shape-buckets) compiles instead of O(designs) — and because
   every backend (serial, pipeline, fabric workers) dispatches the *same*
   canonical executable, cross-backend records are bit-identical by
   construction (XLA cannot constant-fold per-design values it never
   sees).

2. **AOT compile service.**  `CompileService` is a small background
   thread pool that drives `wrapper.lower(avals).compile()` to completion
   off the critical path.  The pipeline producer submits the (key, input
   shape) pairs of upcoming superbatches while packing the current one;
   finished executables land in the entry's AOT table inside
   `pathfinder._COMPILED`, so the device stage only dispatches warm
   functions.  Submissions are deduped fleet-wide within the process (one
   compile per (key, signature)), submitted keys are pinned against LRU
   eviction until first dispatch, and a lookahead miss falls back to the
   lazy inline compile (counted as `stall_seconds`).

Bucketing is on by default and is an execution-only change: chunk hashes,
point keys, record payloads, and frontier merges are unaffected.  Set env
``REPRO_NO_BUCKETING=1`` (or pass ``--no-bucketing`` / ``bucketed=False``)
to fall back to the legacy per-design closed-over compilation path, which
is numerically equivalent only to float32 rounding (~1e-7 relative).
"""

from __future__ import annotations

import collections
import dataclasses
import os
import queue
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import core

from repro.core import pathfinder

__all__ = [
    "Bucket", "DesignVector", "design_vector", "batch_entry",
    "design_batch_fn", "bucketing_default", "set_bucketing_default",
    "bucket_stats", "CompileService", "service",
]


# ---------------------------------------------------------------------------
# Bucketing default (the --no-bucketing escape hatch)
# ---------------------------------------------------------------------------

_BUCKETING_DEFAULT = os.environ.get(
    "REPRO_NO_BUCKETING", "").lower() not in ("1", "true", "yes")


def bucketing_default() -> bool:
    """Whether canonical bucketed executables are used when callers don't
    say (env ``REPRO_NO_BUCKETING`` flips the process default)."""
    return _BUCKETING_DEFAULT


def set_bucketing_default(flag: bool) -> bool:
    """Set the process-wide bucketing default; returns the previous value."""
    global _BUCKETING_DEFAULT
    prev, _BUCKETING_DEFAULT = _BUCKETING_DEFAULT, bool(flag)
    return prev


def resolve_bucketed(flag: Optional[bool]) -> bool:
    return _BUCKETING_DEFAULT if flag is None else bool(flag)


# ---------------------------------------------------------------------------
# Jaxpr canonicalization
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One equivalence class of design functions: the canonical jaxpr with
    every closed-over constant and literal abstracted into coefficient
    slots, plus the slot -> packed-class indexing needed to rebind a
    member design's values at dispatch time."""

    id: int
    jaxpr: "core.Jaxpr"            # constvars=[]; invars = coeffs + data
    classes: Tuple[tuple, ...]     # (dtype_str, shape) per coeff pack
    class_sizes: Tuple[int, ...]
    slots: Tuple[Tuple[int, int], ...]  # per coeff invar: (class, index)
    n_data: int                    # trailing data invars
    n_outs: int

    def scalar_fn(self) -> Callable:
        """(packs_tuple, *data) -> outputs, replaying the canonical jaxpr.

        ``packs_tuple[c]`` stacks this design's class-``c`` coefficients as
        one ``(class_sizes[c], *shape)`` array; slots are statically
        indexed out, so the whole rebind traces to gathers and the XLA
        program stays one executable for every bucket member.
        """
        jaxpr, slots = self.jaxpr, self.slots

        def scalar(packs, *data):
            coeffs = [packs[c][i] for c, i in slots]
            out = core.eval_jaxpr(jaxpr, [], *coeffs, *data)
            return out[0] if len(out) == 1 else tuple(out)

        return scalar


@dataclasses.dataclass(frozen=True)
class DesignVector:
    """A design reduced to (shared bucket, packed per-design coefficients)."""

    bucket: Bucket
    packs: Tuple[np.ndarray, ...]  # aligned with bucket.classes

    def broadcast_packs(self, lead: Tuple[int, ...]) -> Tuple[np.ndarray, ...]:
        """Replicate the coefficient packs across leading batch dims."""
        return tuple(np.broadcast_to(p, tuple(lead) + p.shape)
                     for p in self.packs)


def _shaped(aval):
    try:
        return core.raise_to_shaped(aval)
    except Exception:
        return aval


def _aval_sig(aval) -> tuple:
    a = _shaped(aval)
    return (str(getattr(a, "dtype", a)), tuple(getattr(a, "shape", ())),
            bool(getattr(a, "weak_type", False)))


def _hashable(x):
    if isinstance(x, (core.Jaxpr, core.ClosedJaxpr)):
        return ("jaxpr", repr(x))
    if isinstance(x, dict):
        return tuple(sorted((k, _hashable(v)) for k, v in x.items()))
    if isinstance(x, (list, tuple)):
        return (type(x).__name__,) + tuple(_hashable(v) for v in x)
    if isinstance(x, np.ndarray):
        return ("nd", x.shape, str(x.dtype), x.tobytes())
    try:
        hash(x)
        return x
    except TypeError:
        return repr(x)


def _canonicalize(closed: "core.ClosedJaxpr"):
    """Abstract literals/constvars out of a closed jaxpr.

    Returns ``(jaxpr, coeff_vals, coeff_avals, fingerprint)`` where
    ``jaxpr`` has ``constvars=[]`` and ``invars = [constvar slots...,
    literal slots..., original invars...]``; ``coeff_vals`` holds this
    design's values for the coefficient invars in order; ``fingerprint``
    is a hashable tuple of the remaining structure — identical fingerprints mean
    the canonical jaxprs are interchangeable up to coefficient values.
    """
    jaxpr = closed.jaxpr
    var_ids: Dict[object, int] = {}

    def vid(v) -> int:
        i = var_ids.get(v)
        if i is None:
            i = var_ids[v] = len(var_ids)
        return i

    for cv in jaxpr.constvars:
        vid(cv)
    for iv in jaxpr.invars:
        vid(iv)

    lit_vars: List[core.Var] = []
    lit_vals: List[np.ndarray] = []
    lit_avals: List[object] = []
    new_eqns = []
    fp_eqns: List[tuple] = []
    for eqn in jaxpr.eqns:
        invars = []
        fp_in = []
        changed = False
        for a in eqn.invars:
            if isinstance(a, core.Literal):
                aval = _shaped(a.aval)
                var = core.Var("", aval)
                lit_vars.append(var)
                lit_vals.append(np.asarray(a.val))
                lit_avals.append(aval)
                invars.append(var)
                fp_in.append(("l", _aval_sig(aval)))
                changed = True
            else:
                invars.append(a)
                fp_in.append(("v", vid(a)))
        out_ids = tuple(vid(v) for v in eqn.outvars)
        fp_eqns.append((eqn.primitive.name, _hashable(eqn.params),
                        tuple(fp_in), out_ids))
        new_eqns.append(eqn.replace(invars=invars) if changed else eqn)

    coeff_avals = [_shaped(v.aval) for v in jaxpr.constvars] + lit_avals
    coeff_vals = [np.asarray(c) for c in closed.consts] + lit_vals
    fp_out = tuple(
        ("l", _aval_sig(v.aval)) if isinstance(v, core.Literal)
        else ("v", var_ids.get(v, -1)) for v in jaxpr.outvars)
    fingerprint = (
        tuple(_aval_sig(v.aval) for v in jaxpr.constvars),
        tuple(_aval_sig(v.aval) for v in jaxpr.invars),
        tuple(fp_eqns), fp_out,
    )
    # debug_info=None: the stored result_paths no longer match the widened
    # invar list and Jaxpr.__init__ asserts on the mismatch.
    canonical = jaxpr.replace(
        constvars=[], eqns=new_eqns, debug_info=None,
        invars=list(jaxpr.constvars) + lit_vars + list(jaxpr.invars))
    return canonical, coeff_vals, coeff_avals, fingerprint


def _pack(coeff_vals, coeff_avals):
    """Group coefficient slots by (dtype, shape) and stack the values.

    Slot -> class assignment is purely structural (derived from the
    coefficient aval sequence, which the fingerprint covers), so every
    bucket member maps slots to pack positions identically.
    """
    classes: List[tuple] = []
    class_pos: Dict[tuple, int] = {}
    members: List[List[int]] = []
    slots: List[Tuple[int, int]] = []
    for i, aval in enumerate(coeff_avals):
        ck = (str(aval.dtype), tuple(aval.shape))
        c = class_pos.get(ck)
        if c is None:
            c = class_pos[ck] = len(classes)
            classes.append(ck)
            members.append([])
        slots.append((c, len(members[c])))
        members[c].append(i)
    packs = []
    for c, ck in enumerate(classes):
        dtype = np.dtype(ck[0])
        packs.append(np.stack(
            [np.asarray(coeff_vals[i], dtype=dtype) for i in members[c]]))
    return tuple(classes), tuple(len(m) for m in members), \
        tuple(slots), tuple(packs)


# ---------------------------------------------------------------------------
# Registries (process-wide, shared by every backend)
# ---------------------------------------------------------------------------

_REG_LOCK = threading.Lock()
_BUCKETS: Dict[tuple, Bucket] = {}          # fingerprint -> bucket
_DESIGNS: "collections.OrderedDict[tuple, DesignVector]" = \
    collections.OrderedDict()
_DESIGNS_MAXSIZE = 4096
_STATS = {"designs_traced": 0, "buckets": 0}


def _clear_registries() -> None:
    with _REG_LOCK:
        _BUCKETS.clear()
        _DESIGNS.clear()
        _STATS["designs_traced"] = 0
        _STATS["buckets"] = 0


def bucket_stats() -> Dict[str, int]:
    """How many designs have been canonicalized and into how many buckets
    they collapsed (`buckets` << `designs_traced` is the win)."""
    with _REG_LOCK:
        return dict(_STATS, designs_registered=len(_DESIGNS))


def design_vector(design_key: tuple, make_scalar: Callable[[], Callable],
                  in_avals: Sequence[jax.ShapeDtypeStruct]) -> DesignVector:
    """Trace + canonicalize a design's scalar function (memoized).

    ``design_key`` identifies the design process-wide (the same keys used
    for the legacy per-design compiled store), ``make_scalar`` builds the
    scalar function to trace, ``in_avals`` are its data input avals.
    Tracing happens outside the registry lock (it is the expensive step);
    a concurrent duplicate trace is resolved at intern time.
    """
    with _REG_LOCK:
        dv = _DESIGNS.get(design_key)
        if dv is not None:
            _DESIGNS.move_to_end(design_key)
            return dv
    closed = jax.make_jaxpr(make_scalar())(*[
        jnp.zeros(a.shape, a.dtype) for a in in_avals])
    canonical, coeff_vals, coeff_avals, fp = _canonicalize(closed)
    classes, sizes, slots, packs = _pack(coeff_vals, coeff_avals)
    with _REG_LOCK:
        dv = _DESIGNS.get(design_key)
        if dv is not None:
            _DESIGNS.move_to_end(design_key)
            return dv
        bucket = _BUCKETS.get(fp)
        if bucket is None:
            bucket = Bucket(id=len(_BUCKETS), jaxpr=canonical,
                            classes=classes, class_sizes=sizes, slots=slots,
                            n_data=len(in_avals),
                            n_outs=len(canonical.outvars))
            _BUCKETS[fp] = bucket
            _STATS["buckets"] += 1
        _STATS["designs_traced"] += 1
        dv = DesignVector(bucket=bucket, packs=packs)
        _DESIGNS[design_key] = dv
        while len(_DESIGNS) > _DESIGNS_MAXSIZE:
            _DESIGNS.popitem(last=False)
        return dv


def bucket_builder(bucket: Bucket, n_dev: int = 1) -> Callable:
    """Build closure for a bucket's vmapped (``n_dev > 1``: pmapped)
    lazy wrapper — shared by `batch_entry` and the AOT prefetch path."""
    def build():
        inner = jax.vmap(bucket.scalar_fn())
        return jax.pmap(inner) if n_dev > 1 else jax.jit(inner)
    return build


def batch_entry(bucket: Bucket, n_dev: int = 1) -> "pathfinder.CompiledEntry":
    """The process-wide compiled entry for a bucket's vmapped executable.

    ``n_dev > 1`` wraps in `jax.pmap` (leading device axis); the entry
    lives in `pathfinder._COMPILED` under ``("cabucket", id, n_dev)`` so
    hit/miss/AOT accounting and LRU policy are shared with every other
    compiled function.
    """
    return pathfinder.compiled_entry(("cabucket", bucket.id, n_dev),
                                     bucket_builder(bucket, n_dev))


def design_batch_fn(design_key: tuple, make_scalar: Callable[[], Callable],
                    in_avals: Sequence[jax.ShapeDtypeStruct],
                    n_dev: int = 1) -> Callable:
    """Batched canonical dispatch for a single design.

    Returns ``fn(hw)`` accepting a batch of the design's (single) data
    input with 1 (jit) or 2 (pmap) leading batch dims; the design's
    coefficient packs are broadcast across the batch so the executable is
    the shared per-row bucket program (bit-identical to megabatched
    dispatch of the same bucket).
    """
    dv = design_vector(design_key, make_scalar, in_avals)
    entry = batch_entry(dv.bucket, n_dev)
    data_ndim = len(in_avals[0].shape)

    def fn(hw):
        lead = tuple(hw.shape[:hw.ndim - data_ndim])
        return entry(dv.broadcast_packs(lead), hw)

    return fn


# ---------------------------------------------------------------------------
# AOT compile service
# ---------------------------------------------------------------------------


class CompileService:
    """Background thread pool driving `.lower().compile()` off-path.

    `warm` registers (or fetches) a `CompiledEntry` and queues an AOT
    compile for one input-shape signature.  Dedupe is fleet-wide within
    the process: a (key, signature) already finished, in flight, or
    queued is not submitted again.  Every queued submission pins its
    store key (`pathfinder.pin_compiled`) so the LRU cannot evict the
    entry between build and first dispatch; the *dispatcher* releases the
    pin after first use (see `PipelineExecutor`), which is why `warm`
    reports whether it pinned.
    """

    def __init__(self, workers: Optional[int] = None):
        if workers is None:
            workers = int(os.environ.get("REPRO_COMPILE_WORKERS", "2"))
        self.workers = max(1, workers)
        self._q: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._pending: set = set()
        self._threads: List[threading.Thread] = []
        self._started = False

    def _ensure_threads(self) -> None:
        with self._lock:
            if self._started:
                return
            self._started = True
            for i in range(self.workers):
                t = threading.Thread(target=self._worker,
                                     name=f"compile-ahead-{i}", daemon=True)
                t.start()
                self._threads.append(t)

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.put(None)  # let sibling workers drain out too
                return
            entry, args, key, sig = item
            try:
                entry.compile_for(args)
            finally:
                with self._lock:
                    self._pending.discard((key, sig))

    def warm(self, key: tuple, build_wrapper: Callable[[], Callable],
             example_args: tuple) -> bool:
        """Queue an AOT compile of ``key`` for ``example_args``' shapes.

        ``example_args`` may be concrete arrays or `ShapeDtypeStruct`
        pytrees.  Returns True when a submission was queued (and the key
        pinned — the caller owes one `pathfinder.unpin_compiled(key)`
        after first dispatch), False when it was already warm/in flight.
        """
        entry = pathfinder.compiled_entry(key, build_wrapper)
        sig = entry.signature(example_args)
        with self._lock:
            if (key, sig) in self._pending or sig in entry.aot:
                return False
            self._pending.add((key, sig))
        pathfinder.pin_compiled(key)
        self._ensure_threads()
        self._q.put((entry, example_args, key, sig))
        return True

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every queued compile finished (tests/benchmarks)."""
        import time as _time
        deadline = None if timeout is None else _time.monotonic() + timeout
        while True:
            with self._lock:
                if not self._pending:
                    return True
            if deadline is not None and _time.monotonic() > deadline:
                return False
            _time.sleep(0.005)

    def shutdown(self) -> None:
        if self._started:
            self._q.put(None)


_SERVICE: Optional[CompileService] = None
_SERVICE_LOCK = threading.Lock()


def service() -> CompileService:
    """The process-wide compile service (workers via REPRO_COMPILE_WORKERS;
    fabric worker processes each get their own, inherited through this
    module the same way `pathfinder._COMPILED` is)."""
    global _SERVICE
    with _SERVICE_LOCK:
        if _SERVICE is None:
            _SERVICE = CompileService()
        return _SERVICE
