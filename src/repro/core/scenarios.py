"""Scenario registry — named workload scenarios for design-space sweeps.

The paper's §9 studies sweep *training* iteration time; full-stack co-design
studies (DFModel, COSMIC) also need *inference/serving* workloads, where the
objectives are latency-SLO attainment and tokens/sec/device rather than
step time.  A `Scenario` packages, for one named workload:

  * which shape cells an architecture runs (training cell, or a
    prefill + decode pair for serving),
  * how one labeled design point expands into batched-engine `EvalPoint`s,
  * how raw metric rows fold back into a result record, and
  * the objective fields a Pareto frontier should minimize.

`repro.core.sweeprunner` drives every registered architecture config in
`src/repro/configs/` through a scenario; the CLI exposes it as
``python -m repro.pathfind sweep --scenario serving ...``.

The serving scenario is the paper-model's inference mode: the prefill phase
is a `prefill`-kind graph (TTFT objective), the decode phase a `decode`-kind
graph (one token per sequence per step), and KV-cache *capacity* pressure —
weights + KV resident bytes vs per-device main memory — derates decode
bandwidth via `roofline.capacity_pressure_derate` (the decode graph's
attention GEMMs already charge KV *bandwidth* per step).
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig, SHAPE_CELLS, get_config
from repro.core import lmgraph, simulate, traffic
from repro.core import objectives as objectives_lib
from repro.core.age import MicroArch
from repro.core.graph import ComputeGraph
from repro.core.parallelism import Strategy
from repro.core.pathfinder import EvalPoint
from repro.core.placement import SystemGraph

DTYPE_BYTES = 2                     # bf16 weights / KV cache


def point_key(arch: str, cell: str, mesh: Tuple[int, ...], logic: str,
              hbm: str, net: str, scale: float, strategy_name: str) -> str:
    """THE design-point identity string.

    Both `DesignPoint.key` (result records) and
    `sweeprunner.PointLabel.key` (checkpoint chunk hashes) delegate here —
    resume correctness depends on the two staying byte-identical, so there
    is exactly one formatter.
    """
    return "|".join([arch, cell, "x".join(map(str, mesh)), logic, hbm,
                     net, f"{scale:g}", strategy_name])


# ---------------------------------------------------------------------------
# Labeled design points
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One fully-resolved sweep candidate (labels + live objects)."""

    arch: str                       # model architecture id
    cell: str                       # cell name, or "prefill+decode" pair id
    mesh: Tuple[int, ...]
    logic: str
    hbm: str
    net: str
    scale: float                    # budget-scale variant (1.0 = nominal)
    strategy: Strategy
    cfg: ArchConfig
    hw: MicroArch
    system: SystemGraph

    def key(self) -> str:
        """Stable identity used in result records and resume bookkeeping."""
        return point_key(self.arch, self.cell, self.mesh, self.logic,
                         self.hbm, self.net, self.scale,
                         self.strategy.name)

    def label_fields(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "cell": self.cell,
            "mesh": "x".join(map(str, self.mesh)),
            "logic": self.logic, "hbm": self.hbm, "net": self.net,
            "scale": self.scale, "strategy": self.strategy.name,
            "devices": self.strategy.devices,
        }


# graphs are immutable once built; share them across threads and chunks
_GRAPH_CACHE: Dict[Tuple[str, str], ComputeGraph] = {}
_GRAPH_LOCK = threading.Lock()


def workload_graph(arch: str, cell_name: str) -> ComputeGraph:
    key = (arch, cell_name)
    with _GRAPH_LOCK:
        g = _GRAPH_CACHE.get(key)
    if g is None:
        g = lmgraph.build_graph(get_config(arch), SHAPE_CELLS[cell_name])
        with _GRAPH_LOCK:
            g = _GRAPH_CACHE.setdefault(key, g)
    return g


# ---------------------------------------------------------------------------
# Serving memory model
# ---------------------------------------------------------------------------


def weight_bytes(cfg: ArchConfig, dtype_bytes: int = DTYPE_BYTES) -> float:
    """Resident parameter bytes of one full replica."""
    return float(cfg.param_count()) * dtype_bytes


def kv_cache_bytes(cfg: ArchConfig, kv_len: int, batch: int,
                   dtype_bytes: int = DTYPE_BYTES) -> float:
    """Total KV-cache (+ recurrent-state) bytes for `batch` live sequences.

    Attention layers hold K+V per token: global layers over the full
    context, local layers over min(context, window).  Recurrent blocks
    (RG-LRU, m/sLSTM) hold O(1)-per-sequence state instead — this is
    exactly why hybrid archs win the long-context serving sweeps.
    """
    hd = cfg.resolved_head_dim
    if cfg.is_encoder_decoder:
        # the decoder holds self-KV over the trained decoder length plus
        # cross-KV over the encoded source sequence; its layers must NOT
        # also be charged the decoder-only full-context KV below
        dec = min(cfg.decoder_len, kv_len)
        per_seq = cfg.n_layers * 2.0 * cfg.n_kv_heads * hd * \
            (dec + kv_len) * dtype_bytes
        return per_seq * batch
    per_seq = 0.0
    for i in range(cfg.n_layers):
        bk = cfg.block_kind(i)
        if bk == "attn":
            ctx = kv_len
            if cfg.attn_kind(i) == "local":
                ctx = min(kv_len, cfg.local_window)
            per_seq += 2.0 * cfg.n_kv_heads * hd * ctx * dtype_bytes
        elif bk == "rglru":
            w = cfg.lru_width or cfg.d_model
            per_seq += (w + cfg.conv1d_width * w) * 4  # f32 carry state
        else:                                          # mlstm / slstm
            per_seq += cfg.n_heads * hd * hd * 4
    return per_seq * batch


def _kv_shard_degree(cfg: ArchConfig, st: Strategy) -> int:
    """How many ways the KV cache is split: DP/LP always shard batch and
    layers; the model axis shards KV heads only up to n_kv_heads (GQA
    floor) unless sequence parallelism shards the context dim instead."""
    kp_shard = min(st.kp, max(cfg.n_kv_heads, 1))
    if st.sp > 1:
        kp_shard = st.kp
    return st.dp * st.lp * max(kp_shard, 1)


def serving_bytes_per_device(cfg: ArchConfig, st: Strategy,
                             cell) -> Tuple[float, float]:
    """(weight bytes, KV-cache bytes) resident per device for one decode
    cell under one strategy — the serving capacity model shared by
    `ServingScenario.record` and the cooptimize refinement objective."""
    w_dev = weight_bytes(cfg) / max(st.kp * st.lp, 1)
    kv_dev = kv_cache_bytes(cfg, cell.seq_len, cell.global_batch) \
        / _kv_shard_degree(cfg, st)
    return w_dev, kv_dev


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------


class Scenario:
    """One named workload: cells, eval-point expansion, record schema."""

    name: str = ""
    description: str = ""
    # record fields holding metrics (after the shared label fields)
    fields: Tuple[str, ...] = ()
    # record fields a Pareto frontier optimizes (canonically minimized;
    # max-direction registry objectives are sign-flipped by
    # `objective_values` / the frontier folds — see repro.core.objectives)
    objectives: Tuple[str, ...] = ()
    # the continuous subset of `objectives` that `refine_objectives` folds
    # (discrete objectives like device count are fixed within a refinement)
    refine_objective_fields: Tuple[str, ...] = ()
    # which per-unit ctx the objective registry folds read: "step"
    # (training iterations) or "token" (serving) — picks the alias family
    # `--objectives energy,cost,goodput` resolves through
    objective_kind: str = "step"
    # set by `with_objectives`: composed registry objectives + their params
    _custom: bool = False
    extra_objectives: Tuple = ()
    obj_params: Optional[Dict[str, float]] = None
    _obj_signs: Tuple[float, ...] = ()

    # hardware ctx keys the objective folds read (all are HW_FIELDS, so
    # every fold variant — scalar record, vectorized metrics, traced
    # frontier — reads them from the same packed columns)
    _CTX_HW_KEYS: Tuple[str, ...] = (
        "compute_throughput", "dram_bw", "net_inter_bw", "dram_capacity",
        "energy_per_flop", "dram_energy_per_byte", "net_energy_per_byte",
        "static_power_w", "device_cost_usd")

    # ------------------------------------------------ objective layer
    def with_objectives(self, names: Optional[Sequence[str]] = None,
                        params: Optional[Mapping[str, float]] = None
                        ) -> "Scenario":
        """Compose registry objectives onto a copy of this scenario.

        ``names`` (aliases like "energy"/"cost"/"goodput", canonical
        registry names, or this scenario's own base objective fields)
        REPLACE the objective tuple; registry objectives among them (plus
        their deps) are appended to ``fields`` and computed by every fold
        variant.  With ``names=None`` the base objectives stand and only
        the objective model params change.  Returns ``self`` untouched
        when nothing changes — the default scenarios stay the shared
        singletons with byte-identical PR7 behavior.
        """
        import copy
        base_objectives = self.objectives
        resolved = objectives_lib.resolve_names(
            names, self.objective_kind, base_objectives) \
            if names else base_objectives
        merged = {**objectives_lib.PARAM_DEFAULTS, **dict(params or {})}
        if resolved == base_objectives and not params:
            return self
        scn = copy.copy(self)
        scn.objectives = resolved
        scn.obj_params = merged
        scn.extra_objectives = objectives_lib.computation_order(resolved)
        scn.fields = self.fields + tuple(
            o.name for o in scn.extra_objectives
            if o.name not in self.fields)
        refine = []
        for n in resolved:
            o = objectives_lib.REGISTRY.get(n)
            if o is not None:
                if o.continuous:
                    refine.append(n)
            elif n in type(self).refine_objective_fields:
                refine.append(n)
        scn.refine_objective_fields = tuple(refine)
        scn._obj_signs = objectives_lib.canonical_signs(resolved)
        scn._custom = (resolved != base_objectives
                       or bool(scn.extra_objectives))
        return scn

    def _objective_consts(self, cfg: ArchConfig,
                          strategy: Strategy) -> Dict[str, float]:
        """Host-constant ctx entries of one design: the objective model
        params plus the goodput derate (checkpoint write/restore timings
        from `repro.checkpoint.manager` over `repro.runtime.fault`'s
        fleet-MTBF model).  No hardware dependence — computed once per
        fold closure."""
        from repro.checkpoint import manager as ckpt_manager
        from repro.runtime import fault
        p = dict(self.obj_params or objectives_lib.PARAM_DEFAULTS)
        devices = float(strategy.devices)
        # train checkpoints optimizer state (bf16 weights + f32 master +
        # Adam moments ~ 12 B/param); serving restores bf16 weights only
        per_param = 12.0 if self.objective_kind == "step" \
            else float(DTYPE_BYTES)
        ckpt_bytes = float(cfg.param_count()) * per_param
        write_s = ckpt_manager.checkpoint_write_s(
            ckpt_bytes, devices, p["ckpt_write_gbps"])
        restore_s = ckpt_manager.checkpoint_restore_s(
            ckpt_bytes, devices, p["ckpt_read_gbps"])
        mtbf = fault.fleet_mtbf_s(p["device_mtbf_s"], devices)
        if self.objective_kind == "step":
            frac = fault.goodput_fraction(write_s, restore_s, mtbf)
        else:
            frac = fault.availability(restore_s, mtbf)
        p.update({"devices": devices, "goodput_fraction": frac,
                  "ckpt_write_s": write_s, "ckpt_restore_s": restore_s,
                  "fleet_mtbf_s": mtbf})
        return p

    def _objective_extras_scalar(self, dp: "DesignPoint",
                                 units: Dict[str, float]) -> Dict[str, float]:
        """Registry objective values for one scalar record.

        Hardware inputs are rounded through f32 (`pack_hw` packs f32
        columns) so this path is bitwise identical to the vectorized
        metrics fold reading those columns back as f64.
        """
        from repro.core import pathfinder

        def r32(x) -> float:
            return float(np.float32(x))

        ctx: Dict[str, object] = {
            k: r32(v) for k, v in pathfinder.hw_coeffs(dp.hw).items()}
        ctx["compute_throughput"] = r32(dp.hw.compute_throughput)
        ctx["dram_bw"] = r32(dp.hw.dram_bw)
        ctx["net_inter_bw"] = r32(dp.hw.net_inter_bw)
        ctx["dram_capacity"] = r32(dp.hw.dram_capacity)
        ctx.update(self._objective_consts(dp.cfg, dp.strategy))
        ctx.update(units)
        vals = objectives_lib.evaluate(np, self.extra_objectives, ctx)
        return {k: float(v) for k, v in vals.items()}

    def _wrap_metrics_fold(self, base_fold, cfg: ArchConfig,
                           strategy: Strategy, units_fn):
        """Extend a legacy vectorized metrics fold with the composed
        registry objectives (no-op passthrough on default scenarios).

        ``units_fn(rows, recs) -> {unit: (B,) f64}`` supplies the
        scenario-kind unit values; hardware coefficients come from the
        packed f32 hw columns, mirroring `_objective_extras_scalar`
        op-for-op.
        """
        if not self._custom or base_fold is None:
            return base_fold
        from repro.core import pathfinder
        idx = {k: pathfinder.HW_FIELDS.index(k)
               for k in self._CTX_HW_KEYS}
        consts = self._objective_consts(cfg, strategy)
        extras = self.extra_objectives

        def fold(rows, hw):
            recs = base_fold(rows, hw)
            ctx: Dict[str, object] = {
                k: hw[:, i].astype(np.float64) for k, i in idx.items()}
            ctx.update(consts)
            with np.errstate(divide="ignore", invalid="ignore",
                             over="ignore"):
                ctx.update(units_fn(rows, recs))
                vals = objectives_lib.evaluate(np, extras, ctx)
            cols = {k: np.asarray(v) for k, v in vals.items()}
            for i, r in enumerate(recs):
                for k, col in cols.items():
                    r[k] = float(col[i])
            return recs
        return fold

    def _custom_frontier_fold(self, cfg: ArchConfig, strategy: Strategy,
                              values_fn):
        """Traced frontier fold over a composed objective set.

        ``values_fn(jnp, rows, ctx) -> (values, ok)`` supplies the base
        objective/unit values from one design's metric rows (ctx already
        holds the hardware coefficients + per-design consts); composed
        registry objectives are evaluated on top, canonical signs applied
        (max-direction negated), and everything outside the feasible/SLO
        region masks to +inf so the device Pareto merge excludes it.
        """
        import jax.numpy as jnp
        from repro.core import pathfinder
        idx = {k: pathfinder.HW_FIELDS.index(k)
               for k in self._CTX_HW_KEYS}
        consts = self._objective_consts(cfg, strategy)
        extras = self.extra_objectives
        names = self.objectives
        signs = objectives_lib.canonical_signs(names)

        def fold(rows, hw_vec):
            ctx: Dict[str, object] = {k: hw_vec[i] for k, i in idx.items()}
            ctx.update(consts)
            values, ok = values_fn(jnp, rows, ctx)
            ctx.update(values)
            objectives_lib.evaluate(jnp, extras, ctx)
            outs = [jnp.where(ok, s * jnp.asarray(ctx[n],
                                                  dtype=jnp.float32),
                              jnp.inf)
                    for s, n in zip(signs, names)]
            return jnp.stack(outs)
        return fold

    def _custom_refine_fold(self, dp: "DesignPoint", units_fn):
        """Differentiable refine fold over a composed objective set.

        ``units_fn(jnp, bds, ctx) -> values`` maps the per-eval-point
        `simulate.TimeBreakdown`s (soft-derated, barrier-penalized —
        gradients must point back into the feasible region) to base
        objective/unit values; registry objectives evaluate on top of the
        LIVE hardware ctx (`pathfinder.hw_ctx`), so DVFS voltage reaches
        energy through `techlib.dynamic_energy_scale`.  Returns canonical
        (sign-applied) scalars ordered like `refine_objective_fields`.
        """
        import jax.numpy as jnp
        consts = self._objective_consts(dp.cfg, dp.strategy)
        extras = self.extra_objectives
        fields = self.refine_objective_fields
        signs = objectives_lib.canonical_signs(fields)

        def fold(bds, ctx):
            vals: Dict[str, object] = dict(ctx)
            vals.update(consts)
            vals.update(units_fn(jnp, bds, vals))
            objectives_lib.evaluate(jnp, extras, vals)
            return tuple(s * vals[f] for s, f in zip(signs, fields))
        return fold

    def cells(self, cfg: ArchConfig) -> Tuple[str, ...]:
        """Shape cells this scenario needs for one architecture."""
        raise NotImplementedError

    def cell_id(self) -> str:
        """The label used in point keys / records for this scenario."""
        return "+".join(self.cells(None))

    def points_per_design(self) -> int:
        """How many EvalPoints one design point expands to."""
        raise NotImplementedError

    def applicable(self, cfg: ArchConfig) -> bool:
        return True

    def eval_points(self, dp: DesignPoint) -> List[EvalPoint]:
        raise NotImplementedError

    def record(self, dp: DesignPoint, rows: np.ndarray) -> Dict:
        """Fold the (points_per_design, 5) metric rows into one record."""
        raise NotImplementedError

    def objective_values(self, rec: Dict) -> Optional[Tuple[float, ...]]:
        """This scenario's Pareto objective tuple for one result record,
        or None if the record is infeasible / has missing or non-finite
        objectives (mirrors the `sweeprunner.pareto_records` filter)."""
        if not rec.get("feasible", True):
            return None
        try:
            vs = tuple(float(rec[k]) for k in self.objectives)
        except (KeyError, TypeError, ValueError):
            return None
        if not all(np.isfinite(v) for v in vs):
            return None
        signs = self._obj_signs
        if signs and any(s != 1.0 for s in signs):
            vs = tuple(s * v for s, v in zip(signs, vs))
        return vs

    def refine_objectives(self, dp: DesignPoint):
        """Differentiable objective fold for cross-stack refinement
        (`repro.core.cooptimize`).

        Returns ``fold(bds, ctx) -> tuple`` mapping the per-eval-point
        predicted `simulate.TimeBreakdown`s (one per `eval_points` entry)
        and the candidate's traced hardware ctx (`pathfinder.hw_ctx` —
        capacity, bandwidths, energy coefficients, all theta-dependent)
        to this scenario's *continuous* objective scalars, ordered like
        `refine_objective_fields` (discrete objectives such as device
        count are omitted — they are fixed within one refinement).
        Max-direction objectives are sign-flipped: every scalar is
        canonically minimized.
        """
        raise NotImplementedError

    def frontier_fold(self, cfg: ArchConfig, strategy: Strategy):
        """Traceable objective fold for the device-resident streaming
        frontier (`repro.core.sweeppipeline`, ``pathfind sweep
        --frontier-only``).

        Returns ``fold(rows, hw_vec) -> (n_obj,) jnp vector`` mapping one
        design's ``(points_per_design, 5)`` metric rows and its packed
        hardware vector (`pathfinder.HW_FIELDS` order) to the FULL
        `objectives` tuple — fused into the compiled eval fn, so frontier
        sweeps never pull per-point rows to host.  Must mirror
        `objective_values` exactly: an infeasible/unusable record maps to
        a non-finite objective (the frontier merge excludes it).  ``None``
        = this scenario has no device fold (frontier-only unsupported).
        """
        return None

    def metrics_fold(self, cfg: ArchConfig, strategy: Strategy, cell_id):
        """Host-side vectorized fold for the pipelined executor's record
        stage.

        Returns ``fold(rows, hw) -> List[Dict]`` mapping a batch of
        ``(B, points_per_design, 5)`` metric rows and the matching
        ``(B, HW_DIM)`` packed hardware matrix to exactly the metric
        fields `record` appends after the label fields (same keys, same
        order, same values — parity-tested per scenario).  Per-design
        constants are captured at skeleton-build time and the arithmetic
        runs over the whole batch in NumPy, so the per-label cost is one
        dict literal.  ``None`` = no fast fold; the executor falls back
        to `record` on a resolved `DesignPoint`.
        """
        return None


class TrainScenario(Scenario):
    """Per-iteration training step time (the paper's Fig. 9 axis)."""

    name = "train"
    description = "training step time on one shape cell"
    fields = ("time_s", "compute_s", "comm_s", "exposed_comm_s")
    objectives = ("time_s", "devices")
    refine_objective_fields = ("time_s",)

    def __init__(self, cell: str = "train_4k", name: str = "train"):
        self.cell = cell
        self.name = name

    def _step_tokens(self) -> float:
        cell = SHAPE_CELLS[self.cell]
        return float(cell.global_batch) * cell.seq_len

    def cells(self, cfg) -> Tuple[str, ...]:
        return (self.cell,)

    def cell_id(self) -> str:
        return self.cell

    def points_per_design(self) -> int:
        return 1

    def eval_points(self, dp: DesignPoint) -> List[EvalPoint]:
        g = workload_graph(dp.arch, self.cell)
        return [EvalPoint(dp.hw, g, dp.strategy, system=dp.system)]

    def record(self, dp: DesignPoint, rows: np.ndarray) -> Dict:
        row = rows[0]
        rec = {**dp.label_fields(),
               "time_s": float(row[0]), "compute_s": float(row[1]),
               "comm_s": float(row[2]), "exposed_comm_s": float(row[3])}
        if not self._custom:
            return rec
        tokens = self._step_tokens()
        t = float(row[0])
        with np.errstate(divide="ignore", invalid="ignore"):
            base = float(np.float64(tokens) / np.float64(t))
        rec.update(self._objective_extras_scalar(dp, {
            "step_time_s": t, "step_compute_s": float(row[1]),
            "step_comm_s": float(row[2]), "base_tokens_per_s": base}))
        return rec

    def refine_objectives(self, dp: DesignPoint):
        if self._custom:
            tokens = self._step_tokens()
            devices = float(dp.strategy.devices)

            def units(jnp, bds, vals):
                t = bds[0].total_s
                return {"time_s": t, "devices": devices,
                        "step_time_s": t,
                        "step_compute_s": bds[0].compute_s,
                        "step_comm_s": bds[0].comm_s,
                        "base_tokens_per_s": tokens / t}
            return self._custom_refine_fold(dp, units)

        def fold(bds, ctx):
            return (bds[0].total_s,)               # step time; devices fixed
        return fold

    def frontier_fold(self, cfg: ArchConfig, strategy: Strategy):
        import jax.numpy as jnp
        devices = float(strategy.devices)
        if self._custom:
            tokens = self._step_tokens()

            def values_fn(jnp, rows, ctx):
                t = rows[0, 0]
                return ({"time_s": t, "devices": devices,
                         "step_time_s": t, "step_compute_s": rows[0, 1],
                         "step_comm_s": rows[0, 2],
                         "base_tokens_per_s": tokens / t},
                        jnp.isfinite(t))
            return self._custom_frontier_fold(cfg, strategy, values_fn)

        def fold(rows, hw_vec):
            return jnp.stack([rows[0, 0], jnp.float32(devices)])
        return fold

    def metrics_fold(self, cfg: ArchConfig, strategy: Strategy, cell_id):
        def fold(rows, hw):
            return [{"time_s": r[0], "compute_s": r[1], "comm_s": r[2],
                     "exposed_comm_s": r[3]}
                    for r in rows[:, 0, :4].tolist()]
        if not self._custom:
            return fold
        tokens = self._step_tokens()

        def units(rows, recs):
            t = rows[:, 0, 0].astype(np.float64)
            return {"step_time_s": t,
                    "step_compute_s": rows[:, 0, 1].astype(np.float64),
                    "step_comm_s": rows[:, 0, 2].astype(np.float64),
                    "base_tokens_per_s": tokens / t}
        return self._wrap_metrics_fold(fold, cfg, strategy, units)


class ServingScenario(Scenario):
    """Prefill + decode inference: TTFT / TPOT / tokens-per-sec-per-device
    with KV-cache memory pressure (see module docstring)."""

    name = "serving"
    description = "prefill+decode serving: TTFT, tokens/s/device, KV pressure"
    fields = ("ttft_s", "tpot_s", "tokens_per_s", "tokens_per_s_per_device",
              "cost_device_s_per_token", "hbm_occupancy", "kv_derate",
              "feasible", "slo_ok")
    objectives = ("ttft_s", "cost_device_s_per_token")
    refine_objective_fields = ("ttft_s", "cost_device_s_per_token")
    objective_kind = "token"

    def __init__(self, prefill_cell: str = "prefill_32k",
                 decode_cell: str = "decode_32k",
                 slo_s: Optional[float] = None, name: str = "serving"):
        self.prefill_cell = prefill_cell
        self.decode_cell = decode_cell
        self.slo_s = slo_s
        self.name = name

    def cells(self, cfg) -> Tuple[str, ...]:
        return (self.prefill_cell, self.decode_cell)

    def cell_id(self) -> str:
        return f"{self.prefill_cell}+{self.decode_cell}"

    def points_per_design(self) -> int:
        return 2

    def applicable(self, cfg: ArchConfig) -> bool:
        if "long" in (self.prefill_cell + self.decode_cell):
            return cfg.supports_long_context
        return True

    def eval_points(self, dp: DesignPoint) -> List[EvalPoint]:
        gp = workload_graph(dp.arch, self.prefill_cell)
        gd = workload_graph(dp.arch, self.decode_cell)
        return [EvalPoint(dp.hw, gp, dp.strategy, system=dp.system),
                EvalPoint(dp.hw, gd, dp.strategy, system=dp.system)]

    def record(self, dp: DesignPoint, rows: np.ndarray) -> Dict:
        prefill = simulate.TimeBreakdown(
            total_s=rows[0][0], compute_s=rows[0][1], comm_s=rows[0][2],
            exposed_comm_s=rows[0][3])
        decode = simulate.TimeBreakdown(
            total_s=rows[1][0], compute_s=rows[1][1], comm_s=rows[1][2],
            exposed_comm_s=rows[1][3])
        cell = SHAPE_CELLS[self.decode_cell]
        st = dp.strategy
        w_dev, kv_dev = serving_bytes_per_device(dp.cfg, st, cell)
        bd = simulate.serving_breakdown(
            prefill, decode, batch=cell.global_batch, devices=st.devices,
            weight_bytes_per_device=w_dev, kv_bytes_per_device=kv_dev,
            dram_capacity=float(dp.hw.dram_capacity), slo_s=self.slo_s)
        rec = {**dp.label_fields(),
               "ttft_s": bd.ttft_s, "tpot_s": bd.tpot_s,
               "tokens_per_s": bd.tokens_per_s,
               "tokens_per_s_per_device": bd.tokens_per_s_per_device,
               "cost_device_s_per_token": bd.cost_device_s_per_token,
               "kv_bytes_per_device": bd.kv_bytes_per_device,
               "weight_bytes_per_device": bd.weight_bytes_per_device,
               "hbm_occupancy": bd.hbm_occupancy,
               "kv_derate": bd.kv_derate,
               "feasible": bd.feasible, "slo_ok": bd.slo_ok}
        if not self._custom:
            return rec
        batch = float(max(cell.global_batch, 1))
        rec.update(self._objective_extras_scalar(dp, {
            "token_compute_s": float(rows[1][1]) / batch,
            "token_comm_s": float(rows[1][2]) / batch,
            "device_s_per_token": float(bd.cost_device_s_per_token),
            "base_tokens_per_s": float(bd.tokens_per_s)}))
        return rec

    def refine_objectives(self, dp: DesignPoint):
        from repro.core import roofline
        import jax.numpy as jnp
        cell = SHAPE_CELLS[self.decode_cell]
        w_dev, kv_dev = serving_bytes_per_device(dp.cfg, dp.strategy, cell)
        devices = dp.strategy.devices
        batch = max(cell.global_batch, 1)
        if self._custom:
            def units(jnp, bds, vals):
                occ = (w_dev + kv_dev) \
                    / jnp.maximum(vals["dram_capacity"], 1.0)
                tpot = bds[1].total_s \
                    * roofline.capacity_pressure_derate_soft(occ)
                cost = devices * tpot / batch
                return {"ttft_s": bds[0].total_s,
                        "cost_device_s_per_token": cost,
                        "token_compute_s": bds[1].compute_s / batch,
                        "token_comm_s": bds[1].comm_s / batch,
                        "device_s_per_token": cost,
                        "base_tokens_per_s": batch / tpot}
            return self._custom_refine_fold(dp, units)

        def fold(bds, ctx):
            occ = (w_dev + kv_dev) / jnp.maximum(ctx["dram_capacity"], 1.0)
            tpot = bds[1].total_s \
                * roofline.capacity_pressure_derate_soft(occ)
            ttft = bds[0].total_s
            return (ttft, devices * tpot / batch)   # (ttft_s, cost/token)
        return fold

    def frontier_fold(self, cfg: ArchConfig, strategy: Strategy):
        from repro.core import pathfinder, roofline
        import jax.numpy as jnp
        cell = SHAPE_CELLS[self.decode_cell]
        w_dev, kv_dev = serving_bytes_per_device(cfg, strategy, cell)
        devices = float(strategy.devices)
        batch = float(cell.global_batch)
        knee = roofline.CAPACITY_PRESSURE_KNEE
        cap_i = pathfinder.HW_FIELDS.index("dram_capacity")
        if self._custom:
            def values_fn(jnp, rows, ctx):
                occ = (w_dev + kv_dev) \
                    / jnp.maximum(ctx["dram_capacity"], 1.0)
                over = jnp.maximum(occ - knee, 0.0) / max(1.0 - knee, 1e-9)
                derate = jnp.where(occ >= 1.0, jnp.inf,
                                   1.0 + 0.5 * over * over)
                ttft = rows[0, 0]
                tpot = rows[1, 0] * derate
                cost = devices * tpot / max(batch, 1.0)
                ok = jnp.isfinite(tpot) & jnp.isfinite(ttft)
                return ({"ttft_s": ttft, "tpot_s": tpot,
                         "cost_device_s_per_token": cost,
                         "token_compute_s": rows[1, 1] / max(batch, 1.0),
                         "token_comm_s": rows[1, 2] / max(batch, 1.0),
                         "device_s_per_token": cost,
                         "base_tokens_per_s": batch / tpot}, ok)
            return self._custom_frontier_fold(cfg, strategy, values_fn)

        def fold(rows, hw_vec):
            # the exact (hard-walled) capacity derate of `record` /
            # `simulate.serving_breakdown`, in traceable jnp: infeasible
            # points fold to +inf objectives and never enter the frontier
            occ = (w_dev + kv_dev) / jnp.maximum(hw_vec[cap_i], 1.0)
            over = jnp.maximum(occ - knee, 0.0) / max(1.0 - knee, 1e-9)
            derate = jnp.where(occ >= 1.0, jnp.inf,
                               1.0 + 0.5 * over * over)
            ttft = rows[0, 0]
            tpot = rows[1, 0] * derate
            cost = devices * tpot / batch if batch \
                else jnp.full((), jnp.inf, dtype=jnp.float32)
            return jnp.stack([ttft, cost])
        return fold

    def metrics_fold(self, cfg: ArchConfig, strategy: Strategy, cell_id):
        from repro.core import pathfinder, roofline
        cell = SHAPE_CELLS[self.decode_cell]
        w_dev, kv_dev = serving_bytes_per_device(cfg, strategy, cell)
        w_f, kv_f = float(w_dev), float(kv_dev)
        cap_i = pathfinder.HW_FIELDS.index("dram_capacity")
        batch, devices = cell.global_batch, strategy.devices
        knee = roofline.CAPACITY_PRESSURE_KNEE
        slo_s = self.slo_s

        def fold(rows, hw):
            # `simulate.serving_breakdown` over the whole batch at once;
            # every expression mirrors the scalar path op-for-op so the
            # IEEE results (and so the records) are bit-identical
            cap = np.maximum(hw[:, cap_i].astype(np.float64), 1.0)
            occ = (w_f + kv_f) / cap
            over = np.maximum(occ - knee, 0.0) / max(1.0 - knee, 1e-9)
            derate = np.where(occ >= 1.0, np.inf, 1.0 + 0.5 * over * over)
            ttft = rows[:, 0, 0]
            tpot = rows[:, 1, 0] * derate
            feasible = np.isfinite(tpot) & np.isfinite(ttft)
            with np.errstate(divide="ignore", invalid="ignore"):
                tokens = np.where(feasible & (tpot > 0), batch / tpot, 0.0)
                cost = np.where(feasible & (batch > 0),
                                devices * tpot / batch, np.inf)
            per_dev = tokens / max(devices, 1)
            slo = [None] * len(occ) if slo_s is None \
                else (ttft <= slo_s).tolist()
            return [
                {"ttft_s": t, "tpot_s": tp, "tokens_per_s": tk,
                 "tokens_per_s_per_device": pd,
                 "cost_device_s_per_token": c,
                 "kv_bytes_per_device": kv_f,
                 "weight_bytes_per_device": w_f,
                 "hbm_occupancy": o, "kv_derate": dr,
                 "feasible": f, "slo_ok": s}
                for t, tp, tk, pd, c, o, dr, f, s in zip(
                    ttft.tolist(), tpot.tolist(), tokens.tolist(),
                    per_dev.tolist(), cost.tolist(), occ.tolist(),
                    derate.tolist(), feasible.tolist(), slo)]
        if not self._custom:
            return fold
        batch_f = float(max(batch, 1))

        def units(rows, recs):
            return {
                "token_compute_s": rows[:, 1, 1].astype(np.float64)
                / batch_f,
                "token_comm_s": rows[:, 1, 2].astype(np.float64) / batch_f,
                "device_s_per_token": np.array(
                    [r["cost_device_s_per_token"] for r in recs],
                    dtype=np.float64),
                "base_tokens_per_s": np.array(
                    [r["tokens_per_s"] for r in recs], dtype=np.float64)}
        return self._wrap_metrics_fold(fold, cfg, strategy, units)


class ServingTrafficScenario(ServingScenario):
    """Traffic-driven continuous-batching serving (`repro.core.traffic`).

    Same prefill/decode phase costs and KV-capacity derate as `serving`,
    but scored against a request arrival process: Poisson QPS, lognormal
    prompt/output lengths, chunked prefill riding decode steps.  Records
    carry TTFT/TPOT *percentiles*, Erlang utilization, the max sustainable
    QPS, and the raw phase costs (``prefill_s`` / derated
    ``decode_step_s``) the inverse fleet-sizing query replays without
    re-evaluating any sweep point.  Configured percentile SLOs act as
    feasibility walls: violating records keep their metrics but fold to
    non-finite objectives (excluded from every frontier).
    """

    name = "serving-traffic"
    description = ("continuous-batching serving under a QPS arrival "
                   "process: TTFT/TPOT percentiles, SLO walls, fleet cost")
    fields = ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
              "util", "qps_max", "tokens_per_s", "tokens_per_s_per_device",
              "cost_device_s_per_token", "prefill_s", "decode_step_s",
              "hbm_occupancy", "kv_derate", "feasible", "slo_ok")
    objectives = ("ttft_p99_s", "cost_device_s_per_token")
    refine_objective_fields = ("ttft_p99_s", "cost_device_s_per_token")

    def __init__(self, prefill_cell: str = "prefill_32k",
                 decode_cell: str = "decode_32k",
                 params: Optional[Mapping] = None,
                 name: str = "serving-traffic",
                 variant: Optional[Mapping[str, float]] = None):
        self.prefill_cell = prefill_cell
        self.decode_cell = decode_cell
        self.params = {**traffic.PARAM_DEFAULTS, **(params or {})}
        self.traffic, self.policy, self.slo = \
            traffic.split_params(self.params)
        self.slo_s = self.slo.get("ttft_p99")    # legacy single-SLO view
        self.name = name
        self.variant = dict(variant or {})

    def cell_id(self) -> str:
        return traffic.encode_variant(
            f"{self.prefill_cell}+{self.decode_cell}", self.variant)

    def _consts(self, devices: float) -> traffic.ServeConsts:
        pc = SHAPE_CELLS[self.prefill_cell]
        dc = SHAPE_CELLS[self.decode_cell]
        return traffic.build_consts(
            self.traffic, self.policy, slots=dc.global_batch,
            prefill_tokens=float(pc.global_batch) * pc.seq_len,
            devices=devices)

    def _amortize_consts(self) -> Tuple[float, float]:
        """(decode slots, prefill-steps-per-output-token) for the energy
        attribution: decode-step compute/comm is shared by the batch
        slots; prefill work amortizes as (prompt_mean / prefill_tokens)
        prefill-graph executions per request over its output_mean
        generated tokens."""
        pc = SHAPE_CELLS[self.prefill_cell]
        dc = SHAPE_CELLS[self.decode_cell]
        prefill_tokens = max(float(pc.global_batch) * pc.seq_len, 1.0)
        k = (float(self.traffic.prompt_mean) / prefill_tokens) \
            / max(float(self.traffic.output_mean), 1.0)
        return float(max(dc.global_batch, 1)), k

    def objective_values(self, rec: Dict) -> Optional[Tuple[float, ...]]:
        if rec.get("slo_ok") is False:           # percentile walls are
            return None                          # feasibility walls here
        return super().objective_values(rec)

    def record(self, dp: DesignPoint, rows: np.ndarray) -> Dict:
        from repro.core import roofline
        cell = SHAPE_CELLS[self.decode_cell]
        st = dp.strategy
        w_dev, kv_dev = serving_bytes_per_device(dp.cfg, st, cell)
        w_f, kv_f = float(w_dev), float(kv_dev)
        knee = roofline.CAPACITY_PRESSURE_KNEE
        # mirror the vectorized fold op-for-op (f64 throughout) so the
        # pipelined executor's records are bit-identical to this path
        cap = max(float(dp.hw.dram_capacity), 1.0)
        occ = (w_f + kv_f) / cap
        over = max(occ - knee, 0.0) / max(1.0 - knee, 1e-9)
        derate = np.inf if occ >= 1.0 else 1.0 + 0.5 * over * over
        t_pf = float(rows[0][0])
        t_d = float(rows[1][0]) * derate
        c = self._consts(float(st.devices))
        stats = traffic.continuous_batching_stats(
            np, np.float64(t_pf), np.float64(t_d), c)
        ok = traffic.slo_ok(stats, self.slo)
        f = lambda k: float(np.asarray(stats[k]))  # noqa: E731
        rec = {**dp.label_fields(),
               **{k: f(k) for k in
                  ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
                   "util", "qps_max", "tokens_per_s",
                   "tokens_per_s_per_device", "cost_device_s_per_token")},
               "prefill_s": t_pf, "decode_step_s": t_d,
               "kv_bytes_per_device": kv_f,
               "weight_bytes_per_device": w_f,
               "hbm_occupancy": occ, "kv_derate": derate,
               "feasible": bool(np.asarray(stats["feasible"])),
               "slo_ok": bool(np.asarray(ok))}
        if not self._custom:
            return rec
        slots_f, k_pf = self._amortize_consts()
        rec.update(self._objective_extras_scalar(dp, {
            "token_compute_s": float(rows[1][1]) / slots_f
            + float(rows[0][1]) * k_pf,
            "token_comm_s": float(rows[1][2]) / slots_f
            + float(rows[0][2]) * k_pf,
            "device_s_per_token": rec["cost_device_s_per_token"],
            "base_tokens_per_s": rec["tokens_per_s"]}))
        return rec

    def refine_objectives(self, dp: DesignPoint):
        from repro.core import roofline
        import jax.numpy as jnp
        cell = SHAPE_CELLS[self.decode_cell]
        w_dev, kv_dev = serving_bytes_per_device(dp.cfg, dp.strategy, cell)
        c = self._consts(float(dp.strategy.devices))
        if self._custom:
            slots_f, k_pf = self._amortize_consts()

            def units(jnp, bds, vals):
                occ = (w_dev + kv_dev) \
                    / jnp.maximum(vals["dram_capacity"], 1.0)
                t_d = bds[1].total_s \
                    * roofline.capacity_pressure_derate_soft(occ)
                st = traffic.continuous_batching_stats(
                    jnp, bds[0].total_s, t_d, c, mask_infeasible=False)
                wall = jnp.maximum(st["util"] - 1.0, 0.0)
                barrier = 1.0 + 1e3 * wall * wall
                # minimized values scale UP with the barrier, the
                # maximized throughput scales DOWN — descent always
                # points back inside the feasible region
                return {"ttft_p99_s": st["ttft_p99_s"] * barrier,
                        "cost_device_s_per_token":
                            st["cost_device_s_per_token"] * barrier,
                        "device_s_per_token":
                            st["cost_device_s_per_token"] * barrier,
                        "base_tokens_per_s": st["tokens_per_s"] / barrier,
                        "token_compute_s": bds[1].compute_s / slots_f
                        + bds[0].compute_s * k_pf,
                        "token_comm_s": bds[1].comm_s / slots_f
                        + bds[0].comm_s * k_pf}
            return self._custom_refine_fold(dp, units)

        def fold(bds, ctx):
            occ = (w_dev + kv_dev) / jnp.maximum(ctx["dram_capacity"], 1.0)
            t_d = bds[1].total_s \
                * roofline.capacity_pressure_derate_soft(occ)
            st = traffic.continuous_batching_stats(
                jnp, bds[0].total_s, t_d, c, mask_infeasible=False)
            # the hard util wall is flat after clamping; a soft barrier
            # keeps descent pointed back inside the feasible region
            wall = jnp.maximum(st["util"] - 1.0, 0.0)
            barrier = 1.0 + 1e3 * wall * wall
            return (st["ttft_p99_s"] * barrier,
                    st["cost_device_s_per_token"] * barrier)
        return fold

    def frontier_fold(self, cfg: ArchConfig, strategy: Strategy):
        from repro.core import pathfinder, roofline
        import jax.numpy as jnp
        cell = SHAPE_CELLS[self.decode_cell]
        w_dev, kv_dev = serving_bytes_per_device(cfg, strategy, cell)
        w_f, kv_f = float(w_dev), float(kv_dev)
        knee = roofline.CAPACITY_PRESSURE_KNEE
        cap_i = pathfinder.HW_FIELDS.index("dram_capacity")
        c = self._consts(float(strategy.devices))
        slo = self.slo
        if self._custom:
            slots_f, k_pf = self._amortize_consts()

            def values_fn(jnp, rows, ctx):
                occ = (w_f + kv_f) \
                    / jnp.maximum(ctx["dram_capacity"], 1.0)
                over = jnp.maximum(occ - knee, 0.0) / max(1.0 - knee, 1e-9)
                derate = jnp.where(occ >= 1.0, jnp.inf,
                                   1.0 + 0.5 * over * over)
                st = traffic.continuous_batching_stats(
                    jnp, rows[0, 0], rows[1, 0] * derate, c)
                # slo_ok AND feasible: a masked-infeasible point's
                # tokens_per_s is 0, which would otherwise survive the
                # non-finite goodput masking as a finite -0.0 objective
                ok = jnp.logical_and(
                    jnp.asarray(traffic.slo_ok(st, slo, xp=jnp)),
                    jnp.asarray(st["feasible"]))
                return ({"ttft_p99_s": st["ttft_p99_s"],
                         "cost_device_s_per_token":
                             st["cost_device_s_per_token"],
                         "device_s_per_token":
                             st["cost_device_s_per_token"],
                         "base_tokens_per_s": st["tokens_per_s"],
                         "token_compute_s": rows[1, 1] / slots_f
                         + rows[0, 1] * k_pf,
                         "token_comm_s": rows[1, 2] / slots_f
                         + rows[0, 2] * k_pf}, ok)
            return self._custom_frontier_fold(cfg, strategy, values_fn)

        def fold(rows, hw_vec):
            occ = (w_f + kv_f) / jnp.maximum(hw_vec[cap_i], 1.0)
            over = jnp.maximum(occ - knee, 0.0) / max(1.0 - knee, 1e-9)
            derate = jnp.where(occ >= 1.0, jnp.inf,
                               1.0 + 0.5 * over * over)
            st = traffic.continuous_batching_stats(
                jnp, rows[0, 0], rows[1, 0] * derate, c)
            ok = traffic.slo_ok(st, slo, xp=jnp)
            return jnp.stack([
                jnp.where(ok, st["ttft_p99_s"], jnp.inf),
                jnp.where(ok, st["cost_device_s_per_token"], jnp.inf)])
        return fold

    def metrics_fold(self, cfg: ArchConfig, strategy: Strategy, cell_id):
        from repro.core import pathfinder, roofline
        cell = SHAPE_CELLS[self.decode_cell]
        w_dev, kv_dev = serving_bytes_per_device(cfg, strategy, cell)
        w_f, kv_f = float(w_dev), float(kv_dev)
        cap_i = pathfinder.HW_FIELDS.index("dram_capacity")
        knee = roofline.CAPACITY_PRESSURE_KNEE
        c = self._consts(float(strategy.devices))
        slo = self.slo
        keys = ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
                "util", "qps_max", "tokens_per_s",
                "tokens_per_s_per_device", "cost_device_s_per_token")

        def fold(rows, hw):
            cap = np.maximum(hw[:, cap_i].astype(np.float64), 1.0)
            occ = (w_f + kv_f) / cap
            over = np.maximum(occ - knee, 0.0) / max(1.0 - knee, 1e-9)
            derate = np.where(occ >= 1.0, np.inf, 1.0 + 0.5 * over * over)
            t_pf = rows[:, 0, 0].astype(np.float64)
            t_d = rows[:, 1, 0].astype(np.float64) * derate
            stats = traffic.continuous_batching_stats(np, t_pf, t_d, c)
            ok = traffic.slo_ok(stats, slo)
            cols = [np.asarray(stats[k]).tolist() for k in keys]
            return [
                {**dict(zip(keys, vals)),
                 "prefill_s": tp, "decode_step_s": td,
                 "kv_bytes_per_device": kv_f,
                 "weight_bytes_per_device": w_f,
                 "hbm_occupancy": o, "kv_derate": dr,
                 "feasible": fz, "slo_ok": sk}
                for vals, tp, td, o, dr, fz, sk in zip(
                    zip(*cols), t_pf.tolist(), t_d.tolist(), occ.tolist(),
                    derate.tolist(), np.asarray(stats["feasible"]).tolist(),
                    np.asarray(ok).tolist())]
        if not self._custom:
            return fold
        slots_f, k_pf = self._amortize_consts()

        def units(rows, recs):
            return {
                "token_compute_s": rows[:, 1, 1].astype(np.float64)
                / slots_f + rows[:, 0, 1].astype(np.float64) * k_pf,
                "token_comm_s": rows[:, 1, 2].astype(np.float64)
                / slots_f + rows[:, 0, 2].astype(np.float64) * k_pf,
                "device_s_per_token": np.array(
                    [r["cost_device_s_per_token"] for r in recs],
                    dtype=np.float64),
                "base_tokens_per_s": np.array(
                    [r["tokens_per_s"] for r in recs], dtype=np.float64)}
        return self._wrap_metrics_fold(fold, cfg, strategy, units)


# ---------------------------------------------------------------------------
# Registry + ScenarioSpec (THE way scenarios are constructed)
# ---------------------------------------------------------------------------


_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario, replace: bool = False) -> Scenario:
    if scenario.name in _REGISTRY and not replace:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def _canon_params(params) -> Tuple[Tuple[str, object], ...]:
    """Sorted (key, value) pairs; multi-valued entries (sweep axes) become
    float tuples, scalars become floats, None stays None."""
    if not params:
        return ()
    items = dict(params)
    out = []
    for k in sorted(items):
        v = items[k]
        if isinstance(v, (list, tuple)):
            v = tuple(float(x) for x in v)
            if len(v) == 1:
                v = v[0]
        elif v is not None:
            v = float(v)
        out.append((str(k), v))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Typed, JSON-serializable scenario construction request.

    The single way scenarios are built across `SweepSpec`, `cooptimize`,
    `pathfinder.sweep`, and the CLI: a registry name plus optional cell
    overrides, a legacy scalar SLO, and typed per-scenario ``params``
    (see `traffic.PARAM_DEFAULTS` for the serving-traffic keys).  A param
    set to a *list* of values declares a sweep axis: `variants()` expands
    the cross product, and each variant's swept values ride in the cell-id
    as a ``@k=v,...`` suffix so point keys, chunk hashes, and checkpoint
    resume work unchanged.  Construction is side-effect free; `resolve()`
    returns the live `Scenario`.
    """

    name: str = "train"
    cells: Tuple[str, ...] = ()
    slo_s: Optional[float] = None
    params: Tuple[Tuple[str, object], ...] = ()
    # params keys that came from a sweep axis (encoded into the cell id)
    variant_keys: Tuple[str, ...] = ()
    # composed Pareto objective set (None = the scenario's defaults —
    # serialized only when set, so pre-objective specs fingerprint
    # byte-identically)
    objectives: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        object.__setattr__(self, "cells", tuple(self.cells))
        object.__setattr__(self, "params", _canon_params(self.params))
        object.__setattr__(self, "variant_keys",
                           tuple(self.variant_keys))
        if self.objectives is not None:
            object.__setattr__(self, "objectives",
                               tuple(str(o) for o in self.objectives))

    # -------------------------------------------------- construction
    @classmethod
    def coerce(cls, obj, cells: Sequence[str] = (),
               slo_s: Optional[float] = None,
               params: Optional[Mapping] = None,
               objectives: Optional[Sequence[str]] = None
               ) -> "ScenarioSpec":
        """Normalize a scenario name / dict / spec into a ScenarioSpec."""
        if isinstance(obj, ScenarioSpec):
            return obj
        if isinstance(obj, str):
            return cls(name=obj, cells=tuple(cells), slo_s=slo_s,
                       params=_canon_params(params),
                       objectives=objectives)
        if isinstance(obj, Mapping):
            return cls.from_dict(obj)
        raise TypeError(f"cannot build a ScenarioSpec from {type(obj)!r}")

    @property
    def param_dict(self) -> Dict[str, object]:
        return dict(self.params)

    def to_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {"name": self.name}
        if self.cells:
            d["cells"] = list(self.cells)
        if self.slo_s is not None:
            d["slo_s"] = self.slo_s
        if self.params:
            d["params"] = {k: (list(v) if isinstance(v, tuple) else v)
                           for k, v in self.params}
        if self.objectives is not None:
            d["objectives"] = list(self.objectives)
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "ScenarioSpec":
        objs = d.get("objectives")
        return cls(name=d.get("name", "train"),
                   cells=tuple(d.get("cells", ())),
                   slo_s=d.get("slo_s"),
                   params=_canon_params(d.get("params")),
                   objectives=tuple(objs) if objs is not None else None)

    # -------------------------------------------------- axis expansion
    def axes(self) -> Dict[str, Tuple[float, ...]]:
        """The multi-valued params — the scenario's sweep axes."""
        return {k: v for k, v in self.params if isinstance(v, tuple)}

    def variants(self) -> List["ScenarioSpec"]:
        """Expand sweep-axis params into scalar variant specs (sorted-key
        cross product; a spec with no axes yields itself)."""
        axes = self.axes()
        if not axes:
            return [self]
        keys = sorted(axes)
        out = []
        for combo in itertools.product(*(axes[k] for k in keys)):
            p = self.param_dict
            p.update(zip(keys, combo))
            out.append(dataclasses.replace(
                self, params=_canon_params(p), variant_keys=tuple(keys)))
        return out

    def for_cell_id(self, cell_id: str) -> "ScenarioSpec":
        """The variant spec for one recorded cell id (cells + any swept
        param overrides carried in its ``@k=v,...`` suffix)."""
        base, over = traffic.decode_variant(cell_id)
        p = self.param_dict
        p.update(over)
        return dataclasses.replace(
            self, cells=tuple(base.split("+")), params=_canon_params(p),
            variant_keys=tuple(sorted(over)))

    # -------------------------------------------------- resolution
    def resolve(self) -> Scenario:
        """Build the live Scenario (registry lookup + overrides)."""
        base = _REGISTRY.get(self.name)
        if base is None:
            raise KeyError(f"unknown scenario {self.name!r}; "
                           f"registered: {sorted(_REGISTRY)}")
        if self.axes():
            raise ValueError(
                f"scenario {self.name!r} has multi-valued params "
                f"{sorted(self.axes())}: expand with variants() first")
        # objective model knobs split off FIRST so economic/reliability
        # constants never reach scenarios that take no workload params
        obj_params, params = objectives_lib.split_objective_params(
            self.param_dict)
        if isinstance(base, ServingTrafficScenario):
            pc, dc = base.prefill_cell, base.decode_cell
            if self.cells:
                if len(self.cells) != 2:
                    raise ValueError("serving scenario takes exactly two "
                                     "cells (prefill, decode)")
                pc, dc = self.cells
            merged = dict(base.params)
            if self.slo_s is not None:
                merged["slo_ttft_p99"] = self.slo_s
            merged.update(params)
            variant = {k: merged[k] for k in self.variant_keys}
            scn: Scenario = ServingTrafficScenario(
                prefill_cell=pc, decode_cell=dc, params=merged,
                name=base.name, variant=variant)
        elif params:
            raise ValueError(f"scenario {self.name!r} takes no params; "
                             f"got {sorted(params)}")
        elif isinstance(base, TrainScenario) and self.cells:
            scn = TrainScenario(cell=self.cells[0], name=base.name)
        elif isinstance(base, ServingScenario) and (self.slo_s is not None
                                                    or self.cells):
            pc, dc = base.prefill_cell, base.decode_cell
            if self.cells:
                if len(self.cells) != 2:
                    raise ValueError("serving scenario takes exactly two "
                                     "cells (prefill, decode)")
                pc, dc = self.cells
            scn = ServingScenario(prefill_cell=pc, decode_cell=dc,
                                  slo_s=self.slo_s, name=base.name)
        else:
            scn = base
        if self.objectives is not None or obj_params:
            scn = scn.with_objectives(self.objectives, obj_params)
        return scn


def get_scenario(name: str, slo_s: Optional[float] = None,
                 cells: Sequence[str] = ()) -> Scenario:
    """Compat shim over `ScenarioSpec` — the pre-PR6 lookup signature."""
    return ScenarioSpec(name=name, cells=tuple(cells),
                        slo_s=slo_s).resolve()


def scenario_names() -> List[str]:
    return sorted(_REGISTRY)


register_scenario(TrainScenario())
register_scenario(ServingScenario())
# long-context serving: recurrent/hybrid archs only (O(1) state is the win)
register_scenario(ServingScenario(prefill_cell="prefill_32k",
                                  decode_cell="long_500k",
                                  name="serving-long"))
# traffic-driven continuous batching (QPS arrivals, percentile SLO walls)
register_scenario(ServingTrafficScenario())
