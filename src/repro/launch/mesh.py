"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and smoke tests / benches must keep seeing 1 device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod single-pod, or 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...],
              axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary mesh for tests/small runs, e.g. ((2, 2), ('data','model'))."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_devices(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
