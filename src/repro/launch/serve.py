"""Batched serving driver: prefill + decode loop with a KV cache.

CLI:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeCell, get_config, reduced
from repro.core import planner as planner_lib
from repro.launch import mesh as mesh_lib
from repro.models import build_model
from repro.parallel import sharding as shard_lib


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          mesh_shape: Tuple[int, ...] = (1, 1), use_reduced: bool = True,
          seed: int = 0, greedy: bool = True) -> Dict:
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    if model.decode_step is None:
        raise ValueError(f"{arch} has no decode path")
    mesh = mesh_lib.make_mesh(mesh_shape)
    cell = ShapeCell("serve", prompt_len + gen, batch, "decode")
    plan = planner_lib.plan(cfg, cell, mesh_shape, mesh.axis_names)
    rules = shard_lib.resolve_rules(plan, mesh)

    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32))

    with mesh:
        params = model.init(jax.random.PRNGKey(seed))
        max_len = prompt_len + gen
        caches = model.init_cache(batch, max_len)
        decode = jax.jit(lambda p, c, t, pos: model.decode_step(
            p, c, t, pos, rules=rules, mesh=mesh))

        # prefill by stepping the prompt (robust across all families)
        t0 = time.time()
        logits = None
        for t in range(prompt_len):
            logits, caches = decode(params, caches, prompts[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32))
        prefill_s = time.time() - t0

        out_tokens = []
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        t0 = time.time()
        for t in range(gen):
            out_tokens.append(np.asarray(cur))
            logits, caches = decode(params, caches, cur,
                                    jnp.asarray(prompt_len + t, jnp.int32))
            cur = jnp.argmax(logits[:, -1], axis=-1).astype(
                jnp.int32)[:, None]
        decode_s = time.time() - t0

    tokens = np.concatenate(out_tokens, axis=1)
    return {"tokens": tokens,
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "tok_per_s": batch * gen / max(decode_s, 1e-9),
            "plan": plan.strategy.name}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()
    out = serve(args.arch, args.batch, args.prompt_len, args.gen,
                tuple(int(x) for x in args.mesh.split("x")),
                use_reduced=args.reduced)
    print(f"[serve] strategy {out['plan']}: prefill {out['prefill_s']:.2f}s, "
          f"decode {out['decode_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s)")
    print("[serve] sample tokens:", out["tokens"][0][:12])


if __name__ == "__main__":
    main()
