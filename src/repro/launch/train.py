"""End-to-end training driver.

Wires the full stack: DeepFlow planner (CrossFlow-predicted sharding plan)
-> NamedShardings -> jit'd train step (loss + grad + AdamW, optional int8
error-feedback gradient compression + remat) -> sharded synthetic data
pipeline with prefetch -> async atomic checkpointing -> preemption handler
+ straggler watchdog.

CLI:
    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 100 --batch 8 --seq 128 --mesh 1x1 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig, ShapeCell, get_config, reduced
from repro.core import planner as planner_lib
from repro.data import DataConfig, PrefetchIterator
from repro.launch import mesh as mesh_lib
from repro.models import build_model
from repro.parallel import sharding as shard_lib
from repro.runtime import PreemptionHandler, StragglerWatchdog, compress, \
    decompress, init_error_state


@dataclasses.dataclass
class TrainConfig:
    arch: str = "qwen1.5-0.5b"
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    mesh_shape: Tuple[int, ...] = (1, 1)
    lr: float = 3e-4
    warmup: int = 20
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    remat: bool = False
    grad_compression: str = "none"      # none | int8
    use_reduced_config: bool = False
    seed: int = 0


class TrainState:
    def __init__(self, params, opt_state, err_state=None):
        self.params = params
        self.opt_state = opt_state
        self.err_state = err_state

    def as_tree(self):
        t = {"params": self.params, "opt": self.opt_state._asdict()}
        if self.err_state is not None:
            t["err"] = self.err_state
        return t

    @staticmethod
    def from_tree(t):
        return TrainState(t["params"], optim.AdamWState(**t["opt"]),
                          t.get("err"))


def make_train_step(model, cfg: ArchConfig, opt_cfg: optim.AdamWConfig,
                    rules, mesh, remat: bool, compression: str,
                    grad_shardings=None):
    def step_fn(params, opt_state, err_state, batch):
        def loss_of(p):
            loss, metrics = model.loss_fn(p, batch, rules=rules, mesh=mesh,
                                          remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_of,
                                                    has_aux=True)(params)
        if grad_shardings is not None:
            # pin wgrads to the param layout: GSPMD can then reduce-scatter
            # at the producer instead of AR-ing the full tensor + slicing
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads,
                                 grad_shardings)
        if compression == "bf16":
            # halve the DP all-reduce volume; optimizer math stays fp32
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
        if compression == "int8":
            comp, err_state = compress(grads, err_state)
            grads = decompress(comp, grads)
        params, opt_state, om = optim.apply(opt_cfg, opt_state, params,
                                            grads)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, err_state, metrics

    return step_fn


def setup(tc: TrainConfig):
    cfg = get_config(tc.arch)
    if tc.use_reduced_config:
        cfg = reduced(cfg)
    model = build_model(cfg)
    mesh = mesh_lib.make_mesh(tc.mesh_shape)
    cell = ShapeCell("train", tc.seq_len, tc.global_batch, "train")
    plan = planner_lib.plan(cfg, cell, tc.mesh_shape, mesh.axis_names)
    rules = shard_lib.resolve_rules(plan, mesh)
    p_shardings = shard_lib.param_shardings(model, plan, mesh)
    b_shardings = shard_lib.batch_shardings(cfg, cell, plan, mesh)
    return cfg, model, mesh, plan, rules, p_shardings, b_shardings


def train(tc: TrainConfig) -> Dict[str, Any]:
    cfg, model, mesh, plan, rules, p_shardings, b_shardings = setup(tc)
    opt_cfg = optim.AdamWConfig(lr=tc.lr, warmup_steps=tc.warmup,
                                total_steps=max(tc.steps, 1))

    with mesh:
        params = jax.jit(
            lambda k: model.init(k),
            out_shardings=p_shardings)(jax.random.PRNGKey(tc.seed))
    opt_state = optim.init(params)
    err_state = (init_error_state(params)
                 if tc.grad_compression == "int8" else None)
    state = TrainState(params, opt_state, err_state)

    ckpt = CheckpointManager(tc.ckpt_dir) if tc.ckpt_dir else None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        tree = ckpt.restore(like=state.as_tree())
        state = TrainState.from_tree(tree)
        start_step = int(state.opt_state.step)
        print(f"[train] resumed from step {start_step}")

    step_fn = make_train_step(model, cfg, opt_cfg, rules, mesh, tc.remat,
                              tc.grad_compression)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))

    data_cfg = DataConfig(global_batch=tc.global_batch, seq_len=tc.seq_len,
                          seed=tc.seed)
    it = PrefetchIterator(data_cfg, cfg, start_step=start_step)
    preempt = PreemptionHandler()
    watchdog = StragglerWatchdog()
    history = []
    t_prev = time.time()
    try:
        with mesh:
            for step, batch in it:
                if step >= tc.steps:
                    break
                state.params, state.opt_state, state.err_state, metrics = \
                    jit_step(state.params, state.opt_state, state.err_state,
                             batch)
                loss = float(metrics["loss"])
                now = time.time()
                watchdog.observe(step, now - t_prev)
                t_prev = now
                history.append(loss)
                if step % tc.log_every == 0:
                    print(f"[train] step {step:5d} loss {loss:.4f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"gnorm {float(metrics['grad_norm']):.3f}")
                if ckpt and step and step % tc.ckpt_every == 0:
                    ckpt.save(step, state.as_tree())
                if preempt.preempted:
                    print("[train] preemption: saving and exiting")
                    if ckpt:
                        ckpt.save(step, state.as_tree(), block=True)
                    break
    finally:
        it.close()
        if ckpt:
            ckpt.wait()
    if ckpt and not preempt.preempted:
        ckpt.save(tc.steps, state.as_tree(), block=True)
    return {"history": history, "final_loss": history[-1] if history else
            float("nan"), "stragglers": watchdog.events, "state": state,
            "plan": plan}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1",
                    help="e.g. 1x1, 2x2, 2x16x16")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config of the arch family")
    args = ap.parse_args()
    tc = TrainConfig(arch=args.arch, steps=args.steps,
                     global_batch=args.batch, seq_len=args.seq,
                     mesh_shape=tuple(int(x) for x in args.mesh.split("x")),
                     lr=args.lr, ckpt_dir=args.ckpt_dir, remat=args.remat,
                     grad_compression=args.compression,
                     use_reduced_config=args.reduced)
    out = train(tc)
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"({len(out['history'])} steps, "
          f"{len(out['stragglers'])} straggler events)")


if __name__ == "__main__":
    main()
