import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the REAL step function (train_step including the
AdamW update, prefill_step, or decode serve_step) with ShapeDtypeStruct
inputs under the production mesh (16x16 single-pod / 2x16x16 multi-pod),
compiles it, and records:

  * memory_analysis()  — proves the cell fits per-device HBM,
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes   — parsed from the compiled HLO per collective kind,
  * the DeepFlow planner's CrossFlow prediction for the same cell
    (prediction vs XLA-derived terms = the validation axis).

Artifacts land in artifacts/dryrun/<arch>__<cell>__<mesh>.json; runs are
resumable (existing artifacts are skipped unless --force).

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b \
        --cell train_4k --mesh single
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ARCH_IDS, SHAPE_CELLS, applicable_cells, \
    get_config
from repro.core import planner as planner_lib
from repro.launch import mesh as mesh_lib
from repro.launch.train import make_train_step
from repro.models import build_model, input_specs
from repro.parallel import sharding as shard_lib

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in the compiled HLO.

    These are PER-DEVICE shapes (SPMD module), i.e. bytes each device
    receives per op — the right operand for the collective roofline term.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^[%\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        op_base = op.split(".")[0]
        if op_base.endswith("-start"):
            op_base = op_base[:-6]
        if op_base in _COLLECTIVES:
            out[op_base] += _shape_bytes(shape_txt)
            out["count"] += 1
    return out


def _abstract(tree):
    return jax.eval_shape(lambda: tree) if callable(tree) else tree


def build_cell(arch: str, cell_name: str, mesh, mesh_shape, fsdp=True,
               remat="auto", cfg_override=None, opts=None):
    """Returns (fn, kwargs_specs, in_shardings tuple, plan).

    `opts` (hillclimb variants): cfg=dict of ArchConfig overrides,
    rules=dict of logical-axis rule overrides, serve_bf16=bool (bf16 params
    for prefill/decode), bf16_grads=bool (bf16 gradient all-reduce),
    remat=bool.
    """
    import dataclasses as _dc
    opts = opts or {}
    cfg = cfg_override or get_config(arch)
    if opts.get("cfg"):
        cfg = _dc.replace(cfg, **opts["cfg"])
    if "remat" in opts:
        remat = opts["remat"]
    model = build_model(cfg)
    cell = SHAPE_CELLS[cell_name]
    plan = planner_lib.plan(cfg, cell, mesh_shape, mesh.axis_names)
    rules = shard_lib.resolve_rules(plan, mesh, fsdp=fsdp)
    if opts.get("rules"):
        rules = dict(rules, **opts["rules"])
    p_shard = shard_lib.param_shardings(model, plan, mesh, fsdp=fsdp)
    p_dtype = (jnp.bfloat16 if (opts.get("serve_bf16")
                                and cell.kind != "train") else jnp.float32)
    p_abs = model.abstract_params(p_dtype)
    specs = input_specs(cfg, cell)
    b_shard = shard_lib.batch_shardings(cfg, cell, plan, mesh)
    b_shard = {k: b_shard[k] for k in specs}    # match input_specs exactly

    if cell.kind == "train":
        # remat may be bool or a policy string ("dots") — pass it through
        use_remat = (cell.seq_len * cell.global_batch >= 2**20
                     if remat == "auto" else remat)
        opt_cfg = optim.AdamWConfig(total_steps=1000)
        compression = "bf16" if opts.get("bf16_grads") else "none"
        gsh = p_shard if opts.get("grad_constraint") else None
        step = make_train_step(model, cfg, opt_cfg, rules, mesh,
                               use_remat, compression, grad_shardings=gsh)

        def fn(params, opt_state, batch):
            p, o, _, metrics = step(params, opt_state, None, batch)
            return p, o, metrics["loss"]

        opt_abs = jax.eval_shape(optim.init, p_abs)
        opt_shard = optim.AdamWState(
            step=shard_lib.scalar_sharding(mesh),
            mu=p_shard, nu=p_shard)
        args = (p_abs, opt_abs, specs)
        in_sh = (p_shard, opt_shard, b_shard)
        return fn, args, in_sh, plan, cfg

    if cell.kind == "prefill":
        if cfg.is_encoder_decoder:
            # whisper prefill = encode + cross-KV precompute
            def fn(params, batch):
                return model.prefill(params, batch, rules=rules, mesh=mesh)
        else:
            from repro.models import transformer as tr

            def fn(params, batch):
                # realistic serving prefill: fill caches AND return the
                # next-token logits (keeps the head/last layer live)
                caches = tr.init_cache(cfg, cell.global_batch, cell.seq_len)
                logits, caches, _ = tr.forward(
                    params, batch["tokens"], cfg,
                    embeds=batch.get("embeds"), caches=caches,
                    rules=rules, mesh=mesh)
                return logits[:, -1], caches

        args = (p_abs, specs)
        in_sh = (p_shard, b_shard)
        return fn, args, in_sh, plan, cfg

    # decode
    max_len = cell.seq_len
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(cell.global_batch, max_len))
    cache_shard = shard_lib.cache_shardings(cfg, plan, mesh, cache_abs)

    def fn(params, caches, batch):
        pos = jnp.asarray(max_len - 1, jnp.int32)
        logits, new_caches = model.decode_step(params, caches,
                                               batch["tokens"], pos,
                                               rules=rules, mesh=mesh)
        return logits, new_caches

    args = (p_abs, cache_abs, specs)
    in_sh = (p_shard, cache_shard, b_shard)
    return fn, args, in_sh, plan, cfg


def _compile_metrics(arch, cell_name, mesh, mesh_shape, fsdp, cfg_override,
                     remat="auto", opts=None):
    """One lower+compile; returns raw metrics (scan bodies counted ONCE —
    XLA cost_analysis does not multiply while-loop trip counts)."""
    t0 = time.time()
    fn, args, in_sh, plan, cfg = build_cell(arch, cell_name, mesh,
                                            mesh_shape, fsdp=fsdp,
                                            remat=remat, opts=opts,
                                            cfg_override=cfg_override)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "plan": plan, "cfg": cfg,
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes",
                                  getattr(mem, "temp_size_in_bytes", 0)),
        },
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }


def _probe_configs(cfg):
    """Variant configs for the scan-trip-count correction.

    Returns (probes, combine) where `combine(full, probe_metrics)` produces
    corrected totals:  m = m_rem + n_groups * (m_full - m_rem)  (decoder)
    or the two-scan version for enc-dec.
    """
    import dataclasses as dc
    from repro.models.transformer import group_layout
    if cfg.is_encoder_decoder:
        n_enc, n_dec = cfg.n_encoder_layers, cfg.n_layers
        probes = {"zero": dc.replace(cfg, n_layers=0, n_encoder_layers=0),
                  "enc0": dc.replace(cfg, n_encoder_layers=0),
                  "dec0": dc.replace(cfg, n_layers=0)}

        def combine(full, pm, key):
            z = pm["zero"][key]
            b_enc = pm["dec0"][key] - z        # dec0 keeps only the encoder
            b_dec = pm["enc0"][key] - z
            return z + n_enc * b_enc + n_dec * b_dec

        return probes, combine
    pat, n_groups, rem = group_layout(cfg)
    probes = {"rem": dc.replace(cfg, n_layers=rem)}   # rem==0 -> zero model

    def combine(full, pm, key):
        m_rem = pm["rem"][key]
        return m_rem + n_groups * (full[key] - m_rem)

    return probes, combine


def _corrected(full, probe_metrics, combine):
    out = {}
    out["flops"] = combine(full, probe_metrics, "flops")
    out["bytes"] = combine(full, probe_metrics, "bytes")
    coll = {}
    for k in list(full["coll"].keys()):
        f = {"k": full["coll"][k]}
        pm = {name: {"k": m["coll"][k]} for name, m in
              probe_metrics.items()}
        coll[k] = combine(f, pm, "k")
    out["coll"] = coll
    return out


def run_cell(arch: str, cell_name: str, mesh_kind: str,
             force: bool = False, fsdp: bool = True,
             save: bool = True, variant: str = "",
             correct_scan: bool = True, remat: str = "auto",
             opts: Optional[Dict] = None) -> Optional[Dict]:
    os.makedirs(ART_DIR, exist_ok=True)
    tag = f"{arch}__{cell_name}__{mesh_kind}" + (f"__{variant}" if variant
                                                 else "")
    path = os.path.join(ART_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    multi = mesh_kind == "multi"
    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
    mesh_shape = (2, 16, 16) if multi else (16, 16)
    try:
        full = _compile_metrics(arch, cell_name, mesh, mesh_shape, fsdp,
                                None, remat=remat, opts=opts)
        plan, cfg = full["plan"], full["cfg"]
        corrected = None
        if correct_scan:
            probes, combine = _probe_configs(cfg)
            pm = {}
            for name, pcfg in probes.items():
                pm[name] = _compile_metrics(arch, cell_name, mesh,
                                            mesh_shape, fsdp, pcfg,
                                            remat=remat, opts=opts)
            corrected = _corrected(full, pm, combine)
        n_dev = 512 if multi else 256
        result = {
            "arch": arch, "cell": cell_name, "mesh": mesh_kind,
            "variant": variant,
            "mesh_shape": list(mesh_shape), "devices": n_dev, "ok": True,
            "strategy": plan.strategy.name,
            "predicted_step_s": plan.predicted_step_s,
            "predicted_breakdown": plan.predicted_breakdown,
            "flops_per_device_raw": full["flops"],
            "bytes_per_device_raw": full["bytes"],
            "flops_per_device": (corrected or full)["flops"],
            "bytes_per_device": (corrected or full)["bytes"],
            "memory": full["memory"],
            "collectives_raw": full["coll"],
            "collectives": (corrected or full)["coll"],
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "lower_s": full["lower_s"],
            "compile_s": full["compile_s"],
            "scan_corrected": bool(corrected),
        }
    except Exception as e:              # noqa: BLE001 — record the failure
        result = {"arch": arch, "cell": cell_name, "mesh": mesh_kind,
                  "variant": variant, "ok": False, "error": str(e),
                  "traceback": traceback.format_exc()[-4000:]}
    if save:
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        cells = [c.name for c in applicable_cells(cfg)]
        if args.cell != "all":
            cells = [c for c in cells if c == args.cell]
        for cell in cells:
            for mk in meshes:
                r = run_cell(arch, cell, mk, force=args.force)
                status = "OK " if r["ok"] else "FAIL"
                extra = ""
                if r["ok"]:
                    peak = r["memory"]["peak_bytes"] or \
                        (r["memory"]["argument_bytes"]
                         + r["memory"]["temp_bytes"])
                    extra = (f"flops/dev={r['flops_per_device']:.3e} "
                             f"coll={r['collectives']['count']} "
                             f"compile={r['compile_s']:.0f}s")
                    n_ok += 1
                else:
                    extra = r["error"][:140]
                    n_fail += 1
                print(f"[dryrun] {status} {arch:22s} {cell:12s} {mk:6s} "
                      f"{extra}", flush=True)
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
