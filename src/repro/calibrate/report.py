"""Validation reporter — paper-style measured-vs-predicted tables + drift.

Produces, per measurement group (kernel kind / model family), the metrics
the paper reports for CrossFlow's validation (Figs. 6-8): correlation of
log times, mean relative error, and bias (signed mean log ratio); plus an
overall row.  `compare_reports` sets an uncalibrated baseline against a
calibrated profile (the acceptance metric: calibrated MRE strictly lower
on the GEMM sweep), and `check_drift` diffs a fresh report against the
stored baseline (``report.json`` next to the profile) so CI can catch a
model or container regression that silently degrades calibration.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.calibrate import fitting
from repro.core.age import MicroArch
from repro.core.roofline import PPEConfig

REPORT_VERSION = 1


def _group_key(rec: Dict) -> str:
    if rec["kind"] in ("train_step", "prefill", "decode_step"):
        return f"{rec['kind']}:{rec.get('arch', '?')}"
    return str(rec["kind"])


def _stats(measured: np.ndarray, predicted: np.ndarray) -> Dict:
    meas = np.maximum(measured, 1e-12)
    pred = np.maximum(predicted, 1e-12)
    logr = np.log(pred / meas)
    corr = float(np.corrcoef(np.log(meas), np.log(pred))[0, 1]) \
        if len(meas) >= 2 and np.std(np.log(meas)) > 0 else float("nan")
    return {"n": int(len(meas)),
            "corr_log": corr,
            "mre": float(np.mean(np.abs(pred - meas) / meas)),
            "bias_log": float(np.mean(logr)),
            "worst_rel": float(np.max(np.abs(pred - meas) / meas))}


def validation_report(measurements: Sequence[Dict], template: MicroArch,
                      params: Optional[Dict[str, float]] = None,
                      ppe: PPEConfig = PPEConfig()) -> Dict:
    """Measured-vs-predicted report for one parameter set.

    ``params=None`` scores the uncalibrated techlib entry (identity
    parameters).  Predictions come from `fitting.predict_measurements` —
    the same path the fit optimized.
    """
    measurements = [r for r in measurements if "t_s" in r]
    if not measurements:
        return {"version": REPORT_VERSION, "groups": {}, "overall": {}}
    pred = fitting.predict_measurements(measurements, template,
                                        params=params, ppe=ppe)
    meas = np.asarray([float(r["t_s"]) for r in measurements])
    groups: Dict[str, List[int]] = {}
    for i, rec in enumerate(measurements):
        groups.setdefault(_group_key(rec), []).append(i)
    out = {g: _stats(meas[idx], pred[idx])
           for g, idx in sorted(groups.items())}
    # overall excludes unfitted kinds so it matches the fit objective
    fitted = [i for i, r in enumerate(measurements)
              if r["kind"] in fitting.KINDS_FITTED]
    overall = _stats(meas[fitted], pred[fitted]) if fitted else {}
    return {"version": REPORT_VERSION, "groups": out, "overall": overall,
            "params": dict(params or fitting.default_params())}


def compare_reports(baseline: Dict, calibrated: Dict) -> Dict:
    """Per-group and overall MRE improvement (baseline -> calibrated)."""
    out = {}
    for g, cal in calibrated.get("groups", {}).items():
        base = baseline.get("groups", {}).get(g)
        if base:
            out[g] = {"mre_baseline": base["mre"], "mre": cal["mre"],
                      "improved": cal["mre"] < base["mre"]}
    b, c = baseline.get("overall") or {}, calibrated.get("overall") or {}
    if b and c:
        out["overall"] = {"mre_baseline": b["mre"], "mre": c["mre"],
                          "improved": c["mre"] < b["mre"]}
    return out


def format_report(report: Dict, baseline: Optional[Dict] = None) -> str:
    """Text table (stderr-friendly); optional baseline column."""
    rows = [f"{'group':24s} {'n':>4s} {'corr(log)':>10s} {'MRE':>8s} "
            f"{'bias':>7s}" + ("  {:>10s}".format("base MRE")
                               if baseline else "")]
    items = list(report.get("groups", {}).items())
    if report.get("overall"):
        items.append(("OVERALL(fitted)", report["overall"]))
    for g, s in items:
        if not s:
            continue
        line = (f"{g:24s} {s['n']:4d} {s['corr_log']:10.3f} "
                f"{s['mre'] * 100:7.1f}% {s['bias_log']:+7.2f}")
        if baseline:
            base = (baseline.get("groups", {}).get(g)
                    or (baseline.get("overall")
                        if g == "OVERALL(fitted)" else None))
            line += (f"  {base['mre'] * 100:9.1f}%" if base
                     else f"  {'-':>10s}")
        rows.append(line)
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# Drift detection
# ---------------------------------------------------------------------------


def save_baseline(report: Dict, path: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
    os.replace(tmp, path)


def load_baseline(path: str) -> Dict:
    with open(path) as fh:
        return json.load(fh)


def check_drift(report: Dict, baseline: Dict,
                tol: float = 0.25) -> List[str]:
    """Regressions of the fresh report vs the stored baseline.

    A group drifts when its MRE worsens by more than ``tol`` (absolute,
    e.g. 0.25 = 25 points of relative error) or when it disappears from
    the fresh report.  Returns human-readable messages (empty = healthy);
    the CLI exits non-zero on drift so a CI lane can gate on it.
    """
    msgs = []
    base_groups = baseline.get("groups", {})
    new_groups = report.get("groups", {})
    for g, b in sorted(base_groups.items()):
        cur = new_groups.get(g)
        if cur is None:
            msgs.append(f"group {g!r} missing from the fresh report "
                        f"(baseline MRE {b['mre'] * 100:.1f}%)")
            continue
        if cur["mre"] > b["mre"] + tol:
            msgs.append(
                f"group {g!r} drifted: MRE {b['mre'] * 100:.1f}% -> "
                f"{cur['mre'] * 100:.1f}% (tol {tol * 100:.0f} points)")
    b, c = baseline.get("overall") or {}, report.get("overall") or {}
    if b and c and c["mre"] > b["mre"] + tol:
        msgs.append(f"overall MRE drifted: {b['mre'] * 100:.1f}% -> "
                    f"{c['mre'] * 100:.1f}%")
    return msgs
