"""Measurement-driven calibration & validation (DeepFlow paper §8).

The paper's credibility claim is validation against *measured* hardware;
this package closes the techlib <- kernels loop for the repo:

  microbench.py  times real executables already in the repo — jit'd GEMMs
                 and the Pallas kernels, `bucketed_psum` collectives under
                 forced multi-device shard_map, end-to-end train/prefill
                 steps of the model families — streaming measurements to
                 JSONL with the sweep runner's fingerprint/resume
                 discipline;
  fitting.py     treats techlib/PPE efficiency + overhead parameters as a
                 batched vector and fits them to the measurements by
                 multi-start gradient descent through the traced
                 `roofline.gemm_time` / `simulate.predict` paths;
  profiles.py    serialized calibration profiles (JSON) that the sweep /
                 pathfind / cooptimize engines consume via ``--profile``;
  report.py      paper-style correlation / mean-relative-error validation
                 tables per kernel & model, plus drift detection against a
                 stored baseline report.

CLI: ``python -m repro.pathfind calibrate --out DIR`` and
``python -m repro.pathfind validate --out DIR``; downstream consumption is
``python -m repro.pathfind sweep --profile DIR/profile.json``.
"""

from repro.calibrate.fitting import (FitConfig, FitResult, PARAM_NAMES,
                                     default_params, fit,
                                     predict_measurements, scale_microarch)
from repro.calibrate.microbench import (MeasureSpec, MicrobenchRunner,
                                        default_spec, enumerate_points,
                                        load_measurements)
from repro.calibrate.profiles import (CalibrationProfile, apply_profile,
                                      load_profile, ppe_with_profile,
                                      save_profile)
from repro.calibrate.report import (check_drift, format_report,
                                    validation_report)

__all__ = [
    "CalibrationProfile", "FitConfig", "FitResult", "MeasureSpec",
    "MicrobenchRunner", "PARAM_NAMES", "apply_profile", "check_drift",
    "default_params", "default_spec", "enumerate_points", "fit",
    "format_report", "load_measurements", "load_profile",
    "ppe_with_profile", "predict_measurements", "save_profile",
    "scale_microarch", "validation_report",
]
