"""Microbenchmark harness — measured wall times of the repo's own kernels.

Every measurement times an executable that already exists in the repo:

  gemm         jit'd ``jnp.dot`` (XLA, the fig-6 methodology)
  gemm_pallas  the block-tiled Pallas GEMM (`repro.kernels.gemm`,
               interpret mode on CPU)
  elementwise  a jit'd saxpy (the PPE's vector/bandwidth path)
  collective   `repro.parallel.collectives.bucketed_psum` under a forced
               multi-device `shard_map` (subprocess when the running
               process has a single device — the device count is fixed at
               first JAX init)
  train_step / prefill
               end-to-end jit'd steps of the `repro.models` families at
               smoke size (`configs.base.reduced`)
  decode_step  one-token jit'd `Model.decode_step` over a full KV cache
               at smoke size — the KV-cache-READ-bound step that anchors
               the model's main-memory bandwidth path (the decode graph's
               attention GEMMs charge the whole context per token)

Measurements stream to ``measurements.jsonl`` with the sweep runner's
fingerprint/resume discipline: ``spec.json`` pins the enumerated point set
(`MeasureSpec.fingerprint`), each finished point appends one JSONL record,
and a resumed run skips every key already on disk with zero re-measurement
(crash-torn tail lines are dropped by the shared `_iter_jsonl` reader).

The records feed `repro.calibrate.fitting` (parameter fit) and
`repro.calibrate.report` (validation tables).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess
import sys
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sweeprunner import _iter_jsonl, json_safe

SPEC_VERSION = 1

# measurement kinds, in enumeration order
KINDS = ("gemm", "gemm_pallas", "elementwise", "collective",
         "train_step", "prefill", "decode_step")


# ---------------------------------------------------------------------------
# Specification (fully serializable — the resume identity)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeasureSpec:
    """Everything that determines the measurement point set."""

    suite: str = "quick"
    gemm_shapes: Tuple[Tuple[int, int, int], ...] = ()
    gemm_dtype_bytes: int = 4
    pallas_shapes: Tuple[Tuple[int, int, int], ...] = ()
    elementwise_sizes: Tuple[int, ...] = ()
    collective_bytes: Tuple[int, ...] = ()
    collective_devices: int = 2
    model_archs: Tuple[str, ...] = ()
    model_phases: Tuple[str, ...] = ("train_step", "prefill")
    model_seq: int = 128
    model_batch: int = 2
    reps: int = 3
    warmup: int = 1

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["gemm_shapes"] = [list(s) for s in self.gemm_shapes]
        d["pallas_shapes"] = [list(s) for s in self.pallas_shapes]
        for k in ("elementwise_sizes", "collective_bytes", "model_archs",
                  "model_phases"):
            d[k] = list(d[k])
        return d

    @staticmethod
    def from_dict(d: Dict) -> "MeasureSpec":
        d = dict(d)
        for k in ("gemm_shapes", "pallas_shapes"):
            d[k] = tuple(tuple(int(x) for x in s) for s in d.get(k) or ())
        for k in ("elementwise_sizes", "collective_bytes"):
            d[k] = tuple(int(x) for x in d.get(k) or ())
        for k in ("model_archs", "model_phases"):
            d[k] = tuple(d.get(k) or ())
        return MeasureSpec(**d)

    def fingerprint(self) -> str:
        blob = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


def default_spec(suite: str = "quick", reps: int = 3) -> MeasureSpec:
    """The standard suites.

    quick  GEMM-only (the CI calibrate-smoke lane and the acceptance
           sweep): seconds of wall time, enough signal to anchor compute
           throughput, memory bandwidth, and kernel overhead.
    full   adds the Pallas GEMM (interpret mode — tiny shapes only),
           elementwise/bandwidth probes, forced-2-device `bucketed_psum`
           collectives, and end-to-end model-family steps.
    """
    gemm = tuple(
        (m, n, k)
        for m in (128, 256, 512, 1024)
        for n, k in ((m, m), (m, 2 * m))
    ) + ((256, 1024, 512), (1024, 256, 2048))
    if suite == "quick":
        return MeasureSpec(suite="quick", gemm_shapes=gemm, reps=reps)
    if suite == "full":
        return MeasureSpec(
            suite="full", gemm_shapes=gemm,
            pallas_shapes=((128, 128, 128), (256, 256, 256)),
            elementwise_sizes=(1 << 16, 1 << 20, 1 << 23),
            collective_bytes=(1 << 16, 1 << 20, 1 << 22),
            model_archs=("qwen1.5-0.5b", "xlstm-125m", "recurrentgemma-2b"),
            model_phases=("train_step", "prefill", "decode_step"),
            reps=reps)
    raise ValueError(f"unknown suite {suite!r}; expected quick|full")


# ---------------------------------------------------------------------------
# Point enumeration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeasurePoint:
    """One enumerated measurement (strings/ints only — checkpointable)."""

    kind: str
    params: Tuple[Tuple[str, object], ...]   # sorted (name, value) pairs

    def get(self, name: str, default=None):
        return dict(self.params).get(name, default)

    def key(self) -> str:
        parts = [f"{k}={v}" for k, v in self.params]
        return "|".join([self.kind] + parts)


def _pt(kind: str, **params) -> MeasurePoint:
    return MeasurePoint(kind=kind, params=tuple(sorted(params.items())))


def enumerate_points(spec: MeasureSpec) -> List[MeasurePoint]:
    """Deterministic measurement point set for one spec."""
    pts: List[MeasurePoint] = []
    for m, n, k in spec.gemm_shapes:
        pts.append(_pt("gemm", m=m, n=n, k=k,
                       dtype_bytes=spec.gemm_dtype_bytes))
    for m, n, k in spec.pallas_shapes:
        pts.append(_pt("gemm_pallas", m=m, n=n, k=k,
                       dtype_bytes=spec.gemm_dtype_bytes))
    for n in spec.elementwise_sizes:
        pts.append(_pt("elementwise", n_elems=n))
    for b in spec.collective_bytes:
        pts.append(_pt("collective", bytes=b,
                       devices=spec.collective_devices))
    for arch in spec.model_archs:
        for phase in spec.model_phases:
            pts.append(_pt(phase, arch=arch, seq=spec.model_seq,
                           batch=spec.model_batch))
    return pts


# ---------------------------------------------------------------------------
# Timing primitives
# ---------------------------------------------------------------------------


def _time_fn(fn: Callable, warmup: int, reps: int) -> Tuple[float, float]:
    """(best, mean) wall seconds of ``fn()`` (must block until ready)."""
    for _ in range(max(warmup, 1)):
        fn()
    ts = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts), sum(ts) / len(ts)


def _measure_gemm(pt: MeasurePoint, spec: MeasureSpec) -> Dict:
    import jax
    import jax.numpy as jnp
    m, n, k = pt.get("m"), pt.get("n"), pt.get("k")
    db = int(pt.get("dtype_bytes", 4))
    dtype = jnp.float32 if db == 4 else jnp.bfloat16
    x = jnp.ones((m, k), dtype)
    w = jnp.ones((k, n), dtype)
    f = jax.jit(jnp.dot)
    best, mean = _time_fn(lambda: f(x, w).block_until_ready(),
                          spec.warmup, spec.reps)
    return {"flops": 2.0 * m * n * k, "bytes": float((m * k + k * n + m * n)
                                                     * db),
            "t_s": best, "t_mean_s": mean}


def _measure_gemm_pallas(pt: MeasurePoint, spec: MeasureSpec) -> Dict:
    from repro.kernels import ops
    import jax.numpy as jnp
    m, n, k = pt.get("m"), pt.get("n"), pt.get("k")
    db = int(pt.get("dtype_bytes", 4))
    dtype = jnp.float32 if db == 4 else jnp.bfloat16
    x = jnp.ones((m, k), dtype)
    w = jnp.ones((k, n), dtype)

    def run():
        ops.matmul(x, w, use_pallas=True, interpret=True) \
            .block_until_ready()
    best, mean = _time_fn(run, spec.warmup, spec.reps)
    return {"flops": 2.0 * m * n * k,
            "bytes": float((m * k + k * n + m * n) * db),
            "t_s": best, "t_mean_s": mean}


def _measure_elementwise(pt: MeasurePoint, spec: MeasureSpec) -> Dict:
    import jax
    import jax.numpy as jnp
    n = int(pt.get("n_elems"))
    a = jnp.ones((n,), jnp.float32)
    b = jnp.ones((n,), jnp.float32)
    f = jax.jit(lambda a, b: a * 1.5 + b)
    best, mean = _time_fn(lambda: f(a, b).block_until_ready(),
                          spec.warmup, spec.reps)
    return {"flops": 2.0 * n, "bytes": 3.0 * n * 4,
            "t_s": best, "t_mean_s": mean}


_COLLECTIVE_SNIPPET = """
import json, sys
from repro.calibrate import microbench
spec = microbench.MeasureSpec.from_dict(json.loads(sys.argv[1]))
wanted = set(json.loads(sys.argv[2]))
for pt in microbench.enumerate_points(spec):
    if pt.kind != "collective" or pt.key() not in wanted:
        continue
    rec = microbench.measure_point(pt, spec)
    print("MEASURE:" + json.dumps(microbench.json_safe(rec)), flush=True)
"""


def _measure_collective(pt: MeasurePoint, spec: MeasureSpec) -> Dict:
    """`bucketed_psum` of a payload tree under multi-device shard_map.

    Requires >= ``devices`` JAX devices in-process; `run_points` routes
    the whole collective group through a forced-device subprocess when the
    parent is single-device (the XLA device count is fixed at first init).
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.parallel import collectives

    n_dev = int(pt.get("devices", 2))
    if jax.local_device_count() < n_dev:
        raise RuntimeError(
            f"collective point needs {n_dev} devices, have "
            f"{jax.local_device_count()} (run via subprocess)")
    payload_bytes = int(pt.get("bytes"))
    n = max(payload_bytes // 4, 1)
    tree = {"g": jnp.ones((n,), jnp.float32)}
    mesh = Mesh(np.asarray(jax.devices()[:n_dev]), ("x",))

    @jax.jit
    def reduce(t):
        return shard_map(
            lambda tt: collectives.bucketed_psum(tt, "x"),
            mesh=mesh, in_specs=(P(),), out_specs=P())(t)

    best, mean = _time_fn(
        lambda: jax.block_until_ready(reduce(tree)), spec.warmup, spec.reps)
    return {"flops": 0.0, "bytes": float(payload_bytes), "t_s": best,
            "t_mean_s": mean}


# smoke-size shape cell used for model-step measurements; the prediction
# side builds its lmgraph from the identical (reduced cfg, cell) pair
_CELL_KINDS = {"train_step": "train", "prefill": "prefill",
               "decode_step": "decode"}


def model_cell(pt: MeasurePoint):
    from repro.configs.base import ShapeCell
    kind = _CELL_KINDS[pt.kind]
    return ShapeCell(f"cal_{kind}", int(pt.get("seq")),
                     int(pt.get("batch")), kind)


def _measure_model(pt: MeasurePoint, spec: MeasureSpec) -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config, reduced
    from repro.models import build_model

    cfg = reduced(get_config(str(pt.get("arch"))))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    seq, batch = int(pt.get("seq")), int(pt.get("batch"))
    tokens = jnp.zeros((batch, seq), jnp.int32)
    batch_d = {"tokens": tokens, "labels": tokens}
    if cfg.is_encoder_decoder:
        frames = jnp.zeros((batch, seq, cfg.d_model), jnp.float32)
        batch_d = {"frames": frames, "tokens": tokens[:, :cfg.decoder_len],
                   "labels": tokens[:, :cfg.decoder_len]}

    if pt.kind == "train_step":
        def loss(p):
            out = model.loss_fn(p, batch_d)
            return out[0] if isinstance(out, tuple) else out
        step = jax.jit(jax.grad(loss))
        run = lambda: jax.block_until_ready(step(params))
    else:                                       # prefill = one forward pass
        fwd = jax.jit(lambda p: model.forward(p, batch_d))
        run = lambda: jax.block_until_ready(fwd(params))
    best, mean = _time_fn(run, spec.warmup, spec.reps)
    return {"flops": 0.0, "bytes": 0.0, "t_s": best, "t_mean_s": mean}


def _measure_decode(pt: MeasurePoint, spec: MeasureSpec) -> Dict:
    """One-token decode over a FULL KV cache (pos = seq-1): the measured
    step is KV-cache-read-bound — attention reads the whole context per
    token — anchoring the dram-bandwidth path the serving scenarios lean
    on (the ROADMAP's missing decode-phase calibration depth)."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config, reduced
    from repro.core.scenarios import kv_cache_bytes
    from repro.models import build_model

    cfg = reduced(get_config(str(pt.get("arch"))))
    model = build_model(cfg)
    if model.decode_step is None or model.init_cache is None:
        raise RuntimeError(f"{cfg.name}: model family has no decode path")
    params = model.init(jax.random.PRNGKey(0))
    seq, batch = int(pt.get("seq")), int(pt.get("batch"))
    caches = model.init_cache(batch, seq)
    tokens = jnp.zeros((batch, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    pos = jnp.asarray(seq - 1, jnp.int32)     # read the whole context
    run = lambda: jax.block_until_ready(step(params, caches, tokens, pos))
    best, mean = _time_fn(run, spec.warmup, spec.reps)
    return {"flops": 0.0, "bytes": float(kv_cache_bytes(cfg, seq, batch)),
            "t_s": best, "t_mean_s": mean}


_MEASURERS: Dict[str, Callable[[MeasurePoint, MeasureSpec], Dict]] = {
    "gemm": _measure_gemm,
    "gemm_pallas": _measure_gemm_pallas,
    "elementwise": _measure_elementwise,
    "collective": _measure_collective,
    "train_step": _measure_model,
    "prefill": _measure_model,
    "decode_step": _measure_decode,
}


def measure_point(pt: MeasurePoint, spec: MeasureSpec) -> Dict:
    """Measure one point -> JSONL record (label fields + timings)."""
    data = _MEASURERS[pt.kind](pt, spec)
    return {"key": pt.key(), "kind": pt.kind, **dict(pt.params),
            "reps": spec.reps, **data}


def _collective_subprocess(spec: MeasureSpec,
                           keys: Sequence[str]) -> List[Dict]:
    """Run the *pending* collective points (by key) in a forced-device
    child process — already-persisted points are never re-measured, the
    same zero-re-measurement discipline as the in-process path."""
    import repro
    env = dict(os.environ)
    # repro is a namespace package (no __init__.py): locate via __path__
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH", "")) if p)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{spec.collective_devices}").strip()
    proc = subprocess.run(
        [sys.executable, "-c", _COLLECTIVE_SNIPPET,
         json.dumps(spec.to_dict()), json.dumps(list(keys))],
        env=env, capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"collective subprocess failed: {proc.stderr}")
    out = []
    for line in proc.stdout.splitlines():
        if line.startswith("MEASURE:"):
            out.append(json.loads(line[len("MEASURE:"):]))
    return out


def run_points(points: Sequence[MeasurePoint], spec: MeasureSpec,
               on_record: Callable[[Dict], None],
               verbose: bool = False) -> int:
    """Measure ``points`` in order, invoking ``on_record`` per record.

    Collective points are grouped into one forced-device subprocess when
    the parent lacks devices; everything else runs in-process.
    """
    import jax
    n = 0
    need_sub = [p for p in points if p.kind == "collective"] \
        if jax.local_device_count() < spec.collective_devices else []
    sub_keys = {p.key() for p in need_sub}
    if need_sub:
        for rec in _collective_subprocess(spec, sorted(sub_keys)):
            if rec["key"] in sub_keys:
                on_record(rec)
                n += 1
                if verbose:
                    print(f"# measured {rec['key']}: "
                          f"{rec['t_s'] * 1e6:.1f} us", flush=True)
    for pt in points:
        if pt.key() in sub_keys:
            continue
        rec = measure_point(pt, spec)
        on_record(rec)
        n += 1
        if verbose:
            print(f"# measured {rec['key']}: {rec['t_s'] * 1e6:.1f} us",
                  flush=True)
    return n


# ---------------------------------------------------------------------------
# The runner (spec.json + measurements.jsonl, resumable)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MeasureStats:
    n_points_total: int
    n_skipped: int
    n_measured: int
    elapsed_s: float
    out_dir: Optional[str]
    records: List[Dict]


class MicrobenchRunner:
    """Streams measurements to ``out_dir`` with resume discipline.

    Layout:
      spec.json           {"version", "fingerprint", "spec": {...}}
      measurements.jsonl  one record per measured point

    A resumed run must present the identical spec (fingerprint-checked)
    and re-measures nothing already on disk.
    """

    def __init__(self, spec: MeasureSpec, out_dir: Optional[str] = None):
        self.spec = spec
        self.out_dir = out_dir
        self._fp = spec.fingerprint()

    @staticmethod
    def from_dir(out_dir: str) -> "MicrobenchRunner":
        with open(os.path.join(out_dir, "spec.json")) as fh:
            head = json.load(fh)
        return MicrobenchRunner(MeasureSpec.from_dict(head["spec"]),
                                out_dir=out_dir)

    def _paths(self):
        return (os.path.join(self.out_dir, "spec.json"),
                os.path.join(self.out_dir, "measurements.jsonl"))

    def existing(self) -> Dict[str, Dict]:
        """Records already streamed (torn tail lines dropped)."""
        if self.out_dir is None:
            return {}
        _, mpath = self._paths()
        return {r["key"]: r for r in _iter_jsonl(mpath) if "key" in r}

    def run(self, resume: bool = False, verbose: bool = False
            ) -> MeasureStats:
        t0 = time.perf_counter()
        points = enumerate_points(self.spec)
        done: Dict[str, Dict] = {}
        fh = None
        records: List[Dict] = []
        if self.out_dir is not None:
            os.makedirs(self.out_dir, exist_ok=True)
            spec_path, mpath = self._paths()
            if os.path.exists(spec_path):
                with open(spec_path) as f:
                    head = json.load(f)
                if head.get("fingerprint") != self._fp:
                    raise ValueError(
                        f"cannot reuse {self.out_dir}: measurement spec "
                        f"changed (was {head.get('fingerprint')}, now "
                        f"{self._fp}); point --out at a fresh directory")
                if not resume and os.path.exists(mpath):
                    raise FileExistsError(
                        f"{self.out_dir} already holds measurements; pass "
                        f"resume=True (CLI: --resume) to continue, or use "
                        f"a fresh directory")
            if resume:
                done = self.existing()
            tmp = spec_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"version": SPEC_VERSION, "fingerprint": self._fp,
                           "spec": self.spec.to_dict()}, f, indent=2)
            os.replace(tmp, spec_path)
            fh = open(mpath, "a")
        elif resume:
            raise ValueError("resume=True requires an out_dir")

        pending = [p for p in points if p.key() not in done]

        def commit(rec: Dict):
            records.append(rec)
            if fh is not None:
                fh.write(json.dumps(json_safe(rec)) + "\n")
                fh.flush()

        try:
            n = run_points(pending, self.spec, commit, verbose=verbose)
        finally:
            if fh is not None:
                fh.close()
        return MeasureStats(
            n_points_total=len(points), n_skipped=len(done), n_measured=n,
            elapsed_s=time.perf_counter() - t0, out_dir=self.out_dir,
            records=list(done.values()) + records)


def load_measurements(out_dir: str) -> List[Dict]:
    """All measurement records streamed into ``out_dir``, spec order."""
    runner = MicrobenchRunner.from_dir(out_dir)
    by_key = runner.existing()
    return [by_key[p.key()] for p in enumerate_points(runner.spec)
            if p.key() in by_key]
