"""Serialized calibration profiles — the measurement-anchored contract.

A profile is the JSON artifact `pathfind calibrate` produces and every
downstream engine consumes (``pathfind sweep --profile DIR/profile.json``;
`sweeprunner.SweepSpec` embeds the profile dict so the sweep fingerprint —
and therefore resume identity — changes with the calibration; `cooptimize`
inherits it through the sweep spec).  It records:

  * the fitted parameter vector (`fitting.PARAM_NAMES`),
  * which tech entry it anchors (``tech`` name) and the measurement-spec
    fingerprint it was fitted against,
  * fit metadata (loss/MRE before and after, candidate selected), and
  * the validation report at fit time (the drift baseline).

Applying a profile = scaling a MicroArch's efficiency leaves
(`fitting.scale_microarch`) + overriding the PPE kernel overhead — both
traceable, so calibrated sweeps keep their vmapped/jitted fast paths.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

from repro.calibrate.fitting import default_params, scale_microarch
from repro.core.age import MicroArch
from repro.core.roofline import PPEConfig

PROFILE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """One calibration result, JSON round-trippable."""

    tech: str                               # techlib entry it anchors
    params: Dict[str, float]                # fitting.PARAM_NAMES -> value
    measure_fingerprint: str = ""           # MeasureSpec.fingerprint()
    fit: Dict[str, float] = dataclasses.field(default_factory=dict)
    validation: Dict = dataclasses.field(default_factory=dict)
    version: int = PROFILE_VERSION

    def to_dict(self) -> Dict:
        return {"version": self.version, "tech": self.tech,
                "params": {k: float(v) for k, v in self.params.items()},
                "measure_fingerprint": self.measure_fingerprint,
                "fit": self.fit, "validation": self.validation}

    @staticmethod
    def from_dict(d: Dict) -> "CalibrationProfile":
        return CalibrationProfile(
            tech=str(d.get("tech", "")),
            params={k: float(v) for k, v in (d.get("params") or {}).items()},
            measure_fingerprint=str(d.get("measure_fingerprint", "")),
            fit=dict(d.get("fit") or {}),
            validation=dict(d.get("validation") or {}),
            version=int(d.get("version", PROFILE_VERSION)))

    def kernel_overhead_s(self) -> Optional[float]:
        v = self.params.get("kernel_overhead_s")
        return float(v) if v is not None else None


def identity_profile(tech: str = "") -> CalibrationProfile:
    """The do-nothing profile (uncalibrated techlib entry)."""
    return CalibrationProfile(tech=tech, params=default_params())


def save_profile(profile: CalibrationProfile, path: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(profile.to_dict(), fh, indent=2, sort_keys=True)
    os.replace(tmp, path)


def load_profile(path: str) -> CalibrationProfile:
    with open(path) as fh:
        return CalibrationProfile.from_dict(json.load(fh))


def coerce(profile) -> Optional[CalibrationProfile]:
    """CalibrationProfile | dict | path | None -> CalibrationProfile."""
    if profile is None:
        return None
    if isinstance(profile, CalibrationProfile):
        return profile
    if isinstance(profile, dict):
        return CalibrationProfile.from_dict(profile)
    if isinstance(profile, str):
        return load_profile(profile)
    raise TypeError(f"cannot interpret profile {type(profile).__name__}")


def apply_profile(arch: MicroArch, profile) -> MicroArch:
    """Measurement-anchored MicroArch: efficiency scales applied.

    Accepts a CalibrationProfile, its dict form, a profile.json path, or
    None (identity).  Traceable: safe inside the vmapped evaluators.
    """
    prof = coerce(profile)
    if prof is None:
        return arch
    return scale_microarch(arch, prof.params)


def ppe_with_profile(ppe: PPEConfig, profile) -> PPEConfig:
    """PPEConfig carrying the profile's PPE-level parameters.

    ``kernel_overhead_s`` replaces the default launch latency, and
    ``vector_frac`` is scaled by vector_eff / compute_eff: the MicroArch's
    compute throughput is already scaled by compute_eff
    (`fitting.scale_microarch`), so the elementwise rate
    (throughput * vector_frac) lands on the *fitted* vector efficiency —
    the same model the fit validated.
    """
    prof = coerce(profile)
    if prof is None:
        return ppe
    out = ppe
    ov = prof.kernel_overhead_s()
    if ov is not None:
        out = dataclasses.replace(out, kernel_overhead_s=float(ov))
    vec = prof.params.get("vector_eff")
    comp = prof.params.get("compute_eff")
    if vec is not None and comp:
        out = dataclasses.replace(
            out, vector_frac=out.vector_frac * float(vec) / float(comp))
    return out
