"""Differentiable fitting engine: measurements -> techlib/PPE parameters.

The calibration parameter vector collects the efficiency and overhead
knobs the performance model exposes, treated as ONE batched vector:

  compute_eff        achieved / nominal compute throughput (MXU derate)
  dram_bw_eff        main-memory bandwidth efficiency
  l2/l1/l0_bw_eff    per-level on-chip bandwidth efficiencies
  vector_eff         vector-pipe (elementwise) efficiency — consumed by
                     sweeps through `profiles.ppe_with_profile`, which
                     folds vector_eff/compute_eff into PPE vector_frac
  kernel_overhead_s  software-stack launch latency (PPE overhead)
  net_alpha_eff      collective latency (alpha) scale on the techlib link
                     latency — a scale, not an absolute, so the identity
                     parameter set stays a strict no-op on the MicroArch
  net_beta_eff       collective bandwidth efficiency (beta derate)

Predictions flow through the *existing* traced paths — `roofline.gemm_time`
/ `roofline.elementwise_time` for kernels and `simulate.predict` for
end-to-end model steps — on a MicroArch whose leaves are scaled by the
parameters, so the loss is differentiable and the fit is exact-gradient
multi-start GD.  The batched update mirrors the SOE's vmapped eq.-6 shape
(`soe.eq6_update`: normalized gradient, parameter-space EMA, projection)
with a log-space box projection replacing the budget simplex.

Selection is by the *report* metric: among {identity, analytic seed, every
GD start's best iterate}, `fit` returns the candidate with the lowest mean
relative error on the measurement set, so a calibrated profile can never
validate worse than the uncalibrated techlib entry it started from.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import roofline, simulate
from repro.core.age import MicroArch
from repro.core.parallelism import Strategy
from repro.core.roofline import PPEConfig

# (name, default, lo, hi) — defaults are the identity / PPE defaults, so
# theta0 reproduces the uncalibrated model exactly.
PARAM_SPECS: Tuple[Tuple[str, float, float, float], ...] = (
    ("compute_eff", 1.0, 0.01, 50.0),
    ("dram_bw_eff", 1.0, 0.01, 50.0),
    ("l2_bw_eff", 1.0, 0.02, 20.0),
    ("l1_bw_eff", 1.0, 0.02, 20.0),
    ("l0_bw_eff", 1.0, 0.02, 20.0),
    ("vector_eff", 1.0, 0.01, 50.0),
    ("kernel_overhead_s", 3e-6, 1e-8, 1e-2),
    ("net_alpha_eff", 1.0, 1e-2, 1e6),
    ("net_beta_eff", 1.0, 1e-3, 100.0),
)
PARAM_NAMES: Tuple[str, ...] = tuple(s[0] for s in PARAM_SPECS)
# measurement kinds the default fit consumes (gemm_pallas is reported but
# not fitted: CPU interpret mode times the emulator, not the hardware)
KINDS_FITTED: Tuple[str, ...] = ("gemm", "elementwise", "collective",
                                 "train_step", "prefill", "decode_step")
N_PARAMS = len(PARAM_SPECS)
_LOG_LO = np.log(np.asarray([s[2] for s in PARAM_SPECS], dtype=np.float64))
_LOG_HI = np.log(np.asarray([s[3] for s in PARAM_SPECS], dtype=np.float64))


def default_params() -> Dict[str, float]:
    """The identity parameter set (uncalibrated model)."""
    return {name: default for name, default, _, _ in PARAM_SPECS}


def params_to_theta(params: Dict[str, float]) -> np.ndarray:
    """Params dict -> log-space theta vector (fit coordinates)."""
    full = {**default_params(), **params}
    vals = np.asarray([max(float(full[n]), 1e-30) for n in PARAM_NAMES])
    return np.clip(np.log(vals), _LOG_LO, _LOG_HI)


def theta_to_params(theta) -> Dict[str, float]:
    vals = np.exp(np.asarray(theta, dtype=np.float64))
    return {n: float(v) for n, v in zip(PARAM_NAMES, vals)}


def scale_microarch(arch: MicroArch, params: Dict[str, float]) -> MicroArch:
    """Apply efficiency parameters to a MicroArch (traceable in values).

    Every parameter here is a *scale* with identity 1.0, so the default
    parameter set is a strict no-op.  The remaining two fitted parameters
    live elsewhere: ``kernel_overhead_s`` and ``vector_eff`` ride on the
    PPEConfig (`profiles.ppe_with_profile`).
    """
    bw = arch.mem_bw
    alpha = params.get("net_alpha_eff", 1.0)
    return dataclasses.replace(
        arch,
        compute_throughput=arch.compute_throughput
        * params.get("compute_eff", 1.0),
        dram_bw=arch.dram_bw * params.get("dram_bw_eff", 1.0),
        mem_bw=(bw[0] * params.get("l0_bw_eff", 1.0),
                bw[1] * params.get("l1_bw_eff", 1.0),
                bw[2] * params.get("l2_bw_eff", 1.0)),
        net_intra_bw=arch.net_intra_bw * params.get("net_beta_eff", 1.0),
        net_inter_bw=arch.net_inter_bw * params.get("net_beta_eff", 1.0),
        net_intra_latency=arch.net_intra_latency * alpha,
        net_inter_latency=arch.net_inter_latency * alpha,
    )


# ---------------------------------------------------------------------------
# Per-measurement predictors (traced; theta is a jnp vector)
# ---------------------------------------------------------------------------


def _graph_overhead_count(graph) -> float:
    """Number of kernel launches one prediction charges overhead for."""
    return float(sum(node.meta.get("repeat", 1)
                     for node in graph.nodes.values()
                     if node.kind != "comm"))


def _model_skeleton(rec: Dict):
    """(graph, strategy) for one model-step measurement record — the
    prediction side of the identical (reduced cfg, smoke cell) pair the
    microbench measured.  ``decode_step`` builds the decode-kind graph
    (one token over the full KV context — the KV-bandwidth path)."""
    from repro.configs.base import ShapeCell, get_config, reduced
    from repro.core import lmgraph
    kind = {"train_step": "train", "prefill": "prefill",
            "decode_step": "decode"}[rec["kind"]]
    cell = ShapeCell(f"cal_{kind}", int(rec["seq"]), int(rec["batch"]),
                     kind)
    cfg = reduced(get_config(str(rec["arch"])))
    graph = lmgraph.build_graph(cfg, cell)
    return graph, Strategy("RC", kp1=1, kp2=1, dp=1)


def build_predictor(measurements: Sequence[Dict], template: MicroArch,
                    ppe: PPEConfig = PPEConfig()) -> Callable:
    """-> ``predict_all(theta_log) -> (R,) jnp vector`` of predicted times.

    One closure per measurement record, all flowing through the traced
    roofline / simulate paths with a zero-overhead PPEConfig; the traced
    ``kernel_overhead_s`` parameter is added explicitly (per launch for
    kernels, per graph node for model steps).
    """
    ppe0 = dataclasses.replace(ppe, kernel_overhead_s=0.0)
    closures: List[Callable] = []
    for rec in measurements:
        kind = rec["kind"]
        if kind in ("gemm", "gemm_pallas"):
            m, n, k = int(rec["m"]), int(rec["n"]), int(rec["k"])
            db = int(rec.get("dtype_bytes", 4))

            def f(p, m=m, n=n, k=k, db=db):
                arch = scale_microarch(template, p)
                return (roofline.gemm_time(arch, m, n, k, dtype_bytes=db,
                                           cfg=ppe0)
                        + p["kernel_overhead_s"])
        elif kind == "elementwise":
            n_elems = float(rec["n_elems"])

            def f(p, n_elems=n_elems):
                arch = scale_microarch(template, p)
                arch = dataclasses.replace(
                    arch, compute_throughput=template.compute_throughput
                    * p["vector_eff"])
                return (roofline.elementwise_time(arch, n_elems, 2.0,
                                                  dtype_bytes=4, cfg=ppe0)
                        + p["kernel_overhead_s"])
        elif kind == "collective":
            payload = float(rec["bytes"])
            n_dev = int(rec["devices"])
            base_bw = float(template.net_intra_bw)
            base_lat = float(template.net_intra_latency)

            def f(p, payload=payload, n_dev=n_dev, base_bw=base_bw,
                  base_lat=base_lat):
                # ring all-reduce alpha-beta: (n-1) latency hops plus
                # 2(n-1)/n of the payload over the efficient link bw;
                # alpha = the techlib link latency scaled by the fitted
                # net_alpha_eff (the same scaling scale_microarch applies)
                wire = 2.0 * (n_dev - 1) / n_dev * payload
                return (base_lat * p["net_alpha_eff"] * (n_dev - 1)
                        + wire / (base_bw * p["net_beta_eff"]))
        elif kind in ("train_step", "prefill", "decode_step"):
            graph, st = _model_skeleton(rec)
            n_launch = _graph_overhead_count(graph)

            def f(p, graph=graph, st=st, n_launch=n_launch):
                arch = scale_microarch(template, p)
                bd = simulate.predict(arch, graph, st, cfg=ppe0)
                return bd.total_s + p["kernel_overhead_s"] * n_launch
        else:
            raise ValueError(f"unknown measurement kind {kind!r}")
        closures.append(f)

    def predict_all(theta_log):
        p = {name: jnp.exp(theta_log[i])
             for i, name in enumerate(PARAM_NAMES)}
        return jnp.stack([jnp.asarray(f(p), dtype=jnp.float32)
                          for f in closures])

    return predict_all


def predict_measurements(measurements: Sequence[Dict], template: MicroArch,
                         params: Optional[Dict[str, float]] = None,
                         ppe: PPEConfig = PPEConfig()) -> np.ndarray:
    """Concrete (host-side) predicted times, one per measurement record.

    The single prediction path shared by the fit loss and the validation
    reporter — `report.validation_report` scores exactly what `fit`
    optimized, so the two cannot drift apart.
    """
    predict_all = build_predictor(measurements, template, ppe)
    theta = jnp.asarray(params_to_theta(params or default_params()),
                        dtype=jnp.float32)
    return np.asarray(predict_all(theta), dtype=np.float64)


def mean_relative_error(measurements: Sequence[Dict],
                        predicted: np.ndarray) -> float:
    meas = np.asarray([float(r["t_s"]) for r in measurements])
    return float(np.mean(np.abs(predicted - meas) / np.maximum(meas,
                                                               1e-12)))


# ---------------------------------------------------------------------------
# Multi-start batched fit
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FitConfig:
    steps: int = 80
    starts: int = 6
    lr: float = 0.15
    beta: float = 0.7               # parameter-space EMA (eq.-6 style)
    seed: int = 0
    jitter: float = 0.5             # log-space start spread


@dataclasses.dataclass
class FitResult:
    params: Dict[str, float]
    theta: np.ndarray               # log-space
    loss: float                     # selected candidate's fit loss
    loss_identity: float            # identity-params fit loss
    mre: float                      # selected candidate's mean rel. error
    mre_identity: float
    history: List[float]
    n_evals: int
    selected: str                   # "identity" | "seed" | "fit"

    @property
    def improved(self) -> bool:
        return self.mre < self.mre_identity


def _loss_fn(predict_all: Callable, measured: jnp.ndarray,
             weights: jnp.ndarray) -> Callable:
    """Weighted mean squared log error (smooth, scale-free)."""
    log_meas = jnp.log(jnp.maximum(measured, 1e-12))

    def loss(theta_log):
        pred = predict_all(theta_log)
        d = jnp.log(jnp.maximum(pred, 1e-12)) - log_meas
        return jnp.sum(weights * d * d) / jnp.sum(weights)

    return loss


def _kind_weights(measurements: Sequence[Dict]) -> np.ndarray:
    """Balance kinds: each measurement kind contributes equal total weight
    (a 10-shape GEMM sweep must not drown two model-step records)."""
    kinds = [r["kind"] for r in measurements]
    counts = {k: kinds.count(k) for k in set(kinds)}
    return np.asarray([1.0 / counts[k] for k in kinds], dtype=np.float32)


def analytic_seed(measurements: Sequence[Dict],
                  template: MicroArch) -> Dict[str, float]:
    """Closed-form anchor (the fig-6 methodology, per parameter): peak
    achieved GEMM rate -> compute_eff, fastest kernel -> overhead,
    achieved collective bandwidth -> net_beta_eff."""
    params = default_params()
    gemm = [r for r in measurements if r["kind"] == "gemm"]
    if gemm:
        rate = max(float(r["flops"]) / max(float(r["t_s"]), 1e-12)
                   for r in gemm)
        params["compute_eff"] = rate / max(
            float(template.compute_throughput), 1e-12)
        params["kernel_overhead_s"] = min(float(r["t_s"]) for r in gemm) / 2
    elem = [r for r in measurements if r["kind"] == "elementwise"]
    if elem:
        bw = max(float(r["bytes"]) / max(float(r["t_s"]), 1e-12)
                 for r in elem)
        params["dram_bw_eff"] = bw / max(float(template.dram_bw), 1e-12)
    coll = [r for r in measurements if r["kind"] == "collective"]
    if coll:
        r = max(coll, key=lambda r: float(r["bytes"]))
        n_dev = int(r["devices"])
        wire = 2.0 * (n_dev - 1) / n_dev * float(r["bytes"])
        bw = wire / max(float(r["t_s"]), 1e-12)
        params["net_beta_eff"] = bw / max(float(template.net_intra_bw),
                                          1e-12)
        alpha = min(float(c["t_s"]) for c in coll) / max(n_dev - 1, 1)
        params["net_alpha_eff"] = alpha \
            / max(float(template.net_intra_latency), 1e-12)
    return theta_to_params(params_to_theta(params))   # clip into bounds


def fit_update(W: jnp.ndarray, M: jnp.ndarray, G: jnp.ndarray, lr: float,
               beta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One batched fit step — the eq.-6 shape (`soe.eq6_update`) with the
    budget simplex replaced by the log-space parameter box: normalized
    gradient descent, parameter-space EMA, clip projection."""
    G = jnp.nan_to_num(G, nan=0.0, posinf=0.0, neginf=0.0)
    gnorm = jnp.linalg.norm(G, axis=1, keepdims=True)
    G = jnp.where(gnorm > 0, G / (gnorm + 1e-12), G)
    W_new = W - lr * G
    M_new = beta * M + (1.0 - beta) * W_new
    lo = jnp.asarray(_LOG_LO, dtype=W.dtype)
    hi = jnp.asarray(_LOG_HI, dtype=W.dtype)
    return jnp.clip(M_new, lo, hi), M_new


def _initial_thetas(seed_theta: np.ndarray, cfg: FitConfig) -> np.ndarray:
    """(S, N_PARAMS) stack: start 0 identity, start 1 the analytic seed,
    the rest log-space jitter around the seed."""
    rng = np.random.default_rng(cfg.seed)
    rows = [params_to_theta(default_params()), np.asarray(seed_theta)]
    for _ in range(2, max(cfg.starts, 2)):
        jit = rng.uniform(-cfg.jitter, cfg.jitter, N_PARAMS)
        rows.append(np.clip(seed_theta + jit, _LOG_LO, _LOG_HI))
    return np.stack(rows[:max(cfg.starts, 2)]).astype(np.float32)


def fit(measurements: Sequence[Dict], template: MicroArch,
        ppe: PPEConfig = PPEConfig(), cfg: FitConfig = FitConfig(),
        kinds: Optional[Sequence[str]] = None) -> FitResult:
    """Fit the calibration vector to a measurement set.

    All S starts advance together (one jitted vmapped value-and-grad +
    one vectorized update per step); per-start best iterates are kept and
    the final winner is chosen by mean relative error, with the identity
    and the analytic seed always in the candidate pool.

    ``kinds`` restricts which measurement kinds enter the fit.  The
    default excludes ``gemm_pallas``: interpret-mode Pallas timing on CPU
    measures the emulation harness, not the silicon, and no single
    efficiency vector can fit it alongside the XLA kernels — it still
    appears in the validation report as its own group.
    """
    if kinds is None:
        kinds = tuple(k for k in KINDS_FITTED)
    measurements = [r for r in measurements
                    if "t_s" in r and r.get("kind") in kinds]
    if not measurements:
        raise ValueError("no measurements to fit")
    predict_all = build_predictor(measurements, template, ppe)
    measured = jnp.asarray([float(r["t_s"]) for r in measurements],
                           dtype=jnp.float32)
    weights = jnp.asarray(_kind_weights(measurements))
    loss = _loss_fn(predict_all, measured, weights)

    seed_params = analytic_seed(measurements, template)
    W = jnp.asarray(_initial_thetas(params_to_theta(seed_params), cfg))
    S = W.shape[0]
    vg = jax.vmap(jax.value_and_grad(loss))
    step = jax.jit(functools.partial(
        _fit_step, vg=vg, lr=cfg.lr, beta=cfg.beta))

    M = W
    done = jnp.zeros(S, dtype=bool)
    last = jnp.full(S, jnp.inf)
    best_theta = np.asarray(W)                 # per-start best iterate
    best_loss = np.full(S, np.inf)
    history: List[float] = []
    n_evals = 0
    for _ in range(cfg.steps):
        if bool(np.all(np.asarray(done))):
            break
        n_evals += S
        W_before = np.asarray(W)
        W, M, done, vals = step(W, M, done, last)
        vals_np = np.asarray(vals, dtype=np.float64)
        history.append(float(np.nanmin(vals_np)))
        improved = np.isfinite(vals_np) & (vals_np < best_loss)
        best_loss = np.where(improved, vals_np, best_loss)
        best_theta = np.where(improved[:, None], W_before, best_theta)
        last = vals

    # candidate pool: identity, analytic seed, every start's best iterate
    cands: List[Tuple[str, np.ndarray]] = [
        ("identity", params_to_theta(default_params())),
        ("seed", params_to_theta(seed_params)),
    ] + [("fit", best_theta[s]) for s in range(S)
         if np.isfinite(best_loss[s])]
    meas_np = np.asarray(measured, dtype=np.float64)
    best = None
    for label, theta in cands:
        pred = np.asarray(predict_all(jnp.asarray(theta,
                                                  dtype=jnp.float32)),
                          dtype=np.float64)
        mre = float(np.mean(np.abs(pred - meas_np)
                            / np.maximum(meas_np, 1e-12)))
        if best is None or mre < best[0]:
            best = (mre, label, np.asarray(theta, dtype=np.float64))
    mre_best, label, theta = best
    theta0 = params_to_theta(default_params())
    pred0 = np.asarray(predict_all(jnp.asarray(theta0,
                                               dtype=jnp.float32)),
                       dtype=np.float64)
    mre0 = float(np.mean(np.abs(pred0 - meas_np)
                         / np.maximum(meas_np, 1e-12)))
    return FitResult(
        params=theta_to_params(theta), theta=theta,
        loss=float(loss(jnp.asarray(theta, dtype=jnp.float32))),
        loss_identity=float(loss(jnp.asarray(theta0,
                                             dtype=jnp.float32))),
        mre=mre_best, mre_identity=mre0, history=history,
        n_evals=n_evals, selected=label)


def _fit_step(W, M, done, last, *, vg, lr, beta):
    vals, G = vg(W)
    W_proj, M_new = fit_update(W, M, G, lr, beta)
    conv = jnp.abs(last - vals) < 1e-8 * jnp.maximum(vals, 1e-12)
    frozen = done[:, None]
    return (jnp.where(frozen, W, W_proj), jnp.where(frozen, M, M_new),
            done | conv, vals)
