"""DeepFlow pathfinding CLI — batched design-space exploration from a shell.

Subcommands:

  sweep   cross-product (arch x cell x mesh x logic x hbm x net) scored by
          the batched evaluator; prints CSV (optionally only the Pareto
          frontier) and can write it to a file:

              PYTHONPATH=src python -m repro.pathfind sweep \
                  --arch qwen1.5-0.5b --cell train_4k \
                  --mesh 8x8 --mesh 16x16 \
                  --logic N7,N5,N3 --hbm HBM2E,HBM3 --csv sweep.csv

  plan    the CrossFlow -> runtime bridge: best runtime-realizable strategy
          for one (arch, cell, mesh) on the TPU-v5e micro-arch:

              PYTHONPATH=src python -m repro.pathfind plan \
                  --arch qwen1.5-0.5b --cell train_4k --mesh 16x16

  soe     joint strategy x hardware-budget co-optimization (paper §7/§9.2)
          with the batched multi-start GD:

              PYTHONPATH=src python -m repro.pathfind soe \
                  --arch qwen1.5-0.5b --cell train_4k --devices 64 \
                  --steps 10 --starts 4
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Tuple


def _mesh(text: str) -> Tuple[int, ...]:
    try:
        dims = tuple(int(x) for x in text.lower().split("x"))
    except ValueError:
        dims = ()
    if not dims or any(d <= 0 for d in dims):
        raise argparse.ArgumentTypeError(
            f"bad mesh {text!r}; expected e.g. 16x16 or 2x16x16")
    return dims


def _csv_list(text: str) -> List[str]:
    return [x.strip() for x in text.split(",") if x.strip()]


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="repro.pathfind", description=__doc__,
                                formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = p.add_subparsers(dest="cmd", required=True)

    sw = sub.add_parser("sweep", help="batched design-space sweep")
    sw.add_argument("--arch", action="append", required=True,
                    help="model arch id (repeatable)")
    sw.add_argument("--cell", action="append", required=True,
                    help="shape cell name (repeatable)")
    sw.add_argument("--mesh", action="append", type=_mesh, required=True,
                    help="mesh shape like 16x16 (repeatable)")
    sw.add_argument("--logic", type=_csv_list, default=["N7"],
                    help="comma-separated logic nodes (default N7)")
    sw.add_argument("--hbm", type=_csv_list, default=["HBM2E"],
                    help="comma-separated HBM generations")
    sw.add_argument("--net", type=_csv_list, default=["IB-NDR-X8"],
                    help="comma-separated inter-node networks")
    sw.add_argument("--area", type=float, default=None,
                    help="proc chip area budget (mm^2)")
    sw.add_argument("--power", type=float, default=None,
                    help="node power budget (W)")
    sw.add_argument("--tilings", type=int, default=8,
                    help="PPE tiling samples per level")
    sw.add_argument("--pareto", type=_csv_list, default=None, metavar="OBJS",
                    help="print only the Pareto frontier over these "
                         "objectives (e.g. time_s,devices)")
    sw.add_argument("--csv", default=None, help="also write CSV here")

    pl = sub.add_parser("plan", help="runtime sharding plan for one point")
    pl.add_argument("--arch", required=True)
    pl.add_argument("--cell", required=True)
    pl.add_argument("--mesh", type=_mesh, required=True)

    so = sub.add_parser("soe", help="strategy x budget co-optimization")
    so.add_argument("--arch", required=True)
    so.add_argument("--cell", required=True)
    so.add_argument("--devices", type=int, default=64)
    so.add_argument("--logic", default="N7")
    so.add_argument("--hbm", default="HBM2E")
    so.add_argument("--net", default="IB-NDR-X8")
    so.add_argument("--steps", type=int, default=20)
    so.add_argument("--starts", type=int, default=4)
    so.add_argument("--tilings", type=int, default=8)
    so.add_argument("--no-search-arch", action="store_true",
                    help="rank strategies only (skip the budget GD)")
    return p


def _cmd_sweep(args) -> int:
    import dataclasses
    from repro.core import pathfinder
    from repro.core.age import Budgets
    from repro.core.roofline import PPEConfig

    budgets = Budgets.default()
    if args.area is not None:
        budgets = dataclasses.replace(budgets, proc_chip_area_mm2=args.area)
    if args.power is not None:
        budgets = dataclasses.replace(budgets, power_w=args.power)
    result = pathfinder.sweep(
        args.arch, args.cell, args.mesh, logic_nodes=args.logic,
        hbms=args.hbm, nets=args.net, budgets=budgets,
        ppe=PPEConfig(n_tilings=args.tilings))
    points = result.points
    if args.pareto:
        points = result.pareto(objectives=args.pareto)
    lines = [pathfinder.CSV_HEADER] + [p.as_csv_row() for p in points]
    print("\n".join(lines))
    if args.csv:
        with open(args.csv, "w") as fh:
            fh.write("\n".join(lines) + "\n")
        print(f"# wrote {len(points)} points to {args.csv}", file=sys.stderr)
    best = result.best()
    print(f"# best: {best.arch}/{best.cell} mesh="
          f"{'x'.join(map(str, best.mesh))} {best.logic}/{best.hbm}/"
          f"{best.net} {best.strategy.name} -> {best.time_s*1e3:.2f} ms",
          file=sys.stderr)
    return 0


def _cmd_plan(args) -> int:
    from repro.configs.base import SHAPE_CELLS, get_config
    from repro.core import planner

    axes = ("pod", "data", "model")[-len(args.mesh):]
    plan = planner.plan(get_config(args.arch), SHAPE_CELLS[args.cell],
                        args.mesh, axes)
    print(f"strategy       {plan.strategy.name}")
    print(f"predicted_step {plan.predicted_step_s*1e3:.3f} ms")
    for k, v in plan.predicted_breakdown.items():
        print(f"  {k:15s} {v*1e3:.3f} ms")
    for axis, rule in plan.rules:
        print(f"rule {axis:10s} -> {rule}")
    if plan.notes:
        print(f"notes: {plan.notes}")
    return 0


def _cmd_soe(args) -> int:
    from repro.configs.base import SHAPE_CELLS, get_config
    from repro.core import lmgraph, soe, techlib
    from repro.core.roofline import PPEConfig

    tech = techlib.make_tech_config(args.logic, args.hbm, args.net)
    g = lmgraph.build_graph(get_config(args.arch), SHAPE_CELLS[args.cell])
    res = soe.co_optimize(
        tech, g, n_devices=args.devices,
        cfg=soe.SOEConfig(steps=args.steps, starts=args.starts),
        search_arch=not args.no_search_arch,
        ppe=PPEConfig(n_tilings=args.tilings))
    print(f"strategy  {res.strategy.name}")
    print(f"time      {res.time_s*1e3:.3f} ms/iter")
    print(f"queries   {res.n_queries}")
    for comp, frac in res.budgets.area_frac.items():
        print(f"area[{comp:9s}] {float(frac):.3f}")
    return 0


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    try:
        return {"sweep": _cmd_sweep, "plan": _cmd_plan,
                "soe": _cmd_soe}[args.cmd](args)
    except ModuleNotFoundError as e:
        print(f"error: unknown arch (no config module): {e.name}",
              file=sys.stderr)
    except KeyError as e:
        print(f"error: unknown name: {e}", file=sys.stderr)
    except (ValueError, AttributeError) as e:
        print(f"error: {e}", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
