"""DeepFlow pathfinding CLI — batched design-space exploration from a shell.

Subcommands:

  sweep   cross-product (arch x cell x mesh x logic x hbm x net) scored by
          the batched evaluator; prints CSV (optionally only the Pareto
          frontier) and can write it to a file:

              PYTHONPATH=src python -m repro.pathfind sweep \
                  --arch qwen1.5-0.5b --cell train_4k \
                  --mesh 8x8 --mesh 16x16 \
                  --logic N7,N5,N3 --hbm HBM2E,HBM3 --csv sweep.csv

          With --out DIR the sweep runs on the chunked, resumable engine
          (repro.core.sweeprunner; default backend = the pipelined
          executor of repro.core.sweeppipeline): results stream to
          DIR/results.jsonl, finished chunks are checkpointed, compiled
          XLA executables persist under DIR/xla_cache, and an
          interrupted sweep continues with ZERO re-evaluation via:

              PYTHONPATH=src python -m repro.pathfind sweep \
                  --out sweeps/serve --resume

          --scenario picks the workload semantics (scenario registry,
          repro.core.scenarios): "train" = step time; "serving" =
          prefill+decode TTFT / tokens-per-sec-per-device with KV-cache
          memory pressure; "serving-long" = 500k-token decode (recurrent /
          hybrid archs).  --arch all sweeps every registered config:

              PYTHONPATH=src python -m repro.pathfind sweep \
                  --scenario serving --arch all --mesh 16x16 \
                  --logic N7,N5 --slo 10 --out sweeps/serve

          --frontier-only streams every point through a device-resident
          Pareto reduction fused into the compiled evaluator: only the
          frontier is materialized/printed (DIR/frontier.jsonl), so
          10^6-point sweeps never pull per-point rows to host.  The
          carried state checkpoints to DIR/frontier_state.npz per
          committed superbatch, so --resume continues an interrupted
          frontier sweep with zero re-evaluation.

          --scenario serving-traffic scores continuous batching with
          chunked prefill under a QPS arrival model (repro.core.traffic):
          TTFT/TPOT percentiles, utilization walls, and device-seconds
          per token.  Traffic/batching params are typed --scenario-param
          flags; a comma list (e.g. --scenario-param
          prefill_chunk=256,512) declares a sweep axis.

  explore surrogate-driven exploration (repro.core.surrogate): instead of
          exhausting the cross-product, fit an ensemble of small jit'd
          MLPs (mean + epistemic spread + feasibility head) on the points
          evaluated so far and spend the real-evaluation budget on the
          top-acquisition chunks (UCB / expected-Pareto-improvement over
          the canonical-signed objectives) until the frontier stagnates
          or the budget runs out.  The output directory is a normal
          partial sweep (spec.json / results.jsonl / checkpoint.jsonl) —
          resumable, and readable by size/cooptimize:

              PYTHONPATH=src python -m repro.pathfind explore \
                  --arch qwen1.5-0.5b --mesh 2x2 --mesh 4x4 \
                  --logic N7,N5 --scale 0.9,1.1 \
                  --eval-frac 0.25 --out sweeps/explore

          With --order-dir DIR the surrogate instead ranks a fabric
          sweep directory's chunks and writes DIR/order.json — an
          advisory claim order that makes `sweep --workers N` fleets
          evaluate frontier-adjacent chunks first (results are
          byte-identical to an unordered run; only the schedule moves).

  size    inverse fleet sizing over a swept design space: the minimum
          device count serving --qps under percentile SLO walls, by
          doubling+bisection on the closed-form traffic model — swept
          points are never re-evaluated:

              PYTHONPATH=src python -m repro.pathfind size \
                  --from sweeps/traffic --qps 24 \
                  --slo-ttft-p99 2.0 --slo-tpot-p50 0.05

          --rank-by cost_per_token | energy_per_token re-ranks the
          feasible fleet plans by the PR8 objective columns already in
          the swept records (zero re-evaluation; needs a sweep run with
          --objectives energy,cost)

  plan    the CrossFlow -> runtime bridge: best runtime-realizable strategy
          for one (arch, cell, mesh) on the TPU-v5e micro-arch:

              PYTHONPATH=src python -m repro.pathfind plan \
                  --arch qwen1.5-0.5b --cell train_4k --mesh 16x16

  soe     joint strategy x hardware-budget co-optimization (paper §7/§9.2)
          with the batched multi-start GD:

              PYTHONPATH=src python -m repro.pathfind soe \
                  --arch qwen1.5-0.5b --cell train_4k --devices 64 \
                  --steps 10 --starts 4

  calibrate  measurement-driven calibration (repro.calibrate): run the
          microbenchmark suite on THIS machine (jit'd GEMMs, optionally
          Pallas kernels, forced-multi-device collectives, model-family
          steps), fit the techlib/PPE efficiency+overhead vector to the
          measurements by multi-start GD through the traced performance
          model, and write DIR/profile.json + DIR/report.json (the drift
          baseline).  Resumable like a sweep (--resume skips measured
          points):

              PYTHONPATH=src python -m repro.pathfind calibrate \
                  --out calib --suite quick

  validate  re-measure (or reuse) the suite and diff the validation
          report against the stored baseline — non-zero exit on drift:

              PYTHONPATH=src python -m repro.pathfind validate --out calib

  cooptimize  cross-stack sweep -> refine: load a checkpointed sweep's
          Pareto frontier and run batched gradient refinement around each
          frontier point, jointly over continuous technology knobs (DVFS
          voltage, HBM bandwidth/capacity scaling), the hardware budget
          vector (eq.-6 SOE update), and the discrete strategy/mesh axis
          (ranked from the sweep's own records — scored points are never
          re-evaluated).  Refined records stream to DIR/refined.jsonl in
          the sweep's JSONL schema:

              PYTHONPATH=src python -m repro.pathfind cooptimize \
                  --from sweeps/serve --top-k 4 --steps 24
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Tuple


def _mesh(text: str) -> Tuple[int, ...]:
    try:
        dims = tuple(int(x) for x in text.lower().split("x"))
    except ValueError:
        dims = ()
    if not dims or any(d <= 0 for d in dims):
        raise argparse.ArgumentTypeError(
            f"bad mesh {text!r}; expected e.g. 16x16 or 2x16x16")
    return dims


def _csv_list(text: str) -> List[str]:
    return [x.strip() for x in text.split(",") if x.strip()]


def _scenario_param(text: str) -> Tuple[str, object]:
    """KEY=V or KEY=V1,V2,... (a comma list declares a sweep axis)."""
    key, sep, val = text.partition("=")
    vals = [v for v in val.split(",") if v]
    if not sep or not key or not vals:
        raise argparse.ArgumentTypeError(
            f"bad scenario param {text!r}; expected KEY=V or KEY=V1,V2,...")
    try:
        out = [float(v) for v in vals]
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"bad scenario param {text!r}: values must be numbers")
    return key.strip(), out[0] if len(out) == 1 else out


def _scenario_params_dict(pairs) -> dict:
    return dict(pairs or ())


# -- shared flag groups (sweep / cooptimize / size) -------------------------
# one scenario/profile/out-dir vocabulary across subcommands: a flag means
# the same thing everywhere, and commands that read their spec from a
# directory refuse contradicting flags instead of silently ignoring them


def _add_axis_flags(p) -> None:
    g = p.add_argument_group("design-space axes")
    g.add_argument("--arch", action="append", default=None,
                   help="model arch id (repeatable; 'all' = every config)")
    g.add_argument("--cell", action="append", default=None,
                   help="shape cell name (repeatable; default from the "
                        "scenario, e.g. train_4k / prefill_32k+decode_32k)")
    g.add_argument("--mesh", action="append", type=_mesh, default=None,
                   help="mesh shape like 16x16 (repeatable)")
    g.add_argument("--logic", type=_csv_list, default=["N7"],
                   help="comma-separated logic nodes (default N7)")
    g.add_argument("--hbm", type=_csv_list, default=["HBM2E"],
                   help="comma-separated HBM generations")
    g.add_argument("--net", type=_csv_list, default=["IB-NDR-X8"],
                   help="comma-separated inter-node networks")
    g.add_argument("--area", type=float, default=None,
                   help="proc chip area budget (mm^2)")
    g.add_argument("--power", type=float, default=None,
                   help="node power budget (W)")
    g.add_argument("--scale", type=_csv_list, default=None,
                   metavar="S1,S2,...",
                   help="budget-scale variants (e.g. 0.8,1.0,1.2) "
                        "multiplying area+power per hardware point")
    g.add_argument("--tilings", type=int, default=8,
                   help="PPE tiling samples per level")


def _add_scenario_flags(p, default_scenario: str = "train") -> None:
    g = p.add_argument_group("scenario")
    g.add_argument("--scenario", default=default_scenario,
                   help="workload scenario: train | serving | serving-long "
                        "| serving-traffic (continuous batching + "
                        "percentile SLO walls)")
    g.add_argument("--slo", type=float, default=None,
                   help="serving TTFT SLO in seconds (tags slo_ok; for "
                        "serving-traffic this is the p99 TTFT wall)")
    g.add_argument("--scenario-param", action="append",
                   type=_scenario_param, default=None,
                   metavar="KEY=V[,V2,...]",
                   help="typed scenario parameter (repeatable); for "
                        "serving-traffic: qps, prompt_mean, prompt_cv, "
                        "output_mean, output_cv, prefill_chunk, "
                        "slo_ttft_p50/p99, slo_tpot_p50/p99.  A comma "
                        "list declares a sweep axis (variants ride in "
                        "the cell id)")
    g.add_argument("--objectives", type=_csv_list, default=None,
                   metavar="OBJ1,OBJ2,...",
                   help="Pareto objectives from the objective registry "
                        "(repro.core.objectives): 'energy', 'cost', "
                        "'goodput' (kind-matched aliases), canonical "
                        "names like energy_j_per_token, or the "
                        "scenario's own record fields.  Replaces the "
                        "scenario's default objective set everywhere — "
                        "frontier folds, --frontier-only streaming "
                        "Pareto, cooptimize refinement")
    g.add_argument("--profile", default=None, metavar="FILE",
                   help="calibration profile JSON (pathfind calibrate); "
                        "every hardware point is evaluated on the "
                        "measurement-anchored MicroArch")


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="repro.pathfind", description=__doc__,
                                formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = p.add_subparsers(dest="cmd", required=True)

    sw = sub.add_parser("sweep", help="batched design-space sweep")
    _add_axis_flags(sw)
    _add_scenario_flags(sw)
    sw.add_argument("--pareto", type=_csv_list, default=None, metavar="OBJS",
                    help="print only the Pareto frontier over these "
                         "objectives (default: the scenario's, e.g. "
                         "time_s,devices)")
    sw.add_argument("--csv", default=None, help="also write CSV here")
    # sharded resumable engine (repro.core.sweeprunner)
    sw.add_argument("--out", default=None,
                    help="stream results + checkpoints into this directory "
                         "(enables --resume)")
    sw.add_argument("--resume", action="store_true",
                    help="continue an interrupted sweep from --out "
                         "(spec loaded from DIR/spec.json; zero "
                         "re-evaluation of finished chunks)")
    sw.add_argument("--chunk-size", type=int, default=32,
                    help="design points per chunk (checkpoint granularity)")
    sw.add_argument("--workers", type=int, default=None,
                    help="parallel chunk workers: on the pipeline/auto "
                         "backend this spawns N `sweep-worker` processes "
                         "over --out DIR (the distributed sweep fabric; "
                         "0 = initialize the directory and wait for an "
                         "external fleet); on thread/process backends it "
                         "is the pool size")
    sw.add_argument("--lease-ttl", type=float, default=None,
                    help="fabric chunk-lease TTL in seconds (default 30; "
                         "workers heartbeat at ttl/3, expired leases are "
                         "reclaimed — set comfortably above one "
                         "superbatch's evaluation time)")
    sw.add_argument("--backend", default="auto",
                    choices=["auto", "pipeline", "serial", "thread",
                             "process", "device"],
                    help="chunk fan-out: auto = the pipelined executor "
                         "(async double-buffered producer/device/writer "
                         "pipeline, device-sharded when >1 JAX device)")
    sw.add_argument("--max-chunks", type=int, default=None,
                    help="stop after N chunks (testing/benchmarks; "
                         "combine with --resume to continue)")
    sw.add_argument("--superbatch", type=int, default=None,
                    help="design points per device dispatch on the "
                         "pipeline backend (default 256; commit "
                         "granularity stays --chunk-size)")
    sw.add_argument("--frontier-only", action="store_true",
                    help="device-resident streaming-Pareto mode: only "
                         "the frontier over the scenario's objectives is "
                         "materialized/printed (DIR/frontier.jsonl with "
                         "--out); per-point rows never reach the host; "
                         "the carried state checkpoints to "
                         "DIR/frontier_state.npz per committed superbatch "
                         "(--resume continues with zero re-evaluation)")
    sw.add_argument("--frontier-cap", type=int, default=None,
                    help="carried device frontier capacity (default 512; "
                         "overflow is reported, never silent)")
    sw.add_argument("--no-compile-cache", action="store_true",
                    help="do not persist XLA executables under "
                         "OUT/xla_cache (enabled by default with --out "
                         "so cold starts and resumes skip recompiles)")
    sw.add_argument("--compile-ahead", type=int, default=None,
                    metavar="N",
                    help="superbatches to pack and AOT-compile ahead of "
                         "the device stage on the pipeline backend "
                         "(default 2; the compile service builds "
                         "executables off the critical path so the "
                         "device stage only dispatches warm functions)")
    sw.add_argument("--no-bucketing", action="store_true",
                    help="disable cross-design bucketed dispatch (compile "
                         "one function per design group instead of one "
                         "per shape bucket; execution-only — results are "
                         "numerically equivalent)")

    wk = sub.add_parser(
        "sweep-worker",
        help="join a fabric sweep directory as a lease-claiming worker")
    wk.add_argument("--dir", required=True,
                    help="fabric sweep directory (initialized by `sweep "
                         "--workers N --out DIR`); mode and spec are read "
                         "from the directory, so a fleet cannot disagree")
    wk.add_argument("--id", default=None,
                    help="worker id (default: unique per process "
                         "incarnation — keep the default unless you know "
                         "why)")
    wk.add_argument("--ttl", type=float, default=None,
                    help="lease TTL seconds (default 30)")
    wk.add_argument("--poll", type=float, default=None,
                    help="idle/coordination poll interval seconds "
                         "(default 0.5)")
    wk.add_argument("--claim-batch", type=int, default=None,
                    help="chunks to lease per claim round (default: one "
                         "superbatch's worth)")
    wk.add_argument("--superbatch", type=int, default=None,
                    help="design points per device dispatch (default 256)")
    wk.add_argument("--compile-ahead", type=int, default=None, metavar="N",
                    help="superbatches to pack and AOT-compile ahead of "
                         "the device stage (default 2)")
    wk.add_argument("--no-bucketing", action="store_true",
                    help="disable cross-design bucketed dispatch")
    wk.add_argument("--eval-delay", type=float, default=0.0,
                    help="artificial per-chunk device latency in seconds "
                         "(fan-out benchmarks / fault tests)")
    wk.add_argument("--max-chunks", type=int, default=None,
                    help="exit after committing N chunks (testing)")

    pl = sub.add_parser("plan", help="runtime sharding plan for one point")
    pl.add_argument("--arch", required=True)
    pl.add_argument("--cell", required=True)
    pl.add_argument("--mesh", type=_mesh, required=True)

    co = sub.add_parser("cooptimize",
                        help="sweep -> refine cross-stack co-optimization")
    co.add_argument("--from", dest="from_dir", required=True, metavar="DIR",
                    help="checkpointed sweep directory (spec.json + "
                         "results.jsonl); seeds are read, never re-scored")
    co.add_argument("--scenario", default=None,
                    help="must match the sweep's scenario if given "
                         "(the spec in DIR is authoritative)")
    co.add_argument("--top-k", type=int, default=4,
                    help="frontier points to refine (default 4)")
    co.add_argument("--candidates", type=int, default=2,
                    help="discrete (mesh, strategy) candidates per seed, "
                         "ranked from the sweep's own records (default 2)")
    co.add_argument("--steps", type=int, default=24,
                    help="refinement GD steps (default 24)")
    co.add_argument("--starts", type=int, default=4,
                    help="multi-start batch size (default 4)")
    co.add_argument("--lr", type=float, default=0.05)
    co.add_argument("--seed", type=int, default=0)
    co.add_argument("--scenario-param", action="append",
                    type=_scenario_param, default=None,
                    metavar="KEY=V[,V2,...]",
                    help="must match the sweep's scenario params if given "
                         "(the spec in DIR is authoritative)")
    co.add_argument("--objectives", type=_csv_list, default=None,
                    metavar="OBJ1,OBJ2,...",
                    help="must match the sweep's objectives if given "
                         "(the spec in DIR is authoritative)")
    co.add_argument("--out", default=None, metavar="FILE",
                    help="refined-records JSONL path "
                         "(default DIR/refined.jsonl)")
    co.add_argument("--csv", default=None, help="also write CSV here")

    ex = sub.add_parser("explore",
                        help="surrogate-driven exploration: spend a "
                             "real-evaluation budget on top-acquisition "
                             "chunks instead of the full cross-product")
    _add_axis_flags(ex)
    _add_scenario_flags(ex)
    ex.add_argument("--out", default=None,
                    help="stream evaluated chunks + checkpoints into this "
                         "directory (a normal partial sweep; enables "
                         "--resume)")
    ex.add_argument("--resume", action="store_true",
                    help="continue from --out (spec loaded from "
                         "DIR/spec.json; committed chunks are never "
                         "re-evaluated and keep training the surrogate)")
    ex.add_argument("--chunk-size", type=int, default=8,
                    help="design points per evaluated chunk (default 8; "
                         "acquisition ranks whole chunks)")
    ex.add_argument("--train-from", default=None, metavar="DIR",
                    help="seed the surrogate with a finished/partial "
                         "sweep directory's records (read via the "
                         "torn-line-tolerant JSONL reader; they count "
                         "toward the training floor, not the budget)")
    ex.add_argument("--eval-budget", type=int, default=None,
                    help="hard ceiling on real-evaluated points "
                         "(default: --eval-frac of the grid)")
    ex.add_argument("--eval-frac", type=float, default=0.25,
                    help="budget as a fraction of the full grid when "
                         "--eval-budget is not given (default 0.25)")
    ex.add_argument("--init-chunks", type=int, default=4,
                    help="evenly-spread seed chunks before the first fit "
                         "(default 4)")
    ex.add_argument("--batch-chunks", type=int, default=4,
                    help="top-acquisition chunks evaluated per round "
                         "(default 4)")
    ex.add_argument("--stagnation", type=int, default=3,
                    help="stop after N rounds with an unchanged frontier "
                         "(default 3)")
    ex.add_argument("--acquisition", default="ucb",
                    choices=["ucb", "epi"],
                    help="chunk-ranking rule: ucb = optimistic dominance "
                         "margin; epi = expected Pareto improvement")
    ex.add_argument("--kappa", type=float, default=1.0,
                    help="UCB exploration weight (default 1.0)")
    ex.add_argument("--ensemble", type=int, default=4,
                    help="surrogate ensemble size (default 4)")
    ex.add_argument("--hidden", type=int, default=32,
                    help="surrogate hidden width (default 32)")
    ex.add_argument("--steps", type=int, default=300,
                    help="surrogate fit steps per round (default 300)")
    ex.add_argument("--lr", type=float, default=0.01)
    ex.add_argument("--seed", type=int, default=0)
    ex.add_argument("--csv", default=None,
                    help="also write the explored frontier CSV here")
    ex.add_argument("--order-dir", default=None, metavar="DIR",
                    help="rank DIR's fabric chunks with the surrogate "
                         "and write DIR/order.json (advisory worker "
                         "claim order) instead of evaluating anything; "
                         "trains on DIR's committed shards plus "
                         "--train-from")

    sz = sub.add_parser("size",
                        help="inverse fleet sizing: minimum device count "
                             "serving --qps under percentile SLO walls")
    sz.add_argument("--from", dest="from_dir", default=None, metavar="DIR",
                    help="checkpointed serving-traffic sweep directory; "
                         "swept points are read, never re-scored.  "
                         "Without --from, the design-space axes below "
                         "run a fresh in-memory sweep first")
    _add_axis_flags(sz)
    _add_scenario_flags(sz, default_scenario="serving-traffic")
    sz.add_argument("--qps", type=float, required=True,
                    help="offered load (requests/s) to serve")
    sz.add_argument("--slo-ttft-p50", type=float, default=None,
                    help="median TTFT wall in seconds")
    sz.add_argument("--slo-ttft-p99", type=float, default=None,
                    help="p99 TTFT wall in seconds")
    sz.add_argument("--slo-tpot-p50", type=float, default=None,
                    help="median TPOT wall in seconds")
    sz.add_argument("--slo-tpot-p99", type=float, default=None,
                    help="p99 TPOT wall in seconds")
    sz.add_argument("--top-k", type=int, default=5,
                    help="feasible designs to report (default 5)")
    sz.add_argument("--rank-by", default="devices",
                    choices=["devices", "cost_per_token",
                             "energy_per_token"],
                    help="fleet-plan ranking: devices (default) or a "
                         "PR8 objective column already in the swept "
                         "records ($/token, J/token) — zero "
                         "re-evaluation")
    sz.add_argument("--out", default=None,
                    help="stream the fresh sweep's results + checkpoints "
                         "into this directory (axes mode only)")
    sz.add_argument("--chunk-size", type=int, default=32,
                    help="design points per chunk (axes mode)")
    sz.add_argument("--backend", default="auto",
                    choices=["auto", "pipeline", "serial", "thread",
                             "process", "device"],
                    help="sweep backend (axes mode)")

    ca = sub.add_parser("calibrate",
                        help="measure this machine and fit a calibration "
                             "profile")
    ca.add_argument("--out", required=True, metavar="DIR",
                    help="measurement + profile output directory")
    ca.add_argument("--suite", default="quick", choices=["quick", "full"],
                    help="microbenchmark suite (quick = GEMM-only)")
    ca.add_argument("--reps", type=int, default=3,
                    help="timing repetitions per point (best-of)")
    ca.add_argument("--resume", action="store_true",
                    help="skip points already in DIR/measurements.jsonl")
    ca.add_argument("--tech", default="cpu_host", choices=["cpu_host",
                                                           "tpu_v5e"],
                    help="techlib entry the profile anchors")
    ca.add_argument("--steps", type=int, default=80,
                    help="fit GD steps (default 80)")
    ca.add_argument("--starts", type=int, default=6,
                    help="fit multi-start batch (default 6)")
    ca.add_argument("--tilings", type=int, default=8,
                    help="PPE tiling samples during fit/validation")
    ca.add_argument("--seed", type=int, default=0)

    va = sub.add_parser("validate",
                        help="validation report + drift vs stored baseline")
    va.add_argument("--out", required=True, metavar="DIR",
                    help="calibration directory (measurements + profile)")
    va.add_argument("--profile", default=None, metavar="FILE",
                    help="profile JSON (default DIR/profile.json)")
    va.add_argument("--baseline", default=None, metavar="FILE",
                    help="stored baseline report (default DIR/report.json)")
    va.add_argument("--remeasure", action="store_true",
                    help="re-run the microbenchmark suite instead of "
                         "reusing DIR/measurements.jsonl")
    va.add_argument("--update-baseline", action="store_true",
                    help="overwrite the baseline with this report")
    va.add_argument("--drift-tol", type=float, default=0.25,
                    help="allowed absolute MRE worsening per group "
                         "(default 0.25 = 25 points)")
    va.add_argument("--tilings", type=int, default=None,
                    help="PPE tiling samples (default: the profile's "
                         "fit-time value, so the drift gate compares "
                         "like with like)")

    so = sub.add_parser("soe", help="strategy x budget co-optimization")
    so.add_argument("--arch", required=True)
    so.add_argument("--cell", required=True)
    so.add_argument("--devices", type=int, default=64)
    so.add_argument("--logic", default="N7")
    so.add_argument("--hbm", default="HBM2E")
    so.add_argument("--net", default="IB-NDR-X8")
    so.add_argument("--steps", type=int, default=20)
    so.add_argument("--starts", type=int, default=4)
    so.add_argument("--tilings", type=int, default=8)
    so.add_argument("--no-search-arch", action="store_true",
                    help="rank strategies only (skip the budget GD)")
    return p


def _cmd_sweep(args) -> int:
    # every flag the chunked engine owns must route there — a runner-only
    # flag silently dropped by the in-memory path is a footgun
    use_runner = bool(args.out or args.resume or args.scenario != "train"
                      or args.scale or args.max_chunks is not None
                      or args.backend != "auto" or args.slo is not None
                      or args.workers is not None or args.chunk_size != 32
                      or args.profile is not None
                      or args.scenario_param or args.objectives
                      or args.frontier_only or args.superbatch is not None
                      or args.frontier_cap is not None
                      or args.lease_ttl is not None
                      or args.compile_ahead is not None
                      or args.no_bucketing
                      or (args.arch and "all" in args.arch))
    if use_runner:
        return _cmd_sweep_runner(args)

    import dataclasses
    from repro.core import pathfinder
    from repro.core.age import Budgets
    from repro.core.roofline import PPEConfig

    if not (args.arch and args.mesh):
        print("error: sweep needs --arch and --mesh (or --resume with "
              "--out)", file=sys.stderr)
        return 2
    cells = args.cell or ["train_4k"]
    budgets = Budgets.default()
    if args.area is not None:
        budgets = dataclasses.replace(budgets, proc_chip_area_mm2=args.area)
    if args.power is not None:
        budgets = dataclasses.replace(budgets, power_w=args.power)
    result = pathfinder.sweep(
        args.arch, cells, args.mesh, logic_nodes=args.logic,
        hbms=args.hbm, nets=args.net, budgets=budgets,
        ppe=PPEConfig(n_tilings=args.tilings))
    points = result.points
    if args.pareto:
        points = result.pareto(objectives=args.pareto)
    lines = [pathfinder.CSV_HEADER] + [p.as_csv_row() for p in points]
    print("\n".join(lines))
    if args.csv:
        with open(args.csv, "w") as fh:
            fh.write("\n".join(lines) + "\n")
        print(f"# wrote {len(points)} points to {args.csv}", file=sys.stderr)
    best = result.best()
    print(f"# best: {best.arch}/{best.cell} mesh="
          f"{'x'.join(map(str, best.mesh))} {best.logic}/{best.hbm}/"
          f"{best.net} {best.strategy.name} -> {best.time_s*1e3:.2f} ms",
          file=sys.stderr)
    return 0


def _validate_dispatch_args(args) -> int:
    """Reject nonsensical dispatch sizing up front (rc 2) instead of
    letting a `--superbatch 0` surface as a reshape traceback mid-sweep."""
    superbatch = getattr(args, "superbatch", None)
    if superbatch is not None and superbatch <= 0:
        print(f"error: --superbatch must be a positive number of design "
              f"points (got {superbatch}); drop the flag for the default "
              f"(256)", file=sys.stderr)
        return 2
    compile_ahead = getattr(args, "compile_ahead", None)
    if compile_ahead is not None and compile_ahead <= 0:
        print(f"error: --compile-ahead must be a positive number of "
              f"superbatches to pre-compile (got {compile_ahead}); drop "
              f"the flag for the default (2), or use --no-bucketing to "
              f"fall back to per-group lazy compilation", file=sys.stderr)
        return 2
    return 0


def _runner_exec_kwargs(args) -> dict:
    """Execution-only knobs shared by sweep and sweep-worker — no effect
    on spec fingerprints, chunk hashes, or resume."""
    return dict(
        compile_ahead=args.compile_ahead,
        bucketing=False if args.no_bucketing else None)


def _cmd_sweep_runner(args) -> int:
    """Sharded / chunked / resumable path (repro.core.sweeprunner)."""
    from repro.core import scenarios, sweeprunner

    rc = _validate_dispatch_args(args)
    if rc:
        return rc
    kwargs = dict(backend=args.backend, workers=args.workers,
                  superbatch=args.superbatch,
                  compile_cache=bool(args.out) and not args.no_compile_cache,
                  **_runner_exec_kwargs(args))
    if args.frontier_only:
        if args.pareto:
            print("error: --frontier-only already reduces to the "
                  "scenario's Pareto objectives on device; drop --pareto",
                  file=sys.stderr)
            return 2
    if args.resume:
        if not args.out:
            print("error: --resume requires --out DIR", file=sys.stderr)
            return 2
        # the spec comes from DIR/spec.json; axis/scenario flags on the
        # command line would be silently contradicted, so refuse them
        ignored = [name for name, val, default in (
            ("--arch", args.arch, None), ("--cell", args.cell, None),
            ("--mesh", args.mesh, None), ("--logic", args.logic, ["N7"]),
            ("--hbm", args.hbm, ["HBM2E"]),
            ("--net", args.net, ["IB-NDR-X8"]),
            ("--scale", args.scale, None), ("--area", args.area, None),
            ("--power", args.power, None), ("--slo", args.slo, None),
            ("--scenario", args.scenario, "train"),
            ("--chunk-size", args.chunk_size, 32),
            ("--tilings", args.tilings, 8),
            ("--profile", args.profile, None),
            ("--scenario-param", args.scenario_param, None),
            ("--objectives", args.objectives, None),
        ) if val != default]
        if ignored:
            print(f"error: --resume loads the sweep spec from "
                  f"{args.out}/spec.json; drop these flags (they would "
                  f"be ignored): {', '.join(ignored)}", file=sys.stderr)
            return 2
        runner = sweeprunner.SweepRunner.from_dir(args.out, **kwargs)
    else:
        if not (args.arch and args.mesh):
            print("error: sweep needs --arch and --mesh (or --resume with "
                  "--out)", file=sys.stderr)
            return 2
        profile_dict = None
        if args.profile is not None:
            from repro.calibrate import profiles as profiles_lib
            profile_dict = profiles_lib.load_profile(args.profile).to_dict()
            print(f"# profile: {args.profile} "
                  f"(tech={profile_dict.get('tech')})", file=sys.stderr)
        spec = sweeprunner.SweepSpec(
            arches=tuple(args.arch),
            mesh_shapes=tuple(tuple(m) for m in args.mesh),
            scenario=args.scenario, cells=tuple(args.cell or ()),
            logic_nodes=tuple(args.logic), hbms=tuple(args.hbm),
            nets=tuple(args.net),
            budget_scales=tuple(float(s) for s in args.scale) if args.scale
            else (1.0,),
            area_mm2=args.area, power_w=args.power, slo_s=args.slo,
            n_tilings=args.tilings, chunk_size=args.chunk_size,
            profile=profile_dict,
            scenario_params=_scenario_params_dict(args.scenario_param)
            or None,
            objectives=tuple(args.objectives) if args.objectives else None)
        runner = sweeprunner.SweepRunner(spec, out_dir=args.out, **kwargs)

    # --workers on the pipeline backend = the distributed sweep fabric:
    # spawn N sweep-worker processes over --out and merge their shards
    if args.workers is not None and runner.backend == "pipeline":
        return _cmd_sweep_fabric(args, runner.spec)
    if args.lease_ttl is not None:
        print("error: --lease-ttl is a fabric knob; combine it with "
              "--workers N on the pipeline/auto backend", file=sys.stderr)
        return 2

    run_kwargs = dict(resume=args.resume, max_chunks=args.max_chunks,
                      frontier_only=args.frontier_only)
    if args.frontier_cap is not None:
        run_kwargs["frontier_capacity"] = args.frontier_cap
    stats = runner.run(**run_kwargs)
    # any variant resolves the same fields/objectives for CSV + frontier
    scn = runner.spec.scenario_spec.variants()[0].resolve()
    records = stats.records or []
    shown = records
    objectives = args.pareto or list(scn.objectives)
    if args.pareto:
        shown = sweeprunner.pareto_records(records, objectives)
    csv_text = sweeprunner.to_csv(shown, scn)
    print(csv_text)
    if args.csv:
        with open(args.csv, "w") as fh:
            fh.write(csv_text + "\n")
        print(f"# wrote {len(shown)} points to {args.csv}", file=sys.stderr)
    mode = " frontier-only" if stats.frontier_only else ""
    print(f"# sweep[{scn.name}]{mode} backend={stats.backend}: "
          f"{stats.n_points_total} points in {stats.n_chunks_total} chunks; "
          f"skipped {stats.n_chunks_skipped} checkpointed, evaluated "
          f"{stats.n_chunks_evaluated} "
          f"({stats.n_points_evaluated} points) in {stats.elapsed_s:.1f}s",
          file=sys.stderr)
    print(f"# cache: prediction {stats.cache_hits} hits / "
          f"{stats.cache_misses} misses; compiled fns "
          f"{stats.compile_misses} built / {stats.compile_hits} reused",
          file=sys.stderr)
    print(f"# compile: {stats.compile_seconds:.1f}s building XLA "
          f"executables, {stats.stall_seconds:.1f}s stalling the eval "
          f"path (compile-ahead hides the rest)", file=sys.stderr)
    if stats.frontier_only:
        print(f"# frontier: {len(records)} non-dominated points over "
              f"{'/'.join(scn.objectives)}", file=sys.stderr)
        if stats.n_frontier_overflowed:
            print(f"# warning: device frontier capacity overflowed "
                  f"({stats.n_frontier_overflowed} candidates dropped); "
                  f"raise --frontier-cap", file=sys.stderr)
    if not stats.complete:
        if stats.frontier_only and stats.out_dir:
            print(f"# incomplete: resume with `python -m repro.pathfind "
                  f"sweep --out {stats.out_dir} --resume --frontier-only`"
                  f" (carried state in frontier_state.npz)",
                  file=sys.stderr)
        elif stats.frontier_only:
            print("# incomplete (no --out directory: the carried frontier "
                  "state was not checkpointed)", file=sys.stderr)
        elif stats.out_dir:
            print(f"# incomplete: resume with `python -m repro.pathfind "
                  f"sweep --out {stats.out_dir} --resume`", file=sys.stderr)
        else:
            print("# incomplete (no --out directory: nothing was "
                  "checkpointed)", file=sys.stderr)
    feasible = [r for r in records
                if r.get("feasible", True)
                and r.get(objectives[0]) is not None
                and float(r[objectives[0]]) > 0.0]
    if feasible:
        best = min(feasible, key=lambda r: float(r[objectives[0]]))
        print(f"# best[{objectives[0]}]: {best['key']} -> "
              f"{float(best[objectives[0]]):.4g}", file=sys.stderr)
    return 0


def _cmd_sweep_fabric(args, spec) -> int:
    """Distributed fabric path of `sweep`: coordinator + N local workers
    (repro.core.sweepfabric)."""
    from repro.core import sweepfabric, sweeprunner

    if not args.out:
        print("error: --workers N on the pipeline backend is the "
              "distributed sweep fabric; it needs --out DIR (the shared "
              "coordination directory)", file=sys.stderr)
        return 2
    if args.max_chunks is not None:
        print("error: --max-chunks is incompatible with the fabric (the "
              "coordinator waits for global completion); use "
              "`sweep-worker --max-chunks` on an individual worker",
              file=sys.stderr)
        return 2
    coord = sweepfabric.FabricCoordinator(
        spec, args.out, workers=args.workers,
        ttl_s=args.lease_ttl or sweepfabric.DEFAULT_TTL_S,
        frontier_only=args.frontier_only,
        frontier_capacity=args.frontier_cap,
        superbatch=args.superbatch,
        compile_ahead=args.compile_ahead,
        bucketing=False if args.no_bucketing else None)
    if args.workers == 0:
        print(f"# fabric: directory initialized; join workers with "
              f"`python -m repro.pathfind sweep-worker --dir {args.out}`",
              file=sys.stderr)
    stats = coord.run()
    scn = spec.scenario_spec.variants()[0].resolve()
    records = stats.records or []
    shown = records
    objectives = args.pareto or list(scn.objectives)
    if args.pareto:
        shown = sweeprunner.pareto_records(records, objectives)
    csv_text = sweeprunner.to_csv(shown, scn)
    print(csv_text)
    if args.csv:
        with open(args.csv, "w") as fh:
            fh.write(csv_text + "\n")
        print(f"# wrote {len(shown)} points to {args.csv}",
              file=sys.stderr)
    mode = " frontier-only" if stats.mode == "frontier" else ""
    print(f"# sweep[{scn.name}]{mode} fabric: {stats.n_points_total} "
          f"points in {stats.n_chunks_total} chunks across "
          f"{stats.n_workers} workers; {stats.n_chunks_committed} "
          f"committed in {stats.elapsed_s:.1f}s", file=sys.stderr)
    if stats.mode == "frontier":
        print(f"# frontier: {len(records)} non-dominated points over "
              f"{'/'.join(scn.objectives)}", file=sys.stderr)
        if stats.n_frontier_overflowed:
            print(f"# warning: a worker's device frontier capacity "
                  f"overflowed ({stats.n_frontier_overflowed} candidates "
                  f"dropped); raise --frontier-cap", file=sys.stderr)
    if not stats.complete:
        print(f"# incomplete: resume with the same command (committed "
              f"chunks in {stats.out_dir} are never re-evaluated)",
              file=sys.stderr)
        return 1
    return 0


def _cmd_sweep_worker(args) -> int:
    """Lease-claiming fabric worker (repro.core.sweepfabric)."""
    from repro.core import sweepfabric

    rc = _validate_dispatch_args(args)
    if rc:
        return rc
    kwargs = {}
    if args.ttl is not None:
        kwargs["ttl_s"] = args.ttl
    if args.poll is not None:
        kwargs["poll_s"] = args.poll
    worker = sweepfabric.FabricWorker(
        args.dir, worker_id=args.id, claim_batch=args.claim_batch,
        superbatch=args.superbatch, eval_delay_s=args.eval_delay,
        max_chunks=args.max_chunks,
        compile_ahead=args.compile_ahead,
        bucketing=False if args.no_bucketing else None, **kwargs)
    stats = worker.run()
    print(f"# worker {stats.worker}: committed "
          f"{stats.n_chunks_committed} chunks ({stats.n_points} points) "
          f"in {stats.elapsed_s:.1f}s"
          + (f"; lost {stats.n_lost_leases} lease batch(es)"
             if stats.n_lost_leases else "")
          + ("; preempted (SIGTERM) — in-flight work committed"
             if stats.preempted else ""),
          file=sys.stderr)
    return 0


def _cmd_cooptimize(args) -> int:
    """Sweep -> refine pipeline (repro.core.cooptimize)."""
    import json
    import os

    from repro.core import cooptimize, scenarios, sweeprunner

    spec, records = sweeprunner.load_sweep(args.from_dir)
    if not records:
        # frontier-only sweep: seed refinement from the materialized
        # frontier (exactly the points worth refining anyway)
        fp = os.path.join(args.from_dir, "frontier.jsonl")
        if os.path.exists(fp):
            with open(fp) as fh:
                records = [json.loads(ln) for ln in fh if ln.strip()]
    if args.scenario is not None and args.scenario != spec.scenario:
        print(f"error: --scenario {args.scenario} contradicts the sweep "
              f"spec in {args.from_dir} (scenario={spec.scenario}); the "
              f"spec is authoritative — drop the flag", file=sys.stderr)
        return 2
    if args.scenario_param:
        want = _scenario_params_dict(args.scenario_param)
        have = dict(spec.scenario_params or {})
        if any(have.get(k) != v for k, v in want.items()):
            print(f"error: --scenario-param contradicts the sweep spec in "
                  f"{args.from_dir} (params={have}); the spec is "
                  f"authoritative — drop the flag", file=sys.stderr)
            return 2
    if args.objectives is not None \
            and tuple(args.objectives) != (spec.objectives or ()):
        print(f"error: --objectives {','.join(args.objectives)} "
              f"contradicts the sweep spec in {args.from_dir} "
              f"(objectives="
              f"{','.join(spec.objectives) if spec.objectives else '<default>'}"
              f"); the spec is authoritative — drop the flag",
              file=sys.stderr)
        return 2
    cfg = cooptimize.RefineConfig(
        top_k=args.top_k, candidates_per_seed=args.candidates,
        steps=args.steps, starts=args.starts, lr=args.lr, seed=args.seed)
    out_path = args.out or os.path.join(args.from_dir, "refined.jsonl")
    stats = cooptimize.refine_sweep((spec, records), cfg=cfg,
                                    out_path=out_path, verbose=False)
    scn = spec.scenario_spec.variants()[0].resolve()
    csv_text = sweeprunner.to_csv(stats.records, scn)
    print(csv_text)
    if args.csv:
        with open(args.csv, "w") as fh:
            fh.write(csv_text + "\n")
        print(f"# wrote {len(stats.records)} refined points to {args.csv}",
              file=sys.stderr)
    print(f"# cooptimize[{stats.scenario}]: {stats.n_records} sweep "
          f"records -> frontier {stats.n_frontier}; refined "
          f"{stats.n_candidates} candidates around {stats.n_seeds} seeds "
          f"({stats.n_objective_evals} objective evals, "
          f"{stats.n_unimproved} unimproved) in {stats.elapsed_s:.1f}s",
          file=sys.stderr)
    print(f"# {stats.n_dominating}/{stats.n_refined} refined points "
          f"dominate >=1 sweep frontier point; refined records -> "
          f"{stats.out_path}", file=sys.stderr)
    if stats.n_refined and not stats.n_dominating:
        print("# warning: no refined point dominates the sweep frontier "
              "(try more --steps/--starts)", file=sys.stderr)
    return 0


def _cmd_size(args) -> int:
    """Inverse fleet-sizing query (repro.core.traffic.size_fleet)."""
    import json
    import os

    from repro.core import sweeprunner, traffic

    if args.from_dir:
        # the swept records are authoritative: refuse contradicting flags
        # exactly as `sweep --resume` does
        ignored = [name for name, val, default in (
            ("--arch", args.arch, None), ("--cell", args.cell, None),
            ("--mesh", args.mesh, None), ("--logic", args.logic, ["N7"]),
            ("--hbm", args.hbm, ["HBM2E"]),
            ("--net", args.net, ["IB-NDR-X8"]),
            ("--scale", args.scale, None), ("--area", args.area, None),
            ("--power", args.power, None), ("--slo", args.slo, None),
            ("--scenario", args.scenario, "serving-traffic"),
            ("--scenario-param", args.scenario_param, None),
            ("--objectives", args.objectives, None),
            ("--tilings", args.tilings, 8),
            ("--profile", args.profile, None),
            ("--out", args.out, None),
        ) if val != default]
        if ignored:
            print(f"error: --from loads the sweep spec from "
                  f"{args.from_dir}/spec.json; drop these flags (they "
                  f"would be ignored): {', '.join(ignored)}",
                  file=sys.stderr)
            return 2
        spec, records = sweeprunner.load_sweep(args.from_dir)
        if not records:
            # frontier-only sweep: size over the materialized frontier
            fp = os.path.join(args.from_dir, "frontier.jsonl")
            if os.path.exists(fp):
                with open(fp) as fh:
                    records = [json.loads(ln) for ln in fh if ln.strip()]
    else:
        if not (args.arch and args.mesh):
            print("error: size needs --arch and --mesh (or --from DIR)",
                  file=sys.stderr)
            return 2
        profile_dict = None
        if args.profile is not None:
            from repro.calibrate import profiles as profiles_lib
            profile_dict = profiles_lib.load_profile(args.profile).to_dict()
        spec = sweeprunner.SweepSpec(
            arches=tuple(args.arch),
            mesh_shapes=tuple(tuple(m) for m in args.mesh),
            scenario=args.scenario, cells=tuple(args.cell or ()),
            logic_nodes=tuple(args.logic), hbms=tuple(args.hbm),
            nets=tuple(args.net),
            budget_scales=tuple(float(s) for s in args.scale) if args.scale
            else (1.0,),
            area_mm2=args.area, power_w=args.power, slo_s=args.slo,
            n_tilings=args.tilings, chunk_size=args.chunk_size,
            profile=profile_dict,
            scenario_params=_scenario_params_dict(args.scenario_param)
            or None,
            objectives=tuple(args.objectives) if args.objectives else None)
        runner = sweeprunner.SweepRunner(spec, out_dir=args.out,
                                         backend=args.backend)
        records = runner.run().records
    if spec.scenario != "serving-traffic":
        print(f"error: fleet sizing needs the serving-traffic scenario "
              f"(the sweep used {spec.scenario!r})", file=sys.stderr)
        return 2
    # model defaults = the spec's single-valued params; swept
    # (multi-valued) params override per record via the cell-id suffix
    base = dict(traffic.PARAM_DEFAULTS)
    base.update({k: v for k, v in spec.scenario_spec.params
                 if not isinstance(v, tuple)})
    if spec.slo_s is not None:
        base["slo_ttft_p99"] = spec.slo_s
    # objective-model params (energy price, MTBF, ...) are not traffic
    # params; split them out before the strict traffic parser
    from repro.core import objectives as objectives_lib
    _, base = objectives_lib.split_objective_params(base)
    tm, pol, spec_slo = traffic.split_params(base)
    slo = {name: float(v) for name in
           ("ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99")
           if (v := getattr(args, "slo_" + name)) is not None}
    if not slo:         # fall back to the walls the sweep itself carried
        slo = {k[len("slo_"):]: float(v) for k, v in spec_slo.items()
               if v is not None}
    if not slo:
        print("error: size needs at least one SLO wall (--slo-ttft-p99 "
              "0.5, --slo-tpot-p50 0.05, ...)", file=sys.stderr)
        return 2
    plan = traffic.size_fleet(records, args.qps, slo=slo, traffic=tm,
                              policy=pol, top_k=args.top_k,
                              rank_by=args.rank_by)
    walls = " ".join(f"{k}<={v:g}s" for k, v in sorted(slo.items()))
    print(f"# size: {plan.n_records} serving-traffic records, "
          f"{plan.n_sized} sizeable under {walls} at {plan.qps:g} qps "
          f"({plan.n_unsizeable} unsizeable; {plan.n_evals} closed-form "
          f"evals, zero sweep re-evaluations)", file=sys.stderr)
    if plan.best is None:
        print("# no swept design meets the SLO walls at any replica "
              "count", file=sys.stderr)
        return 1
    rank_col = traffic.RANK_COLUMNS[args.rank_by]
    header = ("devices,replicas,devices_per_replica,per_replica_qps,"
              "ttft_p99_s,tpot_p50_s,util,key")
    if rank_col is not None:       # default devices output stays identical
        header += f",{rank_col}"
    print(header)
    for c in plan.candidates:
        m = c.metrics
        row = (f"{c.devices},{c.replicas},{c.devices_per_replica},"
               f"{c.per_replica_qps:.4g},{m['ttft_p99_s']:.4g},"
               f"{m['tpot_p50_s']:.4g},{m['util']:.3f},{c.key}")
        if rank_col is not None:
            row += f",{c.rank_value:.6g}" if c.rank_value is not None \
                else ","
        print(row)
    b = plan.best
    print(f"# best: {b.devices} devices = {b.replicas} replicas x "
          f"{b.devices_per_replica} ({b.key}) -> ttft_p99 "
          f"{b.metrics['ttft_p99_s']:.4g}s, tpot_p50 "
          f"{b.metrics['tpot_p50_s']:.4g}s at {b.per_replica_qps:.4g} "
          f"qps/replica", file=sys.stderr)
    return 0


def _cmd_explore(args) -> int:
    """Surrogate + acquisition-driven exploration (repro.core.surrogate)."""
    from repro.core import surrogate, sweeprunner

    cfg = surrogate.ExploreConfig(
        eval_budget=args.eval_budget, eval_frac=args.eval_frac,
        init_chunks=args.init_chunks, batch_chunks=args.batch_chunks,
        stagnation=args.stagnation, acquisition=args.acquisition,
        kappa=args.kappa,
        surrogate=surrogate.SurrogateConfig(
            ensemble=args.ensemble, hidden=args.hidden, steps=args.steps,
            lr=args.lr, seed=args.seed))

    train_records = None
    if args.train_from:
        _, train_records = surrogate.load_training_records(args.train_from)
        if not train_records:
            print(f"error: no committed records in {args.train_from}",
                  file=sys.stderr)
            return 2
        print(f"# surrogate: seeded with {len(train_records)} records "
              f"from {args.train_from}", file=sys.stderr)

    # axis/scenario flags are meaningless when the spec comes from a
    # directory; refuse them instead of silently ignoring them
    spec_from_dir = args.resume or args.order_dir
    if spec_from_dir:
        src = args.order_dir or args.out
        ignored = [name for name, val, default in (
            ("--arch", args.arch, None), ("--cell", args.cell, None),
            ("--mesh", args.mesh, None), ("--logic", args.logic, ["N7"]),
            ("--hbm", args.hbm, ["HBM2E"]),
            ("--net", args.net, ["IB-NDR-X8"]),
            ("--scale", args.scale, None), ("--area", args.area, None),
            ("--power", args.power, None), ("--slo", args.slo, None),
            ("--scenario", args.scenario, "train"),
            ("--chunk-size", args.chunk_size, 8),
            ("--tilings", args.tilings, 8),
            ("--profile", args.profile, None),
            ("--scenario-param", args.scenario_param, None),
            ("--objectives", args.objectives, None),
        ) if val != default]
        if ignored:
            print(f"error: the spec is loaded from {src}/spec.json; drop "
                  f"these flags (they would be ignored): "
                  f"{', '.join(ignored)}", file=sys.stderr)
            return 2

    if args.order_dir:
        # ranking-only mode: no real evaluations, just DIR/order.json
        if args.out or args.resume:
            print("error: --order-dir ranks an existing fabric "
                  "directory; it is incompatible with --out/--resume",
                  file=sys.stderr)
            return 2
        from repro.core import sweepfabric
        _, fabric = sweepfabric.load_dir(args.order_dir)
        if fabric.get("mode") == "frontier":
            committed, _, _ = sweepfabric.merge_frontier(args.order_dir)
        else:
            committed, _ = sweepfabric.merge_results(args.order_dir)
        rows = list(train_records or []) + list(committed)
        if not rows:
            print(f"error: nothing to train on — {args.order_dir} has no "
                  f"committed chunks yet; seed with --train-from DIR",
                  file=sys.stderr)
            return 2
        order = surrogate.order_fabric_dir(args.order_dir, rows, cfg=cfg)
        print(f"# explore: wrote advisory order for {len(order)} chunks "
              f"-> {args.order_dir}/order.json (trained on {len(rows)} "
              f"records); workers claim frontier-adjacent chunks first",
              file=sys.stderr)
        head = ",".join(str(i) for i in order[:8])
        print(f"# explore: first claims: {head}"
              + (",..." if len(order) > 8 else ""), file=sys.stderr)
        return 0

    if args.resume:
        if not args.out:
            print("error: --resume requires --out DIR", file=sys.stderr)
            return 2
        spec, _ = surrogate.load_training_records(args.out)
    else:
        if not (args.arch and args.mesh):
            print("error: explore needs --arch and --mesh (or --resume "
                  "with --out / --order-dir DIR)", file=sys.stderr)
            return 2
        profile_dict = None
        if args.profile is not None:
            from repro.calibrate import profiles as profiles_lib
            profile_dict = profiles_lib.load_profile(args.profile).to_dict()
        spec = sweeprunner.SweepSpec(
            arches=tuple(args.arch),
            mesh_shapes=tuple(tuple(m) for m in args.mesh),
            scenario=args.scenario, cells=tuple(args.cell or ()),
            logic_nodes=tuple(args.logic), hbms=tuple(args.hbm),
            nets=tuple(args.net),
            budget_scales=tuple(float(s) for s in args.scale)
            if args.scale else (1.0,),
            area_mm2=args.area, power_w=args.power, slo_s=args.slo,
            n_tilings=args.tilings, chunk_size=args.chunk_size,
            profile=profile_dict,
            scenario_params=_scenario_params_dict(args.scenario_param)
            or None,
            objectives=tuple(args.objectives) if args.objectives else None)

    stats = surrogate.explore(spec, out_dir=args.out, cfg=cfg,
                              resume=args.resume,
                              train_records=train_records, verbose=True)
    scn = spec.scenario_spec.variants()[0].resolve()
    csv_text = sweeprunner.to_csv(stats.frontier, scn)
    print(csv_text)
    if args.csv:
        with open(args.csv, "w") as fh:
            fh.write(csv_text + "\n")
        print(f"# wrote {len(stats.frontier)} frontier points to "
              f"{args.csv}", file=sys.stderr)
    frac = stats.n_points_evaluated / max(stats.n_points_total, 1)
    print(f"# explore[{scn.name}] acq={cfg.acquisition}: evaluated "
          f"{stats.n_points_evaluated}/{stats.n_points_total} points "
          f"({frac:.0%}) in {stats.n_chunks_evaluated} chunks "
          f"(+{stats.n_chunks_skipped} resumed) over {stats.rounds} "
          f"rounds in {stats.elapsed_s:.1f}s; stop={stats.stop}",
          file=sys.stderr)
    print(f"# frontier: {len(stats.frontier)} non-dominated points over "
          f"{'/'.join(stats.objectives)}", file=sys.stderr)
    if stats.out_dir:
        print(f"# continue with `python -m repro.pathfind explore --out "
              f"{stats.out_dir} --resume`, or exhaust the grid with "
              f"`sweep --out {stats.out_dir} --resume`", file=sys.stderr)
    return 0


def _template_arch(tech: str):
    from repro.core import age
    return age.cpu_host_microarch() if tech == "cpu_host" \
        else age.tpu_v5e_microarch()


def _cmd_calibrate(args) -> int:
    """Measure -> fit -> profile.json + report.json (repro.calibrate)."""
    import os

    from repro.calibrate import fitting, microbench, profiles, report
    from repro.core.roofline import PPEConfig

    spec = microbench.default_spec(args.suite, reps=args.reps)
    runner = microbench.MicrobenchRunner(spec, out_dir=args.out)
    stats = runner.run(resume=args.resume, verbose=True)
    print(f"# measured {stats.n_measured} points "
          f"(skipped {stats.n_skipped} existing) in {stats.elapsed_s:.1f}s",
          file=sys.stderr)
    if not stats.records:
        print("error: no measurements", file=sys.stderr)
        return 2

    template = _template_arch(args.tech)
    ppe = PPEConfig(n_tilings=args.tilings)
    res = fitting.fit(stats.records, template, ppe=ppe,
                      cfg=fitting.FitConfig(steps=args.steps,
                                            starts=args.starts,
                                            seed=args.seed))
    base_rep = report.validation_report(stats.records, template, ppe=ppe)
    cal_rep = report.validation_report(stats.records, template,
                                       params=res.params, ppe=ppe)
    profile = profiles.CalibrationProfile(
        tech=args.tech, params=res.params,
        measure_fingerprint=spec.fingerprint(),
        fit={"mre": res.mre, "mre_uncalibrated": res.mre_identity,
             "loss": res.loss, "loss_uncalibrated": res.loss_identity,
             "selected": res.selected, "n_evals": res.n_evals,
             "n_measurements": len(stats.records),
             "n_tilings": args.tilings},
        validation={"uncalibrated": base_rep["overall"],
                    "calibrated": cal_rep["overall"]})
    ppath = os.path.join(args.out, "profile.json")
    profiles.save_profile(profile, ppath)
    report.save_baseline(cal_rep, os.path.join(args.out, "report.json"))

    print(report.format_report(cal_rep, baseline=base_rep))
    print(f"# fit[{res.selected}]: MRE {res.mre_identity * 100:.1f}% -> "
          f"{res.mre * 100:.1f}% over {res.n_evals} objective evals",
          file=sys.stderr)
    print(f"# profile -> {ppath}; baseline report -> "
          f"{os.path.join(args.out, 'report.json')}", file=sys.stderr)
    if not res.improved:
        print("# warning: calibration did not improve on the "
              "uncalibrated techlib entry", file=sys.stderr)
    return 0


def _cmd_validate(args) -> int:
    """Fresh validation report + drift detection vs the stored baseline."""
    import os

    from repro.calibrate import microbench, profiles, report
    from repro.core.roofline import PPEConfig

    ppath = args.profile or os.path.join(args.out, "profile.json")
    bpath = args.baseline or os.path.join(args.out, "report.json")
    profile = profiles.load_profile(ppath)
    if args.remeasure:
        runner = microbench.MicrobenchRunner.from_dir(args.out)
        spec = runner.spec
        records = microbench.MicrobenchRunner(spec).run().records
    else:
        records = microbench.load_measurements(args.out)
    if not records:
        print(f"error: no measurements in {args.out}", file=sys.stderr)
        return 2
    template = _template_arch(profile.tech)
    # tilings must match the fit-time sampling or every group's MRE
    # shifts and the drift gate fires with nothing actually changed
    tilings = args.tilings if args.tilings is not None \
        else int(profile.fit.get("n_tilings", 8))
    ppe = PPEConfig(n_tilings=tilings)
    cal_rep = report.validation_report(records, template,
                                       params=profile.params, ppe=ppe)
    base_rep = report.validation_report(records, template, ppe=ppe)
    print(report.format_report(cal_rep, baseline=base_rep))
    stored = report.load_baseline(bpath) if os.path.exists(bpath) else None
    if args.update_baseline or stored is None:
        report.save_baseline(cal_rep, bpath)
        print(f"# baseline written -> {bpath}", file=sys.stderr)
        return 0
    drift = report.check_drift(cal_rep, stored, tol=args.drift_tol)
    if drift:
        for msg in drift:
            print(f"# DRIFT: {msg}", file=sys.stderr)
        return 1
    print(f"# no drift vs {bpath} (tol "
          f"{args.drift_tol * 100:.0f} points)", file=sys.stderr)
    return 0


def _cmd_plan(args) -> int:
    from repro.configs.base import SHAPE_CELLS, get_config
    from repro.core import planner

    axes = ("pod", "data", "model")[-len(args.mesh):]
    plan = planner.plan(get_config(args.arch), SHAPE_CELLS[args.cell],
                        args.mesh, axes)
    print(f"strategy       {plan.strategy.name}")
    print(f"predicted_step {plan.predicted_step_s*1e3:.3f} ms")
    for k, v in plan.predicted_breakdown.items():
        print(f"  {k:15s} {v*1e3:.3f} ms")
    for axis, rule in plan.rules:
        print(f"rule {axis:10s} -> {rule}")
    if plan.notes:
        print(f"notes: {plan.notes}")
    return 0


def _cmd_soe(args) -> int:
    from repro.configs.base import SHAPE_CELLS, get_config
    from repro.core import lmgraph, soe, techlib
    from repro.core.roofline import PPEConfig

    tech = techlib.make_tech_config(args.logic, args.hbm, args.net)
    g = lmgraph.build_graph(get_config(args.arch), SHAPE_CELLS[args.cell])
    res = soe.co_optimize(
        tech, g, n_devices=args.devices,
        cfg=soe.SOEConfig(steps=args.steps, starts=args.starts),
        search_arch=not args.no_search_arch,
        ppe=PPEConfig(n_tilings=args.tilings))
    print(f"strategy  {res.strategy.name}")
    print(f"time      {res.time_s*1e3:.3f} ms/iter")
    print(f"queries   {res.n_queries}")
    for comp, frac in res.budgets.area_frac.items():
        print(f"area[{comp:9s}] {float(frac):.3f}")
    return 0


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    try:
        return {"sweep": _cmd_sweep, "sweep-worker": _cmd_sweep_worker,
                "plan": _cmd_plan,
                "soe": _cmd_soe, "calibrate": _cmd_calibrate,
                "validate": _cmd_validate, "size": _cmd_size,
                "explore": _cmd_explore,
                "cooptimize": _cmd_cooptimize}[args.cmd](args)
    except ModuleNotFoundError as e:
        print(f"error: unknown arch (no config module): {e.name}",
              file=sys.stderr)
    except KeyError as e:
        print(f"error: unknown name: {e}", file=sys.stderr)
    except (ValueError, AttributeError, OSError) as e:
        print(f"error: {e}", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
