"""Fault-tolerant checkpointing: async, atomic, keep-N, cross-mesh restore.

Layout (one directory per step):
    <dir>/step_000123.tmp/...      (in-flight write)
    <dir>/step_000123/
        meta.json                  (step, tree structure, shapes, dtypes)
        arr_00000.npy ...          (one file per leaf, LOGICAL/unsharded)
    <dir>/LATEST                   (atomic pointer file)

Atomicity: write to `.tmp`, fsync files, rename dir, then rewrite LATEST —
a crash at any point leaves either the previous or the new checkpoint
valid. Async: saves run on a worker thread over host copies
(jax.device_get) so the train loop doesn't block on I/O.

Elastic restore: arrays are stored logically (fully replicated values), so
a checkpoint written on a (4, 2) mesh restores onto (2, 4), (8, 1) or a
different device count — `restore(..., shardings=...)` re-shards on load
(jax.device_put with the new NamedShardings). This is the checkpoint/
restart + elastic-rescale path required for 1000+-node runs.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def checkpoint_write_s(total_bytes: float, n_devices: float,
                       gbps_per_device: float) -> float:
    """Modeled wall-clock of one checkpoint save.

    Leaves are written in parallel across the fleet (each device owns its
    shard of the logical arrays), so write time is the per-device share
    over the per-device storage bandwidth.  Feeds the goodput objective
    (repro.core.objectives) together with `repro.runtime.fault`'s MTBF
    model.
    """
    return float(total_bytes) / max(float(n_devices), 1.0) \
        / (float(gbps_per_device) * 1e9)


def checkpoint_restore_s(total_bytes: float, n_devices: float,
                         gbps_per_device: float) -> float:
    """Modeled wall-clock of one restore (parallel read, then re-shard)."""
    return float(total_bytes) / max(float(n_devices), 1.0) \
        / (float(gbps_per_device) * 1e9)


def _leaf_paths(tree) -> List[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, block: bool = False) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()                       # one in-flight save at a time
        if self.async_save and not block:
            self._pending = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._pending.start()
        else:
            self._write(step, host_tree)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree: Any) -> None:
        with self._lock:
            final = os.path.join(self.directory, f"step_{step:09d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            leaves, treedef = jax.tree_util.tree_flatten(host_tree)
            meta = {"step": step,
                    "treedef": jax.tree_util.tree_structure(host_tree)
                    .serialize_using_proto().hex(),
                    "paths": _leaf_paths(host_tree),
                    "shapes": [list(l.shape) for l in leaves],
                    "dtypes": [str(l.dtype) for l in leaves]}
            for i, leaf in enumerate(leaves):
                with open(os.path.join(tmp, f"arr_{i:05d}.npy"), "wb") as f:
                    np.save(f, leaf)
                    f.flush()
                    os.fsync(f.fileno())
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)         # atomic publish
            latest_tmp = os.path.join(self.directory, "LATEST.tmp")
            with open(latest_tmp, "w") as f:
                f.write(os.path.basename(final))
                f.flush()
                os.fsync(f.fileno())
            os.rename(latest_tmp, os.path.join(self.directory, "LATEST"))
            self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.directory, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                name = f.read().strip()
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.isdir(os.path.join(self.directory, name)):
                return int(m.group(1))
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, like: Any = None,
                shardings: Any = None) -> Any:
        """Load a checkpoint; optionally re-shard onto a (different) mesh.

        `like` (a pytree) supplies the target structure; `shardings` (same
        structure, NamedSharding leaves) places each logical array — this
        is what makes restore elastic across mesh shapes.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        leaves = [np.load(os.path.join(d, f"arr_{i:05d}.npy"))
                  for i in range(len(meta["paths"]))]
        treedef = jax.tree_util.tree_structure(like) if like is not None \
            else jax.tree_util.tree_structure_from_proto(  # pragma: no cover
                bytes.fromhex(meta["treedef"]))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(jnp.asarray(arr), sh),
                tree, shardings)
        return tree
