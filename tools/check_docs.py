#!/usr/bin/env python3
"""Docs link checker — fails CI on broken intra-repo references.

Scans every tracked markdown file for:

  * inline links/images  [text](target)  — external (http/https/mailto)
    and pure-anchor (#...) targets are skipped; everything else must
    resolve to an existing file or directory relative to the file (or the
    repo root for absolute-style `/path` links);
  * anchors on internal links (file.md#section) — the heading must exist
    in the target file (GitHub-style slugs);
  * inline code spans that look like repo paths (`src/.../file.py`) in the
    docs/ tree — these are the "file pointers" the architecture page
    promises, so they must stay valid.

Usage: python tools/check_docs.py [root]   (exit 1 on any broken link)
"""

from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_PATH_RE = re.compile(
    r"`((?:src|tests|benchmarks|docs|tools|examples)/[A-Za-z0-9_./-]+)`")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
SKIP_DIRS = {".git", "__pycache__", ".github", "node_modules", ".claude",
             ".pytest_cache"}


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for fn in filenames:
            if fn.endswith(".md"):
                yield os.path.join(dirpath, fn)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (close enough for our headings)."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(path: str) -> set:
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    return {slugify(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_file(path: str, root: str) -> list:
    errors = []
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    # strip fenced code blocks: diagrams/snippets aren't links
    prose = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for m in LINK_RE.finditer(prose):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        target, _, anchor = target.partition("#")
        if not target:
            continue
        if target.startswith("/"):
            resolved = os.path.join(root, target.lstrip("/"))
        else:
            resolved = os.path.join(os.path.dirname(path), target)
        resolved = os.path.normpath(resolved)
        if not os.path.exists(resolved):
            errors.append(f"{path}: broken link -> {m.group(1)}")
        elif anchor and resolved.endswith(".md"):
            if slugify(anchor) not in anchors_of(resolved):
                errors.append(f"{path}: missing anchor -> {m.group(1)}")
    # file pointers in docs/ prose must resolve
    if os.sep + "docs" + os.sep in path or path.endswith("README.md"):
        for m in CODE_PATH_RE.finditer(prose):
            p = os.path.normpath(os.path.join(root, m.group(1)))
            if not os.path.exists(p):
                errors.append(f"{path}: dangling file pointer `{m.group(1)}`")
    return errors


def main() -> int:
    root = os.path.abspath(sys.argv[1] if len(sys.argv) > 1
                           else os.path.join(os.path.dirname(__file__),
                                             os.pardir))
    files = sorted(md_files(root))
    errors = []
    for path in files:
        errors.extend(check_file(path, root))
    rel = [os.path.relpath(f, root) for f in files]
    print(f"checked {len(files)} markdown files: {', '.join(rel)}")
    if errors:
        print(f"\n{len(errors)} broken reference(s):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print("all intra-repo links and file pointers resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
