"""Calibration & validation subsystem tests (ISSUE-4 tentpole).

Covers: measurement spec fingerprints + deterministic enumeration, the
resumable microbench runner (zero re-measurement), the differentiable fit
recovering synthetic ground-truth parameters, profile round-trip and
MicroArch application, validation reports + drift detection, profile
embedding in SweepSpec (fingerprint identity + calibrated hardware), and
the slow-lane CLI flow calibrate -> validate -> sweep --profile.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.calibrate import fitting, microbench, profiles, report
from repro.calibrate.microbench import MeasureSpec, MicrobenchRunner
from repro.core import age, sweeprunner
from repro.core.roofline import PPEConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TINY = MeasureSpec(suite="quick", gemm_shapes=((64, 64, 64), (64, 64, 128),
                                               (128, 128, 128)), reps=1)
PPE = PPEConfig(n_tilings=4)


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"),
                    env.get("PYTHONPATH", "")) if p)
    return env


def _synthetic_records(spec, template, true_params, noise=0.0, seed=0):
    """Measurements generated from the model itself (known ground truth)."""
    recs = [{"key": p.key(), "kind": p.kind, **dict(p.params)}
            for p in microbench.enumerate_points(spec)]
    pred = fitting.predict_measurements(recs, template, params=true_params,
                                        ppe=PPE)
    rng = np.random.default_rng(seed)
    for r, t in zip(recs, pred):
        jitter = rng.uniform(1 - noise, 1 + noise) if noise else 1.0
        r["t_s"] = float(t) * jitter
        r["t_mean_s"] = r["t_s"]
        r["flops"] = 2.0 * r["m"] * r["n"] * r["k"]
    return recs


# ----------------------------------------------------------- spec/enumerate
def test_measure_spec_fingerprint_roundtrip():
    assert MeasureSpec.from_dict(TINY.to_dict()) == TINY
    assert MeasureSpec.from_dict(TINY.to_dict()).fingerprint() \
        == TINY.fingerprint()
    other = MeasureSpec(suite="quick", gemm_shapes=((64, 64, 64),), reps=1)
    assert other.fingerprint() != TINY.fingerprint()
    # the shipped suites enumerate deterministically with unique keys
    for suite in ("quick", "full"):
        pts = microbench.enumerate_points(microbench.default_spec(suite))
        assert pts == microbench.enumerate_points(
            microbench.default_spec(suite))
        keys = [p.key() for p in pts]
        assert len(set(keys)) == len(keys)


def test_full_suite_covers_all_kinds():
    kinds = {p.kind for p in microbench.enumerate_points(
        microbench.default_spec("full"))}
    assert kinds == set(microbench.KINDS)


# ----------------------------------------------------------------- runner
def test_runner_resume_zero_remeasurement(tmp_path, monkeypatch):
    calls = []

    def fake_measure(pt, spec):
        calls.append(pt.key())
        return {"key": pt.key(), "kind": pt.kind, **dict(pt.params),
                "reps": spec.reps, "t_s": 1e-3, "t_mean_s": 1e-3,
                "flops": 1.0, "bytes": 1.0}

    monkeypatch.setattr(microbench, "measure_point", fake_measure)
    out = str(tmp_path / "cal")
    stats = MicrobenchRunner(TINY, out_dir=out).run()
    assert stats.n_measured == 3 and len(calls) == 3
    # a fresh run into the same dir must refuse (measurements exist)
    with pytest.raises(FileExistsError):
        MicrobenchRunner(TINY, out_dir=out).run()
    # resume re-measures nothing
    calls.clear()
    stats2 = MicrobenchRunner(TINY, out_dir=out).run(resume=True)
    assert stats2.n_measured == 0 and stats2.n_skipped == 3
    assert calls == []
    # drop one record (simulated partial run) -> only that one re-measured
    mpath = os.path.join(out, "measurements.jsonl")
    lines = open(mpath).read().strip().splitlines()
    with open(mpath, "w") as fh:
        fh.write("\n".join(lines[:-1]) + "\n")
    stats3 = MicrobenchRunner(TINY, out_dir=out).run(resume=True)
    assert stats3.n_measured == 1 and len(calls) == 1
    # a changed spec must refuse the directory
    other = MeasureSpec(suite="quick", gemm_shapes=((32, 32, 32),), reps=1)
    with pytest.raises(ValueError, match="spec changed"):
        MicrobenchRunner(other, out_dir=out).run(resume=True)
    # loader returns every record in spec order
    recs = microbench.load_measurements(out)
    assert [r["key"] for r in recs] \
        == [p.key() for p in microbench.enumerate_points(TINY)]


# ------------------------------------------------------------------- fit
def test_fit_recovers_synthetic_ground_truth():
    template = age.cpu_host_microarch()
    true = fitting.default_params()
    true["compute_eff"] = 0.5
    true["kernel_overhead_s"] = 5e-5
    recs = _synthetic_records(TINY, template, true, noise=0.03)
    res = fitting.fit(recs, template, ppe=PPE,
                      cfg=fitting.FitConfig(steps=40, starts=3))
    assert res.improved
    assert res.mre < 0.15 < res.mre_identity
    assert 0.35 < res.params["compute_eff"] < 0.7
    assert res.n_evals > 0 and res.selected in ("seed", "fit")


def test_fit_identity_never_beaten_by_selection():
    """On measurements generated exactly by the identity parameters the
    selected candidate can't validate worse than identity."""
    template = age.cpu_host_microarch()
    recs = _synthetic_records(TINY, template, fitting.default_params())
    res = fitting.fit(recs, template, ppe=PPE,
                      cfg=fitting.FitConfig(steps=10, starts=2))
    assert res.mre <= res.mre_identity + 1e-12


def test_predictor_rejects_unknown_kind():
    template = age.cpu_host_microarch()
    with pytest.raises(ValueError, match="unknown measurement kind"):
        fitting.build_predictor([{"kind": "nope", "t_s": 1.0}], template)


# ------------------------------------------------------------- decode_step
def test_decode_step_measured_predicted_and_fitted():
    """ISSUE-5 satellite: the KV-cache-read-bound decode step is a
    first-class microbench kind — measured on the real model's
    `decode_step` over a full cache, predicted through the decode-kind
    lmgraph, and part of the default fit groups."""
    assert "decode_step" in microbench.KINDS
    assert "decode_step" in fitting.KINDS_FITTED
    full = microbench.default_spec("full")
    assert "decode_step" in full.model_phases
    spec = MeasureSpec(suite="dec", model_archs=("qwen1.5-0.5b",),
                       model_phases=("decode_step",), reps=1)
    pts = microbench.enumerate_points(spec)
    assert [p.kind for p in pts] == ["decode_step"]
    cell = microbench.model_cell(pts[0])
    assert cell.kind == "decode"
    rec = microbench.measure_point(pts[0], spec)
    assert rec["kind"] == "decode_step" and rec["t_s"] > 0
    assert rec["bytes"] > 0                # KV read volume is the traffic
    template = age.cpu_host_microarch()
    pred = fitting.predict_measurements([rec], template, ppe=PPE)
    assert np.isfinite(pred).all() and (pred > 0).all()
    # the fitter consumes the record (its group appears in the report)
    from repro.calibrate import report
    rep = report.validation_report([rec], template, ppe=PPE)
    assert "decode_step:qwen1.5-0.5b" in rep["groups"]
    assert rep["overall"]["n"] == 1        # fitted kind -> in the overall


# --------------------------------------------------------------- profiles
def test_profile_roundtrip_and_apply(tmp_path):
    template = age.cpu_host_microarch()
    params = fitting.default_params()
    params["compute_eff"] = 2.0
    params["dram_bw_eff"] = 0.5
    params["kernel_overhead_s"] = 1e-4
    prof = profiles.CalibrationProfile(tech="cpu_host", params=params,
                                       fit={"mre": 0.1})
    path = str(tmp_path / "profile.json")
    profiles.save_profile(prof, path)
    back = profiles.load_profile(path)
    assert back == prof
    arch = profiles.apply_profile(template, back)
    assert float(arch.compute_throughput) \
        == pytest.approx(2.0 * float(template.compute_throughput))
    assert float(arch.dram_bw) \
        == pytest.approx(0.5 * float(template.dram_bw))
    # identity profile is a no-op; None passes through
    same = profiles.apply_profile(template, profiles.identity_profile())
    assert float(same.compute_throughput) \
        == pytest.approx(float(template.compute_throughput))
    assert profiles.apply_profile(template, None) is template
    # PPE overhead override
    ppe = profiles.ppe_with_profile(PPE, back)
    assert ppe.kernel_overhead_s == pytest.approx(1e-4)
    assert profiles.ppe_with_profile(PPE, None) is PPE


# ---------------------------------------------------------------- reports
def test_validation_report_and_drift(tmp_path):
    template = age.cpu_host_microarch()
    true = fitting.default_params()
    true["compute_eff"] = 0.5
    recs = _synthetic_records(TINY, template, true)
    base = report.validation_report(recs, template, ppe=PPE)
    cal = report.validation_report(recs, template, params=true, ppe=PPE)
    assert cal["groups"]["gemm"]["mre"] < base["groups"]["gemm"]["mre"]
    assert cal["overall"]["mre"] == pytest.approx(0.0, abs=1e-6)
    cmp = report.compare_reports(base, cal)
    assert cmp["gemm"]["improved"] and cmp["overall"]["improved"]
    text = report.format_report(cal, baseline=base)
    assert "gemm" in text and "OVERALL(fitted)" in text
    # drift: no messages against itself, messages against a worse report
    assert report.check_drift(cal, cal) == []
    msgs = report.check_drift(base, cal, tol=0.05)
    assert msgs and any("gemm" in m for m in msgs)
    # missing group detection
    missing = {"groups": {}, "overall": cal["overall"]}
    assert any("missing" in m for m in report.check_drift(missing, cal))
    # baseline round-trip
    path = str(tmp_path / "report.json")
    report.save_baseline(cal, path)
    assert report.load_baseline(path)["groups"]["gemm"]["n"] == 3


# ----------------------------------------------------- sweep integration
def test_sweepspec_profile_changes_fingerprint_and_hardware():
    base = sweeprunner.SweepSpec(arches=("qwen1.5-0.5b",),
                                 mesh_shapes=((2, 2),), n_tilings=4)
    params = fitting.default_params()
    params["dram_bw_eff"] = 0.25
    params["kernel_overhead_s"] = 7e-5
    prof = profiles.CalibrationProfile(tech="cpu_host", params=params)
    import dataclasses
    calib = dataclasses.replace(base, profile=prof.to_dict())
    # a profile-less spec keys byte-identically to pre-profile specs
    assert "profile" not in base.to_dict()
    assert base.fingerprint() != calib.fingerprint()
    rt = sweeprunner.SweepSpec.from_dict(calib.to_dict())
    assert rt.fingerprint() == calib.fingerprint()
    # hardware resolution applies the profile (distinct cache entries)
    hw_plain = sweeprunner._hardware(base, "N7", "HBM2E", "IB-NDR-X8", 1.0)
    hw_cal = sweeprunner._hardware(calib, "N7", "HBM2E", "IB-NDR-X8", 1.0)
    assert float(hw_cal.dram_bw) \
        == pytest.approx(0.25 * float(hw_plain.dram_bw))
    # and the spec's PPE carries the fitted kernel overhead
    assert sweeprunner.spec_ppe(calib).kernel_overhead_s \
        == pytest.approx(7e-5)
    assert sweeprunner.spec_ppe(base).kernel_overhead_s \
        == PPEConfig().kernel_overhead_s


def test_calibrated_sweep_records_differ():
    spec = sweeprunner.SweepSpec(arches=("qwen1.5-0.5b",),
                                 mesh_shapes=((2, 2),), n_tilings=4)
    params = fitting.default_params()
    params["compute_eff"] = 0.5
    prof = profiles.CalibrationProfile(tech="cpu_host", params=params)
    import dataclasses
    calib = dataclasses.replace(spec, profile=prof.to_dict())
    plain_recs = sweeprunner.SweepRunner(spec, backend="serial").run() \
        .records
    cal_recs = sweeprunner.SweepRunner(calib, backend="serial").run() \
        .records
    assert len(plain_recs) == len(cal_recs) >= 1
    assert cal_recs[0]["time_s"] != pytest.approx(plain_recs[0]["time_s"])


# ------------------------------------------------------------------- CLI
@pytest.mark.slow
def test_cli_calibrate_validate_sweep(tmp_path):
    """The acceptance flow: calibrate -> validate -> sweep --profile."""
    out = str(tmp_path / "calib")
    cal = subprocess.run(
        [sys.executable, "-m", "repro.pathfind", "calibrate",
         "--out", out, "--suite", "quick", "--reps", "1",
         "--steps", "40", "--starts", "3"],
        env=_env(), capture_output=True, text=True, cwd=REPO, timeout=420)
    assert cal.returncode == 0, cal.stderr
    prof = json.load(open(os.path.join(out, "profile.json")))
    # acceptance: strictly lower MRE than the uncalibrated techlib entry
    assert prof["fit"]["mre"] < prof["fit"]["mre_uncalibrated"]
    assert os.path.exists(os.path.join(out, "report.json"))

    # resume measures nothing new
    resumed = subprocess.run(
        [sys.executable, "-m", "repro.pathfind", "calibrate",
         "--out", out, "--suite", "quick", "--reps", "1", "--resume",
         "--steps", "5", "--starts", "2"],
        env=_env(), capture_output=True, text=True, cwd=REPO, timeout=420)
    assert resumed.returncode == 0, resumed.stderr
    assert "measured 0 points" in resumed.stderr

    val = subprocess.run(
        [sys.executable, "-m", "repro.pathfind", "validate", "--out", out],
        env=_env(), capture_output=True, text=True, cwd=REPO, timeout=420)
    assert val.returncode == 0, val.stderr
    assert "no drift" in val.stderr

    sweep_dir = str(tmp_path / "sweep")
    sw = subprocess.run(
        [sys.executable, "-m", "repro.pathfind", "sweep",
         "--arch", "qwen1.5-0.5b", "--mesh", "2x2", "--tilings", "4",
         "--backend", "serial", "--out", sweep_dir,
         "--profile", os.path.join(out, "profile.json")],
        env=_env(), capture_output=True, text=True, cwd=REPO, timeout=420)
    assert sw.returncode == 0, sw.stderr
    head = json.load(open(os.path.join(sweep_dir, "spec.json")))
    assert head["spec"]["profile"]["params"]
    rows = [json.loads(ln) for ln in
            open(os.path.join(sweep_dir, "results.jsonl"))]
    assert rows and all(r.get("time_s") for r in rows)
    # --resume refuses a contradicting --profile (spec is authoritative)
    refused = subprocess.run(
        [sys.executable, "-m", "repro.pathfind", "sweep",
         "--out", sweep_dir, "--resume",
         "--profile", os.path.join(out, "profile.json")],
        env=_env(), capture_output=True, text=True, cwd=REPO, timeout=420)
    assert refused.returncode == 2
    assert "--profile" in refused.stderr
