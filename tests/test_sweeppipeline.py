"""Pipelined sweep executor tests (ISSUE-5 tentpole).

Covers: pipeline-vs-serial record parity (train and serving, including
after an interrupted sweep resumes across backends), the device-resident
streaming frontier (fused Pareto reduction == full materialization, tie
and overflow semantics of `frontier_merge`), resume-identity stability
(PR4-era fingerprints and checkpoints), call-time prediction-cache
resolution, and the cache/compile hit-miss accounting on `RunStats`.
"""

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import pathfinder, scenarios, sweeprunner
from repro.core.sweeprunner import SweepRunner, SweepSpec

SPEC = SweepSpec(arches=("qwen1.5-0.5b",), mesh_shapes=((2, 2), (4, 4)),
                 scenario="train", logic_nodes=("N7", "N5"),
                 budget_scales=(0.9, 1.0, 1.1), n_tilings=4, chunk_size=4)

# meshes chosen so the grid spans infeasible (KV cache does not fit on
# 2x2) AND feasible points — the parity/frontier tests must exercise the
# non-finite masking path
SERVING_SPEC = SweepSpec(arches=("qwen1.5-0.5b",),
                         mesh_shapes=((2, 2), (4, 4)), scenario="serving",
                         logic_nodes=("N7",), budget_scales=(0.8, 1.0),
                         n_tilings=4, chunk_size=3)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _by_key(records):
    return {r["key"]: r for r in records}


def _assert_records_match(got, want):
    got, want = _by_key(got), _by_key(want)
    assert got.keys() == want.keys()
    for k, w in want.items():
        g = got[k]
        assert g.keys() == w.keys(), k
        for f, wv in w.items():
            gv = g[f]
            if isinstance(wv, float) and np.isfinite(wv):
                np.testing.assert_allclose(gv, wv, rtol=1e-5,
                                           err_msg=f"{k}:{f}")
            else:
                assert gv == wv, (k, f, gv, wv)


# ------------------------------------------------------------ record parity
def test_pipeline_matches_serial_train(tmp_path):
    serial = SweepRunner(SPEC, out_dir=str(tmp_path / "s"),
                         backend="serial", cache=None).run()
    pipe = SweepRunner(SPEC, out_dir=str(tmp_path / "p"),
                       backend="pipeline", cache=None).run()
    assert pipe.complete and pipe.n_points_evaluated == \
        serial.n_points_evaluated
    _assert_records_match(pipe.records, serial.records)


def test_pipeline_matches_serial_serving(tmp_path):
    serial = SweepRunner(SERVING_SPEC, out_dir=str(tmp_path / "s"),
                         backend="serial", cache=None).run()
    pipe = SweepRunner(SERVING_SPEC, out_dir=str(tmp_path / "p"),
                       backend="pipeline", cache=None).run()
    _assert_records_match(pipe.records, serial.records)
    # the reference grid must exercise both feasible and infeasible points
    feas = {r["feasible"] for r in serial.records}
    assert feas == {True, False}, feas


def test_pipeline_resumes_serial_checkpoints_with_zero_reeval(tmp_path):
    """A PR4-era checkpoint dir (written by the synchronous serial
    backend) resumes under the pipeline executor: zero re-evaluation,
    identical point set, unchanged fingerprint for profile-less specs."""
    first = SweepRunner(SPEC, out_dir=str(tmp_path),
                        backend="serial").run(max_chunks=2)
    assert first.n_chunks_evaluated == 2 and not first.complete
    second = SweepRunner(SPEC, out_dir=str(tmp_path),
                         backend="pipeline").run(resume=True)
    assert second.n_chunks_skipped == 2
    assert second.complete
    keys = sorted(r["key"] for r in second.records)
    assert keys == sorted(lb.key()
                          for lb in sweeprunner.enumerate_labels(SPEC))


def test_fingerprint_pinned_for_profile_less_specs():
    """Resume identity: the PR4-era fingerprint of a profile-less spec
    must never change (old checkpoint dirs must keep resuming)."""
    spec = SweepSpec(arches=("qwen1.5-0.5b",),
                     mesh_shapes=((2, 2), (4, 4)), scenario="train",
                     logic_nodes=("N7", "N5"), n_tilings=4, chunk_size=1)
    assert spec.fingerprint() == "fadd310e03f4106b"


def test_pick_backend_auto_is_pipeline():
    assert sweeprunner.pick_backend("auto") == "pipeline"
    assert sweeprunner.pick_backend("serial") == "serial"


# --------------------------------------------------------- frontier mode
def test_frontier_only_matches_full_materialization(tmp_path):
    for spec in (SPEC, SERVING_SPEC):
        scn = scenarios.get_scenario(spec.scenario)
        full = SweepRunner(spec, backend="pipeline", cache=None).run()
        want = sweeprunner.pareto_records(full.records, scn.objectives)
        assert want, "reference frontier must be non-empty"
        front = SweepRunner(spec, backend="pipeline", cache=None,
                            out_dir=str(tmp_path / spec.scenario)).run(
            frontier_only=True)
        assert front.frontier_only
        assert front.n_frontier_overflowed == 0
        assert front.n_points_evaluated == full.n_points_evaluated
        _assert_records_match(front.records, want)
        # frontier.jsonl holds exactly the frontier
        path = tmp_path / spec.scenario / "frontier.jsonl"
        rows = [json.loads(ln) for ln in
                path.read_text().strip().splitlines()]
        assert sorted(r["key"] for r in rows) == \
            sorted(r["key"] for r in want)


def test_frontier_only_resumes_carried_state(tmp_path):
    """ISSUE-6 satellite: an interrupted frontier-only sweep resumes from
    DIR/frontier_state.npz with zero re-evaluation and reaches the same
    frontier as an uninterrupted run."""
    d = str(tmp_path / "front")
    part = SweepRunner(SPEC, out_dir=d, backend="pipeline",
                       cache=None).run(frontier_only=True, max_chunks=2)
    assert not part.complete
    assert os.path.exists(os.path.join(d, "frontier_state.npz"))
    done = SweepRunner(SPEC, out_dir=d, backend="pipeline",
                       cache=None).run(frontier_only=True, resume=True)
    assert done.complete
    assert done.n_chunks_skipped == 2
    assert done.n_points_evaluated == part.n_points_total - \
        part.n_points_evaluated
    fresh = SweepRunner(SPEC, out_dir=str(tmp_path / "fresh"),
                        backend="pipeline", cache=None).run(
        frontier_only=True)
    _assert_records_match(done.records, fresh.records)
    # a fully-resumed frontier re-evaluates nothing at all
    again = SweepRunner(SPEC, out_dir=d, backend="pipeline",
                        cache=None).run(frontier_only=True, resume=True)
    assert again.n_points_evaluated == 0
    _assert_records_match(again.records, fresh.records)


def test_frontier_resume_guards(tmp_path):
    d = str(tmp_path / "front")
    SweepRunner(SPEC, out_dir=d, backend="pipeline",
                cache=None).run(frontier_only=True, max_chunks=1)
    # a second non-resume run must not silently merge into stale state
    with pytest.raises(FileExistsError, match="frontier-state"):
        SweepRunner(SPEC, out_dir=d, backend="pipeline",
                    cache=None).run(frontier_only=True)
    # capacity changes the carried-state shape: refuse, don't corrupt
    with pytest.raises(ValueError, match="capacity"):
        SweepRunner(SPEC, out_dir=d, backend="pipeline", cache=None).run(
            frontier_only=True, resume=True, frontier_capacity=16)
    # a different spec cannot adopt the state
    other = dataclasses.replace(SPEC, budget_scales=(1.0,))
    with pytest.raises(ValueError, match="spec changed"):
        SweepRunner(other, out_dir=d, backend="pipeline", cache=None).run(
            frontier_only=True, resume=True)


def test_frontier_merge_dominance_ties_and_overflow():
    state = pathfinder.frontier_init(4, 2, 1)
    vals = jnp.asarray([[1.0, 5.0], [1.0, 5.0],    # exact tie pair
                        [5.0, 1.0], [4.0, 4.0],    # (4,4) dominated later
                        [3.0, 3.0], [np.inf, 0.0]])
    payload = jnp.arange(6, dtype=jnp.float32)[:, None]
    idx = jnp.asarray([0, 1, 2, 3, 4, -1], dtype=jnp.int32)
    state = pathfinder.frontier_merge(state, vals, payload, idx)
    out_vals, out_pay, out_idx, over = pathfinder.frontier_unpack(state)
    # ties both kept; dominated (4,4) dropped; non-finite/padding excluded
    assert sorted(out_idx.tolist()) == [0, 1, 2, 4]
    assert over == 0
    # a later batch can evict carried points it dominates
    state = pathfinder.frontier_merge(
        state, jnp.asarray([[0.5, 0.5]]),
        jnp.asarray([[9.0]]), jnp.asarray([7], dtype=jnp.int32))
    _, _, out_idx, over = pathfinder.frontier_unpack(state)
    assert out_idx.tolist() == [7]
    assert over == 0


def test_frontier_merge_overflow_counted():
    state = pathfinder.frontier_init(2, 2, 1)
    # 4 mutually non-dominated points into capacity 2
    vals = jnp.asarray([[1.0, 4.0], [2.0, 3.0], [3.0, 2.0], [4.0, 1.0]])
    payload = jnp.zeros((4, 1), dtype=jnp.float32)
    idx = jnp.asarray([0, 1, 2, 3], dtype=jnp.int32)
    state = pathfinder.frontier_merge(state, vals, payload, idx)
    out_vals, _, out_idx, over = pathfinder.frontier_unpack(state)
    assert over == 2
    assert out_idx.tolist() == [0, 1]          # lowest first objective


# ------------------------------------------------- cache + compile stats
def test_eval_labels_resolves_cache_at_call_time():
    """Regression (ISSUE-5 satellite): replacing the process-wide
    prediction cache must take effect for default-arg callers — the old
    `cache=pathfinder.prediction_cache()` default froze the singleton at
    import time."""
    old = pathfinder.prediction_cache()
    fresh = pathfinder.PredictionCache()
    pathfinder.set_prediction_cache(fresh)
    try:
        labels = sweeprunner.enumerate_labels(SPEC)[:2]
        with pytest.warns(DeprecationWarning, match="eval_labels"):
            sweeprunner.eval_labels(SPEC, labels)
        stats = fresh.stats
        assert stats["hits"] + stats["misses"] > 0, (
            "replacement cache saw no traffic: eval_labels is still "
            "bound to the import-time singleton")
    finally:
        pathfinder.set_prediction_cache(old)


def test_runstats_reports_cache_and_compile_counters(tmp_path):
    pathfinder.clear_prediction_cache()
    spec = dataclasses.replace(SPEC, budget_scales=(1.0,))
    n = len(sweeprunner.enumerate_labels(spec))
    first = SweepRunner(spec, out_dir=str(tmp_path / "a"),
                        backend="pipeline").run()
    assert first.cache_misses >= n
    # identical spec, fresh dir, same process: every point is a hit
    second = SweepRunner(spec, out_dir=str(tmp_path / "b"),
                         backend="pipeline").run()
    assert second.cache_hits >= n
    assert second.cache_misses == 0
    _assert_records_match(second.records, first.records)
    # a fresh (empty) cache re-evaluates but REUSES the compiled fns
    third = SweepRunner(spec, out_dir=str(tmp_path / "c"),
                        backend="pipeline",
                        cache=pathfinder.PredictionCache()).run()
    assert third.cache_misses >= n
    assert third.compile_hits > 0 and third.compile_misses == 0
    # resumed completed sweep: 100% chunk-skip, nothing evaluated
    resumed = SweepRunner(spec, out_dir=str(tmp_path / "a"),
                          backend="pipeline").run(resume=True)
    assert resumed.n_chunks_skipped == resumed.n_chunks_total
    assert resumed.n_chunks_evaluated == 0
    assert resumed.n_points_evaluated == 0


def test_cli_frontier_only_and_cache_summary(tmp_path, capsys):
    import jax

    from repro import pathfind
    prev_cc = jax.config.jax_compilation_cache_dir
    try:
        _cli_frontier_and_summary(tmp_path, capsys, pathfind)
    finally:
        # the CLI enables the persistent compile cache under tmp_path;
        # leaving the global config pointed at a deleted dir would make
        # every later compile in this process log write failures
        jax.config.update("jax_compilation_cache_dir", prev_cc)


def _cli_frontier_and_summary(tmp_path, capsys, pathfind):
    out = str(tmp_path / "sweep")
    rc = pathfind.main(["sweep", "--arch", "qwen1.5-0.5b",
                        "--mesh", "2x2", "--mesh", "4x4",
                        "--tilings", "4", "--chunk-size", "4",
                        "--backend", "pipeline", "--out", out])
    assert rc == 0
    err = capsys.readouterr().err
    assert "cache: prediction" in err and "compiled fns" in err
    # resumed completed sweep reports 100% chunk-skip and, rerun into a
    # fresh dir, >0 prediction-cache hits on the summary line
    rc = pathfind.main(["sweep", "--out", out, "--resume",
                        "--backend", "pipeline"])
    assert rc == 0
    err = capsys.readouterr().err
    assert "evaluated 0 (0 points)" in err
    rc = pathfind.main(["sweep", "--arch", "qwen1.5-0.5b",
                        "--mesh", "2x2", "--mesh", "4x4",
                        "--tilings", "4", "--chunk-size", "4",
                        "--backend", "pipeline",
                        "--out", str(tmp_path / "sweep2")])
    assert rc == 0
    err = capsys.readouterr().err
    hits = int(err.split("cache: prediction ")[1].split(" hits")[0])
    assert hits > 0
    # frontier-only CLI: a full-sweep dir is not a frontier checkpoint —
    # resuming it under --frontier-only must refuse, not re-merge
    rc = pathfind.main(["sweep", "--out", out, "--resume",
                        "--frontier-only"])
    assert rc == 2
    rc = pathfind.main(["sweep", "--arch", "qwen1.5-0.5b",
                        "--mesh", "2x2", "--mesh", "4x4",
                        "--tilings", "4", "--chunk-size", "4",
                        "--frontier-only",
                        "--out", str(tmp_path / "front")])
    assert rc == 0
    cap = capsys.readouterr()
    assert "frontier-only" in cap.err
    assert os.path.exists(os.path.join(str(tmp_path / "front"),
                                       "frontier.jsonl"))


def test_compilation_cache_helper(tmp_path):
    import jax
    prev = jax.config.jax_compilation_cache_dir
    try:
        jax.config.update("jax_compilation_cache_dir", None)
        assert sweeprunner.enable_compilation_cache(str(tmp_path / "x"))
        assert jax.config.jax_compilation_cache_dir == str(tmp_path / "x")
        # sticky: a second sweep's dir must not steal the configured one
        assert not sweeprunner.enable_compilation_cache(
            str(tmp_path / "y"))
        assert jax.config.jax_compilation_cache_dir == str(tmp_path / "x")
    finally:
        jax.config.update("jax_compilation_cache_dir", prev)


# ------------------------------------------------------------------- CLI
@pytest.mark.slow
def test_cli_sigkill_pipeline_then_resume_matches_serial(tmp_path):
    """Pipeline parity through a hard kill: SIGKILL a pipeline-backend
    sweep mid-flight, resume it, and compare records against a clean
    serial run of the same spec."""
    import signal
    import time

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"),
                    env.get("PYTHONPATH", "")) if p)
    out = str(tmp_path / "sweep")
    cmd = [sys.executable, "-m", "repro.pathfind", "sweep",
           "--arch", "qwen1.5-0.5b", "--mesh", "2x2", "--mesh", "2x4",
           "--mesh", "4x4", "--mesh", "2x8", "--mesh", "8x8",
           "--mesh", "4x8",
           "--tilings", "4", "--chunk-size", "1", "--superbatch", "1",
           "--backend", "pipeline", "--out", out]
    proc = subprocess.Popen(cmd, env=env, cwd=REPO,
                            stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL)
    ckpt = os.path.join(out, "checkpoint.jsonl")
    deadline = time.time() + 300
    try:
        while time.time() < deadline:
            if os.path.exists(ckpt) and \
                    len(open(ckpt).read().strip().splitlines()) >= 1:
                break
            if proc.poll() is not None:
                break
            time.sleep(0.2)
        if proc.poll() is None:
            proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    done_before = 0
    for line in open(ckpt).read().strip().splitlines():
        try:
            json.loads(line)
            done_before += 1
        except json.JSONDecodeError:
            pass
    assert done_before >= 1, "sweep produced no checkpoint before kill"
    resumed = subprocess.run(
        [sys.executable, "-m", "repro.pathfind", "sweep",
         "--out", out, "--resume", "--backend", "pipeline"],
        env=env, capture_output=True, text=True, cwd=REPO, timeout=420)
    assert resumed.returncode == 0, resumed.stderr
    assert f"skipped {done_before} checkpointed" in resumed.stderr
    spec = SweepSpec(
        arches=("qwen1.5-0.5b",),
        mesh_shapes=((2, 2), (2, 4), (4, 4), (2, 8), (8, 8), (4, 8)),
        n_tilings=4, chunk_size=1)
    serial = SweepRunner(spec, backend="serial", cache=None).run()
    rows = [json.loads(ln) for ln in open(os.path.join(out,
                                                       "results.jsonl"))]
    got = {r["key"]: r for r in rows}
    want = _by_key(serial.records)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k]["time_s"], want[k]["time_s"],
                                   rtol=1e-5)
