"""Distributed sweep fabric tests (ISSUE-7 tentpole).

Covers: the lease protocol (exclusive claim, expiry + reclaim-by-rename,
heartbeat renewal, torn lease files), directory init guards, the
deterministic first-wins shard merge, in-process worker parity against the
serial backend (full and frontier mode), and the fault-injection
kill-matrix: real `pathfind sweep-worker` processes SIGKILL'd mid-chunk /
mid-commit / mid-renewal, a deliberately stalled worker whose expired
leases are reclaimed, and SIGTERM preemption that commits in-flight work
and exits clean.  The fleet-wide invariant throughout: a committed chunk
is NEVER re-evaluated, and the merged output is duplicate-free and
matches the serial backend.
"""

import glob
import json
import os
import signal
import time

import pytest

import fabrichelpers as fh
from repro.core import sweepexec, sweepfabric, sweeprunner
from repro.core.sweepfabric import (FabricCoordinator, FabricWorker,
                                    LeaseManager)
from repro.core.sweeprunner import SweepRunner, SweepSpec

SPEC = SweepSpec(arches=("qwen1.5-0.5b",), mesh_shapes=((2, 2), (4, 4)),
                 scenario="train", logic_nodes=("N7", "N5"),
                 n_tilings=4, chunk_size=1)            # 4 points, 4 chunks

# spans capacity-infeasible AND SLO-wall-failing points (percentile walls
# from the traffic scenario) — the fabric must agree with the serial
# backend on every regime, not just the happy path
TRAFFIC_SPEC = SweepSpec(
    arches=("qwen1.5-0.5b",), mesh_shapes=((2, 2), (4, 4)),
    scenario="serving-traffic", logic_nodes=("N7",),
    budget_scales=(0.9, 1.1), n_tilings=4, chunk_size=4,
    scenario_params={"qps": 0.1, "prefill_chunk": [1024.0, 8192.0],
                     "slo_ttft_p99": [5.0, 50.0]})     # 16 points, 4 chunks

CHUNKS = sweeprunner.make_chunks(sweeprunner.enumerate_labels(SPEC),
                                 SPEC.chunk_size)
FP = SPEC.fingerprint()


@pytest.fixture(scope="module")
def serial_records():
    return SweepRunner(SPEC, backend="serial", cache=None).run().records


# ------------------------------------------------------------ lease protocol
def test_lease_claim_is_exclusive(tmp_path):
    a = LeaseManager(str(tmp_path), "a")
    b = LeaseManager(str(tmp_path), "b")
    assert a.claim(0)
    assert not b.claim(0)                  # O_EXCL: exactly one winner
    assert a.owns(0) and not b.owns(0)
    assert a.holder(0) == "a"
    assert b.claim(1)                      # other chunks unaffected


def test_lease_steal_requires_expiry(tmp_path):
    a = LeaseManager(str(tmp_path), "a", ttl_s=0.3)
    b = LeaseManager(str(tmp_path), "b", ttl_s=0.3)
    assert a.claim(0)
    assert not b.steal_expired(0)          # still live
    time.sleep(0.4)
    assert b.steal_expired(0)              # expired: rename-steal wins
    assert b.owns(0) and not a.owns(0)
    assert a.renew([0]) == [0]             # old holder learns it lost


def test_lease_renew_pushes_expiry(tmp_path):
    a = LeaseManager(str(tmp_path), "a", ttl_s=0.6)
    b = LeaseManager(str(tmp_path), "b", ttl_s=0.6)
    assert a.claim(0)
    time.sleep(0.4)
    assert a.renew([0]) == []              # heartbeat
    time.sleep(0.3)                        # past the ORIGINAL expiry
    assert not b.steal_expired(0)          # renewal kept it alive
    time.sleep(0.4)                        # past the renewed expiry
    assert b.steal_expired(0)


def test_lease_torn_file_falls_back_to_mtime(tmp_path):
    a = LeaseManager(str(tmp_path), "a", ttl_s=5.0)
    path = os.path.join(str(tmp_path), "leases", "chunk_0.json")
    with open(path, "w") as fhdl:
        fhdl.write('{"worker": "dead", "exp')      # torn mid-write
    assert not a.steal_expired(0)          # fresh mtime: not stealable yet
    os.utime(path, (time.time() - 60, time.time() - 60))
    assert a.steal_expired(0)              # old + unreadable = expired
    assert a.owns(0)


def test_lease_release_only_own(tmp_path):
    a = LeaseManager(str(tmp_path), "a")
    b = LeaseManager(str(tmp_path), "b")
    assert a.claim(3)
    b.release(3)                           # not b's to drop
    assert a.owns(3)
    a.release(3)
    assert a.holder(3) is None
    assert b.claim(3)                      # released chunk claimable again


# ------------------------------------------------------------ dir init
def test_init_dir_guards_mode_and_spec(tmp_path):
    out = str(tmp_path / "fab")
    head = sweepfabric.init_dir(SPEC, out)
    assert head["mode"] == "full"
    sweepfabric.init_dir(SPEC, out)        # re-join: idempotent
    with pytest.raises(ValueError, match="mode"):
        sweepfabric.init_dir(SPEC, out, frontier_only=True)
    import dataclasses
    other = dataclasses.replace(SPEC, logic_nodes=("N7",))
    with pytest.raises(ValueError, match="spec changed"):
        sweepfabric.init_dir(other, out)
    spec2, fabric = sweepfabric.load_dir(out)
    assert spec2.fingerprint() == FP and fabric["mode"] == "full"


# ------------------------------------------------------------ shard merge
def test_merge_results_first_wins_on_double_commit(tmp_path):
    """Even if an expired-lease race ever let two workers commit the same
    chunk, exactly one copy survives the merge, deterministically."""
    out = str(tmp_path / "fab")
    sweepfabric.init_dir(SPEC, out)
    for wid, committed in (("a", (0, 1)), ("b", (0, 2))):
        sp = sweepfabric.shard_paths(out, wid)
        j = sweepexec.ChunkJournal(sp["results"], sp["checkpoint"]).open()
        for i in committed:
            j.commit(i, CHUNKS[i].hash(FP),
                     [{"key": f"pt{i}", "src": wid}])
        j.close()
    records, done = sweepfabric.merge_results(out)
    assert sorted(done) == [0, 1, 2]
    by_key = {r["key"]: r for r in records}
    assert by_key["pt0"]["src"] == "a"     # sorted shard order: a wins
    assert by_key["pt1"]["src"] == "a" and by_key["pt2"]["src"] == "b"
    assert all("chunk" not in r for r in records)
    with open(os.path.join(out, "checkpoint.jsonl")) as fhdl:
        lines = [json.loads(ln) for ln in fhdl if ln.strip()]
    assert [ln["chunk"] for ln in lines] == [0, 1, 2]
    assert all(ln["hash"] == CHUNKS[ln["chunk"]].hash(FP) for ln in lines)


def test_worker_cmd_carries_fabric_knobs(tmp_path):
    coord = FabricCoordinator(SPEC, str(tmp_path), workers=0,
                              superbatch=8, claim_batch=2,
                              eval_delay_s=0.01)
    cmd = coord.worker_cmd()
    assert "sweep-worker" in cmd
    for flag, val in (("--dir", str(tmp_path)), ("--superbatch", "8"),
                      ("--claim-batch", "2"), ("--eval-delay", "0.01")):
        assert cmd[cmd.index(flag) + 1] == val


# ------------------------------------------------------------ in-process
def test_worker_full_mode_matches_serial(tmp_path, serial_records):
    out = str(tmp_path / "fab")
    sweepfabric.init_dir(SPEC, out)
    stats = FabricWorker(out, ttl_s=60.0, claim_batch=2,
                         compile_cache=False).run()
    assert stats.n_chunks_committed == len(CHUNKS)
    assert stats.n_points == len(serial_records)
    assert not stats.preempted and stats.n_lost_leases == 0
    records, done = sweepfabric.merge_results(out)
    assert len(done) == len(CHUNKS)
    fh.assert_no_duplicate_point_keys(records)
    fh.assert_records_match(records, serial_records)
    # merged layout is the standard single-host one
    assert [r["key"] for r in fh.merged_record_lines(out)] == \
        [r["key"] for r in records]


def test_two_sequential_workers_split_the_sweep(tmp_path, serial_records):
    """Worker A commits half and leaves; worker B (fresh incarnation,
    fresh shard) finishes the rest off A's committed state."""
    out = str(tmp_path / "fab")
    sweepfabric.init_dir(SPEC, out)
    a = FabricWorker(out, worker_id="wa", ttl_s=60.0, claim_batch=1,
                     max_chunks=2, compile_cache=False).run()
    assert a.n_chunks_committed == 2
    b = FabricWorker(out, worker_id="wb", ttl_s=60.0, claim_batch=2,
                     compile_cache=False).run()
    assert b.n_chunks_committed == len(CHUNKS) - 2
    records, done = sweepfabric.merge_results(out)
    assert len(done) == len(CHUNKS)
    fh.assert_records_match(records, serial_records)
    fh.assert_no_committed_chunk_reevaluated(out)
    ckpts = glob.glob(os.path.join(out, "shards", "checkpoint.*.jsonl"))
    assert len(ckpts) == 2                 # one shard per incarnation


def test_worker_frontier_mode_matches_single_host(tmp_path):
    out = str(tmp_path / "fab")
    sweepfabric.init_dir(SPEC, out, frontier_only=True)
    a = FabricWorker(out, worker_id="wa", ttl_s=60.0, claim_batch=1,
                     max_chunks=2, compile_cache=False).run()
    assert a.n_chunks_committed == 2
    b = FabricWorker(out, worker_id="wb", ttl_s=60.0, claim_batch=2,
                     compile_cache=False).run()
    assert a.n_chunks_committed + b.n_chunks_committed == len(CHUNKS)
    records, n_over, done = sweepfabric.merge_frontier(out)
    assert len(done) == len(CHUNKS) and n_over == 0
    single = SweepRunner(SPEC, backend="pipeline",
                         cache=None).run(frontier_only=True)
    assert single.n_frontier_overflowed == 0
    fh.assert_records_match(records, single.records)
    assert os.path.exists(os.path.join(out, "frontier.jsonl"))
    assert os.path.exists(os.path.join(out, "frontier_state.npz"))
    fh.assert_no_committed_chunk_reevaluated(out)


# ------------------------------------------------------------ kill matrix
@pytest.mark.slow
@pytest.mark.parametrize("point,nth", [
    ("eval", 2),        # mid-chunk: evaluated, nothing written
    ("post_rows", 2),   # torn commit: rows on disk, no done-line
    ("renew", 1),       # mid-heartbeat: renewal tmp written, not renamed
])
def test_kill_matrix_survivor_resumes(tmp_path, point, nth,
                                      serial_records):
    out = str(tmp_path / "fab")
    xla = str(tmp_path / "xla")
    sweepfabric.init_dir(SPEC, out)
    token = str(tmp_path / "kill.token")
    victim = fh.spawn_worker(
        out, ttl=3.0, claim_batch=4, xla_cache=xla,
        env={"REPRO_FABRIC_KILL": f"{point}:{nth}:{token}"})
    fh.wait_procs([victim], 240.0)
    assert victim.returncode == -signal.SIGKILL
    assert os.path.exists(token), "injection point never fired"
    survivor = fh.spawn_worker(out, ttl=60.0, claim_batch=4,
                               xla_cache=xla)
    fh.wait_procs([survivor], 240.0)
    assert survivor.returncode == 0
    records, done = sweepfabric.merge_results(out)
    assert len(done) == len(CHUNKS), "sweep did not resume to completion"
    fh.assert_no_duplicate_point_keys(records)
    fh.assert_no_committed_chunk_reevaluated(out)
    fh.assert_records_match(records, serial_records)


@pytest.mark.slow
def test_stalled_worker_leases_are_reclaimed(tmp_path, serial_records):
    """A worker claims every chunk then stalls past its TTL without
    heartbeating; a healthy worker reclaims the expired leases and does
    all the work.  The stalled worker wakes, discovers it lost its whole
    batch, and exits clean with zero commits."""
    out = str(tmp_path / "fab")
    xla = str(tmp_path / "xla")
    sweepfabric.init_dir(SPEC, out)
    stalled = fh.spawn_worker(out, ttl=2.0, claim_batch=4, xla_cache=xla,
                              env={"REPRO_FABRIC_STALL_S": "20"})
    fh.wait_for(
        lambda: len(glob.glob(os.path.join(out, "leases",
                                           "chunk_*.json"))) == 4,
        60.0, "the stalled worker to claim every lease")
    healthy = fh.spawn_worker(out, ttl=60.0, claim_batch=4,
                              xla_cache=xla)
    fh.wait_procs([stalled, healthy], 240.0)
    assert stalled.returncode == 0 and healthy.returncode == 0
    by_pid = {s["pid"]: s for s in fh.read_stats(out)}
    st, he = by_pid[stalled.pid], by_pid[healthy.pid]
    assert st["n_chunks_committed"] == 0 and st["n_lost_leases"] >= 1
    assert he["n_chunks_committed"] == len(CHUNKS)
    for i in range(len(CHUNKS)):           # healthy worker holds them now
        assert LeaseManager(out, "probe").holder(i) == he["worker"]
    records, done = sweepfabric.merge_results(out)
    assert len(done) == len(CHUNKS)
    fh.assert_no_duplicate_point_keys(records)
    fh.assert_records_match(records, serial_records)


@pytest.mark.slow
def test_sigterm_commits_inflight_then_exits_clean(tmp_path,
                                                   serial_records):
    out = str(tmp_path / "fab")
    xla = str(tmp_path / "xla")
    sweepfabric.init_dir(SPEC, out)
    w = fh.spawn_worker(out, ttl=60.0, claim_batch=1, xla_cache=xla,
                        extra_args=["--eval-delay", "1.5"])
    fh.wait_for(lambda: any(s.get("committed") for s in
                            fh.read_stats(out)),
                240.0, "the first chunk commit")
    w.send_signal(signal.SIGTERM)
    fh.wait_procs([w], 120.0)
    assert w.returncode == 0               # preemption is a CLEAN exit
    s = next(s for s in fh.read_stats(out) if s["pid"] == w.pid)
    assert s["preempted"] is True
    assert 1 <= s["n_chunks_committed"] < len(CHUNKS)
    # unfinished leases were released on the way out: the successor never
    # has to wait out a TTL
    committed_chunks = {c for c, _ in s["committed"]}
    probe = LeaseManager(out, "probe")
    for i in range(len(CHUNKS)):
        if probe.holder(i) == s["worker"]:
            assert i in committed_chunks, (
                f"preempted worker still holds the lease of "
                f"UNFINISHED chunk {i}")
    # preemption cost zero finished work: a fresh worker completes the rest
    w2 = fh.spawn_worker(out, ttl=60.0, claim_batch=4, xla_cache=xla)
    fh.wait_procs([w2], 240.0)
    records, done = sweepfabric.merge_results(out)
    assert len(done) == len(CHUNKS)
    fh.assert_no_committed_chunk_reevaluated(out)
    fh.assert_records_match(records, serial_records)


@pytest.mark.slow
def test_frontier_kill_and_cross_worker_merge(tmp_path):
    """Frontier mode under fire: the victim dies before its first state
    checkpoint lands, two concurrent survivors split the reclaimed work,
    and the cross-worker merge equals the single-host frontier."""
    out = str(tmp_path / "fab")
    xla = str(tmp_path / "xla")
    sweepfabric.init_dir(SPEC, out, frontier_only=True)
    token = str(tmp_path / "kill.token")
    victim = fh.spawn_worker(
        out, ttl=3.0, claim_batch=2, xla_cache=xla,
        env={"REPRO_FABRIC_KILL": f"post_rows:1:{token}"})
    fh.wait_procs([victim], 240.0)
    assert victim.returncode == -signal.SIGKILL
    survivors = [fh.spawn_worker(out, ttl=60.0, claim_batch=1,
                                 xla_cache=xla) for _ in range(2)]
    fh.wait_procs(survivors, 300.0)
    assert all(pr.returncode == 0 for pr in survivors)
    records, n_over, done = sweepfabric.merge_frontier(out)
    assert len(done) == len(CHUNKS) and n_over == 0
    fh.assert_no_committed_chunk_reevaluated(out)
    single = SweepRunner(SPEC, backend="pipeline",
                         cache=None).run(frontier_only=True)
    fh.assert_records_match(records, single.records)


# ------------------------------------------------------------ parity (grid)
@pytest.mark.slow
def test_two_worker_fabric_matches_serial_on_traffic_grid(tmp_path):
    """2 concurrent workers on the serving-traffic grid — percentile SLO
    walls, capacity-infeasible points and all — against the serial
    backend."""
    serial = SweepRunner(TRAFFIC_SPEC, backend="serial",
                         cache=None).run()
    regimes = {(r["feasible"], r["slo_ok"]) for r in serial.records}
    assert (False, False) in regimes, "grid lost its infeasible points"
    assert (True, False) in regimes, "grid lost its SLO-wall failures"
    out = str(tmp_path / "fab")
    xla = str(tmp_path / "xla")
    sweepfabric.init_dir(TRAFFIC_SPEC, out)
    workers = [fh.spawn_worker(out, ttl=60.0, claim_batch=1,
                               xla_cache=xla) for _ in range(2)]
    fh.wait_procs(workers, 300.0)
    assert all(pr.returncode == 0 for pr in workers)
    records, done = sweepfabric.merge_results(out)
    n_chunks = len(sweeprunner.make_chunks(
        sweeprunner.enumerate_labels(TRAFFIC_SPEC),
        TRAFFIC_SPEC.chunk_size))
    assert len(done) == n_chunks
    fh.assert_no_duplicate_point_keys(records)
    fh.assert_no_committed_chunk_reevaluated(out)
    fh.assert_records_match(records, serial.records)


@pytest.mark.slow
def test_coordinator_end_to_end(tmp_path, serial_records):
    """The user-facing path: coordinator spawns 2 local workers, waits,
    merges — `FabricStats` mirrors what the CLI prints."""
    out = str(tmp_path / "fab")
    coord = FabricCoordinator(
        SPEC, out, workers=2, ttl_s=60.0, poll_s=0.3, claim_batch=1,
        worker_env={"PYTHONPATH": os.pathsep.join(
            p for p in (os.path.join(fh.REPO, "src"),
                        os.environ.get("PYTHONPATH", "")) if p),
            "JAX_COMPILATION_CACHE_DIR": str(tmp_path / "xla")})
    stats = coord.run()
    assert stats.complete and stats.mode == "full"
    assert stats.n_chunks_committed == len(CHUNKS)
    assert stats.n_points_total == len(serial_records)
    fh.assert_no_duplicate_point_keys(stats.records)
    fh.assert_records_match(stats.records, serial_records)
    assert os.path.exists(os.path.join(out, "results.jsonl"))
